"""End-to-end behaviour tests for the paper's system (PECB + baselines)."""

import numpy as np
import pytest

from repro.core.temporal_graph import TemporalGraph, gen_temporal_graph
from repro.core.kcore import tccs_oracle, k_max, temporal_kcore_edges
from repro.core.core_time import edge_core_times, edge_core_time_naive
from repro.core.ctmsf import kruskal_msf, boruvka_msf_np
from repro.core.ecb_forest import active_versions, build_forest_at, IncrementalBuilder
from repro.core.pecb_index import build_pecb_index
from repro.core.ctmsf_index import CTMSFIndex
from repro.core.ef_index import EFIndex
from repro.core.batch_query import batch_query_np


def paper_graph() -> TemporalGraph:
    """Figure 1 of the paper (v1..v8 -> ids 0..7)."""
    return TemporalGraph.from_edges(8, [
        (0, 1, 4), (0, 2, 4), (1, 2, 4),
        (2, 7, 2), (3, 4, 3),
        (5, 6, 4), (5, 7, 5), (6, 7, 5),
        (1, 3, 6), (1, 4, 6), (4, 5, 7),
    ])


class TestPaperExamples:
    def test_example_2_3_two_components(self):
        g = paper_graph()
        ids = temporal_kcore_edges(g, 2, 4, 5)
        verts = set(g.src[ids]) | set(g.dst[ids])
        assert verts == {0, 1, 2, 5, 6, 7}          # v1,v2,v3 + v6,v7,v8
        assert tccs_oracle(g, 2, 1, 4, 5) == {0, 1, 2}
        assert tccs_oracle(g, 2, 6, 4, 5) == {5, 6, 7}

    def test_example_4_4_core_times(self):
        g = paper_graph()
        tab = edge_core_times(g, 2)
        # CT((v1,v2,4))_{ts=4} = 4 ; CT((v6,v7,4))_{ts=4} = 5
        e1 = int(np.nonzero((g.src == 0) & (g.dst == 1) & (g.t == 4))[0][0])
        e2 = int(np.nonzero((g.src == 5) & (g.dst == 6) & (g.t == 4))[0][0])
        assert tab.ct_at(e1, 4) == 4
        assert tab.ct_at(e2, 4) == 5

    def test_table_1_incremental_core_times(self):
        g = paper_graph()
        tab = edge_core_times(g, 2)
        INF = tab.INF
        # (v2,v5,6): <1,6>, <4,7>, <5,inf>
        e = int(np.nonzero((g.src == 1) & (g.dst == 4) & (g.t == 6))[0][0])
        for ts, want in [(1, 6), (2, 6), (3, 6), (4, 7), (5, INF), (6, INF)]:
            assert tab.ct_at(e, ts) == want, (ts, tab.ct_at(e, ts), want)
        # (v3,v8,2): <1,5>, <3,inf>
        e = int(np.nonzero((g.src == 2) & (g.dst == 7))[0][0])
        for ts, want in [(1, 5), (2, 5), (3, INF)]:
            assert tab.ct_at(e, ts) == want

    def test_example_4_14_query(self):
        g = paper_graph()
        idx = build_pecb_index(g, 2)
        assert idx._component_vertices(1, 3, 5) == {0, 1, 2}  # v2, [3,5] -> {v1,v2,v3}


class TestCoreTimes:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    @pytest.mark.parametrize("k", [2, 3])
    def test_vs_naive(self, seed, k):
        g = gen_temporal_graph(n=25, m=120, t_max=12, seed=seed)
        tab = edge_core_times(g, k)
        for ts in range(1, g.t_max + 1):
            naive = edge_core_time_naive(g, k, ts)
            for e in range(g.m):
                assert tab.ct_at(e, ts) == naive[e], (ts, e)

    def test_monotone_in_ts(self):
        g = gen_temporal_graph(n=40, m=300, t_max=20, seed=3)
        tab = edge_core_times(g, 2)
        for e in range(g.m):
            prev = -1
            for ts in range(1, g.t_max + 1):
                ct = tab.ct_at(e, ts)
                assert ct >= prev
                prev = ct


class TestMSF:
    @pytest.mark.parametrize("seed", [0, 1])
    def test_boruvka_equals_kruskal(self, seed):
        g = gen_temporal_graph(n=40, m=300, t_max=25, seed=seed)
        tab = edge_core_times(g, 2)
        for ts in range(1, g.t_max + 1, 4):
            e_ids, cts = active_versions(tab, ts)
            if e_ids.size == 0:
                continue
            u = g.src[e_ids].astype(np.int64)
            v = g.dst[e_ids].astype(np.int64)
            km = kruskal_msf(u, v, cts.astype(np.int64), g.n)
            bm = boruvka_msf_np(u.astype(np.int32), v.astype(np.int32),
                                cts.astype(np.int32), g.n)
            assert np.array_equal(km, bm)


class TestECBForest:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_binary_bound_and_rank_order(self, seed):
        g = gen_temporal_graph(n=30, m=200, t_max=15, seed=seed)
        tab = edge_core_times(g, 2)
        for ts in range(1, g.t_max + 1, 3):
            f = build_forest_at(g, tab, ts)
            nn = f.ct.shape[0]
            child_count = np.zeros(nn, int)
            for i in range(nn):
                if not f.in_forest[i]:
                    continue
                for c in (f.left[i], f.right[i]):
                    if c >= 0:
                        child_count[i] += 1
                        # child ranks strictly below the parent
                        assert (f.ct[c], f.edge_id[c]) < (f.ct[i], f.edge_id[i])
            assert (child_count <= 2).all()

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_incremental_equals_from_scratch(self, seed):
        """The builder's live node set at each ts equals the Def-4.9
        from-scratch construction's forest node set."""
        g = gen_temporal_graph(n=25, m=150, t_max=12, seed=seed)
        tab = edge_core_times(g, 2)
        idx = build_pecb_index(g, 2, tab)
        for ts in range(1, g.t_max + 1):
            f = build_forest_at(g, tab, ts)
            scratch = {(int(f.edge_id[i]), int(f.ct[i]))
                       for i in range(f.ct.shape[0]) if f.in_forest[i]}
            inc = {(int(idx.node_edge[x]), int(idx.node_ct[x]))
                   for x in range(idx.num_nodes)
                   if idx.node_live_from[x] <= ts <= idx.node_live_to[x]}
            assert scratch == inc, ts


class TestQueries:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    @pytest.mark.parametrize("k", [2, 3])
    def test_all_indexes_match_oracle(self, seed, k):
        rng = np.random.default_rng(seed)
        g = gen_temporal_graph(n=30, m=220, t_max=18, seed=seed + 40)
        tab = edge_core_times(g, k)
        pecb = build_pecb_index(g, k, tab)
        ef = EFIndex(g, k, tab)
        cm = CTMSFIndex(g, k, tab)
        for _ in range(120):
            u = int(rng.integers(0, g.n))
            ts = int(rng.integers(1, g.t_max + 1))
            te = int(rng.integers(ts, g.t_max + 1))
            want = tccs_oracle(g, k, u, ts, te)
            assert pecb._component_vertices(u, ts, te) == want
            assert ef._component_vertices(u, ts, te) == want
            assert cm._component_vertices(u, ts, te) == want

    def test_batched_engine_matches_host(self):
        rng = np.random.default_rng(11)
        g = gen_temporal_graph(n=35, m=260, t_max=16, seed=77)
        idx = build_pecb_index(g, 2)
        qs = [(int(rng.integers(0, g.n)), *sorted(int(x) for x in rng.integers(1, g.t_max + 1, 2)))
              for _ in range(96)]
        got = batch_query_np(idx, qs)
        for (u, ts, te), res in zip(qs, got):
            assert res == idx._component_vertices(u, ts, te)

    def test_kmax_positive(self):
        g = gen_temporal_graph(n=60, m=600, t_max=30, seed=5)
        assert k_max(g) >= 2


class TestConstructionEngines:
    """Seeded (non-hypothesis) engine-equivalence coverage, so the batched
    plane is exercised even where hypothesis is not installed."""

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_three_engines_bit_identical(self, seed):
        g = gen_temporal_graph(n=30, m=180, t_max=14, seed=seed)
        for k in (2, 3):
            legacy = edge_core_times(g, k, engine="legacy")
            host = edge_core_times(g, k, engine="host")
            jaxed = edge_core_times(g, k, engine="jax")
            for f in ("edge_id", "ts_from", "ts_to", "ct", "vertex_ct"):
                assert np.array_equal(getattr(legacy, f), getattr(host, f)), f
                assert np.array_equal(getattr(legacy, f), getattr(jaxed, f)), f

    def test_jax_pallas_engine_matches_host(self):
        g = gen_temporal_graph(n=14, m=60, t_max=6, seed=7)
        host = edge_core_times(g, 2, engine="host")
        pallas = edge_core_times(g, 2, engine="jax_pallas")
        for f in ("edge_id", "ts_from", "ts_to", "ct", "vertex_ct"):
            assert np.array_equal(getattr(host, f), getattr(pallas, f)), f

    def test_self_loops_do_not_corrupt_builder(self):
        """Directly-constructed graphs may carry self-loops (from_edges
        drops them); the builder must treat them as degenerate on both
        prefilter paths instead of corrupting the forest."""
        import dataclasses
        from repro.core.ecb_forest import IncrementalBuilder
        from repro.core.pecb_index import pack_index

        base = gen_temporal_graph(n=12, m=60, t_max=6, seed=3)
        g = TemporalGraph(
            base.n,
            np.concatenate([base.src, np.int32([1, 4])]),
            np.concatenate([base.dst, np.int32([1, 4])]),
            np.concatenate([base.t, np.int32([2, 5])]),
        )
        tab = edge_core_times(g, 2)
        a = pack_index(g, 2, IncrementalBuilder(g, tab, prefilter=True).run())
        b = pack_index(g, 2, IncrementalBuilder(g, tab, prefilter=False).run())
        for f in dataclasses.fields(a):
            va, vb = getattr(a, f.name), getattr(b, f.name)
            same = (np.array_equal(va, vb) if isinstance(va, np.ndarray)
                    else va == vb)
            assert same, f.name

    def test_unknown_engine_raises(self):
        g = gen_temporal_graph(n=10, m=30, t_max=5, seed=0)
        with pytest.raises(ValueError, match="engine"):
            edge_core_times(g, 2, engine="warp")

    def test_nbytes_counts_actual_version_bytes(self):
        g = gen_temporal_graph(n=25, m=120, t_max=10, seed=1)
        tab = edge_core_times(g, 2)
        assert tab.nbytes() == (tab.edge_id.nbytes + tab.ts_from.nbytes
                                + tab.ts_to.nbytes + tab.ct.nbytes)
        assert tab.nbytes() == 16 * tab.num_versions   # 4 int32 words

    def test_builder_prefilter_identical_index(self):
        import dataclasses
        from repro.core.ecb_forest import IncrementalBuilder
        from repro.core.pecb_index import pack_index

        g = gen_temporal_graph(n=30, m=200, t_max=12, seed=5)
        tab = edge_core_times(g, 2)
        a = pack_index(g, 2, IncrementalBuilder(g, tab, prefilter=True).run())
        b = pack_index(g, 2, IncrementalBuilder(g, tab, prefilter=False).run())
        for f in dataclasses.fields(a):
            va, vb = getattr(a, f.name), getattr(b, f.name)
            same = (np.array_equal(va, vb) if isinstance(va, np.ndarray)
                    else va == vb)
            assert same, f.name

    def test_query_invariant_error_not_assert(self):
        """The reachable-state guard must survive `python -O`: it raises an
        explicit error instead of asserting."""
        from repro.core.ecb_forest import ForestInvariantError
        from repro.core.pecb_index import build_pecb_index

        g = gen_temporal_graph(n=20, m=120, t_max=8, seed=2)
        idx = build_pecb_index(g, 2)
        if idx.num_nodes == 0:
            pytest.skip("degenerate graph")
        # corrupt the index: point an entry's left child at a node that has
        # no entry covering ts (simulates the exact state a bare assert hid)
        idx.ent_left[:] = idx.num_nodes - 1
        idx.row_ptr[-1] = idx.row_ptr[-2]       # last node: no entries at all
        u = int(idx.node_u[0])
        with pytest.raises(ForestInvariantError):
            for ts in range(1, g.t_max + 1):
                idx._component_vertices(u, ts, g.t_max)

    def test_t_max_cached(self):
        g = gen_temporal_graph(n=10, m=40, t_max=6, seed=0)
        assert g.t_max == int(g.t.max())
        assert g._t_max == g.t_max              # computed once in __post_init__
