"""Hypothesis property tests on the system's invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.core.temporal_graph import TemporalGraph
from repro.core.kcore import tccs_oracle, distinct_kcore_edge_mask
from repro.core.core_time import edge_core_times
from repro.core.ecb_forest import active_versions, build_forest_at
from repro.core.pecb_index import build_pecb_index

SETTINGS = dict(max_examples=25, deadline=None,
                suppress_health_check=[HealthCheck.too_slow])


@st.composite
def temporal_graphs(draw, max_n=14, max_m=60, max_t=8):
    n = draw(st.integers(3, max_n))
    m = draw(st.integers(1, max_m))
    t_max = draw(st.integers(1, max_t))
    edges = []
    for _ in range(m):
        u = draw(st.integers(0, n - 1))
        v = draw(st.integers(0, n - 1))
        t = draw(st.integers(1, t_max))
        if u != v:
            edges.append((u, v, t))
    if not edges:
        edges = [(0, 1, 1)]
    return TemporalGraph.from_edges(n, edges)


@given(g=temporal_graphs(), k=st.integers(2, 3), data=st.data())
@settings(**SETTINGS)
def test_pecb_equals_oracle(g, k, data):
    idx = build_pecb_index(g, k)
    t_max = max(g.t_max, 1)
    for _ in range(10):
        u = data.draw(st.integers(0, g.n - 1))
        ts = data.draw(st.integers(1, t_max))
        te = data.draw(st.integers(ts, t_max))
        assert idx._component_vertices(u, ts, te) == tccs_oracle(g, k, u, ts, te)


@given(g=temporal_graphs(), k=st.integers(2, 3))
@settings(**SETTINGS)
def test_core_time_characterizes_membership(g, k):
    """CT(e)_ts <= te  <=>  e in the temporal k-core of [ts, te]."""
    tab = edge_core_times(g, k)
    t_max = max(g.t_max, 1)
    for ts in range(1, t_max + 1):
        for te in range(ts, t_max + 1):
            s, d, ids = g.project(ts, te)
            alive = distinct_kcore_edge_mask(s, d, g.n, k)
            member = {int(e) for e, a in zip(ids, alive) if a}
            by_ct = {e for e in range(g.m) if tab.ct_at(e, ts) <= te}
            assert member == by_ct, (ts, te)


@given(g=temporal_graphs(), k=st.integers(2, 3))
@settings(**SETTINGS)
def test_ecb_forest_ec_equivalence(g, k):
    """Def 4.2: for every (ts, te), connected components of the forest
    restricted to CT <= te equal the k-core components (Lemma 4.11)."""
    import networkx as nx

    tab = edge_core_times(g, k)
    t_max = max(g.t_max, 1)
    for ts in range(1, t_max + 1):
        f = build_forest_at(g, tab, ts)
        for te in range(ts, t_max + 1):
            # components from the forest
            fg = nx.Graph()
            for i in range(f.ct.shape[0]):
                if f.in_forest[i] and f.ct[i] <= te:
                    fg.add_edge(int(f.u[i]), int(f.v[i]))
            forest_comps = {frozenset(c) for c in nx.connected_components(fg)}
            # components from the raw graph
            s, d, ids = g.project(ts, te)
            alive = distinct_kcore_edge_mask(s, d, g.n, k)
            gg = nx.Graph()
            gg.add_edges_from(zip(s[alive].tolist(), d[alive].tolist()))
            graph_comps = {frozenset(c) for c in nx.connected_components(gg)}
            assert forest_comps == graph_comps, (ts, te)


@given(g=temporal_graphs())
@settings(**SETTINGS)
def test_version_ranges_disjoint_and_sorted(g):
    """Each edge's version records tile [1, t_max] disjointly with
    monotone core times (Table 1 invariant)."""
    tab = edge_core_times(g, 2)
    by_edge = {}
    for i in range(tab.num_versions):
        by_edge.setdefault(int(tab.edge_id[i]), []).append(
            (int(tab.ts_from[i]), int(tab.ts_to[i]), int(tab.ct[i])))
    for e, vers in by_edge.items():
        vers.sort()
        for (a1, b1, c1), (a2, b2, c2) in zip(vers, vers[1:]):
            assert b1 < a2                     # disjoint, ordered
            assert c1 <= c2                    # CT non-decreasing in ts
        for a, b, c in vers:
            assert a <= b
            assert c <= g.t_max                # finite versions only


@given(data=st.data())
@settings(max_examples=20, deadline=None)
def test_kernel_segment_sum_property(data):
    import jax.numpy as jnp
    from repro.kernels import ops, ref

    m = data.draw(st.integers(1, 200))
    d = data.draw(st.sampled_from([1, 3, 16]))
    s = data.draw(st.integers(1, 40))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    vals = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, s, m), jnp.int32)
    got = np.asarray(ops.segment_sum(vals, ids, s))
    want = np.asarray(ref.segment_sum_sorted(vals, ids, s))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(g=temporal_graphs(max_n=12, max_m=45, max_t=7), k=st.integers(2, 3))
@settings(**SETTINGS)
def test_construction_engines_bit_identical(g, k):
    """Tentpole invariant: the batched host and JAX sweep engines produce a
    CoreTimeTable identical (all five arrays) to the seed's numpy fixpoint
    loop, and all of them match the brute-force oracle."""
    from repro.core.core_time import edge_core_time_naive

    legacy = edge_core_times(g, k, engine="legacy")
    host = edge_core_times(g, k, engine="host")
    jaxed = edge_core_times(g, k, engine="jax")
    for f in ("edge_id", "ts_from", "ts_to", "ct", "vertex_ct"):
        assert np.array_equal(getattr(legacy, f), getattr(host, f)), f
        assert np.array_equal(getattr(legacy, f), getattr(jaxed, f)), f
    t_max = max(g.t_max, 1)
    for ts in range(1, t_max + 1):
        naive = edge_core_time_naive(g, k, ts)
        for e in range(g.m):
            assert host.ct_at(e, ts) == naive[e], (ts, e)


@given(g=temporal_graphs(), k=st.integers(2, 3))
@settings(**SETTINGS)
def test_builder_prefilter_is_pure_acceleration(g, k):
    """The MSF candidate prefilter must not change the packed index."""
    import dataclasses
    from repro.core.ecb_forest import IncrementalBuilder
    from repro.core.pecb_index import pack_index

    tab = edge_core_times(g, k)
    with_f = pack_index(g, k, IncrementalBuilder(g, tab, prefilter=True).run())
    without = pack_index(g, k, IncrementalBuilder(g, tab, prefilter=False).run())
    for f in dataclasses.fields(with_f):
        va, vb = getattr(with_f, f.name), getattr(without, f.name)
        same = np.array_equal(va, vb) if isinstance(va, np.ndarray) else va == vb
        assert same, f.name


@given(g=temporal_graphs(), k=st.integers(2, 3), data=st.data())
@settings(**SETTINGS)
def test_canonical_windows_answer_identically_all_backends(g, k, data):
    """Query API v2: a raw window and its canonical form (clamped to
    [1, t_max], empty windows folded) answer identically on all three
    backends, and the three backends agree."""
    from repro.core.ctmsf_index import CTMSFIndex
    from repro.core.ef_index import EFIndex
    from repro.core.query_api import TCCSQuery

    tab = edge_core_times(g, k)
    backends = [build_pecb_index(g, k, tab), EFIndex(g, k, tab),
                CTMSFIndex(g, k, tab)]
    t_max = max(g.t_max, 1)
    for _ in range(5):
        u = data.draw(st.integers(0, g.n - 1))
        ts = data.draw(st.integers(1, t_max))
        te = data.draw(st.integers(ts, 2 * t_max + 3))
        raw = TCCSQuery(u, ts, te, k)
        canon = raw.canonical(g.t_max)
        answers = []
        for b in backends:
            assert b.answer(raw).vertices == b.answer(canon).vertices, \
                (b.backend_name, u, ts, te)
            answers.append(b.answer(canon).vertices)
        assert answers[0] == answers[1] == answers[2], (u, ts, te)


@given(g=temporal_graphs(), k=st.integers(2, 3), data=st.data())
@settings(**SETTINGS)
def test_edges_mode_projects_and_matches_oracle(g, k, data):
    """Query API v2: EDGES-mode results vertex-project exactly to the
    VERTICES-mode result and their edge ids equal the brute-force oracle's
    induced member edges, on all three backends."""
    from repro.core.ctmsf_index import CTMSFIndex
    from repro.core.ef_index import EFIndex
    from repro.core.kcore import tccs_oracle_edges
    from repro.core.query_api import ResultMode, TCCSQuery

    tab = edge_core_times(g, k)
    backends = [build_pecb_index(g, k, tab), EFIndex(g, k, tab),
                CTMSFIndex(g, k, tab)]
    t_max = max(g.t_max, 1)
    for _ in range(5):
        u = data.draw(st.integers(0, g.n - 1))
        ts = data.draw(st.integers(1, t_max))
        te = data.draw(st.integers(ts, t_max))
        want_e = frozenset(tccs_oracle_edges(g, k, u, ts, te))
        for b in backends:
            r = b.answer(TCCSQuery(u, ts, te, k, ResultMode.EDGES))
            rv = b.answer(TCCSQuery(u, ts, te, k))
            assert r.edges.edge_ids() == want_e, (b.backend_name, u, ts, te)
            assert r.edges.vertex_projection() == rv.vertices, \
                (b.backend_name, u, ts, te)


@given(g=temporal_graphs())
@settings(**SETTINGS)
def test_core_time_table_nbytes_is_exact(g):
    """Index-size metric regression: nbytes must equal the true byte size
    of the stored version arrays (the seed hardcoded 16 B/version while
    storing int64 — overstating the paper's space numbers 2x)."""
    tab = edge_core_times(g, 2)
    true_bytes = (tab.edge_id.nbytes + tab.ts_from.nbytes
                  + tab.ts_to.nbytes + tab.ct.nbytes)
    assert tab.nbytes() == true_bytes
    for f in ("edge_id", "ts_from", "ts_to", "ct", "vertex_ct"):
        assert getattr(tab, f).dtype == np.int32, f


@given(g=temporal_graphs(max_t=10), k=st.integers(2, 3),
       cut=st.floats(0.15, 0.9), data=st.data())
@settings(**SETTINGS)
def test_streaming_refresh_equals_cold_rebuild(g, k, cut, data):
    """Streaming epoch plane: ``extend()`` + incremental refresh produces
    core-time tables, a PECB index and answers identical to a cold rebuild
    on the merged edge list, on all three backends (DESIGN.md §9)."""
    from repro.core.core_time import extend_core_times
    from repro.core.ctmsf_index import CTMSFIndex
    from repro.core.ef_index import EFIndex
    from repro.core.query_api import TCCSQuery
    from repro.core.streaming import extend_pecb_index

    t_old = max(1, int(g.t_max * cut))
    g0, suffix = g.split_at(t_old)
    if g0.m == 0 or suffix.shape[0] == 0:
        return
    tab0 = edge_core_times(g0, k)
    idx0 = build_pecb_index(g0, k, tab0)
    g1 = g0.extend(map(tuple, suffix.tolist()))
    tab1 = extend_core_times(g1, k, tab0)
    tab_cold = edge_core_times(g, k)
    for f in ("edge_id", "ts_from", "ts_to", "ct", "vertex_ct"):
        assert np.array_equal(getattr(tab1, f), getattr(tab_cold, f)), f

    idx1 = extend_pecb_index(g1, k, tab1, idx0)
    idx_cold = build_pecb_index(g, k, tab_cold)
    for f in ("node_u", "node_v", "node_ct", "node_edge", "node_live_from",
              "node_live_to", "row_ptr", "ent_ts", "ent_left", "ent_right",
              "ent_parent", "vrow_ptr", "vent_ts", "vent_node"):
        assert np.array_equal(getattr(idx1, f), getattr(idx_cold, f)), f
    assert idx1.versions == idx_cold.versions

    # EF/CTMSF have no incremental builder, but fed the incrementally
    # extended table they must answer exactly like their cold builds
    backends = [(idx1, idx_cold),
                (EFIndex(g1, k, tab1), EFIndex(g, k, tab_cold)),
                (CTMSFIndex(g1, k, tab1), CTMSFIndex(g, k, tab_cold))]
    t_max = max(g.t_max, 1)
    for _ in range(6):
        u = data.draw(st.integers(0, g.n - 1))
        ts = data.draw(st.integers(1, t_max))
        te = data.draw(st.integers(ts, t_max))
        q = TCCSQuery(u, ts, te, k)
        want = tccs_oracle(g, k, u, ts, te)
        for inc, cold in backends:
            assert inc.answer(q).vertices == frozenset(want)
            assert cold.answer(q).vertices == frozenset(want)


@given(g=temporal_graphs(max_t=10), k=st.integers(2, 3),
       cut_at=st.floats(0.1, 0.8), cut_frac=st.floats(0.1, 0.95),
       data=st.data())
@settings(**SETTINGS)
def test_retention_shrink_equals_cold_rebuild(g, k, cut_at, cut_frac, data):
    """Retention plane: ``extend()`` ∘ ``expire_before()`` — grow the
    epoch with a suffix, then expire a prefix — produces a core-time
    table, a PECB index and answers field-for-field identical to a cold
    build on the equivalent (truncated, shifted) edge list, on all three
    backends (DESIGN.md §10)."""
    from repro.core.core_time import extend_core_times, shrink_core_times
    from repro.core.ctmsf_index import CTMSFIndex
    from repro.core.ef_index import EFIndex
    from repro.core.query_api import TCCSQuery
    from repro.core.streaming import extend_pecb_index, shrink_pecb_index

    t_old = max(1, int(g.t_max * cut_at))
    g0, suffix = g.split_at(t_old)
    if g0.m == 0:
        return
    tab = edge_core_times(g0, k)
    idx = build_pecb_index(g0, k, tab)
    g1 = g0
    if suffix.shape[0]:
        g1 = g0.extend(map(tuple, suffix.tolist()))
        tab = extend_core_times(g1, k, tab)
        idx = extend_pecb_index(g1, k, tab, idx)
    t_cut = max(2, int(g1.t_max * cut_frac))
    g2 = g1.expire_before(t_cut)
    tab2 = shrink_core_times(g2, k, tab)
    idx2 = shrink_pecb_index(g2, k, tab2, idx)

    tab_cold = edge_core_times(g2, k)
    for f in ("edge_id", "ts_from", "ts_to", "ct", "vertex_ct"):
        assert np.array_equal(getattr(tab2, f), getattr(tab_cold, f)), f
    idx_cold = build_pecb_index(g2, k, tab_cold)
    for f in ("node_u", "node_v", "node_ct", "node_edge", "node_live_from",
              "node_live_to", "row_ptr", "ent_ts", "ent_left", "ent_right",
              "ent_parent", "vrow_ptr", "vent_ts", "vent_node"):
        assert np.array_equal(getattr(idx2, f), getattr(idx_cold, f)), f
    assert idx2.versions == idx_cold.versions

    # EF/CTMSF fed the shrunk table must answer exactly like their cold
    # builds — and like the oracle on the truncated graph
    backends = [(idx2, idx_cold),
                (EFIndex(g2, k, tab2), EFIndex(g2, k, tab_cold)),
                (CTMSFIndex(g2, k, tab2), CTMSFIndex(g2, k, tab_cold))]
    t_max = max(g2.t_max, 1)
    for _ in range(6):
        u = data.draw(st.integers(0, g2.n - 1))
        ts = data.draw(st.integers(1, t_max))
        te = data.draw(st.integers(ts, t_max))
        q = TCCSQuery(u, ts, te, k)
        want = frozenset(tccs_oracle(g2, k, u, ts, te)) if g2.m else frozenset()
        for shr, cold in backends:
            assert shr.answer(q).vertices == want
            assert cold.answer(q).vertices == want
