"""Persistent index store (DESIGN.md §13): blob I/O, the segment/manifest
commit format with its delta classes, IndexStore roundtrips, and the
registry disk tier (write-through, promote, demote, warm restart)."""

import json
import os

import numpy as np
import pytest

from repro.core.temporal_graph import gen_temporal_graph
from repro.serving import EngineConfig, ServingEngine
from repro.serving.metrics import EngineMetrics
from repro.serving.registry import IndexRegistry
from repro.core.query_api import TCCSQuery
from repro.store import IndexStore, StoreCorruption
from repro.store import blobio
from repro.store import segment as seg
from repro.store.index_store import key_dirname

from test_streaming import assert_pecb_identical, split_epoch

TAB_FIELDS = ("kptr", "edge_id", "ts_from", "ts_to", "ct",
              "vptr", "v_ts_from", "v_ts_to", "v_ct")


def small_graph(seed=3):
    return gen_temporal_graph(n=40, m=320, t_max=20, seed=seed)


def build_handle(g, k=2, name="g"):
    """One cold-built IndexHandle via a throwaway registry (no store)."""
    reg = IndexRegistry()
    reg.register_graph(name, g)
    try:
        return reg.get(name)
    finally:
        reg.close()


def assert_handles_identical(a, b):
    assert_pecb_identical(a.pecb, b.pecb)
    assert a.epoch == b.epoch
    for f in TAB_FIELDS:
        assert np.array_equal(getattr(a.tab, f), getattr(b.tab, f)), f
    for f in ("src", "dst", "t"):
        assert np.array_equal(getattr(a.graph, f), getattr(b.graph, f)), f


# ----------------------------------------------------------------------
# blobio (the checkpoint manager shares these helpers — satellite 1)
# ----------------------------------------------------------------------

class TestBlobio:
    def test_atomic_write_roundtrip_no_tmp_left(self, tmp_path):
        p = str(tmp_path / "x.bin")
        blobio.atomic_write(p, b"hello-store")
        with open(p, "rb") as f:
            assert f.read() == b"hello-store"
        assert [n for n in os.listdir(tmp_path) if "tmp" in n] == []

    def test_array_blob_roundtrip(self):
        for a in (np.arange(17, dtype=np.int32),
                  np.linspace(0, 1, 9).reshape(3, 3),
                  np.zeros(0, dtype=np.int64)):
            b = blobio.blob_array(blobio.array_blob(a))
            assert b.dtype == a.dtype and b.shape == a.shape
            assert np.array_equal(b, a)

    def test_blob_crc_failure_detected(self):
        blob = blobio.array_blob(np.arange(8, dtype=np.int32))
        raw = bytearray(blob["raw"])
        raw[3] ^= 0xFF
        blob["raw"] = bytes(raw)
        with pytest.raises(IOError, match="crc32"):
            blobio.blob_array(blob)


# ----------------------------------------------------------------------
# segment/manifest format
# ----------------------------------------------------------------------

class TestSegmentFormat:
    def _commit(self, d, epoch, arrays, prev=None, **kw):
        return seg.write_commit(str(d), {"epoch": epoch}, arrays, prev, **kw)

    def test_full_commit_roundtrip(self, tmp_path):
        arrays = {"a": np.arange(100, dtype=np.int32),
                  "b": np.linspace(0, 1, 33),
                  "c": np.arange(12, dtype=np.int64).reshape(3, 4)}
        res = self._commit(tmp_path, 0, arrays)
        assert res["mode"] == "full" and res["epoch"] == 0
        man, loaded, recovered = seg.open_latest(str(tmp_path))
        assert recovered == 0 and man["epoch"] == 0
        for name, a in arrays.items():
            got = loaded[name]
            assert got.dtype == a.dtype and got.shape == a.shape
            assert np.array_equal(got, a)

    def test_parts_are_aligned(self, tmp_path):
        arrays = {"a": np.arange(7, dtype=np.int32),
                  "b": np.arange(5, dtype=np.int64)}
        self._commit(tmp_path, 0, arrays)
        man, _, _ = seg.open_latest(str(tmp_path))
        for ent in man["arrays"].values():
            for p in ent["parts"]:
                assert p["offset"] % seg.ALIGN == 0

    def test_delta_reuse_suffix_prefix(self, tmp_path):
        a0 = {"keep": np.arange(200, dtype=np.int32),
              "grow": np.arange(300, dtype=np.int32),
              "front": np.arange(100, 300, dtype=np.int32)}
        self._commit(tmp_path, 0, a0)
        man0, arr0, _ = seg.open_latest(str(tmp_path))
        a1 = {"keep": a0["keep"],
              "grow": np.concatenate([a0["grow"],
                                      np.arange(300, 340, dtype=np.int32)]),
              "front": np.arange(100, 300, dtype=np.int32)}
        a1["front"] = np.concatenate([np.arange(50, 100, dtype=np.int32),
                                      a0["front"]])
        res = self._commit(tmp_path, 1, a1, prev=(man0, arr0))
        assert res["mode"] == "delta"
        man1, arr1, _ = seg.open_latest(str(tmp_path))
        assert man1["epoch"] == 1
        # reuse: single part still living in the epoch-0 segment
        keep_parts = man1["arrays"]["keep"]["parts"]
        assert len(keep_parts) == 1
        assert keep_parts[0]["segment"] == man0["arrays"]["keep"]["parts"][0]["segment"]
        # suffix: old part first, tail appended in the new segment
        grow_parts = man1["arrays"]["grow"]["parts"]
        assert len(grow_parts) == 2
        assert grow_parts[1]["segment"] != grow_parts[0]["segment"]
        # prefix: new head first, old bytes second
        front_parts = man1["arrays"]["front"]["parts"]
        assert len(front_parts) == 2
        assert front_parts[0]["segment"] != front_parts[1]["segment"]
        for name, a in a1.items():
            assert np.array_equal(arr1[name], a), name
        # the delta wrote strictly less than a full rewrite would
        full = sum(a.nbytes for a in a1.values())
        assert res["bytes_written"] < full

    def test_full_change_falls_back_to_full_commit(self, tmp_path):
        a0 = {"x": np.arange(64, dtype=np.int32)}
        self._commit(tmp_path, 0, a0)
        man0, arr0, _ = seg.open_latest(str(tmp_path))
        a1 = {"x": a0["x"][::-1].copy()}   # same size, reordered: no delta
        res = self._commit(tmp_path, 1, a1, prev=(man0, arr0))
        assert res["mode"] == "full"
        _, arr1, _ = seg.open_latest(str(tmp_path))
        assert np.array_equal(arr1["x"], a1["x"])

    def test_chain_bound_forces_compaction(self, tmp_path):
        arrays = {"grow": np.arange(512, dtype=np.int32),
                  "pad": np.arange(4096, dtype=np.int32)}
        self._commit(tmp_path, 0, arrays)
        modes = []
        for e in range(1, 6):
            prev = seg.open_latest(str(tmp_path))
            arrays = {"grow": np.concatenate(
                          [arrays["grow"],
                           np.arange(8, dtype=np.int32)]),
                      "pad": arrays["pad"]}
            res = self._commit(tmp_path, e, arrays,
                               prev=(prev[0], prev[1]),
                               max_chain=3, keep_manifests=10)
            modes.append(res["mode"])
        # deltas until the referenced chain would exceed max_chain, then a
        # fresh full commit re-bases the chain and deltas resume
        assert "full" in modes and modes[0] == "delta"
        first_full = modes.index("full")
        assert all(m == "delta" for m in modes[:first_full])
        man, loaded, _ = seg.open_latest(str(tmp_path))
        assert np.array_equal(loaded["grow"], arrays["grow"])
        assert len(man["segments"]) <= 4

    def test_gc_drops_old_manifests_and_orphans(self, tmp_path):
        for e in range(4):
            self._commit(tmp_path, e,
                         {"x": np.arange(32 + e, dtype=np.int32)},
                         keep_manifests=2)
        names = os.listdir(tmp_path)
        assert len([n for n in names if n.startswith("manifest_")]) == 2
        # only the kept manifests' segments survive
        kept_segs = {n for n in names if n.startswith("seg_")}
        man, _, _ = seg.open_latest(str(tmp_path))
        assert set(man["segments"]) <= kept_segs
        assert len(kept_segs) == 2

    def test_next_seq_never_reuses_orphans(self, tmp_path):
        self._commit(tmp_path, 0, {"x": np.arange(8, dtype=np.int32)})
        (tmp_path / "seg_00000007.bin").write_bytes(b"orphan")
        assert seg.next_seq(str(tmp_path)) == 8


class TestSegmentRecovery:
    def _two_commits(self, d):
        a0 = {"x": np.arange(256, dtype=np.int32)}
        seg.write_commit(str(d), {"epoch": 0}, a0)
        a1 = {"x": np.arange(256, 512, dtype=np.int32)}
        seg.write_commit(str(d), {"epoch": 1}, a1)
        return a0, a1

    def test_corrupt_newest_segment_recovers_previous(self, tmp_path):
        a0, _ = self._two_commits(tmp_path)
        man, _, _ = seg.open_latest(str(tmp_path))
        target = tmp_path / man["arrays"]["x"]["parts"][0]["segment"]
        raw = bytearray(target.read_bytes())
        raw[5] ^= 0xFF
        target.write_bytes(bytes(raw))
        man2, loaded, recovered = seg.open_latest(str(tmp_path))
        assert man2["epoch"] == 0 and recovered == 1
        assert np.array_equal(loaded["x"], a0["x"])

    def test_truncated_manifest_recovers_previous(self, tmp_path):
        a0, _ = self._two_commits(tmp_path)
        newest = seg.list_manifests(str(tmp_path))[0][1]
        p = tmp_path / newest
        p.write_bytes(p.read_bytes()[:20])
        man, loaded, recovered = seg.open_latest(str(tmp_path))
        assert man["epoch"] == 0 and recovered == 1
        assert np.array_equal(loaded["x"], a0["x"])

    def test_missing_segment_recovers_previous(self, tmp_path):
        a0, _ = self._two_commits(tmp_path)
        man, _, _ = seg.open_latest(str(tmp_path))
        os.remove(tmp_path / man["arrays"]["x"]["parts"][0]["segment"])
        man2, loaded, recovered = seg.open_latest(str(tmp_path))
        assert man2["epoch"] == 0 and recovered == 1
        assert np.array_equal(loaded["x"], a0["x"])

    def test_stray_tmp_files_ignored(self, tmp_path):
        _, a1 = self._two_commits(tmp_path)
        (tmp_path / "seg_00000009.bin.tmp-123").write_bytes(b"partial")
        (tmp_path / "manifest_00000009.json.tmp-123").write_bytes(b"{")
        man, loaded, recovered = seg.open_latest(str(tmp_path))
        assert man["epoch"] == 1 and recovered == 0
        assert np.array_equal(loaded["x"], a1["x"])

    def test_empty_dir_is_a_miss(self, tmp_path):
        assert seg.open_latest(str(tmp_path)) is None
        assert seg.open_latest(str(tmp_path / "absent")) is None


# ----------------------------------------------------------------------
# IndexStore: handle <-> segment roundtrip
# ----------------------------------------------------------------------

class TestIndexStore:
    def test_put_load_roundtrip(self, tmp_path):
        g = small_graph()
        h = build_handle(g, k=2)
        store = IndexStore(str(tmp_path))
        res = store.put_handle("g", h)
        assert res["mode"] == "full" and res["epoch"] == 0
        assert store.current_epoch("g") == 0
        assert store.keys() == ["g"]
        stored = store.load("g")
        assert stored is not None and stored.recovered == 0
        assert_pecb_identical(stored.pecb, h.pecb)
        for f in TAB_FIELDS:
            assert np.array_equal(getattr(stored.tab, f), getattr(h.tab, f))
        for f in ("src", "dst", "t"):
            assert np.array_equal(getattr(stored.graph, f), getattr(g, f))
        st = store.stats()
        assert st["commits"] == 1 and st["commits_full"] == 1
        assert st["loads"] == 1 and st["load_bytes"] > 0

    def test_put_same_epoch_is_noop(self, tmp_path):
        h = build_handle(small_graph(), k=2)
        store = IndexStore(str(tmp_path))
        store.put_handle("g", h)
        res = store.put_handle("g", h)
        assert res["mode"] == "current" and res["bytes_written"] == 0
        assert store.stats()["commits_noop"] == 1

    def test_load_miss_returns_none(self, tmp_path):
        store = IndexStore(str(tmp_path))
        assert store.load("nope") is None
        assert store.current_epoch("nope") is None

    def test_key_dirname_sanitized_and_collision_proof(self):
        d1 = key_dirname("feed@2026/08")
        d2 = key_dirname("feed@2026_08")
        assert "/" not in d1 and d1 != d2

    def test_stored_answers_match_live_index(self, tmp_path):
        g = small_graph(seed=9)
        h = build_handle(g, k=2)
        store = IndexStore(str(tmp_path))
        store.put_handle("g", h)
        stored = store.load("g")
        rng = np.random.default_rng(0)
        for _ in range(25):
            u = int(rng.integers(0, g.n))
            ts = int(rng.integers(1, g.t_max))
            te = int(rng.integers(ts, g.t_max + 1))
            q = TCCSQuery(u, ts, te, 2)
            assert stored.pecb.answer(q).vertices == h.pecb.answer(q).vertices


# ----------------------------------------------------------------------
# registry disk tier: write-through, promote, demote, warm restart
# ----------------------------------------------------------------------

class TestRegistryDiskTier:
    def test_build_writes_through_then_promotes_on_restart(self, tmp_path):
        g = small_graph(seed=5)
        store_a = IndexStore(str(tmp_path))
        reg_a = IndexRegistry(store=store_a)
        reg_a.register_graph("w", g)
        h_a = reg_a.get("w")
        reg_a.close()
        assert h_a.source == "build"
        assert store_a.stats()["commits"] == 1   # write-through, no demote

        # "restart": fresh registry + fresh store object over the same root
        reg_b = IndexRegistry(store=IndexStore(str(tmp_path)))
        reg_b.register_graph("w", g)
        h_b = reg_b.get("w")
        reg_b.close()
        assert h_b.source == "disk"
        assert reg_b.builds == 0 and reg_b.promotions == 1
        assert_handles_identical(h_b, h_a)

    def test_stale_store_falls_back_to_cold_build(self, tmp_path):
        store = IndexStore(str(tmp_path))
        reg_a = IndexRegistry(store=store)
        reg_a.register_graph("w", small_graph(seed=5))
        reg_a.get("w")
        reg_a.close()
        # same name, different graph: promotion must refuse the stored epoch
        reg_b = IndexRegistry(store=IndexStore(str(tmp_path)))
        reg_b.register_graph("w", small_graph(seed=6))
        h = reg_b.get("w")
        reg_b.close()
        assert h.source == "build"
        assert reg_b.promotions == 0 and reg_b.builds == 1

    def test_evict_demotes_and_promote_counts_metrics(self, tmp_path):
        metrics = EngineMetrics()
        store = IndexStore(str(tmp_path), metrics=metrics)
        reg = IndexRegistry(capacity=1, metrics=metrics, store=store)
        reg.register_graph("a", small_graph(seed=1))
        reg.register_graph("b", small_graph(seed=2))
        h_a = reg.get("a")
        reg.get("b")              # evicts ("a", 2) -> demote
        assert "a" not in reg
        assert reg.stats()["demotions"] == 1
        h_a2 = reg.get("a")       # promoted back, evicting+demoting b
        reg.close()
        assert h_a2.source == "disk"
        assert reg.promotions == 1 and reg.builds == 2
        assert_handles_identical(h_a2, h_a)
        snap = metrics.snapshot(include_sources=False)["counters"]
        assert snap["evictions_demoted"] == 2
        assert snap["promotions"] == 1
        # write-through made both demotions cheap manifest probes
        assert snap.get("demote_bytes", 0) == 0
        assert snap["store_commits"] == 2 and snap["store_loads"] >= 1

    def test_epoch_lifecycle_deltas_and_warm_reopen(self, tmp_path):
        g = small_graph(seed=7)
        g0, suffix = split_epoch(g, 0.7)
        store = IndexStore(str(tmp_path))
        reg = IndexRegistry(store=store)
        reg.register_graph("feed", g0)
        reg.get("feed")
        for fut in reg.extend_graph("feed", suffix).values():
            fut.result(timeout=60)
        t_cut = max(2, g.t_max // 4)
        for fut in reg.retain("feed", t_cut).values():
            fut.result(timeout=60)
        h_live = reg.get("feed")
        g_final = reg.resolve_graph("feed")
        reg.close()
        assert h_live.epoch == 2
        st = store.stats()
        assert st["commits"] == 3
        assert st["commits_delta"] >= 1    # the suffix ingest deltas

        # warm reopen WITHOUT register_graph: resolve_graph adopts the
        # stored graph + epoch, the build promotes the stored index
        reg2 = IndexRegistry(store=IndexStore(str(tmp_path)))
        h2 = reg2.get("feed")
        assert h2.source == "disk" and h2.epoch == 2
        assert_handles_identical(h2, h_live)
        g2 = reg2.resolve_graph("feed")
        assert np.array_equal(g2.t, g_final.t)
        # the adopted graph keeps ingesting from the stored epoch
        nxt = g2.t_max + 1
        futs = reg2.extend_graph(
            "feed", [(int(g2.src[0]), int(g2.dst[0]), nxt)])
        h3 = futs["feed"].result(timeout=60)
        reg2.close()
        assert h3.epoch == 3 and h3.pecb.t_max == nxt

        # and the delta-chained commits replay to a cold-build-identical
        # index on a third open
        fresh = IndexStore(str(tmp_path)).load("feed")
        assert fresh.epoch == 3
        h_cold = build_handle(reg2.resolve_graph("feed"), k=2)
        assert_pecb_identical(fresh.pecb, h_cold.pecb)

    def test_promoted_handle_stamps_disk_provenance(self, tmp_path):
        g = small_graph(seed=11)
        with ServingEngine(EngineConfig(store_dir=str(tmp_path),
                                        flush_ms=1.0)) as eng:
            eng.register_graph("w", g)
            eng.warmup("w")
            res = eng.answer("w", TCCSQuery(0, 1, g.t_max, 2))
            assert res.provenance.route != "disk"
        with ServingEngine(EngineConfig(store_dir=str(tmp_path),
                                        flush_ms=1.0)) as eng:
            eng.register_graph("w", g)
            eng.warmup("w")
            res = eng.answer("w", TCCSQuery(0, 1, g.t_max, 2))
            assert res.provenance.route == "disk"
            stats = eng.stats()
            assert stats["registry"]["promotions"] == 1
            assert stats["store"]["loads"] >= 1
            snap = eng.metrics.snapshot()
            assert snap["sources"]["store"]["commits_noop"] >= 0
            assert "index_promote" in snap["latency"]

    def test_store_failure_degrades_to_build(self, tmp_path):
        class BrokenStore(IndexStore):
            def load(self, key):
                raise OSError("disk on fire")

            def put_handle(self, key, handle, prev=None):
                raise OSError("disk on fire")

        metrics = EngineMetrics()
        reg = IndexRegistry(store=BrokenStore(str(tmp_path)),
                            metrics=metrics)
        reg.register_graph("w", small_graph(seed=4))
        h = reg.get("w")
        reg.close()
        assert h.source == "build" and reg.builds == 1
        snap = metrics.snapshot(include_sources=False)["counters"]
        assert snap["store_load_failures"] == 1
        assert snap["store_commit_failures"] == 1
