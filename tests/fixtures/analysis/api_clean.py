"""Clean API usage — the negatives: none of this may be flagged."""

import time


def uses_v2_surface(engine, spec, pool, fn):
    r = engine.answer("wl", spec)
    f = engine.submit_spec("wl", spec)
    # ThreadPoolExecutor.submit: first arg is a callable reference, not a
    # workload string — arity alone must not flag it
    job = pool.submit(fn, "wl", 2, 3, 1, 9)
    return r, f, job


def counts_through_registry(metrics):
    metrics.count("hits")
    metrics.observe("e2e", 0.001)


def times_with_perf_counter():
    t0 = time.perf_counter()
    return time.perf_counter() - t0


def validates_with_typed_errors(x):
    if x <= 0:
        raise ValueError(f"x must be positive, got {x}")
    return x


def suppressed_assert(x):
    assert x > 0  # repro: ignore[bare-assert]
    return x


def uses_workload_keys(registry, store, engine, cache, spec_key):
    # the modern key space: workload name alone; k stays per-query
    h = registry.get("wl")
    f = registry.get_async("wl", timeout=1.0)
    s = store.load("wl")
    engine.warmup("wl", sweep=True, sweep_ks=(2,))
    resident = "wl" in registry
    # the result cache's 2-tuple keys are a DIFFERENT key space —
    # (index_key, spec_key), not (workload, k) — and must not be flagged
    hit = cache.get(("wl", spec_key))
    return h, f, s, resident, hit
