"""Seeded kernel-contract violations — parsed by tests, never imported.

One deliberate true positive per rule of the ``kernels`` pass family
(DESIGN.md §15.3). Excluded from the strict tree in pyproject; the test
suite pins the per-rule finding counts here.
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

SLOT_BLOCK = 1024


def _body(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


def unpadded_grid(w):
    """pallas-grid-divisibility: ep // SLOT_BLOCK drops the tail — w is
    never padded to a SLOT_BLOCK multiple."""
    ep = w.shape[0]
    return pl.pallas_call(
        _body,
        grid=(ep // SLOT_BLOCK,),
        in_specs=[pl.BlockSpec((SLOT_BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((SLOT_BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(w.shape, jnp.int32),
        interpret=True,
    )(w)


def closure_index_map(x, offset):
    """pallas-indexmap-closure: the in-spec index_map closes over a local
    of the wrapper (a per-call Python value) instead of being a pure
    function of the grid indices."""
    n = x.shape[0]
    npad = int(np.ceil(n / 128)) * 128
    xp = jnp.pad(x, (0, npad - n))
    start = offset // 128
    return pl.pallas_call(
        _body,
        grid=(npad // 128,),
        in_specs=[pl.BlockSpec((128,), lambda i: (i + start,))],
        out_specs=pl.BlockSpec((128,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.int32),
        interpret=True,
    )(xp)


def vmem_hog(a):
    """pallas-vmem-budget: a (4096, 4096) f32 tile is 64 MiB — four times
    the 16 MiB TPU budget before the output tile is even counted."""
    m = a.shape[0]
    mp = int(np.ceil(m / 4096)) * 4096
    ap = jnp.pad(a.astype(jnp.float32), ((0, mp - m), (0, 0)))
    return pl.pallas_call(
        _body,
        grid=(mp // 4096,),
        in_specs=[pl.BlockSpec((4096, 4096), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((4096, 4096), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, 4096), jnp.float32),
        interpret=True,
    )(ap)


def packed_slots_narrowed(k_index, n, u):
    """int32-narrowing: the PR-9 fused slot ``k_index * n + u`` outgrows
    int32 long before any single stratum does, and nothing checks."""
    return np.asarray(k_index * n + u, np.int32)


def row_ptr_narrowed(counts):
    """int32-narrowing: int64 cumsum (the K*n+1 row-pointer build)
    silently wrapped back to int32."""
    row_ptr = np.cumsum(counts.astype(np.int64))
    return row_ptr.astype(np.int32)


def bad_layout(u, v, counts):
    """layout-contract: an undeclared key, a float64 value nobody casts,
    an unprovable value, and the other declared arrays missing from the
    construction site. ``node_ct`` is provably int32 — the in-site
    negative."""
    return {
        "node_u": u.astype(np.float64),
        "node_v": v,
        "node_ct": np.asarray(counts, np.int32),
        "bogus_plane": np.zeros(3),
    }
