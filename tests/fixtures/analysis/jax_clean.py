"""Clean JAX idiom — the negatives: none of this may be flagged."""

import jax
import jax.numpy as jnp
import numpy as np

SCALE = 2.0   # immutable module constant: fine to close over


@jax.jit
def branches_on_static_metadata(dix, q):
    # num_nodes/t_max are aux_data of a registered pytree: Python ints at
    # trace time, safe (and idiomatic) to branch on
    if dix.num_nodes == 0:
        return jnp.zeros_like(q)
    if dix.t_max > 1:
        q = q * 2
    return q * SCALE


@jax.jit
def lax_control_flow(x):
    return jax.lax.cond(x.sum() > 0, lambda v: v, lambda v: -v, x)


def host_wrapper(fn, u, ts):
    # host-side materialization OUTSIDE the traced function: fine
    out = fn(jnp.asarray(u), jnp.asarray(ts))
    return np.asarray(out)


def host_validation(u, ts):
    # asserts outside traced code are the bare-assert pass's business (and
    # this file is a fixture, not library code)
    if len(u) != len(ts):
        raise ValueError("length mismatch")
    return u
