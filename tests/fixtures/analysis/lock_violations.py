"""Seeded lock-discipline violations — parsed by tests, never imported.

Expected findings (tests/test_analysis.py pins rule + line):
  * lock-order: cache acquired under metrics (rank inversion)
  * lock-order: unnamed lock nested under a named lock
  * lock-order: unknown level name
  * lock-order via receiver map: _metrics call under metrics-ranked lock
  * lock-blocking-call: Future.result under a lock
  * lock-blocking-call: device sync under a lock
  * lock-blocking-call: file I/O under a lock
"""

import threading

from repro.obs.locks import named_lock


class BadNesting:
    def __init__(self):
        self._metrics_lock = named_lock("metrics")
        self._cache_lock = named_lock("cache")
        self._plain_lock = threading.Lock()
        self._mystery = named_lock("not-a-level")

    def inverted(self):
        with self._metrics_lock:
            with self._cache_lock:      # lock-order: cache < metrics? no —
                pass                     # cache ranks ABOVE metrics: inversion

    def unnamed_nested(self):
        with self._cache_lock:
            with self._plain_lock:       # lock-order: unnamed under named
                pass

    def unknown_level(self):
        with self._mystery:              # lock-order: unknown level
            pass


class BadBlocking:
    def __init__(self, metrics):
        self._lock = named_lock("registry")
        self._hist_lock = named_lock("histogram")
        self._metrics = metrics

    def waits_under_lock(self, fut):
        with self._lock:
            return fut.result(timeout=5)     # lock-blocking-call

    def syncs_under_lock(self, arr):
        with self._lock:
            arr.block_until_ready()          # lock-blocking-call

    def io_under_lock(self, path):
        with self._lock:
            with open(path) as f:            # lock-blocking-call
                return f.read()

    def receiver_inversion(self):
        with self._hist_lock:
            self._metrics.count("x")         # lock-order via receiver map
