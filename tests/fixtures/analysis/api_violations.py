"""Seeded API-discipline violations — parsed by tests, never imported."""

import time


def uses_legacy_shims(index, engine):
    a = index.query(3, 1, 9)                 # deprecated-shim (3-arg query)
    b = engine.submit("wl", 2, 3, 1, 9)      # deprecated-shim (5-arg submit)
    c = engine.submit_many("wl", 2, [(3, 1, 9)])   # deprecated-shim
    return a, b, c


def mutates_counters(metrics):
    metrics._counters["hits"] = 7            # metrics-direct
    metrics._counters["hits"] += 1           # metrics-direct


def times_with_wallclock():
    t0 = time.time()                         # wallclock-in-traced
    return t0


def has_bare_assert(x):
    assert x > 0                             # bare-assert
    return x


def uses_per_k_keys(registry, store, engine, k):
    h1 = registry.get(("wl", 3))             # per-k-key (tuple key)
    h2 = registry.get_async(("wl", k))       # per-k-key (tuple key)
    h3 = store.load(("wl", 3))               # per-k-key (tuple key)
    h4 = registry.get("wl", k)               # per-k-key (positional k)
    h5 = engine.warmup("wl", 3)              # per-k-key (positional k)
    resident = ("wl", 3) in registry         # per-k-key (tuple membership)
    return h1, h2, h3, h4, h5, resident
