"""Kernel-contract negatives — the shapes the ``kernels`` passes must
NOT flag. This file sits inside the strict include roots, so any false
positive here fails CI.

* padding idiom before the grid division (``ceil`` multiple provable)
* index_map as a pure function of the grid indices
* block sizes well under the VMEM budget
* int64 packed-offset math routed through a checked caster
* a complete, provably-int32 device-layout construction site
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BLOCK = 256


class PackedOverflowError(OverflowError):
    """Packed offsets left the int32 range."""


def _checked_i32(a):
    a = np.asarray(a)
    if a.size and (a.max() > np.iinfo(np.int32).max
                   or a.min() < np.iinfo(np.int32).min):
        raise PackedOverflowError("packed offsets exceed int32")
    return a.astype(np.int32, copy=False)


def _body(x_ref, o_ref):
    o_ref[...] = x_ref[...] + 1


def padded_grid(w):
    """The padding idiom the divisibility rule must prove through."""
    e = w.shape[0]
    ep = int(np.ceil(max(e, 1) / BLOCK)) * BLOCK
    wp = jnp.pad(w, (0, ep - e))
    return pl.pallas_call(
        _body,
        grid=(ep // BLOCK,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((ep,), jnp.int32),
        interpret=True,
    )(wp)


def packed_slots(k_index, n, u):
    """int64 first, then the checked caster: the sanctioned narrowing."""
    slots = np.asarray(k_index, np.int64) * int(n) + np.asarray(u, np.int64)
    return _checked_i32(slots)


def tiny_layout(n_entries):
    """Every declared array present and constructed int32."""
    z = np.zeros(n_entries, np.int32)
    return {
        "node_u": z, "node_v": z, "node_ct": z,
        "live_from": z, "live_to": z, "row_ptr": z,
        "ent_ts": z, "ent_left": z, "ent_right": z, "ent_parent": z,
        "vrow_ptr": z, "vent_ts": z, "vent_node": z,
        "ver_ts_from": z, "ver_ts_to": z, "ver_ct": z,
        "ver_src": z, "ver_k": z,
    }
