"""Seeded JAX-hygiene violations — parsed by tests, never imported."""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

MUTABLE_TABLE = {}


@jax.jit
def asserts_on_tracer(x):
    assert x.sum() > 0          # jit-assert
    return x * 2


@jax.jit
def branches_on_tracer(x):
    if x[0] > 0:                # jit-python-branch
        return x
    return -x


@partial(jax.jit, static_argnums=(1,))
def syncs_in_trace(x, n):
    y = x.sum()
    return np.asarray(y)        # jit-host-sync


@jax.jit
def reads_mutable_global(x):
    return x * MUTABLE_TABLE["scale"]   # jit-mutable-closure


def _kernel(x, y, n):
    return x + y + n


jitted = jax.jit(_kernel, static_argnums=(2,))


def call_with_unhashable():
    x = jnp.zeros(4)
    return jitted(x, x, [1, 2, 3])      # jit-unhashable-static
