"""Clean lock usage — the negatives: none of this may be flagged."""

from repro.obs.locks import named_condition, named_lock


class GoodNesting:
    def __init__(self, metrics):
        self._lock = named_lock("registry")
        self._cond = named_condition("batcher")
        self._metrics = metrics

    def downward(self):
        with self._lock:
            # registry -> metrics is a declared downward edge
            self._metrics.count("evictions")

    def sequential_not_nested(self, fut):
        with self._lock:
            key = "pending"
        # blocking work AFTER the lock is released: fine
        result = fut.result(timeout=5)
        with self._lock:
            return key, result

    def callback_not_under_lock(self):
        with self._lock:
            # defining a function under a lock is fine — it runs later
            def cb(f):
                return f.result()
            return cb

    def joins_strings(self, parts):
        with self._lock:
            return ", ".join(parts)   # str.join is not a thread join
