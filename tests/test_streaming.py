"""Streaming epoch plane (DESIGN.md §9): graph epochs, incremental
core-time/index refresh, serving-path epoch swap, and the bugfix-sweep
regressions that rode along (batcher flush flag, cache re-stamp copy,
empty-graph canonicalization, deprecation warnings)."""

import time
from concurrent.futures import Future

import numpy as np
import pytest

from repro.core.batch_query import refresh_device, to_device
from repro.core.core_time import edge_core_times, extend_core_times
from repro.core.ctmsf_index import CTMSFIndex
from repro.core.ef_index import EFIndex
from repro.core.pecb_index import build_pecb_index, build_stratified_index
from repro.core.query_api import (EMPTY_WINDOW, ResultMode, TCCSQuery,
                                  WindowSweep)
from repro.core.streaming import extend_pecb_index
from repro.core.temporal_graph import (TemporalGraph, gen_temporal_graph,
                                       random_queries)
from repro.serving import EngineConfig, ServingEngine
from repro.serving.batcher import MicroBatcher, Request
from repro.serving.metrics import EngineMetrics

PECB_FIELDS = ("node_u", "node_v", "node_ct", "node_edge", "node_live_from",
               "node_live_to", "row_ptr", "ent_ts", "ent_left", "ent_right",
               "ent_parent", "vrow_ptr", "vent_ts", "vent_node")


def assert_pecb_identical(a, b):
    """Bit-identity for either a per-k PECBIndex or a StratifiedPECB
    (same packed field names; the stratified form adds the k-block
    offset tables and global version endpoints)."""
    for f in PECB_FIELDS:
        assert np.array_equal(getattr(a, f), getattr(b, f)), f
    assert (a.n, a.m, a.t_max) == (b.n, b.m, b.t_max)
    if hasattr(a, "supported_ks"):
        assert a.supported_ks == b.supported_ks
        assert a.k_max_graph == b.k_max_graph
        for f in ("knode_ptr", "kent_ptr", "kvent_ptr",
                  "ver_src", "ver_dst", "ver_t"):
            assert np.array_equal(getattr(a, f), getattr(b, f)), f
    else:
        assert a.k == b.k
    assert a.versions == b.versions


def split_epoch(g, frac):
    t_old = max(1, int(g.t_max * frac))
    g0, suffix = g.split_at(t_old)
    return g0, [tuple(e) for e in suffix.tolist()]


# ----------------------------------------------------------------------
# TemporalGraph.extend / split_at
# ----------------------------------------------------------------------

class TestExtend:
    def test_suffix_append_roundtrips_split(self):
        g = gen_temporal_graph(n=30, m=240, t_max=16, seed=1)
        g0, suffix = split_epoch(g, 0.6)
        g1 = g0.extend(suffix)
        assert g1.m == g.m and g1.t_max == g.t_max
        assert np.array_equal(g1.src, g.src)
        assert np.array_equal(g1.dst, g.dst)
        assert np.array_equal(g1.t, g.t)

    def test_historical_edges_rejected(self):
        g = gen_temporal_graph(n=20, m=100, t_max=10, seed=2)
        with pytest.raises(ValueError, match="suffix"):
            g.extend([(0, 1, g.t_max)])
        with pytest.raises(ValueError, match="suffix"):
            g.extend([(0, 1, 1), (2, 3, g.t_max + 5)])

    def test_out_of_range_vertices_rejected(self):
        g = gen_temporal_graph(n=20, m=100, t_max=10, seed=3)
        with pytest.raises(ValueError, match="endpoints"):
            g.extend([(0, g.n, g.t_max + 1)])

    def test_empty_append_returns_self_and_loops_dropped(self):
        g = gen_temporal_graph(n=20, m=100, t_max=10, seed=4)
        assert g.extend([]) is g
        assert g.extend([(5, 5, g.t_max + 1)]) is g
        g2 = g.extend([(1, 2, g.t_max + 2), (3, 3, g.t_max + 2)])
        assert g2.m == g.m + 1


# ----------------------------------------------------------------------
# incremental refresh == cold rebuild, bit-identically
# ----------------------------------------------------------------------

class TestIncrementalRefresh:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    @pytest.mark.parametrize("k", [2, 3])
    @pytest.mark.parametrize("frac", [0.3, 0.7])
    def test_bit_identical_to_cold(self, seed, k, frac):
        g = gen_temporal_graph(n=30, m=260, t_max=15, seed=seed)
        g0, suffix = split_epoch(g, frac)
        if g0.m == 0 or not suffix:
            pytest.skip("degenerate split")
        tab0 = edge_core_times(g0, k)
        idx0 = build_pecb_index(g0, k, tab0)
        g1 = g0.extend(suffix)
        tab1 = extend_core_times(g1, k, tab0)
        tab_cold = edge_core_times(g, k)
        for f in ("edge_id", "ts_from", "ts_to", "ct", "vertex_ct"):
            assert np.array_equal(getattr(tab1, f), getattr(tab_cold, f)), f
        assert_pecb_identical(extend_pecb_index(g1, k, tab1, idx0),
                              build_pecb_index(g, k, tab_cold))

    def test_chained_epochs(self):
        g = gen_temporal_graph(n=50, m=700, t_max=30, seed=7)
        k = 3
        cuts = [10, 18, 24, g.t_max]
        cur, _ = g.split_at(cuts[0])
        tab = edge_core_times(cur, k)
        idx = build_pecb_index(cur, k, tab)
        for t_cut in cuts[1:]:
            gn, _ = g.split_at(t_cut)
            suffix = np.stack([gn.src[cur.m:], gn.dst[cur.m:],
                               gn.t[cur.m:]], axis=1)
            cur = cur.extend([tuple(e) for e in suffix.tolist()])
            tab = extend_core_times(cur, k, tab)
            idx = extend_pecb_index(cur, k, tab, idx)
        assert_pecb_identical(idx, build_pecb_index(g, k))

    def test_build_pecb_index_resume_from(self):
        g = gen_temporal_graph(n=30, m=220, t_max=12, seed=11)
        g0, suffix = split_epoch(g, 0.5)
        tab0 = edge_core_times(g0, 2)
        idx0 = build_pecb_index(g0, 2, tab0)
        g1 = g0.extend(suffix)
        tab1 = extend_core_times(g1, 2, tab0)
        assert_pecb_identical(
            build_pecb_index(g1, 2, tab1, resume_from=idx0),
            build_pecb_index(g, 2))
        with pytest.raises(ValueError, match="extend_core_times"):
            build_pecb_index(g1, 2, resume_from=idx0)

    def test_mismatched_epoch_inputs_raise(self):
        g = gen_temporal_graph(n=30, m=220, t_max=12, seed=12)
        g0, suffix = split_epoch(g, 0.5)
        tab0 = edge_core_times(g0, 2)
        idx0 = build_pecb_index(g0, 2, tab0)
        g1 = g0.extend(suffix)
        tab1 = extend_core_times(g1, 2, tab0)
        with pytest.raises(ValueError, match="k="):
            extend_pecb_index(g1, 3, tab1, idx0)
        with pytest.raises(ValueError, match="core-time table"):
            extend_pecb_index(g1, 2, tab0, idx0)
        # an index of a *different* graph must be refused, not absorbed
        g_other = gen_temporal_graph(n=30, m=220, t_max=6, seed=99)
        idx_other = build_pecb_index(g_other, 2)
        with pytest.raises(ValueError):
            extend_pecb_index(g1, 2, tab1, idx_other)

    def test_refresh_answers_match_oracle_on_new_windows(self):
        from repro.core.kcore import tccs_oracle
        g = gen_temporal_graph(n=30, m=300, t_max=14, seed=13)
        k = 2
        g0, suffix = split_epoch(g, 0.6)
        tab0 = edge_core_times(g0, k)
        idx0 = build_pecb_index(g0, k, tab0)
        g1 = g0.extend(suffix)
        tab1 = extend_core_times(g1, k, tab0)
        idx1 = extend_pecb_index(g1, k, tab1, idx0)
        rng = np.random.default_rng(0)
        for _ in range(40):
            u = int(rng.integers(0, g.n))
            ts = int(rng.integers(1, g.t_max + 1))
            te = int(rng.integers(ts, g.t_max + 1))
            got = idx1.answer(TCCSQuery(u, ts, te, k)).vertices
            assert got == frozenset(tccs_oracle(g, k, u, ts, te))


# ----------------------------------------------------------------------
# device mirror refresh
# ----------------------------------------------------------------------

class TestDeviceRefresh:
    def test_refresh_device_equals_fresh_upload(self):
        from repro.core.batch_query import batch_query
        import jax.numpy as jnp
        g = gen_temporal_graph(n=30, m=260, t_max=14, seed=21)
        k = 2
        g0, suffix = split_epoch(g, 0.6)
        tab0 = edge_core_times(g0, k)
        idx0 = build_pecb_index(g0, k, tab0)
        dix0 = to_device(idx0)
        g1 = g0.extend(suffix)
        tab1 = extend_core_times(g1, k, tab0)
        idx1 = extend_pecb_index(g1, k, tab1, idx0)
        dix1, stats = refresh_device(idx0, dix0, idx1)
        fresh = to_device(idx1)
        from repro.core.batch_query import _ARRAY_FIELDS, _META_FIELDS
        for f in _ARRAY_FIELDS:
            assert np.array_equal(np.asarray(getattr(dix1, f)),
                                  np.asarray(getattr(fresh, f))), f
        for f in _META_FIELDS:
            assert getattr(dix1, f) == getattr(fresh, f), f
        assert stats["reused"] + stats["suffix"] + stats["full"] == len(_ARRAY_FIELDS)
        qs = random_queries(g1, 16, seed=1)
        u = jnp.asarray([q[0] for q in qs], jnp.int32)
        ts = jnp.asarray([q[1] for q in qs], jnp.int32)
        te = jnp.asarray([q[2] for q in qs], jnp.int32)
        assert np.array_equal(np.asarray(batch_query(dix1, u, ts, te)),
                              np.asarray(batch_query(fresh, u, ts, te)))

    def test_noop_refresh_reuses_everything(self):
        g = gen_temporal_graph(n=20, m=150, t_max=10, seed=22)
        idx = build_pecb_index(g, 2)
        dix = to_device(idx)
        dix2, stats = refresh_device(idx, dix, idx)
        assert stats["full"] == 0 and stats["uploaded_bytes"] == 0
        assert stats["suffix"] == 0


# ----------------------------------------------------------------------
# registry epochs + engine ingest
# ----------------------------------------------------------------------

class TestServingEpochs:
    def _graph(self, seed=31):
        return gen_temporal_graph(n=40, m=420, t_max=18, seed=seed)

    def test_ingest_refreshes_and_swaps_atomically(self):
        g = self._graph()
        g0, suffix = split_epoch(g, 0.6)
        with ServingEngine(EngineConfig(flush_ms=0.5)) as eng:
            eng.register_graph("feed", g0)
            h0 = eng.registry.get("feed")
            assert h0.epoch == 0 and h0.tab is not None
            futures = eng.ingest("feed", suffix, wait=True)
            assert set(futures) == {"feed"}
            h1 = futures["feed"].result()
            assert h1.epoch == 1
            assert h1.graph.t_max == g.t_max
            assert eng.registry.get_nowait("feed") is h1
            # the refreshed index is bit-identical to a cold rebuild
            assert_pecb_identical(h1.pecb, build_stratified_index(g))
            # old handle still answers (old epoch pinned for in-flight use)
            q = TCCSQuery(3, 1, g0.t_max, 2)
            assert h0.pecb.answer(q).vertices == h1.pecb.answer(q).vertices
            assert eng.registry.stats()["refreshes"] == 1
            assert eng.registry.stats()["epochs"] == {"feed": 1}

    def test_ingest_without_resident_index_is_lazy(self):
        g = self._graph(32)
        g0, suffix = split_epoch(g, 0.5)
        with ServingEngine() as eng:
            eng.register_graph("feed", g0)
            assert eng.ingest("feed", suffix) == {}
            h = eng.registry.get("feed")   # cold build sees new epoch
            assert h.graph.t_max == g.t_max and h.epoch == 1

    def test_targeted_purge_preserves_old_window_cache(self):
        g = self._graph(33)
        g0, suffix = split_epoch(g, 0.6)
        with ServingEngine(EngineConfig(flush_ms=0.5)) as eng:
            eng.register_graph("feed", g0)
            eng.registry.get("feed")   # resident, no XLA warmup needed
            q = TCCSQuery(5, 1, g0.t_max // 2, 2)
            first = eng.answer("feed", q)
            hit = eng.answer("feed", q)
            assert hit.provenance.route == "cache"
            cached = len(eng.cache)
            assert cached >= 1
            eng.ingest("feed", suffix, wait=True)
            # suffix epochs invalidate nothing: every cached canonical
            # window predates the appended range
            assert len(eng.cache) == cached
            again = eng.answer("feed", q)
            assert again.provenance.route == "cache"
            assert again.vertices == first.vertices

    def test_queries_answer_throughout_refresh(self):
        g = self._graph(34)
        g0, suffix = split_epoch(g, 0.7)
        with ServingEngine(EngineConfig(flush_ms=0.5)) as eng:
            eng.register_graph("feed", g0)
            eng.registry.get("feed")   # resident, no XLA warmup needed
            futures = eng.ingest("feed", suffix)
            refresh_fut = futures["feed"]
            qs = random_queries(g0, 64, seed=2)
            answered = 0
            while not refresh_fut.done() or answered < 64:
                u, ts, te = qs[answered % len(qs)]
                res = eng.answer("feed", TCCSQuery(u, ts, te, 2))
                assert res is not None
                answered += 1
                if answered >= 256:
                    break
            refresh_fut.result(timeout=60)
            assert answered >= 64

    def test_post_refresh_queries_reach_new_range(self):
        from repro.core.kcore import tccs_oracle
        g = self._graph(35)
        g0, suffix = split_epoch(g, 0.6)
        with ServingEngine(EngineConfig(flush_ms=0.5)) as eng:
            eng.register_graph("feed", g0)
            eng.registry.get("feed")
            eng.ingest("feed", suffix, wait=True)
            rng = np.random.default_rng(3)
            for _ in range(20):
                u = int(rng.integers(0, g.n))
                ts = int(rng.integers(1, g.t_max + 1))
                te = int(rng.integers(ts, g.t_max + 1))
                res = eng.answer("feed", TCCSQuery(u, ts, te, 2))
                assert res.vertices == frozenset(
                    tccs_oracle(g, 2, u, ts, te)), (u, ts, te)

    def test_chained_nonblocking_ingests_land_the_last_epoch(self):
        """Two ingests issued back-to-back without waiting: both refreshes
        may grow from the same epoch-0 handle, and the second must still
        swap in (the registry serving epoch 1 forever was a real bug)."""
        g = self._graph(38)
        gA, _ = g.split_at(int(g.t_max * 0.5))
        gB, _ = g.split_at(int(g.t_max * 0.75))
        day1 = [tuple(e) for e in np.stack(
            [gB.src[gA.m:], gB.dst[gA.m:], gB.t[gA.m:]], axis=1).tolist()]
        day2 = [tuple(e) for e in np.stack(
            [g.src[gB.m:], g.dst[gB.m:], g.t[gB.m:]], axis=1).tolist()]
        with ServingEngine(EngineConfig(flush_ms=0.5)) as eng:
            eng.register_graph("feed", gA)
            eng.registry.get("feed")
            f1 = eng.ingest("feed", day1)
            f2 = eng.ingest("feed", day2)
            for f in list(f1.values()) + list(f2.values()):
                f.result(timeout=120)
            h = eng.registry.get_nowait("feed", start_build=False)
            assert h is not None and h.epoch == 2
            assert h.graph.t_max == g.t_max
            assert_pecb_identical(h.pecb, build_stratified_index(g))

    def test_cold_build_racing_ingest_catches_up(self):
        """An ingest that lands while a cold build is in flight finds no
        resident entry to refresh; the build's completion must notice the
        newer graph epoch and catch the stored handle up, or queries would
        serve pre-ingest data indefinitely."""
        import threading
        from repro.serving import IndexRegistry
        g = self._graph(37)
        g0, suffix = split_epoch(g, 0.6)
        reg = IndexRegistry()
        reg.register_graph("feed", g0)
        built = threading.Event()
        proceed = threading.Event()
        orig = reg._build

        def stalling_build(key):
            h = orig(key)
            built.set()
            assert proceed.wait(30)
            return h

        reg._build = stalling_build
        try:
            fut = reg.get_async("feed")
            assert built.wait(30)
            assert reg.extend_graph("feed", suffix) == {}  # nothing resident
            proceed.set()
            stale = fut.result(timeout=60)
            assert stale.graph.t_max == g0.t_max          # built pre-ingest
            deadline = time.perf_counter() + 60
            while time.perf_counter() < deadline:
                h = reg.get_nowait("feed", start_build=False)
                if h is not None and h.graph.t_max == g.t_max:
                    break
                time.sleep(0.01)
            h = reg.get_nowait("feed", start_build=False)
            assert h is not None and h.graph.t_max == g.t_max
            assert h.epoch == 1
            assert_pecb_identical(h.pecb, build_stratified_index(g))
        finally:
            reg.close()

    def test_sweep_after_ingest(self):
        g = self._graph(36)
        g0, suffix = split_epoch(g, 0.6)
        with ServingEngine(EngineConfig(flush_ms=0.5)) as eng:
            eng.register_graph("feed", g0)
            eng.registry.get("feed")
            eng.ingest("feed", suffix, wait=True)
            windows = [(d, d + 4) for d in range(1, g.t_max - 3)]
            res = eng.sweep("feed", WindowSweep(u=1, k=2, windows=windows))
            h = eng.registry.get("feed")
            for r, (ts, te) in zip(res, windows):
                assert r.vertices == h.pecb.answer(
                    TCCSQuery(1, ts, te, 2)).vertices


# ----------------------------------------------------------------------
# satellite regressions
# ----------------------------------------------------------------------

class TestBatcherFlushFlag:
    def test_empty_flush_does_not_leak_into_next_batch(self):
        """A flush() with nothing pending must not force-flush the next
        unrelated batch (or miscount it as flush_forced)."""
        metrics = EngineMetrics()
        b = MicroBatcher(lambda reqs: [None] * len(reqs),
                         max_batch=64, flush_ms=40.0, metrics=metrics)
        try:
            b.flush()                      # nothing pending: must be a no-op
            time.sleep(0.05)               # give the worker a chance to spin
            t0 = time.perf_counter()
            fut = b.submit(Request(0, 1, 1, Future(), t_submit=t0))
            fut.result(timeout=5)
            waited = time.perf_counter() - t0
            snap = metrics.snapshot()["counters"]
            assert snap.get("flush_forced", 0) == 0
            assert waited >= 0.03          # dispatched by deadline, not force
        finally:
            b.close()

    def test_flush_with_pending_still_forces(self):
        metrics = EngineMetrics()
        b = MicroBatcher(lambda reqs: [None] * len(reqs),
                         max_batch=64, flush_ms=60.0, metrics=metrics)
        try:
            t0 = time.perf_counter()
            fut = b.submit(Request(0, 1, 1, Future(), t_submit=t0))
            b.flush()
            fut.result(timeout=5)
            assert time.perf_counter() - t0 < 0.5
            assert metrics.snapshot()["counters"].get("flush_forced", 0) == 1
        finally:
            b.close()


class TestCacheHitRestamp:
    def test_cache_hit_is_a_copy_not_shared_state(self):
        g = gen_temporal_graph(n=25, m=200, t_max=10, seed=41)
        with ServingEngine(EngineConfig(flush_ms=0.5)) as eng:
            eng.register_graph("g", g)
            eng.registry.get("g")      # resident, no XLA warmup needed
            q = TCCSQuery(1, 1, g.t_max, 2)
            first = eng.answer("g", q)
            hit1 = eng.answer("g", q)
            hit2 = eng.answer("g", q)
            assert hit1.provenance.route == "cache"
            assert hit1 is not hit2
            assert hit1.provenance is not hit2.provenance
            assert hit1.provenance.timings is not hit2.provenance.timings
            # mutating a caller's copy must not corrupt the stored result
            hit1.provenance.timings["poison"] = 1.0
            hit3 = eng.answer("g", q)
            assert "poison" not in hit3.provenance.timings
            assert first.provenance.route != "cache"  # original unchanged


class TestEmptyGraphWindows:
    def test_canonical_folds_t_max_zero(self):
        q = TCCSQuery(0, 5, 9, 2).canonical(0)
        assert (q.ts, q.te) == EMPTY_WINDOW
        assert q.validate() is q            # the marker is valid, not [1,0]
        assert TCCSQuery(0, 1, 3, 2).canonical(0).is_empty_window

    def test_random_queries_on_empty_graph(self):
        g = TemporalGraph.from_edges(4, [])
        qs = random_queries(g, 8, seed=0)
        assert all(ts > te for (_, ts, te) in qs)

    def test_engine_serves_empty_graph(self):
        g = TemporalGraph.from_edges(4, [])
        with ServingEngine() as eng:
            eng.register_graph("empty", g)
            res = eng.answer("empty", TCCSQuery(2, 1, 5, 2))
            assert res.vertices == frozenset()
            assert res.provenance.route == "trivial"
            sub = eng.answer("empty",
                             TCCSQuery(2, 1, 5, 2, ResultMode.SUBGRAPH))
            assert sub.subgraph.m == 0


class TestDeprecationWarnings:
    def _stack(self):
        g = gen_temporal_graph(n=20, m=140, t_max=8, seed=51)
        tab = edge_core_times(g, 2)
        return g, (build_pecb_index(g, 2, tab), EFIndex(g, 2, tab),
                   CTMSFIndex(g, 2, tab))

    def test_backend_query_shims_warn(self):
        _, backends = self._stack()
        for b in backends:
            with pytest.warns(DeprecationWarning, match="deprecated"):
                b.query(0, 1, 5)

    def test_engine_shims_warn_and_match_v2(self):
        g, _ = self._stack()
        with ServingEngine(EngineConfig(flush_ms=0.5)) as eng:
            eng.register_graph("g", g)
            want = eng.answer("g", TCCSQuery(1, 1, g.t_max, 2)).vertices
            with pytest.warns(DeprecationWarning, match="submit_spec"):
                fut = eng.submit("g", 2, 1, 1, g.t_max)
            assert fut.result(timeout=30) == want
            with pytest.warns(DeprecationWarning, match="submit_specs"):
                futs = eng.submit_many("g", 2, [(1, 1, g.t_max)])
            assert futs[0].result(timeout=30) == want
            with pytest.warns(DeprecationWarning, match="answer"):
                got = eng.query("g", 2, 1, 1, g.t_max)
            assert got == want
