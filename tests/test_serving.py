"""Serving-engine tests: exactness vs Algorithm 1 on every route, cache
semantics, shape-bucketed compile stability, planner routing, registry
lifecycle, batcher flush behaviour, metrics."""

import os
import subprocess
import sys
import textwrap
import time
from concurrent.futures import Future

import numpy as np
import pytest

from repro.core.temporal_graph import gen_temporal_graph
from repro.serving import (
    EngineConfig, IndexRegistry, LatencyHistogram, MicroBatcher, Request,
    ServingEngine, ShardedExecutor, TCCSQuery, bucket_size, pad_queries,
)
from repro.core.query_api import EMPTY_WINDOW


def lenient_spec(u, ts, te, k):
    """v2 spec with the legacy streams' lenient window semantics: a
    malformed window (ts > te) folds onto the canonical empty marker
    instead of raising at validation."""
    if ts > te:
        ts, te = EMPTY_WINDOW
    return TCCSQuery(u, ts, te, k)


def alg1(pecb, u, ts, te, k=2):
    """Algorithm-1 reference through the non-deprecated component
    routine (the deprecated .query shim wrapped exactly this). Accepts
    either a per-k PECBIndex or the registry's stratified index (sliced
    to the requested stratum)."""
    if hasattr(pecb, "slice_k"):
        pecb = pecb.slice_k(k)
    return frozenset(pecb._component_vertices(u, ts, te))

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def random_stream(g, n_q, rng, oob_frac=0.2):
    """Random (u, ts, te) stream including out-of-range windows: te < ts
    and ts beyond t_max."""
    qs = []
    for _ in range(n_q):
        u = int(rng.integers(0, g.n))
        if rng.random() < oob_frac:
            ts = int(rng.integers(1, 2 * g.t_max))
            te = int(rng.integers(0, 2 * g.t_max))   # may be < ts
        else:
            ts = int(rng.integers(1, g.t_max + 1))
            te = int(rng.integers(ts, g.t_max + 1))
        qs.append((u, ts, te))
    return qs


def run_engine(eng, workload, k, queries, chunk=64):
    futs = []
    for i in range(0, len(queries), chunk):
        futs += eng.submit_specs(
            workload,
            [lenient_spec(u, ts, te, k) for (u, ts, te) in queries[i:i + chunk]])
    eng.flush()
    return [f.result(timeout=60).vertices for f in futs]


class TestEngineExactness:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_device_route_matches_alg1(self, seed):
        rng = np.random.default_rng(seed)
        g = gen_temporal_graph(n=35, m=260, t_max=16, seed=seed + 70)
        cfg = EngineConfig(max_batch=64, flush_ms=500.0, host_threshold=0,
                           min_bucket=8, cache_capacity=0)
        with ServingEngine(cfg) as eng:
            eng.register_graph("g", g)
            h = eng.registry.get("g")
            qs = random_stream(g, 120, rng)
            got = run_engine(eng, "g", 2, qs)
            assert eng.metrics.counter("device_batches") > 0
            assert eng.metrics.counter("host_batches") == 0
        for (u, ts, te), res in zip(qs, got):
            assert res == alg1(h.pecb, u, ts, te), (u, ts, te)

    def test_host_route_matches_alg1(self):
        rng = np.random.default_rng(3)
        g = gen_temporal_graph(n=30, m=220, t_max=14, seed=41)
        cfg = EngineConfig(max_batch=64, flush_ms=500.0,
                           host_threshold=10**9, cache_capacity=0)
        with ServingEngine(cfg) as eng:
            eng.register_graph("g", g)
            h = eng.registry.get("g")
            qs = random_stream(g, 80, rng)
            got = run_engine(eng, "g", 3, qs)
            assert eng.metrics.counter("host_batches") > 0
            assert eng.metrics.counter("device_batches") == 0
        for (u, ts, te), res in zip(qs, got):
            assert res == alg1(h.pecb, u, ts, te, k=3)

    def test_unsupported_k_returns_empty(self):
        """k above the graph's k-max is outside every stratum: the engine
        answers exactly-empty host-side, no device launch."""
        g = gen_temporal_graph(n=20, m=60, t_max=8, seed=9)
        with ServingEngine(EngineConfig(flush_ms=500.0)) as eng:
            eng.register_graph("g", g)
            h = eng.registry.get("g")
            assert 50 not in h.pecb.supported_ks
            assert 50 > h.pecb.k_max_graph
            qs = [(u, 1, g.t_max) for u in range(g.n)]
            got = run_engine(eng, "g", 50, qs)
            assert all(r == frozenset() for r in got)
            # trivially-empty k always routes host (nothing to launch)
            assert eng.metrics.counter("device_batches") == 0
            assert eng.metrics.counter("unsupported_k_queries") == g.n

    def test_mixed_k_one_engine(self):
        """One engine serves several k values off ONE stratified build;
        answers stay per-k exact and no rebuild happens between ks."""
        g = gen_temporal_graph(n=30, m=240, t_max=12, seed=5)
        rng = np.random.default_rng(5)
        qs = random_stream(g, 40, rng, oob_frac=0.0)
        with ServingEngine(EngineConfig(max_batch=64, flush_ms=500.0,
                                        host_threshold=0)) as eng:
            eng.register_graph("g", g)
            for k in (2, 3):
                got = run_engine(eng, "g", k, qs)
                h = eng.registry.get("g")
                for (u, ts, te), res in zip(qs, got):
                    assert res == alg1(h.pecb, u, ts, te, k=k), (k, u, ts, te)
            assert eng.registry.builds == 1

    def test_mixed_k_single_batch(self):
        """Queries with different k share one flushed batch (one device
        launch) and each resolves against its own stratum."""
        g = gen_temporal_graph(n=30, m=240, t_max=12, seed=6)
        rng = np.random.default_rng(6)
        qs = random_stream(g, 48, rng, oob_frac=0.0)
        cfg = EngineConfig(max_batch=64, flush_ms=500.0, host_threshold=0,
                           cache_capacity=0)
        with ServingEngine(cfg) as eng:
            eng.register_graph("g", g)
            h = eng.registry.get("g")
            ks = [int(rng.choice(h.pecb.supported_ks)) for _ in qs]
            futs = eng.submit_specs(
                "g", [TCCSQuery(u, ts, te, k)
                      for (u, ts, te), k in zip(qs, ks)])
            eng.flush()
            got = [f.result(timeout=60).vertices for f in futs]
            assert eng.metrics.counter("device_batches") == 1
            for (u, ts, te), k, res in zip(qs, ks, got):
                assert res == alg1(h.pecb, u, ts, te, k=k), (k, u, ts, te)


class TestCache:
    def test_cache_hit_is_exact_and_instant(self):
        g = gen_temporal_graph(n=25, m=180, t_max=10, seed=21)
        with ServingEngine(EngineConfig(flush_ms=500.0, host_threshold=0,
                                        cache_capacity=64)) as eng:
            eng.register_graph("g", g)
            h = eng.registry.get("g")
            qs = [(u, 2, 9) for u in range(10)]
            first = run_engine(eng, "g", 2, qs)
            assert eng.metrics.counter("cache_hits") == 0
            futs = eng.submit_specs(
                "g", [TCCSQuery(u, ts, te, 2) for (u, ts, te) in qs])  # all hits
            assert all(f.done() for f in futs)   # resolved on submit path
            second = [f.result().vertices for f in futs]
            assert first == second
            assert eng.metrics.counter("cache_hits") == len(qs)
            for (u, ts, te), res in zip(qs, second):
                assert res == alg1(h.pecb, u, ts, te)

    def test_cache_lru_eviction(self):
        from repro.serving import ResultCache
        c = ResultCache(capacity=2)
        c.put("a", frozenset({1})); c.put("b", frozenset({2}))
        assert c.get("a") == frozenset({1})      # refreshes "a"
        c.put("c", frozenset({3}))               # evicts "b"
        assert c.get("b") is None
        assert c.get("a") is not None and c.get("c") is not None
        assert c.stats()["evictions"] == 1

    def test_cache_disabled(self):
        g = gen_temporal_graph(n=20, m=120, t_max=8, seed=2)
        with ServingEngine(EngineConfig(flush_ms=500.0,
                                        cache_capacity=0)) as eng:
            eng.register_graph("g", g)
            run_engine(eng, "g", 2, [(1, 1, 5)] * 3)
            assert eng.metrics.counter("cache_hits") == 0


class TestBucketing:
    def test_bucket_size(self):
        assert bucket_size(1) == 8
        assert bucket_size(8) == 8
        assert bucket_size(9) == 16
        assert bucket_size(100) == 128
        assert bucket_size(200, max_batch=256) == 256
        assert bucket_size(255, min_bucket=8, max_batch=256) == 256
        assert bucket_size(3, min_bucket=4, max_batch=16) == 4

    def test_pad_queries_inert(self):
        u, ts, te = pad_queries([5, 6], [2, 3], [7, 8], 8)
        assert u.shape == ts.shape == te.shape == (8,)
        assert list(u[:2]) == [5, 6]
        assert (te[2:] < ts[2:]).all()           # pad windows are empty

    def test_no_recompile_within_bucket(self):
        """Batch sizes 3/5/6/8 all pad to one bucket: exactly one compile;
        size 13 moves to the next bucket: exactly one more."""
        g = gen_temporal_graph(n=30, m=200, t_max=12, seed=33)
        rng = np.random.default_rng(0)
        cfg = EngineConfig(max_batch=64, flush_ms=1000.0, host_threshold=0,
                           min_bucket=8, cache_capacity=0)
        with ServingEngine(cfg) as eng:
            eng.register_graph("g", g)
            eng.registry.get("g")             # build outside measurement

            def wave(n_q):
                qs = random_stream(g, n_q, rng, oob_frac=0.0)
                futs = eng.submit_specs(
                    "g", [TCCSQuery(u, ts, te, 2) for (u, ts, te) in qs])
                eng.flush()
                [f.result(timeout=60) for f in futs]
                eng.drain()

            c0 = ShardedExecutor.compile_count()
            wave(3)
            c1 = ShardedExecutor.compile_count()
            assert c1 == c0 + 1                  # first touch of bucket 8
            for n_q in (5, 6, 8):
                wave(n_q)
            assert ShardedExecutor.compile_count() == c1   # no recompiles
            wave(13)                             # bucket 16
            assert ShardedExecutor.compile_count() == c1 + 1

    def test_warmup_non_power_of_two_max_batch(self):
        g = gen_temporal_graph(n=25, m=150, t_max=10, seed=35)
        cfg = EngineConfig(max_batch=100, flush_ms=500.0, host_threshold=0)
        with ServingEngine(cfg) as eng:
            eng.register_graph("g", g)
            eng.warmup("g")                   # must not assert on 128 > 100
            got = run_engine(eng, "g", 2, [(0, 1, 9), (1, 2, 8)])
            h = eng.registry.get("g")
            assert got[0] == alg1(h.pecb, 0, 1, 9)

    def test_warmup_precompiles_all_buckets(self):
        g = gen_temporal_graph(n=30, m=200, t_max=12, seed=34)
        cfg = EngineConfig(max_batch=32, flush_ms=1000.0, host_threshold=0,
                           min_bucket=8, cache_capacity=0)
        with ServingEngine(cfg) as eng:
            eng.register_graph("g", g)
            eng.warmup("g")                   # buckets 8, 16, 32
            c0 = ShardedExecutor.compile_count()
            rng = np.random.default_rng(1)
            for n_q in (2, 7, 12, 20, 32):
                futs = eng.submit_specs(
                    "g", [TCCSQuery(u, ts, te, 2)
                          for (u, ts, te) in random_stream(g, n_q, rng, 0.0)])
                eng.flush()
                [f.result(timeout=60) for f in futs]
                eng.drain()
            assert ShardedExecutor.compile_count() == c0


class TestPlannerRouting:
    def test_straggler_goes_host_big_goes_device(self):
        g = gen_temporal_graph(n=30, m=200, t_max=12, seed=11)
        rng = np.random.default_rng(4)
        cfg = EngineConfig(max_batch=64, flush_ms=1000.0, host_threshold=8,
                           cache_capacity=0)
        with ServingEngine(cfg) as eng:
            eng.register_graph("g", g)
            h = eng.registry.get("g")
            small = random_stream(g, 3, rng, 0.0)
            futs = eng.submit_specs(
                "g", [TCCSQuery(u, ts, te, 2) for (u, ts, te) in small])
            eng.flush(); res_small = [f.result(timeout=60).vertices for f in futs]
            eng.drain()
            assert eng.metrics.counter("host_batches") == 1
            assert eng.metrics.counter("device_batches") == 0
            big = random_stream(g, 40, rng, 0.0)
            futs = eng.submit_specs(
                "g", [TCCSQuery(u, ts, te, 2) for (u, ts, te) in big])
            eng.flush(); res_big = [f.result(timeout=60).vertices for f in futs]
            eng.drain()
            assert eng.metrics.counter("device_batches") == 1
            # both routes exact
            for (u, ts, te), r in zip(small + big, res_small + res_big):
                assert r == alg1(h.pecb, u, ts, te)


class TestRegistry:
    def test_memoize_and_evict(self):
        reg = IndexRegistry(capacity=1)
        g1 = gen_temporal_graph(n=20, m=100, t_max=8, seed=1)
        g2 = gen_temporal_graph(n=20, m=100, t_max=8, seed=2)
        reg.register_graph("g1", g1); reg.register_graph("g2", g2)
        h = reg.get("g1")
        assert reg.get("g1") is h             # memoized
        assert reg.builds == 1
        reg.get("g2")                         # evicts "g1": LRU
        assert reg.evictions == 1
        assert "g1" not in reg
        h2 = reg.get("g1")                    # rebuild (evicts "g2")
        assert h2 is not h and reg.builds == 3

    def test_rebinding_graph_name_raises(self):
        reg = IndexRegistry()
        g1 = gen_temporal_graph(n=15, m=60, t_max=6, seed=1)
        g2 = gen_temporal_graph(n=15, m=60, t_max=6, seed=2)
        reg.register_graph("g", g1)
        reg.register_graph("g", g1)              # same object: no-op
        with pytest.raises(ValueError, match="immutable"):
            reg.register_graph("g", g2)

    def test_eviction_hook_fires_outside_lock(self):
        evicted = []
        reg = IndexRegistry(capacity=1,
                            on_evict=lambda k, h: evicted.append((k, reg.stats())))
        g = gen_temporal_graph(n=15, m=80, t_max=6, seed=3)
        reg.register_graph("g", g)
        reg.get("g")
        reg.register_graph("g2",
                           gen_temporal_graph(n=15, m=80, t_max=6, seed=4))
        reg.get("g2")                         # evicts "g"
        assert [k for (k, _) in evicted] == ["g"]
        # the hook could re-enter the registry (stats() takes the lock)

    def test_engine_retires_batcher_on_eviction(self):
        g1 = gen_temporal_graph(n=20, m=100, t_max=8, seed=1)
        g2 = gen_temporal_graph(n=20, m=100, t_max=8, seed=2)
        cfg = EngineConfig(flush_ms=200.0, registry_capacity=1,
                           cache_capacity=0)
        with ServingEngine(cfg) as eng:
            eng.register_graph("g1", g1)
            eng.register_graph("g2", g2)
            eng.answer("g1", TCCSQuery(0, 1, 6, 2))
            assert "g1" in eng._batchers
            eng.answer("g2", TCCSQuery(0, 1, 6, 2))  # evicts "g1"
            assert "g1" not in eng._batchers
            assert "g2" in eng._batchers
            # re-query after eviction: rebuild + fresh batcher, exact answer
            h1 = eng.registry.get("g1")
            assert eng.answer("g1", TCCSQuery(3, 1, 6, 2)).vertices == \
                alg1(h1.pecb, 3, 1, 6)

    def test_shared_registry_retires_batchers_in_every_engine(self):
        g1 = gen_temporal_graph(n=20, m=100, t_max=8, seed=1)
        g2 = gen_temporal_graph(n=20, m=100, t_max=8, seed=2)
        reg = IndexRegistry(capacity=1)
        reg.register_graph("g1", g1); reg.register_graph("g2", g2)
        cfg = EngineConfig(flush_ms=100.0, cache_capacity=0)
        with ServingEngine(cfg, registry=reg) as a, \
             ServingEngine(cfg, registry=reg) as b:
            a.answer("g1", TCCSQuery(0, 1, 6, 2))
            b.answer("g1", TCCSQuery(1, 1, 6, 2))
            assert "g1" in a._batchers and "g1" in b._batchers
            a.answer("g2", TCCSQuery(0, 1, 6, 2))  # evicts "g1"
            assert "g1" not in a._batchers
            assert "g1" not in b._batchers        # B's listener fired too

    def test_unknown_workload_raises(self):
        with pytest.raises(KeyError):
            IndexRegistry().get("no_such_graph")

    def test_bench_workload_resolves_by_name(self):
        reg = IndexRegistry()
        g = reg.resolve_graph("fb_like")
        assert g.n == 300


class TestBatcher:
    def test_deadline_flush(self):
        b = MicroBatcher(lambda reqs: [len(reqs)] * len(reqs),
                         max_batch=64, flush_ms=30.0)
        try:
            fut = b.submit(Request(0, 1, 1, Future(), time.perf_counter()))
            assert fut.result(timeout=5) == 1    # deadline fired, batch of 1
        finally:
            b.close()

    def test_full_batch_flushes_immediately(self):
        b = MicroBatcher(lambda reqs: [len(reqs)] * len(reqs),
                         max_batch=4, flush_ms=10_000.0)
        try:
            t0 = time.perf_counter()
            futs = b.submit_many([Request(i, 1, 1, Future(), t0) for i in range(4)])
            assert [f.result(timeout=5) for f in futs] == [4] * 4
            assert time.perf_counter() - t0 < 5.0   # did not wait 10s
        finally:
            b.close()

    def test_idle_flush_does_not_leak_into_next_deadline(self):
        b = MicroBatcher(lambda reqs: [len(reqs)] * len(reqs),
                         max_batch=64, flush_ms=500.0)
        try:
            b.flush()                            # idle: must be a no-op
            fut = b.submit(Request(0, 1, 1, Future(), time.perf_counter()))
            time.sleep(0.1)
            assert not fut.done()                # still inside the window
            b.flush()
            assert fut.result(timeout=5) == 1
        finally:
            b.close()

    def test_execute_error_fails_futures(self):
        def boom(reqs):
            raise ValueError("kaput")
        b = MicroBatcher(boom, max_batch=4, flush_ms=5.0)
        try:
            fut = b.submit(Request(0, 1, 1, Future(), time.perf_counter()))
            with pytest.raises(ValueError, match="kaput"):
                fut.result(timeout=5)
        finally:
            b.close()

    def test_close_flushes_pending(self):
        b = MicroBatcher(lambda reqs: [r.u for r in reqs],
                         max_batch=64, flush_ms=10_000.0)
        futs = b.submit_many([Request(i, 1, 1, Future(), time.perf_counter())
                              for i in range(3)])
        b.close()
        assert [f.result(timeout=1) for f in futs] == [0, 1, 2]


class TestMetrics:
    def test_histogram_percentiles(self):
        h = LatencyHistogram()
        for i in range(1, 101):
            h.add(i / 1e3)                       # 1..100 ms
        s = h.summary()
        assert s["count"] == 100
        assert abs(s["p50_ms"] - 50) <= 2
        assert abs(s["p95_ms"] - 95) <= 2
        assert abs(s["p99_ms"] - 99) <= 2
        assert abs(s["mean_ms"] - 50.5) < 0.1

    def test_engine_records_stages(self):
        g = gen_temporal_graph(n=20, m=120, t_max=8, seed=6)
        with ServingEngine(EngineConfig(flush_ms=200.0, host_threshold=0,
                                        cache_capacity=8)) as eng:
            eng.register_graph("g", g)
            run_engine(eng, "g", 2, [(1, 1, 5), (2, 1, 5)])
            eng.submit_spec("g", TCCSQuery(1, 1, 5, 2)).result(timeout=10)  # cache hit
            snap = eng.stats()
            lat = snap["engine"]["latency"]
            assert lat["e2e"]["count"] == 3
            assert lat["queue_wait"]["count"] == 2
            assert "device_exec" in lat
            assert snap["engine"]["counters"]["cache_hits"] == 1
            assert snap["cache"]["size"] == 2
            assert snap["devices"] >= 1


@pytest.mark.slow
def test_engine_multi_device_sharded():
    """The whole engine under a forced 8-CPU-device topology: the executor
    takes the sharded path and answers stay exact."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np
        import jax
        assert jax.device_count() == 8
        from repro.core.temporal_graph import gen_temporal_graph
        from repro.serving import EngineConfig, ServingEngine
        g = gen_temporal_graph(n=40, m=250, t_max=15, seed=1)
        cfg = EngineConfig(max_batch=64, flush_ms=500.0, host_threshold=0,
                           cache_capacity=0)
        with ServingEngine(cfg) as eng:
            assert eng.executor.num_devices == 8
            assert eng.executor.batch_sharding is not None
            eng.register_graph("g", g)
            h = eng.registry.get("g")
            rng = np.random.default_rng(0)
            qs = [(int(rng.integers(0, g.n)), int(rng.integers(1, g.t_max)),
                   int(rng.integers(1, g.t_max + 1))) for _ in range(48)]
            from repro.serving import TCCSQuery
            futs = eng.submit_specs(
                "g", [TCCSQuery(u, ts, te, 2) if ts <= te
                      else TCCSQuery(u, 1, 0, 2) for (u, ts, te) in qs])
            eng.flush()
            got = [f.result(timeout=120).vertices for f in futs]
            for (u, ts, te), res in zip(qs, got):
                assert res == frozenset(
                    h.pecb.slice_k(2)._component_vertices(u, ts, te))
        print("sharded engine ok")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    assert "sharded engine ok" in res.stdout


class TestAsyncRegistry:
    """PR-2: background index builds (DESIGN.md §7.4)."""

    def test_builds_counter_survives_concurrent_cold_keys(self):
        """The builds counter is a read-modify-write under the registry
        lock; hammering many distinct cold keys from many threads must not
        lose updates."""
        import threading

        reg = IndexRegistry(capacity=32, build_workers=8)
        names = []
        for i in range(8):
            name = f"g{i}"
            reg.register_graph(name, gen_temporal_graph(
                n=12, m=50, t_max=5, seed=i))
            names.append(name)
        start = threading.Barrier(16)

        def hammer(name):
            start.wait()
            for _ in range(4):
                reg.get(name)

        threads = [threading.Thread(target=hammer, args=(name,))
                   for name in names for _ in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert reg.builds == len(names)
        reg.close()

    def test_get_nowait_miss_then_hit(self):
        reg = IndexRegistry()
        reg.register_graph("g", gen_temporal_graph(n=12, m=50, t_max=5, seed=0))
        assert reg.get_nowait("g", start_build=False) is None
        assert "g" not in reg
        h = reg.get_nowait("g")              # miss, but schedules the build
        assert h is None
        built = reg.get_async("g").result(timeout=60)
        assert reg.get_nowait("g") is built
        reg.close()

    def test_get_async_coalesces_thundering_herd(self):
        reg = IndexRegistry()
        reg.register_graph("g", gen_temporal_graph(n=14, m=60, t_max=6, seed=1))
        futs = [reg.get_async("g") for _ in range(6)]
        handles = {id(f.result(timeout=60)) for f in futs}
        assert len(handles) == 1 and reg.builds == 1
        reg.close()

    def test_build_failure_surfaces_on_future(self):
        reg = IndexRegistry()
        with pytest.raises(KeyError):
            reg.get_async("no_such_graph").result(timeout=60)
        assert reg.builds == 0
        # the failed key is not stuck pending: a later register succeeds
        reg.register_graph("no_such_graph",
                           gen_temporal_graph(n=10, m=40, t_max=4, seed=2))
        assert reg.get("no_such_graph").pecb is not None
        reg.close()

    def test_build_stage_metrics_recorded(self):
        from repro.serving.metrics import EngineMetrics

        metrics = EngineMetrics()
        reg = IndexRegistry(metrics=metrics)
        reg.register_graph("g", gen_temporal_graph(n=14, m=70, t_max=6, seed=3))
        h = reg.get("g")
        assert set(h.build_stages) == {"core_times", "forest", "device"}
        assert all(v >= 0 for v in h.build_stages.values())
        snap = metrics.snapshot()
        for stage in ("core_times", "forest", "device"):
            assert snap["latency"][f"index_build_{stage}"]["count"] == 1
        reg.close()

    def test_cold_submit_does_not_block_on_build(self):
        """A cold (workload, k) submit returns before the build completes;
        the queries resolve once the background build installs the index."""
        import threading

        release = threading.Event()

        class SlowRegistry(IndexRegistry):
            def _build(self, key):
                release.wait(timeout=60)        # simulate a long offline build
                return super()._build(key)

        g = gen_temporal_graph(n=15, m=70, t_max=6, seed=4)
        reg = SlowRegistry()
        reg.register_graph("g", g)
        cfg = EngineConfig(flush_ms=5.0)
        with ServingEngine(cfg, registry=reg) as eng:
            t0 = time.perf_counter()
            fut = eng.submit_spec("g", TCCSQuery(0, 1, 6, 2))
            submitted_in = time.perf_counter() - t0
            assert submitted_in < 30            # returned while build blocked
            assert not fut.done()
            release.set()
            want = alg1(reg.get("g").pecb, 0, 1, 6)
            assert fut.result(timeout=60).vertices == want
        reg.close()

    def test_engine_prefetch_warms_registry(self):
        g = gen_temporal_graph(n=15, m=70, t_max=6, seed=5)
        with ServingEngine(EngineConfig()) as eng:
            eng.register_graph("g", g)
            eng.prefetch("g").result(timeout=60)
            assert "g" in eng.registry
            assert eng.registry.stats()["pending"] == []
