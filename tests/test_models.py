"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs — for every assigned arch x shape
kind. Plus equivariance property tests for the geometric GNNs."""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import repro.configs as C
from repro.models import gnn, transformer as tfm, recsys
from repro.optim import adamw


def smoke_batch(spec, shape_name, cfg, dims, rng):
    """Concrete arrays matching input_specs(smoke dims)."""
    specs = C.input_specs(spec, shape_name, dims=dims, model_cfg=cfg)

    def mk(path, s):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if s.dtype == jnp.int32:
            if name in ("src", "dst"):
                n = dims["n"]
                return jnp.asarray(rng.integers(0, n, s.shape), jnp.int32)
            if name == "graph_id":
                per = dims["n"] // dims["graphs"]
                return jnp.repeat(jnp.arange(dims["graphs"], dtype=jnp.int32), per)
            if name == "labels":
                return jnp.asarray(rng.integers(0, getattr(cfg, "n_classes", 5), s.shape), jnp.int32)
            if name in ("hist_ids", "target_id", "cand_ids"):
                return jnp.asarray(rng.integers(0, cfg.n_items, s.shape), jnp.int32)
            if name == "cache_len":
                return jnp.int32(3)
            return jnp.asarray(rng.integers(0, getattr(cfg, "vocab", 100), s.shape), jnp.int32)
        if s.dtype == jnp.bool_:
            return jnp.asarray(rng.random(s.shape) < 0.5)
        if name == "hist_mask":
            return jnp.ones(s.shape, jnp.float32)
        return jnp.asarray(rng.normal(size=s.shape) * 0.5, s.dtype)

    return jax.tree_util.tree_map_with_path(mk, specs)


ALL_CELLS = sorted(C.all_cells())

# Heavy shapes run in the scheduled slow CI job; every arch keeps at least
# one cheap shape (prefill/decode/molecule/train_batch) in the fast job.
_SLOW_SHAPES = {"train_4k", "full_graph_sm", "ogb_products", "minibatch_lg"}


@pytest.mark.parametrize(
    "arch_id,shape_name",
    [pytest.param(a, s, id=f"{a}-{s}",
                  marks=[pytest.mark.slow] if s in _SLOW_SHAPES else [])
     for a, s in ALL_CELLS])
def test_cell_smoke(arch_id, shape_name):
    spec = C.get(arch_id)
    dims = C.smoke_dims(spec, shape_name)
    cfg = C.cell_model_cfg(spec, shape_name, smoke=True)
    rng = np.random.default_rng(hash((arch_id, shape_name)) % 2**31)
    batch = smoke_batch(spec, shape_name, cfg, dims, rng)
    params = C.init_params(spec, cfg, jax.random.PRNGKey(0))

    if dims["kind"] == "train":
        opt = adamw.init_state(params)
        step = C.make_train_step(spec, cfg)
        params2, opt2, metrics = jax.jit(step)(params, opt, batch)
        loss = float(metrics["loss"])
        assert np.isfinite(loss), (arch_id, shape_name, loss)
        # the update actually moved the params
        moved = jax.tree.reduce(
            lambda acc, pq: acc + float(jnp.sum(jnp.abs(pq))),
            jax.tree.map(lambda a, b: (a.astype(jnp.float32) - b.astype(jnp.float32)), params, params2),
            0.0)
        assert moved > 0
    else:
        step = C.make_serve_step(spec, shape_name, cfg)
        out = jax.jit(step)(params, batch)
        flat = jax.tree.leaves(out)
        assert flat, (arch_id, shape_name)
        for leaf in flat:
            assert np.isfinite(np.asarray(leaf, np.float32)).all()
        if spec.family.startswith("lm") and dims["kind"] == "decode":
            logits, cache = out
            assert logits.shape == (dims["batch"], cfg.vocab)
            assert cache["k"].shape[0] == cfg.n_layer
        if spec.family == "recsys" and dims["kind"] == "serve":
            assert out.shape == (dims["batch"], dims["cands"])


class TestLMDetails:
    def test_scan_equals_unroll(self):
        cfg = C.get("glm4-9b").smoke_cfg
        cfg_u = dataclasses.replace(cfg, unroll=True)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
        l1, _ = tfm.forward(params, cfg, toks)
        l2, _ = tfm.forward(params, cfg_u, toks)
        # bf16 params: scan vs unroll fuse differently; tolerate bf16 noise
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=3e-2, atol=3e-2)

    def test_decode_matches_forward(self):
        """Greedy decode over a prefix reproduces teacher-forced logits.

        f32 so the check is semantic (bf16 rounding differs between the
        cached and teacher-forced paths by up to ~3e-2)."""
        cfg = dataclasses.replace(C.get("codeqwen1.5-7b").smoke_cfg,
                                  remat=False, dtype=jnp.float32)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        B, S = 2, 8
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
        full_logits, _ = tfm.forward(params, cfg, toks)
        cache = tfm.init_cache(cfg, B, S + 1, dtype=jnp.float32)
        for i in range(S):
            step_logits, cache = tfm.decode_step(params, cfg, toks[:, i:i+1],
                                                 cache, jnp.int32(i))
            np.testing.assert_allclose(np.asarray(step_logits),
                                       np.asarray(full_logits[:, i]),
                                       rtol=2e-3, atol=2e-3)

    def test_moe_capacity_drop_is_bounded(self):
        """With cf=1.25 and near-uniform routing, most tokens survive."""
        mcfg = tfm.MoEConfig(n_experts=4, top_k=2, d_ff_expert=16, capacity_factor=1.25)
        cfg = tfm.LMConfig("m", n_layer=1, d_model=32, n_head=2, n_kv=2,
                           d_ff=0, vocab=64, d_head=16, moe=mcfg,
                           dtype=jnp.float32, remat=False)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, 64)
        logits, aux = tfm.forward(params, cfg, toks)
        assert np.isfinite(np.asarray(logits)).all()
        assert float(aux) > 0


class TestEquivariance:
    @pytest.mark.parametrize(
        "arch", [pytest.param("nequip", marks=pytest.mark.slow), "mace"])
    def test_energy_invariance_force_equivariance(self, arch):
        spec = C.get(arch)
        cfg = dataclasses.replace(spec.smoke_cfg, d_species=8)
        fwd = {"nequip": gnn.nequip_forward, "mace": gnn.mace_forward}[arch]
        params = C.init_params(spec, cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        n, E, ng = 20, 60, 2
        pos = jnp.asarray(rng.normal(size=(n, 3)) * 2)
        batch = {
            "node_feat": jnp.asarray(rng.normal(size=(n, 8)), jnp.float32),
            "pos": pos,
            "src": jnp.asarray(rng.integers(0, n, E), jnp.int32),
            "dst": jnp.asarray((rng.integers(1, n, E))) % n,
            "graph_id": jnp.repeat(jnp.arange(ng), n // ng),
            "energy_target": jnp.zeros(ng), "force_target": jnp.zeros((n, 3)),
        }

        def forces(b):
            def efn(p):
                e, _ = fwd(params, cfg, {**b, "pos": p}, n_graphs=ng)
                return jnp.sum(e)
            return -jax.grad(efn)(b["pos"])

        th = 0.9
        R = jnp.asarray([[np.cos(th), -np.sin(th), 0],
                         [np.sin(th), np.cos(th), 0], [0, 0, 1.0]])
        e1, _ = fwd(params, cfg, batch, n_graphs=ng)
        e2, _ = fwd(params, cfg, {**batch, "pos": pos @ R.T}, n_graphs=ng)
        np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-4, atol=1e-4)
        f1 = forces(batch)
        f2 = forces({**batch, "pos": pos @ R.T})
        np.testing.assert_allclose(np.asarray(f1 @ R.T), np.asarray(f2),
                                   rtol=1e-3, atol=1e-3)


class TestMIND:
    def test_interest_count_and_scores(self):
        cfg = recsys.MINDConfig(n_items=256, embed_dim=16, hist_len=6)
        params = recsys.mind_init(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        b = {"hist_ids": jnp.asarray(rng.integers(0, 256, (5, 6)), jnp.int32),
             "hist_mask": jnp.ones((5, 6))}
        interests = recsys.user_interests(params, cfg, b["hist_ids"], b["hist_mask"])
        assert interests.shape == (5, cfg.n_interests, 16)
        scores = recsys.mind_serve(params, cfg, {**b, "cand_ids": jnp.arange(12)[None].repeat(5, 0)})
        assert scores.shape == (5, 12)
        assert np.isfinite(np.asarray(scores)).all()

    def test_vocab_parallel_take_matches_dense(self):
        """make_vp_take on a 1x1 mesh == plain take (semantics check)."""
        from repro.launch.mesh import make_smoke_mesh
        from repro.runtime.sharding import make_vp_take
        mesh = make_smoke_mesh()
        take = make_vp_take(mesh, leading=None)
        table = jnp.asarray(np.random.default_rng(0).normal(size=(64, 8)), jnp.float32)
        ids = jnp.asarray([[1, 5], [63, 0]], jnp.int32)
        np.testing.assert_allclose(np.asarray(take(table, ids)),
                                   np.asarray(jnp.take(table, ids, axis=0)))
