"""Observability-plane tests (DESIGN.md §11): span-context propagation
across the batcher and registry FIFO-refresh thread boundaries, Chrome
trace export schema, unified metrics snapshot round-trip, slow-query log,
compile-event tracking, histogram thread safety, bench artifact schema."""

import json
import threading
import time

import numpy as np
import pytest

from repro.core.query_api import EMPTY_WINDOW, TCCSQuery, WindowSweep
from repro.core.temporal_graph import gen_temporal_graph
from repro.obs import (NULL_SPAN, LatencyHistogram, MetricsRegistry,
                       SlowQueryLog, Tracer, chrome_trace_events,
                       metrics_from_json, metrics_to_json,
                       validate_chrome_trace, write_chrome_trace)
from repro.obs.export import trace_document
from repro.serving import EngineConfig, EngineMetrics, ServingEngine


# ----------------------------------------------------------------------
# LatencyHistogram: thread safety + interpolated percentiles
# ----------------------------------------------------------------------

class TestLatencyHistogram:
    def test_concurrent_adds_lose_nothing(self):
        """The §11.4 audit regression: adds from many threads land under
        the histogram's own lock — exact count/total, no dropped or
        duplicated reservoir slots below the cap."""
        h = LatencyHistogram(cap=100_000)
        n_threads, per_thread = 8, 2_000

        def feed(t):
            for i in range(per_thread):
                h.add((t * per_thread + i) * 1e-6)

        threads = [threading.Thread(target=feed, args=(t,))
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        total_n = n_threads * per_thread
        assert h.count == total_n
        assert h.total == pytest.approx(
            sum(i * 1e-6 for i in range(total_n)))
        assert len(h._samples) == total_n     # under cap: every sample kept

    def test_concurrent_adds_respect_reservoir_cap(self):
        h = LatencyHistogram(cap=64)
        threads = [threading.Thread(
            target=lambda: [h.add(0.001) for _ in range(500)])
            for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert h.count == 2_000
        assert len(h._samples) == 64

    def test_linear_interpolation_matches_numpy(self):
        h = LatencyHistogram()
        samples = [0.010, 0.020, 0.030, 0.040]
        for s in samples:
            h.add(s)
        for q in (0, 25, 50, 75, 90, 99, 100):
            assert h.percentile(q) == pytest.approx(
                float(np.percentile(samples, q)))
        # p50 of 4 samples interpolates between the middle two — the
        # nearest-rank convention would snap to one of them
        assert h.percentile(50) == pytest.approx(0.025)

    def test_empty_summary(self):
        s = LatencyHistogram().summary()
        assert s["count"] == 0 and s["p99_ms"] == 0.0


# ----------------------------------------------------------------------
# Tracer: span trees, propagation rules, ring bounds
# ----------------------------------------------------------------------

class TestTracer:
    def test_root_and_explicit_child(self):
        tr = Tracer()
        root = tr.start_span("query", parent=None)
        assert root.trace_id == root.span_id and root.parent_id is None
        child = root.child("queue")
        child.end()
        root.end()
        assert child.trace_id == root.trace_id
        assert child.parent_id == root.span_id
        assert {s.name for s in tr.spans(trace_id=root.trace_id)} == \
            {"query", "queue"}

    def test_implicit_thread_local_parent(self):
        tr = Tracer()
        with tr.span("outer") as outer:
            inner = tr.start_span("inner")
            inner.end()
        assert inner.parent_id == outer.span_id
        # after exit nothing is current: new spans are roots
        after = tr.start_span("after")
        after.end()
        assert after.parent_id is None

    def test_context_does_not_leak_across_threads(self):
        tr = Tracer()
        seen = {}

        def worker():
            s = tr.start_span("w")     # no explicit parent, other thread
            s.end()
            seen["parent"] = s.parent_id

        with tr.span("outer"):
            t = threading.Thread(target=worker)
            t.start()
            t.join()
        assert seen["parent"] is None   # thread identity means nothing

    def test_cross_thread_explicit_ctx(self):
        tr = Tracer()
        root = tr.start_span("root", parent=None)
        out = {}

        def worker(ctx):
            s = tr.start_span("bg", parent=ctx)
            s.end()
            out["ids"] = (s.trace_id, s.parent_id)

        t = threading.Thread(target=worker, args=(root.ctx,))
        t.start()
        t.join()
        assert out["ids"] == (root.trace_id, root.span_id)

    def test_ring_buffer_bounds_and_drop_count(self):
        tr = Tracer(capacity=10)
        for i in range(25):
            tr.start_span(f"s{i}", parent=None).end()
        assert len(tr) == 10
        assert tr.dropped == 15
        assert [s.name for s in tr.spans()] == [f"s{i}" for i in range(15, 25)]

    def test_disabled_tracer_hands_out_null_span(self):
        tr = Tracer(enabled=False)
        s = tr.start_span("x")
        assert s is NULL_SPAN
        assert s.child("y") is NULL_SPAN and s.set("a", 1) is NULL_SPAN
        assert s.ids == (None, None) and s.ctx is None
        s.end()
        assert len(tr) == 0

    def test_end_is_idempotent_and_clamps(self):
        tr = Tracer()
        s = tr.start_span("x", parent=None)
        s.end()
        first = s.t_end
        s.end()
        assert s.t_end == first and len(tr) == 1
        # retrospective span whose end predates its (backdated) start
        t_now = time.perf_counter()
        s2 = tr.start_span("y", parent=None, t0=t_now + 10.0)
        s2.end(t_now)
        assert s2.t_end == s2.t_start

    def test_error_recorded_on_context_exit(self):
        tr = Tracer()
        with pytest.raises(ValueError):
            with tr.span("boom"):
                raise ValueError("nope")
        (s,) = tr.spans()
        assert "nope" in s.attrs["error"]


# ----------------------------------------------------------------------
# Chrome trace export
# ----------------------------------------------------------------------

class TestChromeExport:
    def _tracer_with_tree(self):
        tr = Tracer()
        root = tr.start_span("query", parent=None, u=3)
        root.child("queue").end()
        root.child("execute", route="device", bucket=8).end()
        root.end()
        return tr

    def test_export_schema_and_linkage(self, tmp_path):
        tr = self._tracer_with_tree()
        path = tmp_path / "trace.json"
        doc = write_chrome_trace(str(path), tr)
        assert validate_chrome_trace(doc) == len(doc["traceEvents"])
        on_disk = json.loads(path.read_text())
        assert validate_chrome_trace(on_disk) == len(doc["traceEvents"])
        x = [e for e in on_disk["traceEvents"] if e["ph"] == "X"]
        assert {e["name"] for e in x} == {"query", "queue", "execute"}
        root = next(e for e in x if e["name"] == "query")
        for e in x:
            assert e["args"]["trace_id"] == root["args"]["span_id"]
        child = next(e for e in x if e["name"] == "queue")
        assert child["args"]["parent_id"] == root["args"]["span_id"]
        meta = [e for e in on_disk["traceEvents"] if e["ph"] == "M"]
        assert meta and all(e["name"] == "thread_name" for e in meta)
        assert on_disk["otherData"]["dropped_spans"] == 0

    def test_open_spans_are_skipped(self):
        tr = Tracer()
        root = tr.start_span("open", parent=None)
        root.child("done").end()
        events = chrome_trace_events(tr.spans(), t0=tr.t0)
        assert {e["name"] for e in events if e["ph"] == "X"} == {"done"}

    def test_validator_rejects_malformed(self):
        good = trace_document(self._tracer_with_tree())
        with pytest.raises(ValueError):
            validate_chrome_trace({"notTraceEvents": []})
        with pytest.raises(ValueError):
            validate_chrome_trace(42)
        bad = json.loads(json.dumps(good))
        bad["traceEvents"][0]["ph"] = "Z"
        with pytest.raises(ValueError):
            validate_chrome_trace(bad)
        bad = json.loads(json.dumps(good))
        bad["traceEvents"][0]["ts"] = -5
        with pytest.raises(ValueError):
            validate_chrome_trace(bad)
        bad = json.loads(json.dumps(good))
        del bad["traceEvents"][0]["name"]
        with pytest.raises(ValueError):
            validate_chrome_trace(bad)

    def test_nonjson_attrs_flatten(self):
        tr = Tracer()
        s = tr.start_span("x", parent=None, key=("feed", 2),
                          obj=object())
        s.end()
        doc = trace_document(tr)
        validate_chrome_trace(doc)       # round-trips despite exotic attrs


# ----------------------------------------------------------------------
# MetricsRegistry + snapshot export
# ----------------------------------------------------------------------

class TestMetricsRegistry:
    def test_counters_gauges_hists_sources(self):
        m = MetricsRegistry()
        m.count("queries")
        m.count("queries", 4)
        m.gauge("depth", 7)
        m.gauge("lazy", lambda: 42)
        m.observe("e2e", 0.010)
        m.register_source("cache", lambda: {"size": 3})
        snap = m.snapshot()
        assert snap["counters"]["queries"] == 5
        assert snap["gauges"] == {"depth": 7, "lazy": 42}
        assert snap["latency"]["e2e"]["count"] == 1
        assert snap["sources"]["cache"] == {"size": 3}
        assert "sources" not in m.snapshot(include_sources=False)
        m.reset()
        assert m.counter("queries") == 0
        assert m.snapshot()["sources"]["cache"] == {"size": 3}  # sources stay

    def test_engine_metrics_is_registry(self):
        assert issubclass(EngineMetrics, MetricsRegistry)

    def test_json_round_trip(self):
        m = MetricsRegistry()
        m.count("a", 3)
        m.observe("lat", 0.002)
        m.register_source("reg", lambda: {
            "resident": [("feed", 2)], "bytes": np.int64(128)})
        snap = m.snapshot()
        back = metrics_from_json(metrics_to_json(snap))
        assert back["counters"]["a"] == 3
        assert back["latency"]["lat"]["count"] == 1
        assert back["sources"]["reg"]["resident"] == [["feed", 2]]
        assert back["sources"]["reg"]["bytes"] == 128

    def test_non_string_keys_rejected(self):
        with pytest.raises(ValueError):
            metrics_to_json({"sources": {("feed", 2): 1}})


# ----------------------------------------------------------------------
# Engine integration: the full foreground span chain
# ----------------------------------------------------------------------

def _graph(seed=51):
    return gen_temporal_graph(n=40, m=420, t_max=18, seed=seed)


def _names_by_trace(tracer):
    out = {}
    for s in tracer.spans():
        out.setdefault(s.trace_id, set()).add(s.name)
    return out


class TestEngineTracing:
    def test_full_chain_and_provenance_linkage(self):
        g = _graph()
        cfg = EngineConfig(flush_ms=0.5, host_threshold=0, cache_capacity=0)
        with ServingEngine(cfg) as eng:
            eng.register_graph("g", g)
            eng.warmup("g")
            futs = eng.submit_specs(
                "g", [TCCSQuery(u, 1, g.t_max, 2) for u in range(24)])
            eng.flush()
            results = [f.result(timeout=60) for f in futs]
            by_trace = _names_by_trace(eng.tracer)
            for r in results:
                prov = r.provenance
                assert prov.trace_id is not None
                # provenance links the ROOT query span
                roots = [s for s in eng.tracer.spans(trace_id=prov.trace_id)
                         if s.span_id == prov.span_id]
                assert len(roots) == 1 and roots[0].name == "query"
                assert roots[0].attrs["route"] == "device"
                assert {"query", "queue", "route", "execute"} <= \
                    by_trace[prov.trace_id]

    def test_queue_span_crosses_batcher_thread(self):
        """The root span starts on the caller thread; queue/route/execute
        children are recorded from the batcher worker — same trace, two
        distinct thread ids (explicit ctx propagation, §11.2)."""
        g = _graph()
        cfg = EngineConfig(flush_ms=0.5, host_threshold=0, cache_capacity=0)
        with ServingEngine(cfg) as eng:
            eng.register_graph("g", g)
            eng.warmup("g")
            futs = eng.submit_specs(
                "g", [TCCSQuery(u, 1, g.t_max, 2) for u in range(12)])
            eng.flush()
            res = [f.result(timeout=60) for f in futs]
            tr_id = res[0].provenance.trace_id
            spans = {s.name: s for s in eng.tracer.spans(trace_id=tr_id)}
            root, q = spans["query"], spans["queue"]
            assert q.parent_id == root.span_id
            assert q.tid != root.tid
            assert "batcher" in q.thread_name
            # the retrospective queue span covers the enqueue -> execute gap
            assert q.t_start >= root.t_start
            assert spans["execute"].attrs["route"] == "device"

    def test_cache_hit_and_trivial_routes_are_traced(self):
        g = _graph()
        with ServingEngine(EngineConfig(flush_ms=0.5)) as eng:
            eng.register_graph("g", g)
            eng.warmup("g")
            spec = TCCSQuery(3, 1, g.t_max, 2)
            r1 = eng.answer("g", spec)
            r2 = eng.answer("g", spec)              # cache hit
            assert r2.provenance.route == "cache"
            assert r2.provenance.trace_id != r1.provenance.trace_id
            names = _names_by_trace(eng.tracer)[r2.provenance.trace_id]
            assert names == {"query", "cache"}
            r3 = eng.answer("g", TCCSQuery(3, *EMPTY_WINDOW, 2))
            assert r3.provenance.route == "trivial"
            assert r3.provenance.trace_id is not None
            roots = eng.tracer.spans(trace_id=r3.provenance.trace_id)
            assert roots[0].attrs["route"] == "trivial"

    def test_host_route_chain(self):
        g = _graph()
        cfg = EngineConfig(flush_ms=0.5, host_threshold=512,
                           cache_capacity=0)
        with ServingEngine(cfg) as eng:
            eng.register_graph("g", g)
            r = eng.answer("g", TCCSQuery(5, 1, g.t_max, 2))
            spans = {s.name: s
                     for s in eng.tracer.spans(trace_id=r.provenance.trace_id)}
            assert spans["execute"].attrs["route"] == "host"
            assert spans["query"].span_id == r.provenance.span_id

    def test_sweep_root_span(self):
        g = _graph()
        with ServingEngine(EngineConfig(flush_ms=0.5)) as eng:
            eng.register_graph("g", g)
            eng.warmup("g", sweep=True, sweep_ks=(2,))
            res = eng.sweep("g", WindowSweep(
                u=3, k=2, windows=[(t, min(t + 4, g.t_max))
                                   for t in range(1, 14)]))
            tr_id = next(r.provenance.trace_id for r in res
                         if r.provenance.route == "sweep")
            spans = eng.tracer.spans(trace_id=tr_id)
            root = next(s for s in spans if s.name == "sweep")
            assert root.attrs["windows"] == 13
            ex = [s for s in spans if s.name == "execute"]
            assert ex and all(s.parent_id == root.span_id for s in ex)

    def test_tracing_disabled_serves_identically(self):
        g = _graph()
        cfg = EngineConfig(flush_ms=0.5, trace=False)
        with ServingEngine(cfg) as eng:
            eng.register_graph("g", g)
            r = eng.answer("g", TCCSQuery(5, 1, g.t_max, 2))
            assert r.provenance.trace_id is None
            assert len(eng.tracer) == 0
            assert eng.stats()["trace"]["enabled"] is False

    def test_engine_export_and_unified_snapshot(self, tmp_path):
        g = _graph()
        with ServingEngine(EngineConfig(flush_ms=0.5)) as eng:
            eng.register_graph("g", g)
            eng.answer("g", TCCSQuery(5, 1, g.t_max, 2))
            doc = eng.export_trace(str(tmp_path / "t.json"))
            assert validate_chrome_trace(doc) > 0
            snap = eng.metrics.snapshot()
            assert set(snap["sources"]) == {"cache", "registry"}
            assert snap["sources"]["cache"]["size"] >= 1
            assert snap["sources"]["registry"]["builds"] == 1
            metrics_from_json(metrics_to_json(snap))   # exports clean
            s = eng.stats()
            assert s["trace"]["spans"] == len(eng.tracer)
            assert s["slow_queries"] == 0


# ----------------------------------------------------------------------
# Background planes: builds, ingest refresh, retention
# ----------------------------------------------------------------------

class TestBackgroundTracing:
    def test_index_build_span_from_build_pool(self):
        g = _graph()
        with ServingEngine(EngineConfig(flush_ms=0.5)) as eng:
            eng.register_graph("g", g)
            eng.registry.get("g")
            (b,) = eng.tracer.spans(name="index_build")
            assert b.cat == "index" and b.parent_id is None
            assert "build-pool" in b.thread_name
            kids = [s for s in eng.tracer.spans()
                    if s.parent_id == b.span_id]
            assert {s.name for s in kids} == \
                {"core_times", "forest", "device"}

    def test_ingest_refresh_parented_across_fifo_worker(self):
        """A query racing an ingest: the query's spans pin the old epoch
        while the concurrent index_refresh span — recorded from the FIFO
        refresh worker thread — parents under the caller's ingest span."""
        g = _graph()
        with ServingEngine(EngineConfig(flush_ms=0.5)) as eng:
            eng.register_graph("g", g)
            eng.warmup("g")
            suffix = [(0, 1, g.t_max + 1), (1, 2, g.t_max + 2)]
            futures = eng.ingest("g", suffix)
            r = eng.answer("g", TCCSQuery(3, 1, g.t_max, 2))
            for f in futures.values():
                f.result(timeout=60)
            (ing,) = eng.tracer.spans(name="ingest")
            (ref,) = eng.tracer.spans(name="index_refresh")
            assert ing.cat == "epoch"
            assert ref.trace_id == ing.trace_id
            assert ref.parent_id == ing.span_id
            assert ref.tid != ing.tid
            assert "registry-refresh" in ref.thread_name
            assert ref.attrs["swapped"] is True and ref.attrs["epoch"] == 1
            stage_names = {s.name for s in eng.tracer.spans()
                           if s.parent_id == ref.span_id}
            assert stage_names == {"core_times", "forest", "device"}
            # the concurrent query is a separate trace with a full chain
            q_names = _names_by_trace(eng.tracer)[r.provenance.trace_id]
            assert "query" in q_names and r.provenance.trace_id != ing.trace_id

    def test_retention_span_parented_under_retain(self):
        g = _graph()
        with ServingEngine(EngineConfig(flush_ms=0.5)) as eng:
            eng.register_graph("g", g)
            eng.warmup("g")
            eng.retain("g", 6, wait=True)
            (ret,) = eng.tracer.spans(name="retain")
            (trim,) = eng.tracer.spans(name="index_retention")
            assert trim.trace_id == ret.trace_id
            assert trim.parent_id == ret.span_id
            assert trim.attrs["t_cut"] == 6 and trim.attrs["swapped"] is True


# ----------------------------------------------------------------------
# Slow-query log + compile tracking
# ----------------------------------------------------------------------

class TestSlowQueriesAndCompiles:
    def test_slow_query_log_captures_tree(self):
        g = _graph()
        cfg = EngineConfig(flush_ms=0.5, cache_capacity=0,
                           slow_query_ms=0.0)    # everything is "slow"
        with ServingEngine(cfg) as eng:
            eng.register_graph("g", g)
            eng.answer("g", TCCSQuery(5, 1, g.t_max, 2))
            assert len(eng.slow_queries) == 1
            (entry,) = eng.slow_queries.entries()
            assert "TCCSQuery" in entry["query"]
            assert entry["duration_ms"] >= 0
            names = {s["name"] for s in entry["spans"]}
            assert "query" in names and "execute" in names
            assert "slow query" in eng.slow_queries.format()

    def test_slow_query_log_threshold_filters(self):
        g = _graph()
        cfg = EngineConfig(flush_ms=0.5, slow_query_ms=60_000.0)
        with ServingEngine(cfg) as eng:
            eng.register_graph("g", g)
            eng.answer("g", TCCSQuery(5, 1, g.t_max, 2))
            assert len(eng.slow_queries) == 0

    def test_disabled_by_default(self):
        log = SlowQueryLog()
        assert not log.enabled
        assert log.observe(NULL_SPAN) is False

    def test_compile_events_recorded(self):
        """A fresh graph shape forces an XLA compile; the executor records
        it as a counter + a "compile"-category span (cache-size delta)."""
        # unusual n/t_max => shapes no earlier test compiled
        g = gen_temporal_graph(n=53, m=300, t_max=17, seed=97)
        with ServingEngine(EngineConfig(flush_ms=0.5,
                                        host_threshold=0)) as eng:
            eng.register_graph("g", g)
            eng.warmup("g")
            assert eng.metrics.counter("jit_compiles") > 0
            assert eng.metrics.counter("jit_compile_batch_query") > 0
            comp = eng.tracer.spans(name="jit_compile")
            assert comp and all(s.cat == "compile" for s in comp)
            assert comp[0].attrs["program"] == "batch_query"
            before = eng.metrics.counter("jit_compiles")
            eng.warmup("g")     # warm: no cache growth, no new events
            assert eng.metrics.counter("jit_compiles") == before


# ----------------------------------------------------------------------
# Bench artifact schema
# ----------------------------------------------------------------------

class TestBenchArtifacts:
    def test_artifact_round_trip(self, tmp_path):
        import sys
        sys.path.insert(0, str(__import__("pathlib").Path(__file__).parent.parent))
        from benchmarks.artifacts import (load_bench_json,
                                          validate_bench_artifact,
                                          write_bench_json)
        machine = {"platform": "test", "cpu_count": 1, "python": "3",
                   "jax": "0", "numpy": "0", "calib_s": 0.1}
        path = write_bench_json(
            str(tmp_path), "engine",
            {"open_loop_qps": (1000.0, "qps"), "p99": (2.5, "ms"),
             "coverage": (0.99, "frac")},
            {"load": (["a", "b"], [[1, 2], [3, 4]])}, machine)
        doc = load_bench_json(path)
        assert doc["metrics"]["open_loop_qps"]["normalized"] == \
            pytest.approx(100.0)
        assert doc["metrics"]["p99"]["normalized"] == \
            pytest.approx(0.0025 / 0.1)
        assert doc["metrics"]["coverage"]["normalized"] is None
        bad = json.loads(json.dumps(doc))
        bad["schema_version"] = 99
        with pytest.raises(ValueError):
            validate_bench_artifact(bad)
        bad = json.loads(json.dumps(doc))
        bad["tables"]["load"]["rows"][0] = [1]      # width mismatch
        with pytest.raises(ValueError):
            validate_bench_artifact(bad)

    def test_committed_artifacts_validate(self):
        """The BENCH_<area>.json files committed at the repo root must
        parse against the schema (the perf trajectory stays readable)."""
        import os
        import sys
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        sys.path.insert(0, repo)
        from benchmarks.artifacts import AREAS, validate_bench_files
        docs = validate_bench_files(repo, require=AREAS)
        assert set(docs) == set(AREAS)
        assert "span_chain_coverage" in docs["engine"]["metrics"]
        assert docs["engine"]["metrics"]["span_chain_coverage"]["value"] \
            >= 0.95
