"""Query API v2 tests: spec validation/canonicalization, the TCCSBackend
protocol across all three indexes, EDGES/SUBGRAPH/COUNT exactness on host
and device routes (vs the brute-force oracle), window sweeps, canonical
cache keys, and result-cache purging on index eviction."""

import numpy as np
import pytest

from repro.core.batch_query import (batch_query_edges_np, batch_query_np,
                                    to_device, window_sweep)
from repro.core.core_time import edge_core_times
from repro.core.ctmsf_index import CTMSFIndex
from repro.core.ef_index import EFIndex
from repro.core.kcore import tccs_oracle, tccs_oracle_edges
from repro.core.pecb_index import build_pecb_index
from repro.core.query_api import (EMPTY_WINDOW, InvalidQueryError, ResultMode,
                                  TCCSBackend, TCCSQuery, WindowSweep)
from repro.core.temporal_graph import gen_temporal_graph
from repro.serving import EngineConfig, ServingEngine


@pytest.fixture(scope="module")
def stack():
    g = gen_temporal_graph(n=35, m=280, t_max=16, seed=8)
    k = 2
    tab = edge_core_times(g, k)
    return (g, k, build_pecb_index(g, k, tab), EFIndex(g, k, tab),
            CTMSFIndex(g, k, tab))


def random_windows(g, n_q, rng, beyond=False):
    out = []
    for _ in range(n_q):
        u = int(rng.integers(0, g.n))
        ts = int(rng.integers(1, g.t_max + 1))
        hi = 2 * g.t_max if beyond else g.t_max
        te = int(rng.integers(ts, hi + 1))
        out.append((u, ts, te))
    return out


class TestSpec:
    def test_validation_errors(self, stack):
        g, k, pecb, *_ = stack
        with pytest.raises(InvalidQueryError, match="ts > te"):
            TCCSQuery(0, 5, 3, k).validate()
        with pytest.raises(InvalidQueryError, match="k must be"):
            TCCSQuery(0, 1, 5, 1).validate()
        with pytest.raises(InvalidQueryError, match="out of range"):
            TCCSQuery(g.n, 1, 5, k).validate(n=g.n)
        with pytest.raises(InvalidQueryError, match="out of range"):
            TCCSQuery(-1, 1, 5, k).validate(n=g.n)
        # a valid spec validates through, including the canonical empty
        TCCSQuery(0, 1, 5, k).validate(n=g.n)
        TCCSQuery(0, *EMPTY_WINDOW, k).validate(n=g.n)

    def test_backend_answer_raises_not_empty(self, stack):
        """The satellite contract: malformed queries raise a dedicated
        error instead of silently answering the empty set."""
        g, k, pecb, ef, cm = stack
        for backend in (pecb, ef, cm):
            with pytest.raises(InvalidQueryError):
                backend.answer(TCCSQuery(0, 9, 4, k))
            with pytest.raises(InvalidQueryError):
                backend.answer(TCCSQuery(g.n + 7, 1, 4, k))
            with pytest.raises(InvalidQueryError):
                backend.answer(TCCSQuery(0, 1, 4, 1))
            with pytest.raises(InvalidQueryError, match="does not match"):
                backend.answer(TCCSQuery(0, 1, 4, k + 1))

    def test_canonicalization(self, stack):
        g, k, *_ = stack
        t_max = g.t_max
        # clamp beyond-range te; fold empty windows; idempotence
        assert (TCCSQuery(3, 2, 10 * t_max, k).canonical(t_max)
                == TCCSQuery(3, 2, t_max, k))
        assert TCCSQuery(3, -4, 5, k).canonical(t_max) == TCCSQuery(3, 1, 5, k)
        folded = TCCSQuery(3, t_max + 2, t_max + 9, k).canonical(t_max)
        assert (folded.ts, folded.te) == EMPTY_WINDOW
        c = TCCSQuery(3, 2, 9, k).canonical(t_max)
        assert c.canonical(t_max) is c
        # equivalent raw windows share one cache key
        a = TCCSQuery(3, 2, t_max + 5, k).canonical(t_max).cache_key()
        b = TCCSQuery(3, 2, t_max, k).canonical(t_max).cache_key()
        assert a == b
        # mode is part of the key (an EDGES result is not a VERTICES result)
        e = TCCSQuery(3, 2, t_max, k, ResultMode.EDGES).canonical(t_max)
        assert e.cache_key() != b


class TestBackendProtocol:
    def test_all_three_implement_protocol(self, stack):
        _, _, pecb, ef, cm = stack
        for backend in (pecb, ef, cm):
            assert isinstance(backend, TCCSBackend)

    def test_all_modes_match_oracle_on_all_backends(self, stack):
        g, k, pecb, ef, cm = stack
        rng = np.random.default_rng(0)
        for (u, ts, te) in random_windows(g, 25, rng, beyond=True):
            want_v = frozenset(tccs_oracle(g, k, u, ts, te))
            want_e = frozenset(tccs_oracle_edges(g, k, u, ts, te))
            for backend in (pecb, ef, cm):
                r = backend.answer(TCCSQuery(u, ts, te, k, ResultMode.EDGES))
                assert r.vertices == want_v, (backend.backend_name, u, ts, te)
                assert r.edges.edge_ids() == want_e, (backend.backend_name,)
                assert r.edges.vertex_projection() == want_v
                assert r.num_edges == len(want_e)
                rs = backend.answer(TCCSQuery(u, ts, te, k,
                                              ResultMode.SUBGRAPH))
                assert rs.subgraph.m == len(want_e)
                assert rs.edges.edge_ids() == want_e
                rc = backend.answer(TCCSQuery(u, ts, te, k, ResultMode.COUNT))
                assert rc.num_vertices == len(want_v)
                assert rc.vertices == frozenset()

    def test_legacy_shims_agree_with_v2(self, stack):
        g, k, pecb, ef, cm = stack
        rng = np.random.default_rng(1)
        for (u, ts, te) in random_windows(g, 10, rng):
            for backend in (pecb, ef, cm):
                with pytest.warns(DeprecationWarning, match="deprecated"):
                    legacy = backend.query(u, ts, te)
                assert legacy == set(
                    backend.answer(TCCSQuery(u, ts, te, k)).vertices)


class TestDeviceModes:
    def test_device_edge_membership_matches_oracle(self, stack):
        """The tentpole device derivation: version membership from the
        converged component labels equals the brute-force induced edges."""
        g, k, pecb, *_ = stack
        rng = np.random.default_rng(2)
        qs = random_windows(g, 40, rng, beyond=True)
        got_e = batch_query_edges_np(pecb, qs)
        got_v = batch_query_np(pecb, qs)
        for (u, ts, te), ev, vv in zip(qs, got_e, got_v):
            assert ev == tccs_oracle_edges(g, k, u, ts, te), (u, ts, te)
            assert vv == tccs_oracle(g, k, u, ts, te), (u, ts, te)

    def test_engine_device_route_edge_modes(self, stack):
        g, k, *_ = stack
        rng = np.random.default_rng(3)
        cfg = EngineConfig(max_batch=64, flush_ms=500.0, host_threshold=0,
                           cache_capacity=0)
        with ServingEngine(cfg) as eng:
            eng.register_graph("g", g)
            qs = random_windows(g, 24, rng)
            specs = [TCCSQuery(u, ts, te, k, ResultMode.SUBGRAPH)
                     for (u, ts, te) in qs]
            futs = eng.submit_specs("g", specs)
            eng.flush()
            got = [f.result(timeout=60) for f in futs]
            assert eng.metrics.counter("device_batches") > 0
        for (u, ts, te), r in zip(qs, got):
            assert r.provenance.route == "device"
            assert r.vertices == frozenset(tccs_oracle(g, k, u, ts, te))
            want_e = frozenset(tccs_oracle_edges(g, k, u, ts, te))
            assert r.edges.edge_ids() == want_e
            assert r.subgraph.m == len(want_e)
            # the induced snapshot's edges are the member edges verbatim
            assert (frozenset(zip(r.subgraph.src.tolist(),
                                  r.subgraph.dst.tolist(),
                                  r.subgraph.t.tolist()))
                    == frozenset(zip(r.edges.u.tolist(), r.edges.v.tolist(),
                                     r.edges.t.tolist())))


class TestEngineV2:
    def test_submit_spec_validates_at_boundary(self, stack):
        g, k, *_ = stack
        with ServingEngine(EngineConfig(flush_ms=100.0)) as eng:
            eng.register_graph("g", g)
            with pytest.raises(InvalidQueryError):
                eng.submit_spec("g", TCCSQuery(0, 9, 3, k))
            with pytest.raises(InvalidQueryError):
                eng.submit_spec("g", TCCSQuery(g.n + 1, 1, 3, k))
            with pytest.raises(InvalidQueryError):
                eng.sweep("g", WindowSweep(g.n + 1, k, [(1, 3)]))

    def test_mixed_k_validation_is_all_or_nothing(self, stack):
        """A malformed spec in a later k-group must not leave earlier
        groups already enqueued: nothing executes when any spec fails."""
        g, k, *_ = stack
        with ServingEngine(EngineConfig(flush_ms=100.0)) as eng:
            eng.register_graph("g", g)
            with pytest.raises(InvalidQueryError):
                eng.submit_specs("g", [TCCSQuery(0, 1, 5, 2),
                                       TCCSQuery(0, 9, 3, 3)])
            assert eng.metrics.counter("queries") == 0

    def test_canonical_windows_share_cache_entry(self, stack):
        g, k, *_ = stack
        with ServingEngine(EngineConfig(flush_ms=200.0, host_threshold=0,
                                        cache_capacity=64)) as eng:
            eng.register_graph("g", g)
            r1 = eng.answer("g", TCCSQuery(2, 3, g.t_max, k))
            assert eng.metrics.counter("cache_hits") == 0
            # equivalent (beyond-t_max) window: canonical key -> cache hit
            r2 = eng.answer("g", TCCSQuery(2, 3, 5 * g.t_max, k))
            assert eng.metrics.counter("cache_hits") == 1
            assert r2.provenance.route == "cache"
            assert r1.vertices == r2.vertices

    def test_empty_window_short_circuits(self, stack):
        g, k, *_ = stack
        with ServingEngine(EngineConfig(flush_ms=200.0)) as eng:
            eng.register_graph("g", g)
            fut = eng.submit_spec("g", TCCSQuery(0, g.t_max + 4,
                                                 g.t_max + 9, k))
            assert fut.done()               # resolved on the submit path
            res = fut.result()
            assert res.vertices == frozenset()
            assert res.provenance.route == "trivial"
            assert eng.metrics.counter("trivial_queries") == 1

    def test_mixed_k_and_modes_in_one_call(self, stack):
        g, _, *_ = stack
        rng = np.random.default_rng(5)
        with ServingEngine(EngineConfig(max_batch=64, flush_ms=300.0,
                                        host_threshold=0)) as eng:
            eng.register_graph("g", g)
            specs = []
            for (u, ts, te) in random_windows(g, 16, rng):
                k = int(rng.choice([2, 3]))
                mode = (ResultMode.EDGES if rng.random() < 0.5
                        else ResultMode.VERTICES)
                specs.append(TCCSQuery(u, ts, te, k, mode))
            futs = eng.submit_specs("g", specs)
            eng.flush()
            got = [f.result(timeout=60) for f in futs]
        for s, r in zip(specs, got):
            assert r.query.k == s.k and r.query.mode is s.mode
            assert r.vertices == frozenset(tccs_oracle(g, s.k, s.u, s.ts, s.te))
            if s.mode is ResultMode.EDGES:
                assert (r.edges.edge_ids()
                        == frozenset(tccs_oracle_edges(g, s.k, s.u, s.ts, s.te)))


class TestWindowSweep:
    def test_sweep_matches_per_window_and_fills_cache(self, stack):
        g, k, pecb, *_ = stack
        u = 4
        windows = [(d, min(d + 4, g.t_max)) for d in range(1, g.t_max + 1)]
        cfg = EngineConfig(max_batch=64, flush_ms=300.0, host_threshold=4,
                           cache_capacity=256)
        with ServingEngine(cfg) as eng:
            eng.register_graph("g", g)
            got = eng.sweep("g", WindowSweep(u, k, windows))
            assert eng.metrics.counter("sweep_launches") >= 1
            for (ts, te), r in zip(windows, got):
                assert r.vertices == frozenset(
                    pecb._component_vertices(u, ts, te)), (ts, te)
                assert r.provenance.route == "sweep"
            # the sweep filled the cache: a re-sweep is all hits
            misses0 = eng.metrics.counter("cache_misses")
            again = eng.sweep("g", WindowSweep(u, k, windows))
            assert eng.metrics.counter("cache_misses") == misses0
            assert all(r.provenance.route == "cache" for r in again)
            # ...and point queries for the same windows hit too
            res = eng.answer("g", TCCSQuery(u, *windows[0], k))
            assert res.provenance.route == "cache"

    def test_sweep_edges_mode(self, stack):
        g, k, *_ = stack
        u = 7
        windows = [(d, min(d + 5, g.t_max)) for d in range(1, g.t_max, 2)]
        with ServingEngine(EngineConfig(flush_ms=300.0,
                                        host_threshold=4)) as eng:
            eng.register_graph("g", g)
            got = eng.sweep("g", WindowSweep(u, k, windows,
                                             ResultMode.EDGES))
        for (ts, te), r in zip(windows, got):
            assert (r.edges.edge_ids()
                    == frozenset(tccs_oracle_edges(g, k, u, ts, te)))

    def test_sweep_beyond_range_windows_fold(self, stack):
        g, k, *_ = stack
        windows = [(1, 4), (g.t_max + 2, g.t_max + 6)]
        with ServingEngine(EngineConfig(flush_ms=300.0)) as eng:
            eng.register_graph("g", g)
            got = eng.sweep("g", WindowSweep(3, k, windows))
            assert got[1].vertices == frozenset()
            assert got[1].provenance.route == "trivial"

    def test_device_sweep_function_matches_alg1(self, stack):
        g, k, pecb, *_ = stack
        import jax.numpy as jnp
        dix = to_device(pecb)
        u = 11
        wins = [(d, min(d + 3, g.t_max)) for d in range(1, g.t_max + 1)]
        ts = jnp.asarray([w[0] for w in wins], jnp.int32)
        te = jnp.asarray([w[1] for w in wins], jnp.int32)
        mask = np.asarray(window_sweep(dix, jnp.int32(u), ts, te))
        for (a, b), row in zip(wins, mask):
            assert set(np.nonzero(row)[0].tolist()) == \
                pecb._component_vertices(u, a, b)


class TestCachePurge:
    def test_eviction_purges_result_cache(self):
        """Satellite: stale cache keys of an evicted (workload, k) index
        must not occupy LRU capacity forever."""
        g1 = gen_temporal_graph(n=20, m=110, t_max=8, seed=1)
        g2 = gen_temporal_graph(n=20, m=110, t_max=8, seed=2)
        cfg = EngineConfig(flush_ms=150.0, registry_capacity=1,
                           cache_capacity=64)
        with ServingEngine(cfg) as eng:
            eng.register_graph("g1", g1)
            eng.register_graph("g2", g2)
            eng.answer("g1", TCCSQuery(0, 1, 6, 2))
            eng.answer("g1", TCCSQuery(1, 1, 6, 2))
            assert len(eng.cache) == 2
            eng.answer("g2", TCCSQuery(0, 1, 6, 2))  # evicts ("g1", 2)
            assert eng.registry.evictions == 1
            # the dead handle's entries are gone; only g2's remains
            assert len(eng.cache) == 1
            assert eng.cache.stats()["purges"] == 2
            assert eng.metrics.counter("cache_purged") == 2


class TestLegacyEngineShims:
    def test_positional_submit_is_lenient_and_exact(self, stack):
        g, k, pecb, *_ = stack
        with ServingEngine(EngineConfig(flush_ms=200.0)) as eng:
            eng.register_graph("g", g)
            # malformed windows answer empty, pre-v2 style (no raise)
            with pytest.warns(DeprecationWarning, match="deprecated"):
                assert eng.query("g", k, 0, 9, 3) == frozenset()
            with pytest.warns(DeprecationWarning, match="deprecated"):
                got = eng.query("g", k, 5, 2, 9)
            assert got == frozenset(pecb._component_vertices(5, 2, 9))
            with pytest.warns(DeprecationWarning, match="deprecated"):
                futs = eng.submit_many("g", k, [(1, 1, 8), (2, 3, 7)])
            eng.flush()
            for (u, ts, te), f in zip([(1, 1, 8), (2, 3, 7)], futs):
                assert f.result(timeout=30) == frozenset(
                    pecb._component_vertices(u, ts, te))
