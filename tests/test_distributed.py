"""Multi-device semantics tests.

The main test process sees one CPU device (smoke tests must not inherit a
forced device count), so anything that needs real multi-device SPMD runs in
a subprocess with ``--xla_force_host_platform_device_count=8``.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(body: str, n: int = 8) -> str:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        assert jax.device_count() == {n}
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    return res.stdout


@pytest.mark.slow
def test_vp_take_8way():
    run_with_devices("""
        from repro.runtime.sharding import make_vp_take
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        take = make_vp_take(mesh, leading=("data",))
        rng = np.random.default_rng(0)
        table = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
        table = jax.device_put(table, NamedSharding(mesh, P("model", None)))
        ids = jnp.asarray(rng.integers(0, 64, (8, 5)), jnp.int32)
        ids = jax.device_put(ids, NamedSharding(mesh, P(("data",), None)))
        got = jax.jit(take)(table, ids)
        want = jnp.take(jax.device_get(table), jax.device_get(ids), axis=0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
        print("vp_take ok")
    """)


@pytest.mark.slow
def test_compressed_grad_allreduce_8way():
    run_with_devices("""
        from repro.optim import compression
        mesh = jax.make_mesh((8,), ("data",))
        fn = compression.make_compressed_grad_allreduce(mesh, axis="data")
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)}
        e = compression.init_error_state(g)
        mean, new_e = jax.jit(fn)(g, e)
        # replicated identical grads: mean == dequant(quant(g)), error small
        err = np.abs(np.asarray(mean["w"]) - np.asarray(g["w"])).max()
        scale = np.abs(np.asarray(g["w"])).max() / 127.0
        assert err <= scale * 0.51 + 1e-6, (err, scale)
        print("compressed allreduce ok", err)
    """)


@pytest.mark.slow
def test_smoke_train_step_sharded_8way():
    """A reduced LM train step under a (2,4) data x model mesh: the full
    production sharding rules, 8-way."""
    run_with_devices("""
        import repro.configs as C
        from repro.optim import adamw
        spec = C.get("glm4-9b")
        cfg = C.cell_model_cfg(spec, "train_4k", smoke=True)
        import dataclasses
        cfg = dataclasses.replace(cfg, n_head=4, n_kv=2, d_model=64)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        params = C.init_params(spec, cfg, jax.random.PRNGKey(0))
        p_specs = C.param_specs(spec, params, mesh)
        named = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                             is_leaf=lambda x: isinstance(x, P))
        params = jax.tree.map(jax.device_put, params, named)
        opt = adamw.init_state(params)
        batch = {"tokens": jnp.zeros((4, 32), jnp.int32),
                 "labels": jnp.zeros((4, 32), jnp.int32)}
        step = jax.jit(C.make_train_step(spec, cfg))
        p2, o2, m = step(params, opt, batch)
        assert np.isfinite(float(m["loss"]))
        print("sharded train step ok", float(m["loss"]))
    """)


@pytest.mark.slow
def test_batched_tccs_queries_shardable():
    """The batched TCCS engine's (B, N) propagation shards over queries."""
    run_with_devices("""
        from repro.core.temporal_graph import gen_temporal_graph
        from repro.core.pecb_index import build_pecb_index
        from repro.core.batch_query import to_device, batch_query
        g = gen_temporal_graph(n=40, m=250, t_max=15, seed=1)
        idx = build_pecb_index(g, 2)
        dix = to_device(idx)
        rng = np.random.default_rng(0)
        B = 64
        u = jnp.asarray(rng.integers(0, g.n, B), jnp.int32)
        ts = jnp.asarray(rng.integers(1, g.t_max + 1, B), jnp.int32)
        te = jnp.minimum(ts + 5, g.t_max)
        mesh = jax.make_mesh((8,), ("q",))
        sh = NamedSharding(mesh, P("q"))
        out = batch_query(dix, jax.device_put(u, sh), jax.device_put(ts, sh),
                          jax.device_put(te, sh))
        # spot-check against the host index
        mask = np.asarray(out)
        for i in range(0, B, 7):
            want = idx._component_vertices(int(u[i]), int(ts[i]), int(te[i]))
            got = set(np.nonzero(mask[i])[0].tolist())
            assert got == want
        print("sharded batch query ok")
    """)


@pytest.mark.slow
def test_a2a_moe_matches_reference_dispatch():
    """The shard_map all-to-all MoE (runtime/moe_a2a.py) is bit-equal to the
    single-device reference dispatch when capacity is non-binding."""
    run_with_devices("""
        from repro.models import transformer as tfm
        from repro.runtime.moe_a2a import make_a2a_moe
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        mcfg = tfm.MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                             capacity_factor=8.0)
        cfg = tfm.LMConfig("t", n_layer=1, d_model=64, n_head=2, n_kv=2,
                           d_ff=0, vocab=64, d_head=16, moe=mcfg,
                           dtype=jnp.float32, remat=False)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        lp = jax.tree.map(lambda a: a[0], params["layers"])["moe"]
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 64))
        ref_out, _ = tfm.moe_ffn(lp, cfg, x)
        a2a = make_a2a_moe(mesh, ("data",))
        xs = jax.device_put(x, NamedSharding(mesh, P(("data",), None, None)))
        lps = {k: jax.device_put(v, NamedSharding(
                   mesh, P("model", None, None) if k in ("wi", "wg", "wo") else P()))
               for k, v in lp.items()}
        out, aux = jax.jit(lambda p, xx: a2a(p, cfg, xx))(lps, xs)
        err = float(jnp.abs(out - ref_out).max())
        assert err < 1e-4, err
        # gradients flow through the a2a exchanges
        g = jax.grad(lambda p: jnp.sum(a2a(p, cfg, xs)[0] ** 2))(lps)
        assert all(np.isfinite(np.asarray(v)).all() for v in jax.tree.leaves(g))
        print("a2a moe ok", err)
    """)
