"""Tests for the static-analysis suite + dynamic lock witness
(DESIGN.md §12).

Fixture files under ``tests/fixtures/analysis/`` are *parsed*, never
imported: each seeded violation pins its rule (and the clean twins pin
zero findings), so a pass that stops firing — or starts over-firing —
fails here before it lies in CI.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading

import pytest

from repro.analysis import PASSES, AnalysisConfig, Baseline, run_analysis
from repro.analysis.core import Module
from repro.obs.locks import (LOCK_HIERARCHY, LockWitness, WitnessCondition,
                             WitnessLock, named_condition, named_lock,
                             witness_enabled)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = "tests/fixtures/analysis"


def analyze(rel_file: str, **overrides) -> list:
    """Run every pass over one fixture file with the repo config, include
    overridden to just that file."""
    config = AnalysisConfig.from_pyproject(REPO)
    config.include = (f"{FIXTURES}/{rel_file}",)
    for k, v in overrides.items():
        setattr(config, k, v)
    return run_analysis(REPO, config, PASSES)


def rules(findings) -> set[str]:
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# lock passes
# ---------------------------------------------------------------------------

class TestLockPassFixtures:
    def test_seeded_violations_all_detected(self):
        fs = analyze("lock_violations.py")
        by_rule: dict[str, list] = {}
        for f in fs:
            by_rule.setdefault(f.rule, []).append(f)
        # rank inversion, unnamed-under-named, unknown level, receiver map
        assert len(by_rule["lock-order"]) == 4
        # Future.result, block_until_ready, open()
        assert len(by_rule["lock-blocking-call"]) == 3

    def test_inversion_message_names_both_levels_and_ranks(self):
        fs = [f for f in analyze("lock_violations.py")
              if f.rule == "lock-order" and "cache" in f.message
              and "metrics" in f.message]
        assert fs, "cache-under-metrics inversion not detected"
        assert "strictly increasing" in fs[0].message

    def test_clean_fixture_has_zero_findings(self):
        assert analyze("lock_clean.py") == []

    def test_findings_carry_location_and_symbol(self):
        fs = analyze("lock_violations.py")
        f = next(f for f in fs if f.rule == "lock-blocking-call"
                 and "Future.result" in f.message)
        assert f.path.endswith("lock_violations.py")
        assert f.symbol == "BadBlocking.waits_under_lock"
        assert f.line > 0 and f.fingerprint


# ---------------------------------------------------------------------------
# jax passes
# ---------------------------------------------------------------------------

class TestJaxPassFixtures:
    def test_seeded_violations_all_detected(self):
        fs = analyze("jax_violations.py")
        assert rules(fs) >= {"jit-assert", "jit-python-branch",
                             "jit-host-sync", "jit-mutable-closure",
                             "jit-unhashable-static"}

    def test_clean_fixture_has_zero_jax_findings(self):
        fs = analyze("jax_clean.py")
        # static-metadata branches (dix.num_nodes), lax.cond, host wrappers
        # and module constants must all stay silent
        assert not rules(fs) & {"jit-assert", "jit-python-branch",
                                "jit-host-sync", "jit-mutable-closure",
                                "jit-unhashable-static"}

    def test_hot_path_transfer_fires_only_on_listed_modules(self):
        mod = "tests.fixtures.analysis.lock_violations"
        hot = analyze("lock_violations.py", hot_path_modules=(mod,))
        cold = analyze("lock_violations.py")
        assert "hot-path-transfer" in rules(hot)      # block_until_ready
        assert "hot-path-transfer" not in rules(cold)

    def test_repo_batch_query_static_branches_stay_clean(self):
        """The real jitted programs branch on DeviceIndex aux_data
        (num_nodes etc.) — static at trace time, must not be flagged."""
        config = AnalysisConfig.from_pyproject(REPO)
        config.include = ("src/repro/core/batch_query.py",)
        fs = run_analysis(REPO, config, PASSES)
        assert "jit-python-branch" not in rules(fs)


# ---------------------------------------------------------------------------
# api passes
# ---------------------------------------------------------------------------

class TestApiPassFixtures:
    def test_seeded_violations_all_detected(self):
        mod = "tests.fixtures.analysis"
        fs = analyze("api_violations.py", wallclock_modules=(mod,))
        by_rule: dict[str, list] = {}
        for f in fs:
            by_rule.setdefault(f.rule, []).append(f)
        assert len(by_rule["deprecated-shim"]) == 3
        assert len(by_rule["metrics-direct"]) == 2
        assert len(by_rule["wallclock-in-traced"]) == 1
        assert len(by_rule["bare-assert"]) == 1
        assert len(by_rule["per-k-key"]) == 6

    def test_clean_fixture_has_zero_findings(self):
        mod = "tests.fixtures.analysis"
        fs = analyze("api_clean.py", wallclock_modules=(mod,))
        assert fs == []

    def test_wallclock_rule_scoped_to_module_list(self):
        fs = analyze("api_violations.py")   # repo list: repro.serving/.obs
        assert "wallclock-in-traced" not in rules(fs)


# ---------------------------------------------------------------------------
# suppressions, baseline, CLI
# ---------------------------------------------------------------------------

class TestSuppressionAndBaseline:
    def test_inline_suppression_drops_the_finding(self, tmp_path):
        src = ("def f(x):\n"
               "    assert x > 0  # repro: ignore[bare-assert]\n"
               "    return x\n")
        mod = Module(str(tmp_path / "m.py"), "m.py", src)
        assert mod.suppressed(2, "bare-assert")
        assert not mod.suppressed(2, "lock-order")

    def test_line_above_suppression(self, tmp_path):
        src = ("def f(x):\n"
               "    # repro: ignore[bare-assert]\n"
               "    assert x > 0\n")
        mod = Module(str(tmp_path / "m.py"), "m.py", src)
        assert mod.suppressed(3, "bare-assert")

    def test_bare_ignore_suppresses_every_rule(self, tmp_path):
        src = "x = 1  # repro: ignore\n"
        mod = Module(str(tmp_path / "m.py"), "m.py", src)
        assert mod.suppressed(1, "anything")

    def test_suppression_respected_end_to_end(self):
        fs = analyze("api_clean.py")
        assert "bare-assert" not in rules(fs)   # fixture suppresses inline

    def test_baseline_round_trip(self, tmp_path):
        fs = analyze("api_violations.py")
        assert fs
        path = str(tmp_path / "baseline.json")
        Baseline.from_findings(fs, comment="fixture").save(path)
        loaded = Baseline.load(path)
        assert all(f.fingerprint in loaded for f in fs)
        # a fresh finding (different fingerprint) is not baselined
        assert "0" * 16 not in loaded

    def test_fingerprints_stable_across_unrelated_line_shifts(self):
        """Fingerprints hash line *text*, not line numbers."""
        fs1 = analyze("api_violations.py")
        fp = {f.fingerprint for f in fs1}
        fs2 = analyze("api_violations.py")
        assert fp == {f.fingerprint for f in fs2}

    def test_missing_baseline_file_is_empty(self, tmp_path):
        b = Baseline.load(str(tmp_path / "nope.json"))
        assert "anything" not in b


class TestCli:
    def test_strict_on_repo_tree_is_clean(self):
        """The acceptance gate: the shipped tree has zero non-baselined
        findings."""
        from repro.analysis.__main__ import main
        assert main(["--root", REPO, "--strict"]) == 0

    def test_json_artifact_shape(self, tmp_path, capsys):
        from repro.analysis.__main__ import main
        out = str(tmp_path / "findings.json")
        assert main(["--root", REPO, "--json", out]) == 0
        with open(out) as f:
            payload = json.load(f)
        assert set(payload) >= {"findings", "baselined", "fresh", "passes"}
        assert payload["fresh"] == 0

    def test_unknown_pass_is_usage_error(self):
        from repro.analysis.__main__ import main
        assert main(["--root", REPO, "--passes", "nonsense"]) == 2

    def test_pass_subset_runs(self, capsys):
        from repro.analysis.__main__ import main
        assert main(["--root", REPO, "--passes", "api"]) == 0

    def test_write_baseline_then_strict_passes(self, tmp_path):
        """Seeded violations + --write-baseline -> strict exits 0; the
        same findings without the baseline fail strict."""
        from repro.analysis.__main__ import main
        root = tmp_path
        (root / "pyproject.toml").write_text(
            '[tool.repro-analysis]\ninclude = ["bad.py"]\n'
            'baseline = "b.json"\n')
        (root / "bad.py").write_text("def f(x):\n    assert x\n    return x\n")
        assert main(["--root", str(root), "--strict"]) == 1
        assert main(["--root", str(root), "--write-baseline"]) == 0
        assert main(["--root", str(root), "--strict"]) == 0


# ---------------------------------------------------------------------------
# dynamic lock witness
# ---------------------------------------------------------------------------

class TestLockWitness:
    def test_ordered_acquisition_is_clean(self):
        w = LockWitness()
        reg = WitnessLock("registry", w)
        met = WitnessLock("metrics", w)
        with reg:
            with met:
                pass
        assert w.check() == []
        assert w.acquisitions == 2
        (edge,) = w.edges()
        assert (edge["outer"], edge["inner"]) == ("registry", "metrics")

    def test_deliberate_inversion_detected(self):
        """The acceptance-criteria case: acquire out of declared order."""
        w = LockWitness()
        met = WitnessLock("metrics", w)
        reg = WitnessLock("registry", w)
        with met:
            with reg:          # registry ranks ABOVE metrics: inversion
                pass
        problems = w.check()
        kinds = {p["kind"] for p in problems}
        assert "lock-order" in kinds
        inv = next(p for p in problems if p["kind"] == "lock-order")
        assert (inv["outer"], inv["inner"]) == ("metrics", "registry")
        assert inv["threads"]   # owning thread recorded for the report

    def test_undeclared_lock_detected(self):
        w = LockWitness()
        reg = WitnessLock("registry", w)
        rogue = WitnessLock("rogue", w)
        with reg:
            with rogue:
                pass
        assert any(p["kind"] == "undeclared-lock" for p in w.check())

    def test_cross_thread_cycle_detected(self):
        """Thread A takes registry->cache in declared order; thread B
        takes cache->registry. No single thread inverts twice the same
        way, but the union of edges cycles — a real deadlock shape."""
        w = LockWitness(hierarchy=("a", "b"))
        la = WitnessLock("a", w)
        lb = WitnessLock("b", w)
        with la:
            with lb:
                pass

        def other():
            with lb:
                with la:
                    pass

        t = threading.Thread(target=other, name="inverter")
        t.start()
        t.join()
        problems = w.check()
        assert any(p["kind"] == "lock-cycle" for p in problems)
        cyc = next(p for p in problems if p["kind"] == "lock-cycle")
        assert set(cyc["cycle"]) >= {"a", "b"}

    def test_per_thread_hold_stacks_do_not_interleave(self):
        """Two threads each holding one lock concurrently must not create
        a cross-thread 'nesting' edge."""
        w = LockWitness()
        reg = WitnessLock("registry", w)
        met = WitnessLock("metrics", w)
        barrier = threading.Barrier(2)

        def hold(lock):
            with lock:
                barrier.wait(timeout=10)
                barrier.wait(timeout=10)

        t1 = threading.Thread(target=hold, args=(reg,))
        t2 = threading.Thread(target=hold, args=(met,))
        t1.start(); t2.start()
        t1.join(); t2.join()
        assert w.edges() == []          # concurrent != nested
        assert w.check() == []

    def test_condition_wrapper_reports_monitor_sections(self):
        w = LockWitness()
        cond = WitnessCondition("batcher", w)
        met = WitnessLock("metrics", w)
        with cond:
            with met:                   # batcher -> metrics: declared edge
                pass
        assert w.check() == []
        (edge,) = w.edges()
        assert (edge["outer"], edge["inner"]) == ("batcher", "metrics")

    def test_condition_wait_notify_roundtrip(self):
        w = LockWitness()
        cond = WitnessCondition("batcher", w)
        state = {"go": False}

        def producer():
            with cond:
                state["go"] = True
                cond.notify_all()

        t = threading.Thread(target=producer)
        with cond:
            t.start()
            assert cond.wait_for(lambda: state["go"], timeout=10)
        t.join()
        assert w.check() == []

    def test_report_is_json_serializable(self):
        w = LockWitness()
        with WitnessLock("metrics", w):
            with WitnessLock("registry", w):
                pass
        json.dumps(w.report())          # must not raise

    def test_reset_clears_observations(self):
        w = LockWitness()
        with WitnessLock("metrics", w):
            with WitnessLock("registry", w):
                pass
        assert w.check()
        w.reset()
        assert w.check() == [] and w.edges() == []
        assert w.acquisitions == 0


class TestNamedFactories:
    def test_plain_primitives_when_witness_off(self, monkeypatch):
        monkeypatch.delenv("REPRO_LOCK_WITNESS", raising=False)
        assert not witness_enabled()
        lk = named_lock("registry")
        assert isinstance(lk, type(threading.Lock()))
        cd = named_condition("batcher")
        assert isinstance(cd, threading.Condition)

    def test_wrappers_when_witness_passed_explicitly(self):
        w = LockWitness()
        lk = named_lock("registry", witness=w)
        cd = named_condition("batcher", witness=w)
        assert isinstance(lk, WitnessLock)
        assert isinstance(cd, WitnessCondition)
        with lk:
            pass
        assert w.acquisitions == 1

    def test_env_arms_global_witness(self, monkeypatch):
        monkeypatch.setenv("REPRO_LOCK_WITNESS", "1")
        assert witness_enabled()
        lk = named_lock("registry")
        assert isinstance(lk, WitnessLock)

    def test_hierarchy_covers_every_subsystem(self):
        assert LOCK_HIERARCHY == (
            "engine", "registry", "batcher", "cache", "store", "metrics",
            "histogram", "slowlog", "tracer", "checkpoint")


class TestWitnessedServingPath:
    """End-to-end: a real engine built with the witness armed respects
    the declared hierarchy while serving queries + background builds."""

    def test_engine_serving_respects_hierarchy(self, monkeypatch):
        monkeypatch.setenv("REPRO_LOCK_WITNESS", "1")
        w = LockWitness()
        # route the factories at this process's global witness aside: use
        # a local witness by monkeypatching the module singleton so the
        # session-level gate never sees these deliberate test edges
        import repro.obs.locks as locks_mod
        monkeypatch.setattr(locks_mod, "WITNESS", w)

        from repro.core.query_api import TCCSQuery
        from repro.core.temporal_graph import TemporalGraph
        from repro.serving.engine import EngineConfig, ServingEngine
        import numpy as np

        src = np.array([0, 1, 2, 0, 1, 2, 3], np.int32)
        dst = np.array([1, 2, 0, 2, 3, 3, 0], np.int32)
        t = np.array([1, 2, 3, 4, 5, 6, 7], np.int32)
        g = TemporalGraph(n=4, src=src, dst=dst, t=t)
        with ServingEngine(EngineConfig(flush_ms=0.5)) as eng:
            eng.register_graph("g", g)
            eng.warmup("g")
            r = eng.answer("g", TCCSQuery(0, 1, 7, 2))
            assert r is not None
        assert w.acquisitions > 0
        assert w.check() == [], w.report()
