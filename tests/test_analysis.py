"""Tests for the static-analysis suite + dynamic lock witness
(DESIGN.md §12).

Fixture files under ``tests/fixtures/analysis/`` are *parsed*, never
imported: each seeded violation pins its rule (and the clean twins pin
zero findings), so a pass that stops firing — or starts over-firing —
fails here before it lies in CI.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading

import pytest

from repro.analysis import PASSES, AnalysisConfig, Baseline, run_analysis
from repro.analysis.core import Module
from repro.obs.locks import (LOCK_HIERARCHY, LockWitness, WitnessCondition,
                             WitnessLock, named_condition, named_lock,
                             witness_enabled)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = "tests/fixtures/analysis"


def analyze(rel_file: str, **overrides) -> list:
    """Run every pass over one fixture file with the repo config, include
    overridden to just that file."""
    config = AnalysisConfig.from_pyproject(REPO)
    config.include = (f"{FIXTURES}/{rel_file}",)
    for k, v in overrides.items():
        setattr(config, k, v)
    return run_analysis(REPO, config, PASSES)


def rules(findings) -> set[str]:
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# lock passes
# ---------------------------------------------------------------------------

class TestLockPassFixtures:
    def test_seeded_violations_all_detected(self):
        fs = analyze("lock_violations.py")
        by_rule: dict[str, list] = {}
        for f in fs:
            by_rule.setdefault(f.rule, []).append(f)
        # rank inversion, unnamed-under-named, unknown level, receiver map
        assert len(by_rule["lock-order"]) == 4
        # Future.result, block_until_ready, open()
        assert len(by_rule["lock-blocking-call"]) == 3

    def test_inversion_message_names_both_levels_and_ranks(self):
        fs = [f for f in analyze("lock_violations.py")
              if f.rule == "lock-order" and "cache" in f.message
              and "metrics" in f.message]
        assert fs, "cache-under-metrics inversion not detected"
        assert "strictly increasing" in fs[0].message

    def test_clean_fixture_has_zero_findings(self):
        assert analyze("lock_clean.py") == []

    def test_findings_carry_location_and_symbol(self):
        fs = analyze("lock_violations.py")
        f = next(f for f in fs if f.rule == "lock-blocking-call"
                 and "Future.result" in f.message)
        assert f.path.endswith("lock_violations.py")
        assert f.symbol == "BadBlocking.waits_under_lock"
        assert f.line > 0 and f.fingerprint


# ---------------------------------------------------------------------------
# jax passes
# ---------------------------------------------------------------------------

class TestJaxPassFixtures:
    def test_seeded_violations_all_detected(self):
        fs = analyze("jax_violations.py")
        assert rules(fs) >= {"jit-assert", "jit-python-branch",
                             "jit-host-sync", "jit-mutable-closure",
                             "jit-unhashable-static"}

    def test_clean_fixture_has_zero_jax_findings(self):
        fs = analyze("jax_clean.py")
        # static-metadata branches (dix.num_nodes), lax.cond, host wrappers
        # and module constants must all stay silent
        assert not rules(fs) & {"jit-assert", "jit-python-branch",
                                "jit-host-sync", "jit-mutable-closure",
                                "jit-unhashable-static"}

    def test_hot_path_transfer_fires_only_on_listed_modules(self):
        mod = "tests.fixtures.analysis.lock_violations"
        hot = analyze("lock_violations.py", hot_path_modules=(mod,))
        cold = analyze("lock_violations.py")
        assert "hot-path-transfer" in rules(hot)      # block_until_ready
        assert "hot-path-transfer" not in rules(cold)

    def test_repo_batch_query_static_branches_stay_clean(self):
        """The real jitted programs branch on DeviceIndex aux_data
        (num_nodes etc.) — static at trace time, must not be flagged."""
        config = AnalysisConfig.from_pyproject(REPO)
        config.include = ("src/repro/core/batch_query.py",)
        fs = run_analysis(REPO, config, PASSES)
        assert "jit-python-branch" not in rules(fs)


# ---------------------------------------------------------------------------
# api passes
# ---------------------------------------------------------------------------

class TestApiPassFixtures:
    def test_seeded_violations_all_detected(self):
        mod = "tests.fixtures.analysis"
        # assert-exempt covers tests/ in the repo config; disable it so
        # the seeded bare-assert stays a true positive here
        fs = analyze("api_violations.py", wallclock_modules=(mod,),
                     assert_exempt=())
        by_rule: dict[str, list] = {}
        for f in fs:
            by_rule.setdefault(f.rule, []).append(f)
        assert len(by_rule["deprecated-shim"]) == 3
        assert len(by_rule["metrics-direct"]) == 2
        assert len(by_rule["wallclock-in-traced"]) == 1
        assert len(by_rule["bare-assert"]) == 1
        assert len(by_rule["per-k-key"]) == 6

    def test_clean_fixture_has_zero_findings(self):
        mod = "tests.fixtures.analysis"
        fs = analyze("api_clean.py", wallclock_modules=(mod,))
        assert fs == []

    def test_wallclock_rule_scoped_to_module_list(self):
        fs = analyze("api_violations.py")   # repo list: repro.serving/.obs
        assert "wallclock-in-traced" not in rules(fs)


# ---------------------------------------------------------------------------
# suppressions, baseline, CLI
# ---------------------------------------------------------------------------

class TestSuppressionAndBaseline:
    def test_inline_suppression_drops_the_finding(self, tmp_path):
        src = ("def f(x):\n"
               "    assert x > 0  # repro: ignore[bare-assert]\n"
               "    return x\n")
        mod = Module(str(tmp_path / "m.py"), "m.py", src)
        assert mod.suppressed(2, "bare-assert")
        assert not mod.suppressed(2, "lock-order")

    def test_line_above_suppression(self, tmp_path):
        src = ("def f(x):\n"
               "    # repro: ignore[bare-assert]\n"
               "    assert x > 0\n")
        mod = Module(str(tmp_path / "m.py"), "m.py", src)
        assert mod.suppressed(3, "bare-assert")

    def test_bare_ignore_suppresses_every_rule(self, tmp_path):
        src = "x = 1  # repro: ignore\n"
        mod = Module(str(tmp_path / "m.py"), "m.py", src)
        assert mod.suppressed(1, "anything")

    def test_suppression_respected_end_to_end(self):
        fs = analyze("api_clean.py")
        assert "bare-assert" not in rules(fs)   # fixture suppresses inline

    def test_baseline_round_trip(self, tmp_path):
        fs = analyze("api_violations.py")
        assert fs
        path = str(tmp_path / "baseline.json")
        Baseline.from_findings(fs, comment="fixture").save(path)
        loaded = Baseline.load(path)
        assert all(f.fingerprint in loaded for f in fs)
        # a fresh finding (different fingerprint) is not baselined
        assert "0" * 16 not in loaded

    def test_fingerprints_stable_across_unrelated_line_shifts(self):
        """Fingerprints hash line *text*, not line numbers."""
        fs1 = analyze("api_violations.py")
        fp = {f.fingerprint for f in fs1}
        fs2 = analyze("api_violations.py")
        assert fp == {f.fingerprint for f in fs2}

    def test_missing_baseline_file_is_empty(self, tmp_path):
        b = Baseline.load(str(tmp_path / "nope.json"))
        assert "anything" not in b


class TestCli:
    def test_strict_on_repo_tree_is_clean(self):
        """The acceptance gate: the shipped tree has zero non-baselined
        findings."""
        from repro.analysis.__main__ import main
        assert main(["--root", REPO, "--strict"]) == 0

    def test_json_artifact_shape(self, tmp_path, capsys):
        from repro.analysis.__main__ import main
        out = str(tmp_path / "findings.json")
        assert main(["--root", REPO, "--json", out]) == 0
        with open(out) as f:
            payload = json.load(f)
        assert set(payload) >= {"findings", "baselined", "fresh", "passes"}
        assert payload["fresh"] == 0

    def test_unknown_pass_is_usage_error(self):
        from repro.analysis.__main__ import main
        assert main(["--root", REPO, "--passes", "nonsense"]) == 2

    def test_pass_subset_runs(self, capsys):
        from repro.analysis.__main__ import main
        assert main(["--root", REPO, "--passes", "api"]) == 0

    def test_write_baseline_then_strict_passes(self, tmp_path):
        """Seeded violations + --write-baseline -> strict exits 0; the
        same findings without the baseline fail strict."""
        from repro.analysis.__main__ import main
        root = tmp_path
        (root / "pyproject.toml").write_text(
            '[tool.repro-analysis]\ninclude = ["bad.py"]\n'
            'baseline = "b.json"\n')
        (root / "bad.py").write_text("def f(x):\n    assert x\n    return x\n")
        assert main(["--root", str(root), "--strict"]) == 1
        assert main(["--root", str(root), "--write-baseline"]) == 0
        assert main(["--root", str(root), "--strict"]) == 0


# ---------------------------------------------------------------------------
# dynamic lock witness
# ---------------------------------------------------------------------------

class TestLockWitness:
    def test_ordered_acquisition_is_clean(self):
        w = LockWitness()
        reg = WitnessLock("registry", w)
        met = WitnessLock("metrics", w)
        with reg:
            with met:
                pass
        assert w.check() == []
        assert w.acquisitions == 2
        (edge,) = w.edges()
        assert (edge["outer"], edge["inner"]) == ("registry", "metrics")

    def test_deliberate_inversion_detected(self):
        """The acceptance-criteria case: acquire out of declared order."""
        w = LockWitness()
        met = WitnessLock("metrics", w)
        reg = WitnessLock("registry", w)
        with met:
            with reg:          # registry ranks ABOVE metrics: inversion
                pass
        problems = w.check()
        kinds = {p["kind"] for p in problems}
        assert "lock-order" in kinds
        inv = next(p for p in problems if p["kind"] == "lock-order")
        assert (inv["outer"], inv["inner"]) == ("metrics", "registry")
        assert inv["threads"]   # owning thread recorded for the report

    def test_undeclared_lock_detected(self):
        w = LockWitness()
        reg = WitnessLock("registry", w)
        rogue = WitnessLock("rogue", w)
        with reg:
            with rogue:
                pass
        assert any(p["kind"] == "undeclared-lock" for p in w.check())

    def test_cross_thread_cycle_detected(self):
        """Thread A takes registry->cache in declared order; thread B
        takes cache->registry. No single thread inverts twice the same
        way, but the union of edges cycles — a real deadlock shape."""
        w = LockWitness(hierarchy=("a", "b"))
        la = WitnessLock("a", w)
        lb = WitnessLock("b", w)
        with la:
            with lb:
                pass

        def other():
            with lb:
                with la:
                    pass

        t = threading.Thread(target=other, name="inverter")
        t.start()
        t.join()
        problems = w.check()
        assert any(p["kind"] == "lock-cycle" for p in problems)
        cyc = next(p for p in problems if p["kind"] == "lock-cycle")
        assert set(cyc["cycle"]) >= {"a", "b"}

    def test_per_thread_hold_stacks_do_not_interleave(self):
        """Two threads each holding one lock concurrently must not create
        a cross-thread 'nesting' edge."""
        w = LockWitness()
        reg = WitnessLock("registry", w)
        met = WitnessLock("metrics", w)
        barrier = threading.Barrier(2)

        def hold(lock):
            with lock:
                barrier.wait(timeout=10)
                barrier.wait(timeout=10)

        t1 = threading.Thread(target=hold, args=(reg,))
        t2 = threading.Thread(target=hold, args=(met,))
        t1.start(); t2.start()
        t1.join(); t2.join()
        assert w.edges() == []          # concurrent != nested
        assert w.check() == []

    def test_condition_wrapper_reports_monitor_sections(self):
        w = LockWitness()
        cond = WitnessCondition("batcher", w)
        met = WitnessLock("metrics", w)
        with cond:
            with met:                   # batcher -> metrics: declared edge
                pass
        assert w.check() == []
        (edge,) = w.edges()
        assert (edge["outer"], edge["inner"]) == ("batcher", "metrics")

    def test_condition_wait_notify_roundtrip(self):
        w = LockWitness()
        cond = WitnessCondition("batcher", w)
        state = {"go": False}

        def producer():
            with cond:
                state["go"] = True
                cond.notify_all()

        t = threading.Thread(target=producer)
        with cond:
            t.start()
            assert cond.wait_for(lambda: state["go"], timeout=10)
        t.join()
        assert w.check() == []

    def test_report_is_json_serializable(self):
        w = LockWitness()
        with WitnessLock("metrics", w):
            with WitnessLock("registry", w):
                pass
        json.dumps(w.report())          # must not raise

    def test_reset_clears_observations(self):
        w = LockWitness()
        with WitnessLock("metrics", w):
            with WitnessLock("registry", w):
                pass
        assert w.check()
        w.reset()
        assert w.check() == [] and w.edges() == []
        assert w.acquisitions == 0


class TestNamedFactories:
    def test_plain_primitives_when_witness_off(self, monkeypatch):
        monkeypatch.delenv("REPRO_LOCK_WITNESS", raising=False)
        assert not witness_enabled()
        lk = named_lock("registry")
        assert isinstance(lk, type(threading.Lock()))
        cd = named_condition("batcher")
        assert isinstance(cd, threading.Condition)

    def test_wrappers_when_witness_passed_explicitly(self):
        w = LockWitness()
        lk = named_lock("registry", witness=w)
        cd = named_condition("batcher", witness=w)
        assert isinstance(lk, WitnessLock)
        assert isinstance(cd, WitnessCondition)
        with lk:
            pass
        assert w.acquisitions == 1

    def test_env_arms_global_witness(self, monkeypatch):
        monkeypatch.setenv("REPRO_LOCK_WITNESS", "1")
        assert witness_enabled()
        lk = named_lock("registry")
        assert isinstance(lk, WitnessLock)

    def test_hierarchy_covers_every_subsystem(self):
        assert LOCK_HIERARCHY == (
            "engine", "registry", "batcher", "cache", "store", "metrics",
            "histogram", "slowlog", "tracer", "checkpoint")


class TestWitnessedServingPath:
    """End-to-end: a real engine built with the witness armed respects
    the declared hierarchy while serving queries + background builds."""

    def test_engine_serving_respects_hierarchy(self, monkeypatch):
        monkeypatch.setenv("REPRO_LOCK_WITNESS", "1")
        w = LockWitness()
        # route the factories at this process's global witness aside: use
        # a local witness by monkeypatching the module singleton so the
        # session-level gate never sees these deliberate test edges
        import repro.obs.locks as locks_mod
        monkeypatch.setattr(locks_mod, "WITNESS", w)

        from repro.core.query_api import TCCSQuery
        from repro.core.temporal_graph import TemporalGraph
        from repro.serving.engine import EngineConfig, ServingEngine
        import numpy as np

        src = np.array([0, 1, 2, 0, 1, 2, 3], np.int32)
        dst = np.array([1, 2, 0, 2, 3, 3, 0], np.int32)
        t = np.array([1, 2, 3, 4, 5, 6, 7], np.int32)
        g = TemporalGraph(n=4, src=src, dst=dst, t=t)
        with ServingEngine(EngineConfig(flush_ms=0.5)) as eng:
            eng.register_graph("g", g)
            eng.warmup("g")
            r = eng.answer("g", TCCSQuery(0, 1, 7, 2))
            assert r is not None
        assert w.acquisitions > 0
        assert w.check() == [], w.report()


# ---------------------------------------------------------------------------
# kernels passes (static half)
# ---------------------------------------------------------------------------

class TestKernelPassFixtures:
    def test_seeded_violations_all_detected(self):
        fs = analyze("kernel_violations.py")
        by_rule: dict[str, list] = {}
        for f in fs:
            by_rule.setdefault(f.rule, []).append(f)
        # unpadded ep // SLOT_BLOCK
        assert len(by_rule["pallas-grid-divisibility"]) == 1
        # index_map closing over the wrapper-local `start`
        assert len(by_rule["pallas-indexmap-closure"]) == 1
        # the (4096, 4096) f32 tile, in + out
        assert len(by_rule["pallas-vmem-budget"]) == 1
        # k_index*n + u product; int64 cumsum row_ptr wrapped back
        assert len(by_rule["int32-narrowing"]) == 2
        # float64 node_u, unprovable node_v, undeclared bogus_plane,
        # the aggregated missing-arrays finding (node_ct stays clean)
        assert len(by_rule["layout-contract"]) == 4

    def test_vmem_finding_reports_bytes_and_platform(self):
        f = next(f for f in analyze("kernel_violations.py")
                 if f.rule == "pallas-vmem-budget")
        assert "tpu" in f.message and " B " in f.message

    def test_clean_fixture_has_zero_kernel_findings(self):
        fs = analyze("kernel_clean.py")
        assert not rules(fs) & {"pallas-grid-divisibility",
                                "pallas-indexmap-closure",
                                "pallas-vmem-budget", "int32-narrowing",
                                "layout-contract"}

    def test_real_kernel_modules_stay_clean(self):
        """The shipped Pallas wrappers all pad before dividing, use pure
        index_maps and stay inside the VMEM budget (flash's conservative
        static estimate is suppressed inline with its reason)."""
        config = AnalysisConfig.from_pyproject(REPO)
        config.include = ("src/repro/kernels",)
        fs = run_analysis(REPO, config, PASSES)
        assert not [f for f in fs if f.rule.startswith("pallas-")]

    def test_batch_query_packed_math_routed_through_checked_caster(self):
        """Satellite: the PR-9 slot/row-pointer widening — no unguarded
        int32 narrowing anywhere in the device-layout builder."""
        config = AnalysisConfig.from_pyproject(REPO)
        config.include = ("src/repro/core/batch_query.py",)
        fs = run_analysis(REPO, config, PASSES)
        assert "int32-narrowing" not in rules(fs)
        assert "layout-contract" not in rules(fs)


class TestShapeflow:
    def _env(self, src: str):
        import ast
        from repro.analysis import shapeflow as sf
        tree = ast.parse(src)
        fn = next(n for n in ast.walk(tree)
                  if isinstance(n, ast.FunctionDef))
        return sf, fn, sf.function_env(fn, sf.module_int_consts(tree))

    def test_padding_idiom_proves_divisibility(self):
        sf, fn, env = self._env(
            "def f(w, block=256):\n"
            "    e = w.shape[0]\n"
            "    ep = int(np.ceil(max(e, 1) / block)) * block\n"
            "    g = ep // block\n")
        import ast
        ep = env.lin(ast.parse("ep", mode="eval").body)
        blk = env.lin(ast.parse("block", mode="eval").body)
        assert sf.divides(ep, blk)

    def test_unpadded_extent_does_not_divide(self):
        sf, fn, env = self._env(
            "def f(w, block=256):\n"
            "    e = w.shape[0]\n")
        import ast
        e = env.lin(ast.parse("e", mode="eval").body)
        blk = env.lin(ast.parse("block", mode="eval").body)
        assert not sf.divides(e, blk)

    def test_tuple_assignment_stays_arithmetic(self):
        """Mp, Kp = (ceil(M/bm)*bm, ceil(K/bk)*bk) binds element-wise —
        the matmul wrapper's idiom must not degrade to opaque atoms."""
        sf, fn, env = self._env(
            "def f(a, bm=128, bk=64):\n"
            "    M, K = a.shape\n"
            "    Mp, Kp = (int(np.ceil(M / bm)) * bm,\n"
            "              int(np.ceil(K / bk)) * bk)\n")
        import ast
        assert sf.divides(env.lin(ast.parse("Mp", mode="eval").body),
                          env.lin(ast.parse("bm", mode="eval").body))
        assert sf.divides(env.lin(ast.parse("Kp", mode="eval").body),
                          env.lin(ast.parse("bk", mode="eval").body))

    def test_sequence_repetition_is_not_a_product(self):
        import ast
        from repro.analysis import shapeflow as sf
        assert not sf.int_expr_has_product(
            ast.parse("[u] * w", mode="eval").body)
        assert sf.int_expr_has_product(
            ast.parse("k_index * n + u", mode="eval").body)

    def test_dtype_flow_through_preserving_ops(self):
        import ast
        from repro.analysis import shapeflow as sf
        sf_, fn, env = self._env(
            "def f(counts):\n"
            "    r = np.cumsum(counts.astype(np.int64))\n")
        assert env.dtype_of(ast.parse("r", mode="eval").body) == "int64"


# ---------------------------------------------------------------------------
# kernel witness (runtime half)
# ---------------------------------------------------------------------------

class TestKernelWitness:
    @pytest.fixture()
    def armed(self, monkeypatch):
        """Local witness wired into the decorators; the session gate never
        sees these deliberate test violations."""
        import repro.kernels.contracts as kc
        w = kc.KernelWitness()
        monkeypatch.setenv("REPRO_KERNEL_WITNESS", "1")
        monkeypatch.setattr(kc, "WITNESS", w)
        return w

    def test_disarmed_is_passthrough(self, monkeypatch):
        import numpy as np
        import repro.kernels.contracts as kc
        from repro.kernels.segmented_select import segmented_count_le
        monkeypatch.delenv("REPRO_KERNEL_WITNESS", raising=False)
        before = kc.WITNESS.calls
        w = np.array([1, 2, 3, 4], np.int32)
        seg = np.array([0, 0, 1, 1], np.int32)
        thr = np.array([2, 3], np.int32)
        segmented_count_le(w, seg, thr, 2)
        assert kc.WITNESS.calls == before

    def test_armed_clean_call_recorded(self, armed):
        import numpy as np
        from repro.kernels.segmented_select import segmented_count_le
        w = np.array([1, 2, 3, 4], np.int32)
        seg = np.array([0, 0, 1, 1], np.int32)
        thr = np.array([2, 3], np.int32)
        out = segmented_count_le(w, seg, thr, 2)
        assert list(np.asarray(out)) == [2, 1]
        assert armed.calls == 1
        assert armed.problems() == []
        assert armed.report()["kernels"]["segmented_count_le"]["calls"] == 1

    def test_arm_disarm_roundtrip(self, armed, monkeypatch):
        import numpy as np
        from repro.kernels.kcore_peel import degree_count
        src = np.array([0, 1], np.int32)
        dst = np.array([1, 2], np.int32)
        alive = np.ones(2, bool)
        degree_count(src, dst, alive, 3)
        assert armed.calls == 1
        monkeypatch.delenv("REPRO_KERNEL_WITNESS")
        degree_count(src, dst, alive, 3)
        assert armed.calls == 1          # disarmed call not recorded

    def test_symbol_conflict_detected(self, armed):
        import numpy as np
        from repro.kernels.kcore_peel import degree_count
        # src and dst declare the shared symbolic dim E; mismatched
        # lengths must surface as a shape-contract problem
        src = np.array([0, 1, 2], np.int32)
        dst = np.array([1, 2], np.int32)
        alive = np.ones(3, bool)
        try:
            degree_count(src, dst, alive, 3)
        except Exception:
            pass                          # the kernel itself may reject
        kinds = {p["kind"] for p in armed.problems()}
        assert "shape-contract" in kinds

    def test_dtype_violation_detected(self, armed):
        import numpy as np
        from repro.kernels.segmented_select import segmented_count_le
        w = np.array([1.5, 2.5], np.float64)   # ANY_INT expected
        seg = np.array([0, 0], np.int32)
        thr = np.array([2], np.int32)
        try:
            segmented_count_le(w, seg, thr, 1)
        except Exception:
            pass
        kinds = {p["kind"] for p in armed.problems()}
        assert "dtype-contract" in kinds

    def test_vmem_violation_detected(self, armed):
        import numpy as np
        from repro.kernels.segmented_select import segmented_count_le
        armed.vmem_budget = 16            # absurdly small budget
        w = np.array([1, 2], np.int32)
        seg = np.array([0, 0], np.int32)
        thr = np.array([2], np.int32)
        segmented_count_le(w, seg, thr, 1)
        kinds = {p["kind"] for p in armed.problems()}
        assert "vmem-budget" in kinds

    def test_violations_deduplicate(self, armed):
        import numpy as np
        from repro.kernels.segmented_select import segmented_count_le
        armed.vmem_budget = 16
        w = np.array([1, 2], np.int32)
        seg = np.array([0, 0], np.int32)
        thr = np.array([2], np.int32)
        for _ in range(3):
            segmented_count_le(w, seg, thr, 1)
        vmem = [p for p in armed.problems() if p["kind"] == "vmem-budget"]
        assert len(vmem) == 1 and vmem[0]["count"] == 3

    def test_report_is_json_serializable(self, armed):
        json.dumps(armed.report())

    def test_every_pallas_wrapper_carries_a_contract(self):
        """Coverage is assertable unarmed: each module-level Pallas
        wrapper registered its contract at import."""
        import repro.kernels.contracts as kc
        import repro.kernels.flash_attention  # noqa: F401
        import repro.kernels.kcore_peel  # noqa: F401
        import repro.kernels.label_prop  # noqa: F401
        import repro.kernels.segment_matmul  # noqa: F401
        import repro.kernels.segmented_select  # noqa: F401
        assert set(kc.CONTRACTS) >= {
            "segmented_count_le", "kth_smallest_pallas", "degree_count",
            "peel_round", "label_prop_round", "matmul", "segment_sum",
            "flash_attention"}
        from repro.kernels.segmented_select import segmented_count_le
        assert segmented_count_le.__kernel_contract__.name == \
            "segmented_count_le"

    def test_check_layout_roundtrip(self):
        import numpy as np
        import repro.kernels.contracts as kc
        z = np.zeros(4, np.int32)
        good = {name: z for name in kc.LAYOUT_CONTRACTS}
        assert kc.check_layout(good) == []
        bad = dict(good)
        bad["node_u"] = z.astype(np.int64)      # wrong dtype
        bad["bogus_plane"] = z                  # undeclared
        del bad["ver_k"]                        # missing
        w = kc.KernelWitness()
        problems = kc.check_layout(bad, witness=w)
        assert any("int64" in p for p in problems)
        assert any("bogus_plane" in p for p in problems)
        assert any("ver_k" in p for p in problems)
        assert {p["kind"] for p in w.problems()} == {"layout-contract"}


class TestWitnessedDeviceQuery:
    def test_armed_end_to_end_device_query(self, monkeypatch):
        """A real index upload + device query with the witness armed:
        the layout passes check_layout and every kernel call validates
        clean."""
        import numpy as np
        import jax.numpy as jnp
        import repro.kernels.contracts as kc
        from repro.core.batch_query import to_device, window_sweep
        from repro.core.core_time import edge_core_times
        from repro.core.pecb_index import build_pecb_index
        from repro.core.temporal_graph import gen_temporal_graph
        from repro.kernels.kcore_peel import degree_count

        w = kc.KernelWitness()
        monkeypatch.setenv("REPRO_KERNEL_WITNESS", "1")
        monkeypatch.setattr(kc, "WITNESS", w)

        g = gen_temporal_graph(n=20, m=90, t_max=8, seed=3)
        pecb = build_pecb_index(g, 2, edge_core_times(g, 2))
        dix = to_device(pecb)                 # layout checked on upload
        ts = jnp.asarray([1, 2], jnp.int32)
        te = jnp.asarray([5, 6], jnp.int32)
        mask = np.asarray(window_sweep(dix, jnp.int32(0), ts, te))
        assert mask.shape == (2, g.n)

        deg = degree_count(g.src, g.dst, np.ones(g.m, bool), g.n)
        assert int(np.asarray(deg).sum()) == 2 * g.m
        assert w.calls >= 1
        assert w.problems() == []
