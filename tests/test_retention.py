"""Sliding-window retention plane (DESIGN.md §10): prefix expiry, shrink
refresh bit-identity, registry/engine trim integration, cache
purge/rehome semantics, and the serving-stats bugfix-sweep regressions
that rode along (cache eviction counter, batcher drain deadline race)."""

import time
from concurrent.futures import Future

import numpy as np
import pytest

from repro.core.batch_query import refresh_device, to_device
from repro.core.core_time import (edge_core_times, extend_core_times,
                                  shrink_core_times)
from repro.core.kcore import tccs_oracle
from repro.core.pecb_index import build_pecb_index, build_stratified_index
from repro.core.query_api import ResultMode, TCCSQuery
from repro.core.streaming import extend_pecb_index, shrink_pecb_index
from repro.core.temporal_graph import TemporalGraph, gen_temporal_graph
from repro.serving import (EngineConfig, IndexRegistry, ResultCache,
                           RetentionPolicy, ServingEngine)
from repro.serving.batcher import MicroBatcher, Request

PECB_FIELDS = ("node_u", "node_v", "node_ct", "node_edge", "node_live_from",
               "node_live_to", "row_ptr", "ent_ts", "ent_left", "ent_right",
               "ent_parent", "vrow_ptr", "vent_ts", "vent_node")
TAB_FIELDS = ("edge_id", "ts_from", "ts_to", "ct", "vertex_ct")


def assert_pecb_identical(a, b):
    """Bit-identity for a per-k PECBIndex or a StratifiedPECB (same
    packed field names; the stratified form adds k-block offsets)."""
    for f in PECB_FIELDS:
        assert np.array_equal(getattr(a, f), getattr(b, f)), f
    assert (a.n, a.m, a.t_max) == (b.n, b.m, b.t_max)
    if hasattr(a, "supported_ks"):
        assert a.supported_ks == b.supported_ks
        assert a.k_max_graph == b.k_max_graph
        for f in ("knode_ptr", "kent_ptr", "kvent_ptr",
                  "ver_src", "ver_dst", "ver_t"):
            assert np.array_equal(getattr(a, f), getattr(b, f)), f
    else:
        assert a.k == b.k
    assert a.versions == b.versions


def assert_tab_identical(a, b):
    for f in TAB_FIELDS:
        assert np.array_equal(getattr(a, f), getattr(b, f)), f


# ----------------------------------------------------------------------
# TemporalGraph.expire_before / retain_last
# ----------------------------------------------------------------------

class TestExpire:
    def test_prefix_expiry_shifts_and_renumbers(self):
        g = gen_temporal_graph(n=30, m=240, t_max=16, seed=1)
        t_cut = 7
        g2 = g.expire_before(t_cut)
        cut = int(np.searchsorted(g.t, t_cut, side="left"))
        assert g2.m == g.m - cut
        assert g2.t_max == g.t_max - (t_cut - 1)
        assert int(g2.t.min()) == 1 or g2.m == 0
        assert np.array_equal(g2.src, g.src[cut:])
        assert np.array_equal(g2.dst, g.dst[cut:])
        assert np.array_equal(g2.t, g.t[cut:] - (t_cut - 1))

    def test_noop_and_all_expired(self):
        g = gen_temporal_graph(n=20, m=100, t_max=10, seed=2)
        assert g.expire_before(1) is g
        assert g.expire_before(0) is g
        assert g.retain_last(g.t_max) is g
        assert g.retain_last(g.t_max + 3) is g
        ge = g.expire_before(g.t_max + 1)
        assert ge.m == 0 and ge.t_max == 0 and ge.n == g.n
        with pytest.raises(ValueError, match="positive"):
            g.retain_last(0)

    def test_retain_last_is_expire_before(self):
        g = gen_temporal_graph(n=20, m=150, t_max=12, seed=3)
        w = 5
        g2 = g.retain_last(w)
        g3 = g.expire_before(g.t_max - w + 1)
        assert np.array_equal(g2.t, g3.t) and g2.t_max == w

    def test_shift_applies_even_below_min_timestamp(self):
        # a cut below the smallest timestamp still contracts the timeline
        g = TemporalGraph.from_edges(5, [(0, 1, 5), (1, 2, 6), (2, 3, 6)])
        g2 = g.expire_before(3)
        assert g2.m == g.m and g2.t_max == 4
        assert np.array_equal(g2.t, g.t - 2)

    def test_extend_roundtrip_after_expiry(self):
        g = gen_temporal_graph(n=25, m=200, t_max=14, seed=4)
        g2 = g.expire_before(6)
        g3 = g2.extend([(0, 1, g2.t_max + 1), (2, 3, g2.t_max + 2)])
        assert g3.t_max == g2.t_max + 2 and g3.m == g2.m + 2


# ----------------------------------------------------------------------
# shrink == cold rebuild on the truncated edge list, bit-identically
# ----------------------------------------------------------------------

class TestShrink:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    @pytest.mark.parametrize("k", [2, 3])
    @pytest.mark.parametrize("frac", [0.25, 0.6, 0.9])
    def test_bit_identical_to_cold(self, seed, k, frac):
        g = gen_temporal_graph(n=30, m=260, t_max=15, seed=seed)
        t_cut = max(2, int(g.t_max * frac))
        tab0 = edge_core_times(g, k)
        idx0 = build_pecb_index(g, k, tab0)
        g2 = g.expire_before(t_cut)
        tab2 = shrink_core_times(g2, k, tab0)
        tab_cold = edge_core_times(g2, k)
        assert_tab_identical(tab2, tab_cold)
        assert_pecb_identical(shrink_pecb_index(g2, k, tab2, idx0),
                              build_pecb_index(g2, k, tab_cold))

    def test_all_expired_yields_empty_index(self):
        g = gen_temporal_graph(n=20, m=150, t_max=10, seed=11)
        tab0 = edge_core_times(g, 2)
        idx0 = build_pecb_index(g, 2, tab0)
        ge = g.expire_before(g.t_max + 1)
        tab2 = shrink_core_times(ge, 2, tab0)
        assert tab2.num_versions == 0
        idx2 = shrink_pecb_index(ge, 2, tab2, idx0)
        assert idx2.num_nodes == 0
        assert_pecb_identical(idx2, build_pecb_index(ge, 2))

    def test_interleaved_extend_and_shrink_epochs(self):
        """The full epoch lifecycle: grow, trim, grow, trim — every hop
        bit-identical to a cold build of the current retained window."""
        full = gen_temporal_graph(n=35, m=700, t_max=40, seed=7)
        k, window = 3, 12
        cur, _ = full.split_at(window)
        tab = edge_core_times(cur, k)
        idx = build_pecb_index(cur, k, tab)
        offset, t_abs = 0, window
        hops = 0
        while t_abs < full.t_max:
            t_hi = min(t_abs + 9, full.t_max)
            lo = int(np.searchsorted(full.t, t_abs, side="right"))
            hi = int(np.searchsorted(full.t, t_hi, side="right"))
            chunk = [(int(u), int(v), int(t) - offset) for u, v, t in
                     zip(full.src[lo:hi], full.dst[lo:hi], full.t[lo:hi])]
            cur = cur.extend(chunk)
            tab = extend_core_times(cur, k, tab)
            idx = extend_pecb_index(cur, k, tab, idx)
            t_abs = t_hi
            g2 = cur.retain_last(window)
            if g2 is not cur:
                tab = shrink_core_times(g2, k, tab)
                idx = shrink_pecb_index(g2, k, tab, idx)
                offset += cur.t_max - g2.t_max
                cur = g2
                hops += 1
        assert hops >= 2
        tab_cold = edge_core_times(cur, k)
        assert_tab_identical(tab, tab_cold)
        assert_pecb_identical(idx, build_pecb_index(cur, k, tab_cold))

    def test_mismatched_inputs_raise(self):
        g = gen_temporal_graph(n=30, m=220, t_max=12, seed=12)
        tab0 = edge_core_times(g, 2)
        idx0 = build_pecb_index(g, 2, tab0)
        g2 = g.expire_before(5)
        tab2 = shrink_core_times(g2, 2, tab0)
        with pytest.raises(ValueError, match="k="):
            shrink_pecb_index(g2, 3, tab2, idx0)
        with pytest.raises(ValueError, match="core-time table"):
            shrink_pecb_index(g2, 2, tab0, idx0)
        with pytest.raises(ValueError, match="supergraph"):
            shrink_core_times(g, 2, tab2)   # shrink cannot go backwards
        # a table of a *different* graph must be refused, not absorbed
        g_other = gen_temporal_graph(n=30, m=220, t_max=12, seed=99)
        tab_other = edge_core_times(g_other, 2)
        idx_other = build_pecb_index(g_other, 2, tab_other)
        with pytest.raises(ValueError):
            shrink_pecb_index(g2, 2, tab2, idx_other)

    def test_shrunk_answers_match_oracle(self):
        g = gen_temporal_graph(n=30, m=300, t_max=14, seed=13)
        k, t_cut = 2, 6
        tab0 = edge_core_times(g, k)
        idx0 = build_pecb_index(g, k, tab0)
        g2 = g.expire_before(t_cut)
        idx2 = shrink_pecb_index(g2, k, shrink_core_times(g2, k, tab0), idx0)
        rng = np.random.default_rng(0)
        for _ in range(40):
            u = int(rng.integers(0, g2.n))
            ts = int(rng.integers(1, g2.t_max + 1))
            te = int(rng.integers(ts, g2.t_max + 1))
            got = idx2.answer(TCCSQuery(u, ts, te, k)).vertices
            assert got == frozenset(tccs_oracle(g2, k, u, ts, te))

    def test_device_mirror_shrink_is_exact_and_frees_bytes(self):
        from repro.core.batch_query import _ARRAY_FIELDS, _META_FIELDS
        g = gen_temporal_graph(n=30, m=260, t_max=14, seed=21)
        tab0 = edge_core_times(g, 2)
        idx0 = build_pecb_index(g, 2, tab0)
        dix0 = to_device(idx0)
        g2 = g.expire_before(8)
        idx2 = shrink_pecb_index(g2, 2, shrink_core_times(g2, 2, tab0), idx0)
        dix2, stats = refresh_device(idx0, dix0, idx2)
        fresh = to_device(idx2)
        for f in _ARRAY_FIELDS:
            assert np.array_equal(np.asarray(getattr(dix2, f)),
                                  np.asarray(getattr(fresh, f))), f
        for f in _META_FIELDS:
            assert getattr(dix2, f) == getattr(fresh, f), f
        assert stats["freed_bytes"] > 0


# ----------------------------------------------------------------------
# registry retain + engine retention
# ----------------------------------------------------------------------

class TestRegistryRetain:
    def _graph(self, seed=31):
        return gen_temporal_graph(n=40, m=420, t_max=18, seed=seed)

    def test_retain_shrinks_and_swaps_atomically(self):
        g = self._graph()
        reg = IndexRegistry()
        try:
            reg.register_graph("feed", g)
            h0 = reg.get("feed")
            assert h0.epoch == 0
            futs = reg.retain("feed", 7)
            assert set(futs) == {"feed"}
            h1 = futs["feed"].result(timeout=60)
            g2 = g.expire_before(7)
            assert h1.epoch == 1 and h1.graph.t_max == g2.t_max
            assert reg.get_nowait("feed", start_build=False) is h1
            assert_pecb_identical(h1.pecb, build_stratified_index(g2))
            assert reg.stats()["retentions"] == 1
            assert reg.stats()["epochs"] == {"feed": 1}
            # old handle still answers (old epoch pinned for in-flight use)
            q = TCCSQuery(3, 8, g.t_max, 2)
            assert h0.pecb.answer(q).vertices == h1.pecb.answer(
                TCCSQuery(3, 2, g2.t_max, 2)).vertices
        finally:
            reg.close()

    def test_retain_noop_and_without_resident_index(self):
        g = self._graph(32)
        reg = IndexRegistry()
        try:
            reg.register_graph("feed", g)
            assert reg.retain("feed", 1) == {}      # nothing expires
            assert reg.retain("feed", 5) == {}      # nothing resident
            h = reg.get("feed")                  # cold build: new epoch
            assert h.epoch == 1
            assert h.graph.t_max == g.expire_before(5).t_max
        finally:
            reg.close()

    def test_retain_then_ingest_chain_grows_from_trimmed_handle(self):
        """retain + extend scheduled back-to-back without waiting: the
        refresh job captures the pre-trim handle at schedule time, but by
        run time the FIFO shrink has swapped in the trimmed handle — the
        refresh must grow from *that* (regression: it extended the
        captured pre-trim graph and raised)."""
        g = self._graph(34)
        reg = IndexRegistry()
        try:
            reg.register_graph("feed", g)
            reg.get("feed")
            g2 = g.expire_before(9)
            f1 = reg.retain("feed", 9)
            f2 = reg.extend_graph("feed", [(0, 1, g2.t_max + 1)])
            for f in list(f1.values()) + list(f2.values()):
                f.result(timeout=120)
            h = reg.get_nowait("feed", start_build=False)
            expected = g2.extend([(0, 1, g2.t_max + 1)])
            assert h is not None and h.epoch == 2
            assert h.graph.t_max == expected.t_max
            assert_pecb_identical(h.pecb, build_stratified_index(expected))
        finally:
            reg.close()

    def test_ingest_then_retain_chain_lands_in_order(self):
        """extend + retain scheduled back-to-back: the FIFO worker must run
        the suffix refresh first, then shrink the *refreshed* handle."""
        g = self._graph(33)
        g0, suffix = g.split_at(12)
        suffix = [tuple(e) for e in suffix.tolist()]
        reg = IndexRegistry()
        try:
            reg.register_graph("feed", g0)
            reg.get("feed")
            f1 = reg.extend_graph("feed", suffix)
            f2 = reg.retain("feed", 9)
            for f in list(f1.values()) + list(f2.values()):
                f.result(timeout=120)
            h = reg.get_nowait("feed", start_build=False)
            assert h is not None and h.epoch == 2
            g2 = g.expire_before(9)
            assert h.graph.t_max == g2.t_max
            assert_pecb_identical(h.pecb, build_stratified_index(g2))
        finally:
            reg.close()


class TestEngineRetention:
    def _graph(self, seed=41):
        return gen_temporal_graph(n=40, m=420, t_max=18, seed=seed)

    def test_cache_purge_and_rehome_on_trim(self):
        g = self._graph()
        t_cut = 7
        with ServingEngine(EngineConfig(flush_ms=0.5)) as eng:
            eng.register_graph("feed", g)
            eng.registry.get("feed")
            q_dead = TCCSQuery(3, 1, 5, 2)            # touches the prefix
            q_live = TCCSQuery(3, 9, 14, 2)           # survives, rehomes
            q_edge = TCCSQuery(3, 9, 14, 2, ResultMode.EDGES)  # dropped
            eng.answer("feed", q_dead)
            r_live = eng.answer("feed", q_live)
            eng.answer("feed", q_edge)
            eng.retain("feed", t_cut, wait=True)
            shift = t_cut - 1
            hit = eng.answer("feed", TCCSQuery(3, 9 - shift, 14 - shift, 2))
            assert hit.provenance.route == "cache"
            assert hit.vertices == r_live.vertices
            # the rehomed result's canonical spec is in the new timeline
            assert (hit.query.ts, hit.query.te) == (9 - shift, 14 - shift)
            # expired-prefix window: gone from the cache, recomputed exact
            g2 = g.expire_before(t_cut)
            res = eng.answer("feed", TCCSQuery(3, 1, 2, 2))
            assert res.provenance.route != "cache"
            assert res.vertices == frozenset(tccs_oracle(g2, 2, 3, 1, 2))
            # EDGES payload embeds old timestamps: dropped, not rehomed
            re2 = eng.answer(
                "feed", TCCSQuery(3, 9 - shift, 14 - shift, 2,
                                  ResultMode.EDGES))
            assert re2.provenance.route != "cache"
            st = eng.cache.stats()
            assert st["rehomes"] >= 1 and st["purges"] >= 2

    def test_post_trim_queries_match_oracle(self):
        g = self._graph(42)
        with ServingEngine(EngineConfig(flush_ms=0.5)) as eng:
            eng.register_graph("feed", g)
            eng.registry.get("feed")
            eng.retain("feed", 8, wait=True)
            g2 = g.expire_before(8)
            rng = np.random.default_rng(3)
            for _ in range(20):
                u = int(rng.integers(0, g2.n))
                ts = int(rng.integers(1, g2.t_max + 1))
                te = int(rng.integers(ts, g2.t_max + 1))
                res = eng.answer("feed", TCCSQuery(u, ts, te, 2))
                assert res.vertices == frozenset(
                    tccs_oracle(g2, 2, u, ts, te)), (u, ts, te)

    def test_queries_answer_throughout_trim(self):
        g = self._graph(43)
        with ServingEngine(EngineConfig(flush_ms=0.5)) as eng:
            eng.register_graph("feed", g)
            eng.registry.get("feed")
            futs = eng.retain("feed", 9)
            trim_fut = futs["feed"]
            answered = 0
            while not trim_fut.done() or answered < 32:
                res = eng.answer("feed", TCCSQuery(answered % g.n, 1, 5, 2))
                assert res is not None
                answered += 1
                if answered >= 256:
                    break
            trim_fut.result(timeout=60)
            assert answered >= 32

    def test_retention_policy_auto_trims_on_ingest(self):
        g = self._graph(44)
        g0, suffix = g.split_at(12)
        suffix = [tuple(e) for e in suffix.tolist()]
        with ServingEngine(EngineConfig(flush_ms=0.5)) as eng:
            eng.register_graph("feed", g0)
            eng.registry.get("feed")
            eng.set_retention("feed", RetentionPolicy(window=10, slack=2))
            assert eng.retention_policy("feed").window == 10
            eng.ingest("feed", suffix, wait=True)    # 18 > 12: trims to 10
            h = eng.registry.get_nowait("feed", start_build=False)
            assert h.graph.t_max == 10
            assert h.epoch == 2                      # extend then retain
            gt = g.expire_before(g.t_max - 10 + 1)
            assert_pecb_identical(h.pecb, build_stratified_index(gt))
            assert eng.stats()["engine"]["counters"]["auto_trims"] == 1
            # within slack: the next tiny ingest must NOT trim again
            eng.ingest("feed", [(0, 1, h.graph.t_max + 1)], wait=True)
            h2 = eng.registry.get_nowait("feed", start_build=False)
            assert h2.graph.t_max == 11              # grew, under 10 + 2
            assert eng.stats()["engine"]["counters"]["auto_trims"] == 1

    def test_policy_every_and_unset(self):
        g = self._graph(45)
        g0, _ = g.split_at(6)
        with ServingEngine(EngineConfig(flush_ms=0.5)) as eng:
            eng.register_graph("feed", g0)
            eng.registry.get("feed")
            eng.set_retention("feed", RetentionPolicy(window=6, every=2))
            # first ingest: tick 1 of 2 -> no trim despite overflow
            eng.ingest("feed", [(0, 1, 7)], wait=True)
            h = eng.registry.get_nowait("feed", start_build=False)
            assert h.graph.t_max == 7
            # second ingest: tick 2 -> trims back to the window
            eng.ingest("feed", [(1, 2, 8)], wait=True)
            h = eng.registry.get_nowait("feed", start_build=False)
            assert h.graph.t_max == 6
            eng.set_retention("feed", None)
            assert eng.retention_policy("feed") is None
            eng.ingest("feed", [(2, 3, h.graph.t_max + 4)], wait=True)
            h = eng.registry.get_nowait("feed", start_build=False)
            assert h.graph.t_max == 10               # no policy: no trim
        with pytest.raises(ValueError, match="window"):
            RetentionPolicy(window=0)

    def test_rolling_cycles_keep_memory_bounded(self):
        """>=5 append+expire cycles: the retained timeline and the dense
        table stay bounded and each swapped index is bit-identical to a
        cold build of its retained window."""
        full = gen_temporal_graph(n=35, m=900, t_max=45, seed=46)
        window, k = 10, 2
        g0, _ = full.split_at(window)
        with ServingEngine(EngineConfig(flush_ms=0.5)) as eng:
            eng.register_graph("roll", g0)
            eng.registry.get("roll")
            eng.set_retention("roll", RetentionPolicy(window=window))
            offset, t_abs, cycles = 0, window, 0
            while t_abs < full.t_max:
                t_hi = min(t_abs + 7, full.t_max)
                lo = int(np.searchsorted(full.t, t_abs, side="right"))
                hi = int(np.searchsorted(full.t, t_hi, side="right"))
                chunk = [(int(u), int(v), int(t) - offset) for u, v, t in
                         zip(full.src[lo:hi], full.dst[lo:hi],
                             full.t[lo:hi])]
                eng.ingest("roll", chunk, wait=True)
                t_abs = t_hi
                h = eng.registry.get_nowait("roll", start_build=False)
                assert h.graph.t_max <= window
                assert h.tab.num_versions <= len(h.tab.ks) * full.n * (window + 1)
                offset = t_abs - h.graph.t_max
                cycles += 1
            assert cycles >= 5
            expected = full.retain_last(window)
            assert_pecb_identical(h.pecb, build_stratified_index(expected))


# ----------------------------------------------------------------------
# satellite regressions: cache + batcher
# ----------------------------------------------------------------------

class TestCacheStats:
    def test_capacity_evictions_increment_counter(self):
        """Regression: filling past capacity must report every LRU
        eviction in stats() — an under-reporting counter makes the hit
        rate and working-set sizing look healthier than they are."""
        c = ResultCache(capacity=3)
        for i in range(8):
            c.put((("w", 2), (i, 1, 2, 2, "vertices")), frozenset([i]))
        assert len(c) == 3
        assert c.evictions == 5
        assert c.stats()["evictions"] == 5
        # updating an existing key neither evicts nor double-counts
        c.put((("w", 2), (7, 1, 2, 2, "vertices")), frozenset())
        assert c.evictions == 5 and len(c) == 3

    def test_purge_window_suffix_semantics_unchanged(self):
        c = ResultCache()
        c.put((("w", 2), (0, 1, 4, 2, "vertices")), "old")
        c.put((("w", 2), (0, 5, 9, 2, "vertices")), "touch")
        c.put((("x", 2), (0, 5, 9, 2, "vertices")), "foreign")
        assert c.purge_window(("w", 2), 5, 10) == 1
        assert c.get((("w", 2), (0, 1, 4, 2, "vertices"))) == "old"
        assert c.get((("x", 2), (0, 5, 9, 2, "vertices"))) == "foreign"
        assert c.rehomes == 0

    def test_purge_window_shift_rehomes_survivors(self):
        c = ResultCache()
        key = ("w", 2)
        c.put((key, (0, 1, 4, 2, "vertices")), "dead")      # touches prefix
        c.put((key, (0, 7, 9, 2, "vertices")), frozenset([1]))
        c.put((key, (0, 7, 9, 2, "edges")), "payload")      # dropped
        c.put((key, (0, 1, 0, 2, "vertices")), "empty")     # marker: as-is
        c.put((("x", 3), (0, 7, 9, 3, "vertices")), "foreign")
        purged = c.purge_window(key, 1, 5, shift=5)
        assert purged == 2                                  # dead + edges
        assert c.get((key, (0, 1, 4, 2, "vertices"))) is None   # purged
        assert c.get((key, (0, 7, 9, 2, "vertices"))) is None   # rehomed away
        assert c.get((key, (0, 2, 4, 2, "edges"))) is None      # dropped
        assert c.get((key, (0, 1, 0, 2, "vertices"))) == "empty"
        assert c.get((key, (0, 7 - 5, 9 - 5, 2, "vertices"))) == frozenset([1])
        assert c.get((("x", 3), (0, 7, 9, 3, "vertices"))) == "foreign"
        assert c.rehomes == 1

    def test_epoch_floor_gates_pre_trim_fills(self):
        """A fill carrying an epoch below the index key's retention floor
        is dropped atomically inside the put lock — the close-out for a
        batch/sweep bound to a pre-trim handle finishing after the trim's
        purge+rehome (DESIGN.md §10.3)."""
        c = ResultCache()
        key = ("w", 2)
        c.put((key, (0, 1, 4, 2, "vertices")), "pre", epoch=0)   # no floor
        c.raise_floor(key, 2)
        c.put((key, (0, 2, 5, 2, "vertices")), "stale", epoch=1)
        assert c.get((key, (0, 2, 5, 2, "vertices"))) is None
        assert c.gated == 1 and c.stats()["gated"] == 1
        c.put((key, (0, 2, 5, 2, "vertices")), "fresh", epoch=2)
        assert c.get((key, (0, 2, 5, 2, "vertices"))) == "fresh"
        # floors only rise; other index keys and epoch-less puts unaffected
        c.raise_floor(key, 1)
        c.put((key, (0, 3, 6, 2, "vertices")), "still-stale", epoch=1)
        assert c.get((key, (0, 3, 6, 2, "vertices"))) is None
        c.put((("x", 3), (0, 2, 5, 3, "vertices")), "other", epoch=0)
        assert c.get((("x", 3), (0, 2, 5, 3, "vertices"))) == "other"
        c.put("plain-key", "no-epoch")
        assert c.get("plain-key") == "no-epoch"

    def test_purge_window_shift_rewrites_result_query(self):
        import dataclasses as dc
        from repro.core.query_api import Provenance, TCCSResult
        c = ResultCache()
        key = ("w", 2)
        q = TCCSQuery(0, 6, 9, 2)
        res = TCCSResult(q, frozenset([1, 2]), 2,
                         provenance=Provenance(route="host"))
        c.put((key, q.cache_key()), res)
        c.purge_window(key, 1, 5, shift=5)
        hit = c.get((key, (0, 1, 4, 2, "vertices")))
        assert hit is not None
        assert (hit.query.ts, hit.query.te) == (1, 4)
        assert hit.vertices == res.vertices


class TestBatcherDrainDeadline:
    def test_drain_completes_when_work_finishes_before_deadline(self):
        done = []
        b = MicroBatcher(lambda reqs: [done.append(1) or None
                                       for _ in reqs],
                        max_batch=8, flush_ms=1.0)
        try:
            b.submit(Request(0, 1, 1, Future(), t_submit=time.perf_counter()))
            b.drain(timeout=10.0)
            assert done
        finally:
            b.close()

    def test_drain_deadline_race_returns_instead_of_raising(self):
        """Regression: a deadline expiring in the same iteration the queue
        empties must drain cleanly — the predicate is re-checked before
        TimeoutError. Driven by an execute_fn that finishes right as the
        drain deadline lands."""
        release = []

        def execute(reqs):
            while not release:
                time.sleep(0.005)
            return [None] * len(reqs)

        b = MicroBatcher(execute, max_batch=8, flush_ms=0.5)
        try:
            fut = b.submit(Request(0, 1, 1, Future(),
                                   t_submit=time.perf_counter()))
            # expire the deadline while the batch is genuinely in flight:
            # a true timeout must still raise
            with pytest.raises(TimeoutError):
                b.drain(timeout=0.05)
            release.append(1)
            fut.result(timeout=5)
            # after a TimeoutError the batcher must stay fully usable and
            # an already-elapsed deadline with an idle queue must not raise
            b.drain(timeout=0.0)
            b.drain(timeout=-1.0)
            fut2 = b.submit(Request(0, 1, 1, Future(),
                                    t_submit=time.perf_counter()))
            b.drain(timeout=10.0)
            assert fut2.done()
        finally:
            b.close()
