"""Fault tolerance: checkpoint/restart, failure injection, stragglers,
elastic remesh, gradient compression numerics."""

import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.runtime.fault_tolerance import (FailureInjector, HeartbeatMonitor,
                                           RecoverableError, RestartingRunner)
from repro.runtime.elastic import remesh
from repro.optim import compression, adamw


class TestCheckpointManager:
    def test_roundtrip_and_crc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"w": jnp.arange(12.0).reshape(3, 4), "step": jnp.int32(7),
                "nested": [jnp.ones(5), {"b": jnp.zeros(2)}]}
        mgr.save(10, tree, {"note": "hi"})
        step, restored, meta = mgr.restore()
        assert step == 10 and meta["note"] == "hi"
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_retention_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, {"x": jnp.ones(3) * s})
        ckpts = [p for p in os.listdir(tmp_path) if p.endswith(".ckpt")]
        assert len(ckpts) == 2
        assert mgr.latest_step() == 4

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save_async(5, {"x": jnp.ones(4)})
        mgr.wait()
        assert mgr.latest_step() == 5

    def test_atomic_no_partial_visible(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"x": jnp.ones(3)})
        # a stale tmp file from a crashed writer must not confuse restore
        with open(os.path.join(str(tmp_path), "step_0000000002.tmp-999"), "w") as f:
            f.write("garbage")
        assert mgr.latest_step() == 1


class TestRestartingRunner:
    def test_recovers_from_injected_faults(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        state0 = {"x": jnp.zeros(())}

        def step_fn(state, step):
            return {"x": state["x"] + 1.0}

        def save_fn(step, state):
            mgr.save(step, state)

        def restore_fn():
            step, state, _ = mgr.restore()
            return step, state

        injector = FailureInjector(fail_at={7: "preemption", 23: "link flap"})
        runner = RestartingRunner(step_fn, save_fn, restore_fn,
                                  ckpt_every=5, injector=injector)
        save_fn(0, state0)
        end, state = runner.run(state0, 0, 30)
        assert end == 30
        assert float(state["x"]) == 30.0          # exactly-once semantics
        assert runner.restarts == 2
        assert runner.steps_lost > 0

    def test_gives_up_after_max_restarts(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(0, {"x": jnp.zeros(())})
        injector = FailureInjector(fail_at={i: "flaky" for i in range(1, 50)})
        # every step fails fresh (fired set cleared each time)

        def step_fn(state, step):
            injector.fired.discard(step)
            return state

        runner = RestartingRunner(step_fn, lambda s, st: mgr.save(s, st),
                                  lambda: mgr.restore()[:2],
                                  ckpt_every=100, max_restarts=3, injector=injector)
        with pytest.raises(RecoverableError):
            runner.run({"x": jnp.zeros(())}, 0, 10)


class TestHeartbeat:
    def test_straggler_flagged(self):
        mon = HeartbeatMonitor(n_hosts=4, threshold=1.5)
        for step in range(20):
            for h in range(4):
                mon.report(h, 1.0 if h != 2 else 3.0)
        assert mon.stragglers() == [2]

    def test_healthy_fleet_clean(self):
        mon = HeartbeatMonitor(n_hosts=4, threshold=2.0)
        rng = np.random.default_rng(0)
        for _ in range(50):
            for h in range(4):
                mon.report(h, 1.0 + 0.05 * rng.random())
        assert mon.stragglers() == []


class TestElastic:
    def test_remesh_degrades_missing_axes(self):
        mesh1 = jax.make_mesh((1, 1), ("data", "model"))
        host = {"w": np.arange(16.0).reshape(4, 4)}
        specs = {"w": P("data", "model")}
        placed = remesh(host, specs, mesh1)
        np.testing.assert_array_equal(np.asarray(placed["w"]), host["w"])
        # restoring a multi-pod checkpoint spec on a pod-less mesh
        specs2 = {"w": P(("pod", "data"), None)}
        placed2 = remesh(host, specs2, mesh1)
        np.testing.assert_array_equal(np.asarray(placed2["w"]), host["w"])

    def test_checkpoint_restore_with_shardings(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = {"w": jnp.arange(8.0)}
        mgr.save(3, tree)
        mesh = jax.make_mesh((1,), ("data",))
        from jax.sharding import NamedSharding
        sh = {"w": NamedSharding(mesh, P("data"))}
        _, restored, _ = mgr.restore(shardings=sh)
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(8.0))


class TestCompression:
    def test_quantize_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(128, 64)), jnp.float32)
        q, s = compression.quantize(g)
        back = compression.dequantize(q, s)
        assert float(jnp.abs(back - g).max()) <= float(s) * 0.5 + 1e-6

    def test_error_feedback_converges_on_toy_problem(self):
        """SGD with int8 error-feedback gradient compression still drives a
        quadratic to its optimum (the error accumulator does its job)."""
        w = jnp.asarray([3.0, -2.0, 1.5])
        target = jnp.asarray([-1.0, 0.5, 2.0])
        err = jnp.zeros_like(w)
        lr = 0.1
        for _ in range(300):
            g = 2 * (w - target)
            q, s, err = compression.compress_update(g, err)
            w = w - lr * compression.dequantize(q, s)
        np.testing.assert_allclose(np.asarray(w), np.asarray(target), atol=1e-2)

    def test_adamw_moves_toward_minimum(self):
        cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                                total_steps=400, clip_norm=10.0)
        params = {"w": jnp.asarray([4.0, -3.0])}
        state = adamw.init_state(params)
        for _ in range(400):
            grads = {"w": 2 * params["w"]}
            params, state, _ = adamw.apply_updates(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 0.2
