"""Fault tolerance: checkpoint/restart, failure injection, stragglers,
elastic remesh, gradient compression numerics, and crash recovery of the
persistent index store (DESIGN.md §13.5)."""

import os
import shutil

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.core.temporal_graph import gen_temporal_graph
from repro.runtime.fault_tolerance import (FailureInjector, HeartbeatMonitor,
                                           RecoverableError, RestartingRunner)
from repro.runtime.elastic import remesh
from repro.optim import compression, adamw
from repro.serving.registry import IndexRegistry
from repro.store import IndexStore
from repro.store.index_store import key_dirname

from test_streaming import assert_pecb_identical, split_epoch


class TestCheckpointManager:
    def test_roundtrip_and_crc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"w": jnp.arange(12.0).reshape(3, 4), "step": jnp.int32(7),
                "nested": [jnp.ones(5), {"b": jnp.zeros(2)}]}
        mgr.save(10, tree, {"note": "hi"})
        step, restored, meta = mgr.restore()
        assert step == 10 and meta["note"] == "hi"
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_retention_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, {"x": jnp.ones(3) * s})
        ckpts = [p for p in os.listdir(tmp_path) if p.endswith(".ckpt")]
        assert len(ckpts) == 2
        assert mgr.latest_step() == 4

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save_async(5, {"x": jnp.ones(4)})
        mgr.wait()
        assert mgr.latest_step() == 5

    def test_atomic_no_partial_visible(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"x": jnp.ones(3)})
        # a stale tmp file from a crashed writer must not confuse restore
        with open(os.path.join(str(tmp_path), "step_0000000002.tmp-999"), "w") as f:
            f.write("garbage")
        assert mgr.latest_step() == 1


class TestRestartingRunner:
    def test_recovers_from_injected_faults(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        state0 = {"x": jnp.zeros(())}

        def step_fn(state, step):
            return {"x": state["x"] + 1.0}

        def save_fn(step, state):
            mgr.save(step, state)

        def restore_fn():
            step, state, _ = mgr.restore()
            return step, state

        injector = FailureInjector(fail_at={7: "preemption", 23: "link flap"})
        runner = RestartingRunner(step_fn, save_fn, restore_fn,
                                  ckpt_every=5, injector=injector)
        save_fn(0, state0)
        end, state = runner.run(state0, 0, 30)
        assert end == 30
        assert float(state["x"]) == 30.0          # exactly-once semantics
        assert runner.restarts == 2
        assert runner.steps_lost > 0

    def test_gives_up_after_max_restarts(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(0, {"x": jnp.zeros(())})
        injector = FailureInjector(fail_at={i: "flaky" for i in range(1, 50)})
        # every step fails fresh (fired set cleared each time)

        def step_fn(state, step):
            injector.fired.discard(step)
            return state

        runner = RestartingRunner(step_fn, lambda s, st: mgr.save(s, st),
                                  lambda: mgr.restore()[:2],
                                  ckpt_every=100, max_restarts=3, injector=injector)
        with pytest.raises(RecoverableError):
            runner.run({"x": jnp.zeros(())}, 0, 10)


class TestHeartbeat:
    def test_straggler_flagged(self):
        mon = HeartbeatMonitor(n_hosts=4, threshold=1.5)
        for step in range(20):
            for h in range(4):
                mon.report(h, 1.0 if h != 2 else 3.0)
        assert mon.stragglers() == [2]

    def test_healthy_fleet_clean(self):
        mon = HeartbeatMonitor(n_hosts=4, threshold=2.0)
        rng = np.random.default_rng(0)
        for _ in range(50):
            for h in range(4):
                mon.report(h, 1.0 + 0.05 * rng.random())
        assert mon.stragglers() == []


class TestElastic:
    def test_remesh_degrades_missing_axes(self):
        mesh1 = jax.make_mesh((1, 1), ("data", "model"))
        host = {"w": np.arange(16.0).reshape(4, 4)}
        specs = {"w": P("data", "model")}
        placed = remesh(host, specs, mesh1)
        np.testing.assert_array_equal(np.asarray(placed["w"]), host["w"])
        # restoring a multi-pod checkpoint spec on a pod-less mesh
        specs2 = {"w": P(("pod", "data"), None)}
        placed2 = remesh(host, specs2, mesh1)
        np.testing.assert_array_equal(np.asarray(placed2["w"]), host["w"])

    def test_checkpoint_restore_with_shardings(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = {"w": jnp.arange(8.0)}
        mgr.save(3, tree)
        mesh = jax.make_mesh((1,), ("data",))
        from jax.sharding import NamedSharding
        sh = {"w": NamedSharding(mesh, P("data"))}
        _, restored, _ = mgr.restore(shardings=sh)
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(8.0))


class TestCompression:
    def test_quantize_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(128, 64)), jnp.float32)
        q, s = compression.quantize(g)
        back = compression.dequantize(q, s)
        assert float(jnp.abs(back - g).max()) <= float(s) * 0.5 + 1e-6

    def test_error_feedback_converges_on_toy_problem(self):
        """SGD with int8 error-feedback gradient compression still drives a
        quadratic to its optimum (the error accumulator does its job)."""
        w = jnp.asarray([3.0, -2.0, 1.5])
        target = jnp.asarray([-1.0, 0.5, 2.0])
        err = jnp.zeros_like(w)
        lr = 0.1
        for _ in range(300):
            g = 2 * (w - target)
            q, s, err = compression.compress_update(g, err)
            w = w - lr * compression.dequantize(q, s)
        np.testing.assert_allclose(np.asarray(w), np.asarray(target), atol=1e-2)

    def test_adamw_moves_toward_minimum(self):
        cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                                total_steps=400, clip_norm=10.0)
        params = {"w": jnp.asarray([4.0, -3.0])}
        state = adamw.init_state(params)
        for _ in range(400):
            grads = {"w": 2 * params["w"]}
            params, state, _ = adamw.apply_updates(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 0.2


class TestStoreCrashRecovery:
    """Kill-the-writer fault injection on the persistent index store: every
    crash mode must reopen to the *last committed epoch* and serve an index
    bit-identical to the one that was live at that commit. The commit point
    is the manifest rename — everything short of it is ignorable debris."""

    KEY = "crash"

    @pytest.fixture(scope="class")
    def committed(self, tmp_path_factory):
        """Two committed epochs (cold full + suffix-ingest delta) with the
        live handles that produced them. Tests copy the directory before
        injecting damage, so the class pays the two builds once."""
        root = str(tmp_path_factory.mktemp("store-src"))
        g = gen_temporal_graph(n=40, m=320, t_max=20, seed=13)
        g0, suffix = split_epoch(g, 0.7)
        reg = IndexRegistry(store=IndexStore(root))
        reg.register_graph("crash", g0)
        h0 = reg.get("crash")
        h1 = reg.extend_graph("crash", suffix)[self.KEY].result(timeout=60)
        g1 = reg.resolve_graph("crash")
        reg.close()
        return root, h0, h1, g0, g1

    def _wreck(self, committed, tmp_path):
        """A private, mutable copy of the committed store + its key dir."""
        root = str(tmp_path / "store")
        shutil.copytree(committed[0], root)
        return root, os.path.join(root, key_dirname(self.KEY))

    def _reopen(self, root, graph=None):
        """Fresh-process reopen: no register_graph unless a specific epoch's
        graph is forced (resolve_graph otherwise adopts from the store)."""
        reg = IndexRegistry(store=IndexStore(root))
        if graph is not None:
            reg.register_graph("crash", graph)
        try:
            return reg, reg.get("crash")
        finally:
            reg.close()

    def _manifests(self, d):
        return sorted(n for n in os.listdir(d) if n.startswith("manifest_"))

    def test_killed_mid_segment_write_is_ignored(self, committed, tmp_path):
        root, d = self._wreck(committed, tmp_path)
        # a writer died after staging bytes but before the manifest rename:
        # a tmp file and an orphaned (unreferenced) renamed segment
        with open(os.path.join(d, "seg_00000003.bin.tmp-999"), "wb") as f:
            f.write(b"\x00" * 100)
        with open(os.path.join(d, "seg_00000003.bin"), "wb") as f:
            f.write(b"\x00" * 100)
        reg, h = self._reopen(root)
        assert h.source == "disk" and h.epoch == 1
        assert_pecb_identical(h.pecb, committed[2].pecb)
        # and a recovered writer never reuses the crashed commit's names
        from repro.store.segment import next_seq
        assert next_seq(d) >= 4

    def test_truncated_manifest_recovers_prior_epoch(self, committed,
                                                     tmp_path):
        root, d = self._wreck(committed, tmp_path)
        newest = self._manifests(d)[-1]
        with open(os.path.join(d, newest), "r+b") as f:
            f.truncate(25)
        reg, h = self._reopen(root)
        assert h.source == "disk" and h.epoch == 0
        assert_pecb_identical(h.pecb, committed[1].pecb)

    def test_corrupted_segment_crc_recovers_prior_epoch(self, committed,
                                                        tmp_path):
        import json
        root, d = self._wreck(committed, tmp_path)
        mans = self._manifests(d)
        with open(os.path.join(d, mans[0])) as f:
            base_segs = set(json.load(f)["segments"])
        with open(os.path.join(d, mans[-1])) as f:
            delta_segs = set(json.load(f)["segments"]) - base_segs
        assert delta_segs, "epoch 1 should have written its own segment"
        target = os.path.join(d, sorted(delta_segs)[0])
        with open(target, "r+b") as f:
            f.seek(7)
            byte = f.read(1)
            f.seek(7)
            f.write(bytes([byte[0] ^ 0xFF]))
        # the structurally-valid-but-bit-rotted manifest defeats graph
        # adoption for epoch 1; a caller holding epoch 0's graph (the last
        # good commit) still promotes it
        store = IndexStore(root)
        reg = IndexRegistry(store=store)
        reg.register_graph("crash", committed[3])
        h = reg.get("crash")
        reg.close()
        assert h.source == "disk" and h.epoch == 0
        assert_pecb_identical(h.pecb, committed[1].pecb)
        assert store.stats()["recovered_commits"] == 1

    def test_lost_latest_pointer_is_harmless(self, committed, tmp_path):
        root, d = self._wreck(committed, tmp_path)
        os.remove(os.path.join(d, "latest"))
        reg, h = self._reopen(root)
        assert h.source == "disk" and h.epoch == 1
        assert_pecb_identical(h.pecb, committed[2].pecb)

    def test_total_loss_falls_back_to_cold_build(self, committed, tmp_path):
        root, d = self._wreck(committed, tmp_path)
        for name in os.listdir(d):
            if name.startswith("seg_"):
                os.remove(os.path.join(d, name))
        reg, h = self._reopen(root, graph=committed[4])
        assert h.source == "build" and reg.builds == 1
        assert_pecb_identical(h.pecb, committed[2].pecb)

    def test_recovered_store_keeps_committing(self, committed, tmp_path):
        """After recovery the writer continues the epoch chain: re-commit
        the lost epoch, reopen, and the store serves it."""
        root, d = self._wreck(committed, tmp_path)
        newest = self._manifests(d)[-1]
        with open(os.path.join(d, newest), "r+b") as f:
            f.truncate(10)
        store = IndexStore(root)
        reg = IndexRegistry(store=store)
        reg.register_graph("crash", committed[3])
        assert reg.get("crash").epoch == 0       # recovered to epoch 0
        g1 = committed[4]
        suffix = [(int(u), int(v), int(t)) for u, v, t in
                  zip(g1.src[committed[3].m:], g1.dst[committed[3].m:],
                      g1.t[committed[3].m:])]
        h1b = reg.extend_graph("crash", suffix)[self.KEY].result(timeout=60)
        reg.close()
        assert h1b.epoch == 1
        stored = IndexStore(root).load(self.KEY)
        assert stored.epoch == 1
        assert_pecb_identical(stored.pecb, committed[2].pecb)
