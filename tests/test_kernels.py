"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs ref.py."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels import kcore_peel as kp
from repro.kernels import label_prop as lp
from repro.kernels import segment_matmul as sm
from repro.kernels import flash_attention as fa


class TestDegreePeel:
    @pytest.mark.parametrize("n,m", [(17, 40), (300, 900), (1025, 3000)])
    @pytest.mark.parametrize("eb,vb", [(256, 128), (1024, 512)])
    def test_degree_sweep(self, n, m, eb, vb):
        rng = np.random.default_rng(n * m)
        src = jnp.asarray(rng.integers(0, n, m), jnp.int32)
        dst = jnp.asarray(rng.integers(0, n, m), jnp.int32)
        alive = jnp.asarray(rng.random(m) < 0.7)
        got = kp.degree_count(src, dst, alive, n, edge_block=eb, vert_block=vb)
        want = ref.degree_count(src, dst, alive, n)
        assert np.array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("k", [2, 3, 5])
    def test_peel_round(self, k):
        rng = np.random.default_rng(k)
        n, m = 120, 500
        src = jnp.asarray(rng.integers(0, n, m), jnp.int32)
        dst = jnp.asarray(rng.integers(0, n, m), jnp.int32)
        alive = jnp.asarray(rng.random(m) < 0.9)
        got = kp.peel_round(src, dst, alive, n, k)
        want, _ = ref.kcore_peel_round(src, dst, alive, n, k)
        assert np.array_equal(np.asarray(got), np.asarray(want))

    def test_fixpoint_matches_host_peeling(self):
        from repro.core.kcore import kcore_edge_mask
        rng = np.random.default_rng(9)
        n, m = 80, 400
        src = rng.integers(0, n, m)
        dst = rng.integers(0, n, m)
        keep = src != dst
        src, dst = src[keep], dst[keep]
        alive = ref.kcore_fixpoint(jnp.asarray(src, jnp.int32),
                                   jnp.asarray(dst, jnp.int32), n, 3)
        want = kcore_edge_mask(src, dst, n, 3)
        assert np.array_equal(np.asarray(alive), want)


class TestMatmul:
    @pytest.mark.parametrize("shape", [(64, 64, 64), (200, 300, 150),
                                       (128, 256, 384), (33, 65, 17)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, shape, dtype):
        M, K, N = shape
        rng = np.random.default_rng(M + K + N)
        a = jnp.asarray(rng.normal(size=(M, K)), dtype)
        b = jnp.asarray(rng.normal(size=(K, N)), dtype)
        got = np.asarray(sm.matmul(a, b))
        want = np.asarray(ref.matmul(a, b))
        tol = 1e-4 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 10)


class TestSegmentSum:
    @pytest.mark.parametrize("m,d,s", [(10, 4, 3), (700, 32, 90),
                                       (1024, 128, 256), (513, 7, 1)])
    def test_sweep(self, m, d, s):
        rng = np.random.default_rng(m + d + s)
        vals = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
        ids = jnp.asarray(rng.integers(0, s, m), jnp.int32)
        got = np.asarray(sm.segment_sum(vals, ids, s))
        want = np.asarray(ref.segment_sum_sorted(vals, ids, s))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_embedding_bag(self):
        rng = np.random.default_rng(3)
        table = jnp.asarray(rng.normal(size=(50, 8)), jnp.float32)
        ids = jnp.asarray(rng.integers(0, 50, (6, 5)), jnp.int32)
        w = jnp.asarray(rng.random((6, 5)), jnp.float32)
        got = np.asarray(sm.embedding_bag(table, ids, w))
        want = np.asarray(ref.embedding_bag(table, ids, w))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


class TestLabelProp:
    @pytest.mark.parametrize("B,N,bn", [(2, 30, 16), (4, 50, 2048), (8, 300, 64)])
    def test_sweep(self, B, N, bn):
        rng = np.random.default_rng(B * N)
        labels = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32)[None], (B, N))
        act = jnp.asarray(rng.random((B, N)) < 0.7)
        labels = jnp.where(act, labels, N)
        links = [jnp.asarray(rng.integers(-1, N, (B, N)), jnp.int32) for _ in range(3)]
        got = np.asarray(lp.label_prop_round(labels, *links, act, bn=bn))
        want = np.asarray(ref.label_prop_round(labels, *links, act))
        assert np.array_equal(got, want)


class TestFlashAttention:
    @pytest.mark.parametrize("S,T", [(64, 64), (70, 70), (128, 256), (1, 96)])
    @pytest.mark.parametrize("causal", [False, True])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, S, T, causal, dtype):
        if causal and S != T:
            pytest.skip("causal requires square here")
        rng = np.random.default_rng(S * T + causal)
        B, H, dh = 2, 3, 32
        q = jnp.asarray(rng.normal(size=(B, S, H, dh)), dtype)
        k = jnp.asarray(rng.normal(size=(B, T, H, dh)), dtype)
        v = jnp.asarray(rng.normal(size=(B, T, H, dh)), dtype)
        got = np.asarray(fa.flash_attention(q, k, v, causal=causal, bq=32, bk=32),
                         np.float32)
        want = np.asarray(ref.flash_attention(q, k, v, causal=causal), np.float32)
        tol = 2e-3 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(got, want, rtol=tol, atol=tol)

    def test_matches_model_attention(self):
        """The kernel agrees with the transformer's einsum attention path."""
        from repro.models.transformer import gqa_attention
        rng = np.random.default_rng(0)
        B, S, Hq, Hkv, dh = 2, 48, 4, 2, 16
        q = jnp.asarray(rng.normal(size=(B, S, Hq, dh)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, Hkv, dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, Hkv, dh)), jnp.float32)
        wanted = np.asarray(gqa_attention(q, k, v, causal=True))
        head_map = jnp.arange(Hq) // (Hq // Hkv)
        ke, ve = jnp.take(k, head_map, axis=2), jnp.take(v, head_map, axis=2)
        got = np.asarray(fa.flash_attention(q, ke, ve, causal=True, bq=16, bk=16))
        np.testing.assert_allclose(got.reshape(B, S, Hq * dh), wanted,
                                   rtol=2e-3, atol=2e-3)


class TestSegmentedSelect:
    """The construction-plane inner op: three backends, one answer."""

    @pytest.mark.parametrize("seed", [0, 1, 2])
    @pytest.mark.parametrize("k", [1, 2, 5])
    def test_kth_backends_agree(self, seed, k):
        from repro.kernels import segmented_select as ss
        import jax.numpy as jnp

        rng = np.random.default_rng(seed)
        n = int(rng.integers(3, 50))
        deg = rng.integers(0, 14, n)
        seg = np.repeat(np.arange(n), deg).astype(np.int32)
        vptr = np.zeros(n + 1, np.int64)
        np.cumsum(deg, out=vptr[1:])
        inf = int(rng.integers(6, 60))
        w = rng.integers(0, inf + 1, int(deg.sum())).astype(np.int32)
        lo = rng.integers(0, inf + 1, n).astype(np.int32)
        ref_kth = ss.segmented_kth_smallest_np(w, vptr, k, inf, lo=lo)
        steps = int(np.ceil(np.log2(inf + 1))) + 1
        xla = ss.kth_smallest_csr(
            jnp.asarray(w), jnp.asarray(lo), k, inf, steps,
            jnp.asarray(seg), jnp.asarray(vptr.astype(np.int32)))
        assert np.array_equal(np.asarray(xla), ref_kth)
        pallas = ss.kth_smallest_pallas(
            jnp.asarray(w), jnp.asarray(seg), n, k, inf, lo=jnp.asarray(lo))
        assert np.array_equal(np.asarray(pallas), ref_kth)

    def test_count_le_pallas_blocked(self):
        """Pallas counter with blocks smaller than the data (real grid)."""
        from repro.kernels import segmented_select as ss
        import jax.numpy as jnp

        rng = np.random.default_rng(7)
        n, e = 70, 900
        seg = np.sort(rng.integers(0, n, e)).astype(np.int32)
        w = rng.integers(0, 50, e).astype(np.int32)
        thr = rng.integers(0, 50, n).astype(np.int32)
        got = ss.segmented_count_le(jnp.asarray(w), jnp.asarray(seg),
                                    jnp.asarray(thr), n,
                                    slot_block=256, seg_block=32)
        want = np.array([(w[seg == v] <= thr[v]).sum() for v in range(n)])
        assert np.array_equal(np.asarray(got), want)
