"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs ref.py."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels import kcore_peel as kp
from repro.kernels import label_prop as lp
from repro.kernels import segment_matmul as sm
from repro.kernels import flash_attention as fa


class TestDegreePeel:
    @pytest.mark.parametrize("n,m", [(17, 40), (300, 900), (1025, 3000)])
    @pytest.mark.parametrize("eb,vb", [(256, 128), (1024, 512)])
    def test_degree_sweep(self, n, m, eb, vb):
        rng = np.random.default_rng(n * m)
        src = jnp.asarray(rng.integers(0, n, m), jnp.int32)
        dst = jnp.asarray(rng.integers(0, n, m), jnp.int32)
        alive = jnp.asarray(rng.random(m) < 0.7)
        got = kp.degree_count(src, dst, alive, n, edge_block=eb, vert_block=vb)
        want = ref.degree_count(src, dst, alive, n)
        assert np.array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("k", [2, 3, 5])
    def test_peel_round(self, k):
        rng = np.random.default_rng(k)
        n, m = 120, 500
        src = jnp.asarray(rng.integers(0, n, m), jnp.int32)
        dst = jnp.asarray(rng.integers(0, n, m), jnp.int32)
        alive = jnp.asarray(rng.random(m) < 0.9)
        got = kp.peel_round(src, dst, alive, n, k)
        want, _ = ref.kcore_peel_round(src, dst, alive, n, k)
        assert np.array_equal(np.asarray(got), np.asarray(want))

    def test_fixpoint_matches_host_peeling(self):
        from repro.core.kcore import kcore_edge_mask
        rng = np.random.default_rng(9)
        n, m = 80, 400
        src = rng.integers(0, n, m)
        dst = rng.integers(0, n, m)
        keep = src != dst
        src, dst = src[keep], dst[keep]
        alive = ref.kcore_fixpoint(jnp.asarray(src, jnp.int32),
                                   jnp.asarray(dst, jnp.int32), n, 3)
        want = kcore_edge_mask(src, dst, n, 3)
        assert np.array_equal(np.asarray(alive), want)


class TestMatmul:
    @pytest.mark.parametrize("shape", [(64, 64, 64), (200, 300, 150),
                                       (128, 256, 384), (33, 65, 17)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, shape, dtype):
        M, K, N = shape
        rng = np.random.default_rng(M + K + N)
        a = jnp.asarray(rng.normal(size=(M, K)), dtype)
        b = jnp.asarray(rng.normal(size=(K, N)), dtype)
        got = np.asarray(sm.matmul(a, b))
        want = np.asarray(ref.matmul(a, b))
        tol = 1e-4 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 10)


class TestSegmentSum:
    @pytest.mark.parametrize("m,d,s", [(10, 4, 3), (700, 32, 90),
                                       (1024, 128, 256), (513, 7, 1)])
    def test_sweep(self, m, d, s):
        rng = np.random.default_rng(m + d + s)
        vals = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
        ids = jnp.asarray(rng.integers(0, s, m), jnp.int32)
        got = np.asarray(sm.segment_sum(vals, ids, s))
        want = np.asarray(ref.segment_sum_sorted(vals, ids, s))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_embedding_bag(self):
        rng = np.random.default_rng(3)
        table = jnp.asarray(rng.normal(size=(50, 8)), jnp.float32)
        ids = jnp.asarray(rng.integers(0, 50, (6, 5)), jnp.int32)
        w = jnp.asarray(rng.random((6, 5)), jnp.float32)
        got = np.asarray(sm.embedding_bag(table, ids, w))
        want = np.asarray(ref.embedding_bag(table, ids, w))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


class TestLabelProp:
    @pytest.mark.parametrize("B,N,bn", [(2, 30, 16), (4, 50, 2048), (8, 300, 64)])
    def test_sweep(self, B, N, bn):
        rng = np.random.default_rng(B * N)
        labels = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32)[None], (B, N))
        act = jnp.asarray(rng.random((B, N)) < 0.7)
        labels = jnp.where(act, labels, N)
        links = [jnp.asarray(rng.integers(-1, N, (B, N)), jnp.int32) for _ in range(3)]
        got = np.asarray(lp.label_prop_round(labels, *links, act, bn=bn))
        want = np.asarray(ref.label_prop_round(labels, *links, act))
        assert np.array_equal(got, want)


class TestFlashAttention:
    @pytest.mark.parametrize("S,T", [(64, 64), (70, 70), (128, 256), (1, 96)])
    @pytest.mark.parametrize("causal", [False, True])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, S, T, causal, dtype):
        if causal and S != T:
            pytest.skip("causal requires square here")
        rng = np.random.default_rng(S * T + causal)
        B, H, dh = 2, 3, 32
        q = jnp.asarray(rng.normal(size=(B, S, H, dh)), dtype)
        k = jnp.asarray(rng.normal(size=(B, T, H, dh)), dtype)
        v = jnp.asarray(rng.normal(size=(B, T, H, dh)), dtype)
        got = np.asarray(fa.flash_attention(q, k, v, causal=causal, bq=32, bk=32),
                         np.float32)
        want = np.asarray(ref.flash_attention(q, k, v, causal=causal), np.float32)
        tol = 2e-3 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(got, want, rtol=tol, atol=tol)

    def test_matches_model_attention(self):
        """The kernel agrees with the transformer's einsum attention path."""
        from repro.models.transformer import gqa_attention
        rng = np.random.default_rng(0)
        B, S, Hq, Hkv, dh = 2, 48, 4, 2, 16
        q = jnp.asarray(rng.normal(size=(B, S, Hq, dh)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, Hkv, dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, Hkv, dh)), jnp.float32)
        wanted = np.asarray(gqa_attention(q, k, v, causal=True))
        head_map = jnp.arange(Hq) // (Hq // Hkv)
        ke, ve = jnp.take(k, head_map, axis=2), jnp.take(v, head_map, axis=2)
        got = np.asarray(fa.flash_attention(q, ke, ve, causal=True, bq=16, bk=16))
        np.testing.assert_allclose(got.reshape(B, S, Hq * dh), wanted,
                                   rtol=2e-3, atol=2e-3)
