"""K-agnostic index plane (DESIGN.md §14): one k-stratified build serves
every k.

Three-backend equality (stratified vs per-k PECB vs the brute-force
k-core oracle) across every query mode, k-monotonicity as a property
(hypothesis where installed, seeded sweep everywhere), interleaved
extend/shrink epoch chains against cold stratified rebuilds, the
workload-level cache purge (one purge clears every k stratum, touches no
other workload), and the deprecation shims that keep the old
(workload, k) registry surface importable."""

import warnings

import numpy as np
import pytest

from repro.core.batch_query import (batch_query, batch_query_full_mixed,
                                    mixed_slots, stratum_device, to_device,
                                    window_sweep)
from repro.core.core_time import (default_ks, extend_stratified_core_times,
                                  shrink_stratified_core_times,
                                  stratified_core_times)
from repro.core.kcore import k_max as graph_k_max
from repro.core.kcore import tccs_oracle, tccs_oracle_edges
from repro.core.pecb_index import build_pecb_index, build_stratified_index
from repro.core.query_api import (InvalidQueryError, ResultMode, TCCSQuery,
                                  WindowSweep)
from repro.core.streaming import (extend_stratified_index,
                                  shrink_stratified_index)
from repro.core.temporal_graph import gen_temporal_graph, random_queries
from repro.serving import EngineConfig, IndexRegistry, ServingEngine
from repro.serving.cache import ResultCache

from test_streaming import assert_pecb_identical


def graphs():
    return [gen_temporal_graph(n=18, m=70, t_max=7, seed=3),
            gen_temporal_graph(n=30, m=240, t_max=12, seed=5),
            gen_temporal_graph(n=40, m=420, t_max=18, seed=31)]


# ----------------------------------------------------------------------
# three-backend equality: stratified == per-k PECB == brute-force oracle
# ----------------------------------------------------------------------

class TestThreeBackendEquality:
    @pytest.mark.parametrize("gi", [0, 1, 2])
    def test_all_modes_all_ks(self, gi):
        g = graphs()[gi]
        sx = build_stratified_index(g)
        km = graph_k_max(g)
        assert sx.supported_ks == tuple(range(2, km + 1)) == default_ks(g)
        rng = np.random.default_rng(gi)
        for k in list(sx.supported_ks) + [km + 1, km + 3]:
            per_k = build_pecb_index(g, k) if k <= km else None
            for _ in range(10):
                u = int(rng.integers(0, g.n))
                ts = int(rng.integers(1, g.t_max + 1))
                te = int(rng.integers(ts, g.t_max + 1))
                want_v = frozenset(tccs_oracle(g, k, u, ts, te))
                want_e = tccs_oracle_edges(g, k, u, ts, te)
                for mode in ResultMode:
                    q = TCCSQuery(u, ts, te, k, mode)
                    r = sx.answer(q)
                    assert r.num_vertices == len(want_v)
                    if mode is not ResultMode.COUNT:
                        assert r.vertices == want_v, (k, u, ts, te)
                    if mode is ResultMode.EDGES:
                        assert r.edges.edge_ids() == want_e
                    if mode is ResultMode.SUBGRAPH:
                        assert r.subgraph.m == len(want_e)
                    if per_k is not None:
                        rp = per_k.answer(q)
                        assert rp.vertices == r.vertices
                        assert rp.num_vertices == r.num_vertices
                        if mode is ResultMode.EDGES:
                            assert rp.edges.edge_ids() == r.edges.edge_ids()

    def test_slice_k_reconstructs_per_k_bit_identically(self):
        g = graphs()[1]
        sx = build_stratified_index(g)
        for k in sx.supported_ks:
            assert_pecb_identical(sx.slice_k(k), build_pecb_index(g, k))

    def test_unsupported_in_range_k_raises(self):
        g = graphs()[0]
        sx = build_stratified_index(g, ks=(2, 4))
        with pytest.raises(InvalidQueryError, match="supported_ks"):
            sx.answer(TCCSQuery(0, 1, 5, 3))
        with pytest.raises(KeyError):
            sx.k_index(3)
        with pytest.raises(KeyError):
            mixed_slots(sx, [(0, 3)])

    def test_k_above_graph_k_max_is_trivially_empty(self):
        g = graphs()[0]
        sx = build_stratified_index(g)
        r = sx.answer(TCCSQuery(0, 1, g.t_max, sx.k_max_graph + 7))
        assert r.vertices == frozenset()
        assert r.provenance.route == "trivial"


# ----------------------------------------------------------------------
# device plane: one compiled program serves mixed-k batches
# ----------------------------------------------------------------------

class TestMixedKDevice:
    def test_vertex_masks_match_host_per_slot(self):
        g = graphs()[1]
        sx = build_stratified_index(g)
        dix = to_device(sx)
        rng = np.random.default_rng(7)
        qs = random_queries(g, 32, seed=7)
        ks = [int(rng.choice(sx.supported_ks)) for _ in qs]
        slot = mixed_slots(sx, [(u, k) for (u, _, _), k in zip(qs, ks)])
        ts = np.asarray([q[1] for q in qs], np.int32)
        te = np.asarray([q[2] for q in qs], np.int32)
        vmask = np.asarray(batch_query(dix, slot, ts, te))
        for i, ((u, a, b), k) in enumerate(zip(qs, ks)):
            want = sx.slice_k(k)._component_vertices(u, a, b)
            assert frozenset(np.nonzero(vmask[i])[0].tolist()) == \
                frozenset(want), (u, a, b, k)

    def test_full_mixed_version_mask_filters_by_stratum(self):
        g = graphs()[1]
        sx = build_stratified_index(g)
        dix = to_device(sx)
        store = sx.versions
        rng = np.random.default_rng(8)
        qs = random_queries(g, 16, seed=8)
        ks = [int(rng.choice(sx.supported_ks)) for _ in qs]
        slot = mixed_slots(sx, [(u, k) for (u, _, _), k in zip(qs, ks)])
        ts = np.asarray([q[1] for q in qs], np.int32)
        te = np.asarray([q[2] for q in qs], np.int32)
        kq = np.asarray(ks, np.int32)
        _, vermask = batch_query_full_mixed(dix, slot, ts, te, kq)
        vermask = np.asarray(vermask)
        for i, ((u, a, b), k) in enumerate(zip(qs, ks)):
            got = {int(store.edge_id[j])
                   for j in np.nonzero(vermask[i])[0].tolist()}
            assert got == tccs_oracle_edges(g, k, u, a, b), (u, a, b, k)

    def test_window_sweep_slot_selects_stratum(self):
        g = graphs()[0]
        sx = build_stratified_index(g)
        dix = to_device(sx)
        windows = [(d, min(d + 3, g.t_max)) for d in range(1, g.t_max)]
        ts = np.asarray([w[0] for w in windows], np.int32)
        te = np.asarray([w[1] for w in windows], np.int32)
        u = 1
        for k in sx.supported_ks:
            slot = np.full(len(windows), sx.k_index(k) * g.n + u, np.int32)
            vmask = np.asarray(window_sweep(dix, slot, ts, te))
            for i, (a, b) in enumerate(windows):
                want = frozenset(sx.slice_k(k)._component_vertices(u, a, b))
                assert frozenset(np.nonzero(vmask[i])[0].tolist()) == want

    def test_stratum_device_matches_per_k_mirror(self):
        # the single-k sweep path: every stratum's device slice must be
        # array-for-array what uploading the per-k slice would give, and
        # a sweep on the slice must match the fused-mirror slot sweep
        g = graphs()[0]
        sx = build_stratified_index(g)
        dix = to_device(sx)
        windows = [(d, min(d + 3, g.t_max)) for d in range(1, g.t_max)]
        ts = np.asarray([w[0] for w in windows], np.int32)
        te = np.asarray([w[1] for w in windows], np.int32)
        u = 1
        arrays = ("node_u", "node_v", "node_ct", "live_from", "live_to",
                  "row_ptr", "ent_ts", "ent_left", "ent_right", "ent_parent",
                  "vrow_ptr", "vent_ts", "vent_node", "ver_ts_from",
                  "ver_ts_to", "ver_ct", "ver_src", "ver_k")
        for k in sx.supported_ks:
            sd = stratum_device(dix, sx, k)
            ref = to_device(sx.slice_k(k))
            for f in arrays:
                assert np.array_equal(np.asarray(getattr(sd, f)),
                                      np.asarray(getattr(ref, f))), (k, f)
            assert sd.num_versions == ref.num_versions
            slot = np.full(len(windows), sx.k_index(k) * g.n + u, np.int32)
            fused = np.asarray(window_sweep(dix, slot, ts, te))
            sliced = np.asarray(window_sweep(
                sd, np.full(len(windows), u, np.int32), ts, te))
            assert np.array_equal(fused, sliced), k
        with pytest.raises(KeyError):
            stratum_device(dix, sx, 99)

    def test_engine_sweep_uses_stratum_mirror(self):
        # end-to-end: the engine's sweep route answers from the stratum
        # slice and stays oracle-exact; the handle memoizes the slice
        g = graphs()[0]
        with ServingEngine(EngineConfig(flush_ms=0.5,
                                        host_threshold=1)) as eng:
            eng.register_graph("g", g)
            h = eng.warmup("g", sweep=True, sweep_ks=(2,))
            assert 2 in h._stratum_dev
            assert h._stratum_dev[2].num_nodes == \
                h.stratum_device(2).num_nodes
            windows = [(d, min(d + 4, g.t_max)) for d in range(1, 8)]
            res = eng.sweep("g", WindowSweep(u=1, k=2, windows=windows))
            assert any(r.provenance.route == "sweep" for r in res)
            for r, (a, b) in zip(res, windows):
                assert r.vertices == tccs_oracle(g, 2, 1, a, b)


# ----------------------------------------------------------------------
# k-monotonicity: cores are nested in k (property + seeded sweep)
# ----------------------------------------------------------------------

def _assert_monotone(sx, u, ts, te):
    prev = None
    for k in sx.supported_ks:
        cur = sx.answer(TCCSQuery(u, ts, te, k)).vertices
        if prev is not None:
            # u's component can only shrink as k rises: the (k+1)-core is
            # a subgraph of the k-core, so u's (k+1)-component sits inside
            # u's k-component (or u has dropped out entirely)
            assert cur <= prev, (u, ts, te, k)
        prev = cur


class TestKMonotonicity:
    def test_seeded_sweep(self):
        for g in graphs():
            sx = build_stratified_index(g)
            rng = np.random.default_rng(11)
            for _ in range(30):
                u = int(rng.integers(0, g.n))
                ts = int(rng.integers(1, g.t_max + 1))
                te = int(rng.integers(ts, g.t_max + 1))
                _assert_monotone(sx, u, ts, te)

    def test_membership_count_monotone_nonincreasing(self):
        """|core_k| over all vertices is non-increasing in k for a fixed
        window (k-stratification's defining invariant)."""
        g = graphs()[0]
        sx = build_stratified_index(g)
        rng = np.random.default_rng(12)
        for _ in range(10):
            ts = int(rng.integers(1, g.t_max + 1))
            te = int(rng.integers(ts, g.t_max + 1))
            sizes = []
            for k in sx.supported_ks:
                member = set()
                for u in range(g.n):
                    member |= sx.answer(TCCSQuery(u, ts, te, k)).vertices
                sizes.append(len(member))
            assert all(a >= b for a, b in zip(sizes, sizes[1:])), (ts, te)


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    _G = gen_temporal_graph(n=24, m=160, t_max=10, seed=19)
    _SX = build_stratified_index(_G)

    class TestKMonotonicityProperty:
        @settings(max_examples=100, deadline=None,
                  suppress_health_check=[HealthCheck.too_slow])
        @given(u=st.integers(0, _G.n - 1),
               ts=st.integers(1, _G.t_max),
               span=st.integers(0, _G.t_max))
        def test_component_nested_in_k(self, u, ts, span):
            _assert_monotone(_SX, u, ts, min(ts + span, _G.t_max))

        @settings(max_examples=100, deadline=None,
                  suppress_health_check=[HealthCheck.too_slow])
        @given(u=st.integers(0, _G.n - 1),
               ts=st.integers(1, _G.t_max),
               span=st.integers(0, _G.t_max),
               k=st.integers(2, 12))
        def test_matches_oracle(self, u, ts, span, k):
            te = min(ts + span, _G.t_max)
            r = _SX.answer(TCCSQuery(u, ts, te, k))
            assert r.vertices == frozenset(tccs_oracle(_G, k, u, ts, te))
except ImportError:  # pragma: no cover - hypothesis absent in minimal envs
    pass


# ----------------------------------------------------------------------
# interleaved extend/shrink epoch chain == cold stratified rebuild
# ----------------------------------------------------------------------

class TestEpochChain:
    def _suffix(self, g, rng, n_edges, t_span):
        return [(int(rng.integers(0, g.n)), int(rng.integers(0, g.n)),
                 int(g.t_max + 1 + rng.integers(0, t_span)))
                for _ in range(n_edges)]

    def test_interleaved_extend_shrink_chain(self):
        rng = np.random.default_rng(23)
        cur = gen_temporal_graph(n=28, m=220, t_max=10, seed=23)
        tab = stratified_core_times(cur)
        sx = build_stratified_index(cur, strata=tab)
        plan = [("extend", 120), ("shrink", 4), ("extend", 90),
                ("shrink", 6), ("extend", 150), ("shrink", 5)]
        for step, (op, arg) in enumerate(plan):
            if op == "extend":
                suffix = self._suffix(cur, rng, arg, t_span=5)
                cur = cur.extend(suffix)
                # appended edges may raise k_max: pass the grown ks so the
                # fresh strata are built cold alongside the incremental ones
                ks = default_ks(cur)
                tab = extend_stratified_core_times(cur, tab, ks)
                sx = extend_stratified_index(cur, sx, ks, strata=tab)
            else:
                cur = cur.expire_before(arg)
                # expiry may lower k_max; shrink must never add strata
                ks = tuple(k for k in default_ks(cur) if k in tab.ks)
                tab = shrink_stratified_core_times(cur, tab, ks)
                sx = shrink_stratified_index(cur, sx, ks, strata=tab)
            assert_pecb_identical(sx, build_stratified_index(cur))
            qrng = np.random.default_rng(100 + step)
            for _ in range(6):
                u = int(qrng.integers(0, cur.n))
                ts = int(qrng.integers(1, cur.t_max + 1))
                te = int(qrng.integers(ts, cur.t_max + 1))
                for k in list(sx.supported_ks)[:3] + [sx.k_max_graph + 2]:
                    r = sx.answer(TCCSQuery(u, ts, te, k))
                    assert r.vertices == \
                        frozenset(tccs_oracle(cur, k, u, ts, te)), \
                        (step, u, ts, te, k)


# ----------------------------------------------------------------------
# satellite 2: ONE workload-level purge clears every k stratum
# ----------------------------------------------------------------------

class TestWorkloadPurge:
    def test_purge_index_clears_all_k_strata_only(self):
        c = ResultCache(capacity=64)
        for k in (2, 3, 5, 9):
            c.put(("w", (0, 1, 5, k, "vertices")), frozenset({k}))
            c.put(("other", (0, 1, 5, k, "vertices")), frozenset({k}))
        c.put("foreign-key", frozenset({1}))
        assert c.purge_index("w") == 4
        for k in (2, 3, 5, 9):
            assert c.get(("w", (0, 1, 5, k, "vertices"))) is None
            assert c.get(("other", (0, 1, 5, k, "vertices"))) is not None
        assert c.get("foreign-key") is not None
        assert c.stats()["purges"] == 4

    def test_engine_eviction_purges_every_k_of_one_workload(self):
        g1 = gen_temporal_graph(n=20, m=120, t_max=8, seed=1)
        g2 = gen_temporal_graph(n=20, m=120, t_max=8, seed=2)
        cfg = EngineConfig(flush_ms=5.0, registry_capacity=1,
                           cache_capacity=64)
        with ServingEngine(cfg) as eng:
            eng.register_graph("g1", g1)
            eng.register_graph("g2", g2)
            for k in (2, 3):
                eng.answer("g1", TCCSQuery(0, 1, 6, k))
            n_g1 = len(eng.cache)
            assert n_g1 == 2
            eng.answer("g2", TCCSQuery(0, 1, 6, 2))   # evicts workload g1
            # the eviction listener purged BOTH of g1's k strata at once,
            # leaving g2's fresh entry alone
            assert eng.cache.stats()["purges"] == n_g1
            assert len(eng.cache) == 1
            r = eng.answer("g2", TCCSQuery(0, 1, 6, 2))
            assert r.provenance.route == "cache"


# ----------------------------------------------------------------------
# satellite 6: deprecation shims for the old (workload, k) surface
# ----------------------------------------------------------------------

class TestPerKKeyShims:
    def _registry(self):
        reg = IndexRegistry()
        reg.register_graph("g", gen_temporal_graph(n=14, m=60, t_max=6,
                                                   seed=1))
        return reg

    def test_registry_get_with_k_warns_and_serves(self):
        reg = self._registry()
        try:
            with pytest.warns(DeprecationWarning, match="deprecated"):
                h = reg.get("g", 2)
            assert 2 in h.supported_ks
            with warnings.catch_warnings():
                warnings.simplefilter("error")
                assert reg.get("g") is h       # new surface: no warning
        finally:
            reg.close()

    def test_registry_get_nowait_and_async_with_k_warn(self):
        reg = self._registry()
        try:
            with pytest.warns(DeprecationWarning, match="deprecated"):
                reg.get_nowait("g", 3, start_build=False)
            with pytest.warns(DeprecationWarning, match="deprecated"):
                h = reg.get_async("g", 3).result(timeout=60)
            assert 3 in h.supported_ks
        finally:
            reg.close()

    def test_tuple_membership_warns_and_matches_workload(self):
        reg = self._registry()
        try:
            reg.get("g")
            with pytest.warns(DeprecationWarning, match="deprecated"):
                assert ("g", 2) in reg
            with pytest.warns(DeprecationWarning, match="deprecated"):
                assert ("g", 9) in reg         # k ignored: workload-level
            assert "g" in reg
        finally:
            reg.close()

    def test_engine_warmup_prefetch_with_k_warn(self):
        g = gen_temporal_graph(n=14, m=60, t_max=6, seed=2)
        with ServingEngine(EngineConfig(flush_ms=5.0)) as eng:
            eng.register_graph("g", g)
            with pytest.warns(DeprecationWarning, match="deprecated"):
                h = eng.warmup("g", 2)
            assert h.supported_ks
            with pytest.warns(DeprecationWarning, match="deprecated"):
                eng.prefetch("g", 3).result(timeout=60)

    def test_registry_ks_policy_guard(self):
        reg = self._registry()
        try:
            reg.get("g")
            with pytest.raises(RuntimeError, match="resident"):
                reg.set_ks("g", (2, 3))
        finally:
            reg.close()


class TestLayoutOverflowGuard:
    """§15.2 satellite: the packed slot/row-pointer math raises a typed
    error instead of silently wrapping past int32."""

    def test_checked_caster_roundtrip_and_raise(self):
        from repro.core.batch_query import LayoutOverflowError, _i32
        ok = _i32(np.array([0, 7, 2**31 - 1], np.int64))
        assert ok.dtype == np.int32
        with pytest.raises(LayoutOverflowError, match="exceeds int32"):
            _i32(np.array([2**31], np.int64), "fused entry slots")
        with pytest.raises(LayoutOverflowError, match="exceeds int32"):
            _i32(np.array([-2**31 - 1], np.int64))
        # the typed error stays catchable as the stdlib family
        assert issubclass(LayoutOverflowError, OverflowError)

    def test_mixed_slots_computes_in_int64_first(self):
        """k_index * n + u must not wrap *before* the guard sees it: a
        fake stratified view with a huge n keeps the intermediate exact
        and the guard raises rather than returning a wrapped slot."""
        from repro.core.batch_query import LayoutOverflowError

        class FakeSx:
            n = 2**30
            ks = (2, 3, 4)

            def k_index(self, k):
                return self.ks.index(k)

        with pytest.raises(LayoutOverflowError, match="mixed-k entry"):
            mixed_slots(FakeSx(), [(5, 4)])   # 2*2^30 + 5 > int32 max
