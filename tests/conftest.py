"""Suite-wide hooks: the dynamic lock-witness and kernel-witness gates
(DESIGN.md §12.2, §15.4).

With ``REPRO_LOCK_WITNESS=1`` (the CI analysis job sets it around the fast
suite) every ``named_lock``/``named_condition`` in the serving plane is an
instrumented wrapper reporting acquisition edges into the process-wide
:data:`repro.obs.locks.WITNESS`. After the last test, the session-scoped
teardown below cross-checks the observed edges against the declared
hierarchy, writes the JSON report (CI artifact), and fails the run on any
rank inversion, undeclared lock, or cycle.

With ``REPRO_KERNEL_WITNESS=1`` every ``@kernel_contract`` Pallas wrapper
validates its real arrays (rank, dtype family, symbolic-dim consistency)
and its declared VMEM bound per call into
:data:`repro.kernels.contracts.WITNESS`; the kernel gate writes that
report and fails the run on any contract violation. Without the env vars
both fixtures are inert and the suite pays nothing.
"""

import json
import os

import pytest

from repro.kernels.contracts import (KernelContractViolation,
                                     WITNESS as KERNEL_WITNESS,
                                     witness_enabled as kernel_witness_enabled)
from repro.obs.locks import WITNESS, witness_enabled


class LockHierarchyViolation(Exception):
    pass


@pytest.fixture(scope="session", autouse=True)
def _lock_witness_gate():
    yield
    if not witness_enabled():
        return
    report = WITNESS.report()
    out = os.environ.get("REPRO_LOCK_WITNESS_REPORT",
                         "lock_witness_report.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    if report["problems"]:
        raise LockHierarchyViolation(
            "observed lock acquisitions violate the declared hierarchy "
            f"({len(report['problems'])} problem(s); report: {out}):\n"
            + json.dumps(report["problems"], indent=2))


@pytest.fixture(scope="session", autouse=True)
def _kernel_witness_gate():
    yield
    if not kernel_witness_enabled():
        return
    report = KERNEL_WITNESS.report()
    out = os.environ.get("REPRO_KERNEL_WITNESS_REPORT",
                         "kernel_contract_report.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    if report["problems"]:
        raise KernelContractViolation(
            "armed kernel calls violate their declared contracts "
            f"({len(report['problems'])} problem(s); report: {out}):\n"
            + json.dumps(report["problems"], indent=2))
