"""Suite-wide hooks: the dynamic lock-witness gate (DESIGN.md §12.2).

With ``REPRO_LOCK_WITNESS=1`` (the CI analysis job sets it around the fast
suite) every ``named_lock``/``named_condition`` in the serving plane is an
instrumented wrapper reporting acquisition edges into the process-wide
:data:`repro.obs.locks.WITNESS`. After the last test, the session-scoped
teardown below cross-checks the observed edges against the declared
hierarchy, writes the JSON report (CI artifact), and fails the run on any
rank inversion, undeclared lock, or cycle. Without the env var the
fixture is inert and the suite pays nothing.
"""

import json
import os

import pytest

from repro.obs.locks import WITNESS, witness_enabled


class LockHierarchyViolation(Exception):
    pass


@pytest.fixture(scope="session", autouse=True)
def _lock_witness_gate():
    yield
    if not witness_enabled():
        return
    report = WITNESS.report()
    out = os.environ.get("REPRO_LOCK_WITNESS_REPORT",
                         "lock_witness_report.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    if report["problems"]:
        raise LockHierarchyViolation(
            "observed lock acquisitions violate the declared hierarchy "
            f"({len(report['problems'])} problem(s); report: {out}):\n"
            + json.dumps(report["problems"], indent=2))
