"""Streaming contact feed: serve queries while new days arrive (DESIGN.md §9).

    PYTHONPATH=src python examples/streaming_ingest.py

A contact-tracing deployment never has a finished graph: each day's
contacts land after the fact, and the dashboard must keep answering while
the index catches up. The streaming epoch plane makes that a one-liner —
``engine.ingest(name, edges)`` appends the suffix day, refreshes the
resident index incrementally in the background (bit-identical to a cold
rebuild, several times faster), and queries keep resolving against the
old epoch until the refreshed handle is atomically swapped in. Cached
answers for historical windows survive the epoch: a window that predates
the new day cannot have changed.

The second half is the *rolling window* (DESIGN.md §10): contact-tracing
data is only epidemiologically relevant for a couple of weeks, so a
``RetentionPolicy`` expires the stale prefix as new days arrive — the
resident index shrinks to the retained window (bit-identical to a cold
build of the trimmed feed), day numbers shift so "day 1" is always the
oldest retained day, and memory stays bounded no matter how long the feed
runs.

The last act is the *restart* (DESIGN.md §13): with a ``store_dir``
configured, every landed epoch — ingests and retention trims included —
is written through to the persistent index store as it commits, so when
the process dies (deploy, OOM kill, hardware) the next one mmaps the
stored index back in milliseconds instead of rebuilding, adopts the feed
without re-registration, and keeps ingesting from the stored epoch.

Set ``REPRO_EXAMPLE_SCALE=tiny`` (CI smoke) to shrink the network.
"""

import os
import shutil
import tempfile
import time

import numpy as np

from repro.core import TCCSQuery
from repro.core.temporal_graph import gen_contact_network
from repro.core.kcore import k_max
from repro.serving import EngineConfig, RetentionPolicy, ServingEngine

TINY = os.environ.get("REPRO_EXAMPLE_SCALE") == "tiny"
n_people, days_total, days_live = (120, 12, 3) if TINY else (300, 24, 6)

full = gen_contact_network(n_people, days_total, seed=11)
k = max(2, int(0.25 * k_max(full)))
# replay harness: start serving with the first days, stream in the rest
g0, backlog = full.split_at(days_total - days_live)
print(f"contact feed: {n_people} people, day 1..{g0.t_max} indexed, "
      f"{days_live} days ({backlog.shape[0]} contacts) still to arrive, k={k}")

with ServingEngine(EngineConfig(max_batch=64, flush_ms=2.0)) as eng:
    eng.register_graph("feed", g0)
    t0 = time.perf_counter()
    eng.warmup("feed")
    print(f"epoch-0 index built in {time.perf_counter() - t0:.2f}s")

    patient = int(np.argmax(np.bincount(np.concatenate([g0.src, g0.dst]))))
    historic = TCCSQuery(patient, 1, max(1, g0.t_max - 1), k)
    cohort0 = eng.answer("feed", historic)
    print(f"patient {patient}: historical cohort of {len(cohort0.vertices)}")

    for day in range(g0.t_max + 1, days_total + 1):
        arrivals = backlog[backlog[:, 2] == day]
        futures = eng.ingest("feed", [tuple(e) for e in arrivals.tolist()])
        # the dashboard keeps answering while the refresh runs in background
        served = 0
        while any(not f.done() for f in futures.values()):
            eng.answer("feed", historic)
            served += 1
        handle = [f.result() for f in futures.values()][0]
        latest = eng.answer(
            "feed", TCCSQuery(patient, max(1, day - 6), day, k))
        print(f"day {day}: +{arrivals.shape[0]} contacts, refresh "
              f"{handle.build_seconds * 1e3:.0f} ms (epoch {handle.epoch}), "
              f"{served} queries served during refresh, "
              f"7-day cohort now {len(latest.vertices)}")

    hit = eng.answer("feed", historic)
    print(f"historical window after {days_live} ingests: "
          f"route={hit.provenance.route} (cache survived every epoch), "
          f"cohort {len(hit.vertices)} unchanged="
          f"{hit.vertices == cohort0.vertices}")
    s = eng.stats()
    print(f"[stats] refreshes={s['registry']['refreshes']} "
          f"epochs={s['registry']['epochs']} "
          f"cache={s['cache']['hits']} hits/{s['cache']['misses']} misses")

    # -- rolling window: retention keeps memory bounded (DESIGN.md §10) --
    # Contacts older than `keep_days` no longer matter for tracing; a
    # retention policy expires them as new days arrive. Day numbers shift:
    # after a trim, "day 1" is the oldest *retained* day.
    keep_days = days_live + 1
    bytes_before = eng.registry.get("feed").nbytes
    for f in eng.set_retention("feed",
                               RetentionPolicy(window=keep_days)).values():
        f.result(timeout=120)       # wait out the first (catch-up) trim
    for extra_day in range(1, 3):   # two more days arrive, feed stays flat
        day_edges = gen_contact_network(n_people, 1, seed=100 + extra_day)
        # next day number in the *current epoch's* shifted timeline — read
        # it from the graph binding (rebound synchronously by every
        # ingest/trim), not from a resident handle that may predate an
        # in-flight trim
        t_now = eng.registry.resolve_graph("feed").t_max
        eng.ingest("feed",
                   [(int(u), int(v), t_now + 1) for u, v in
                    zip(day_edges.src, day_edges.dst)],
                   wait=True)
        h = eng.registry.get("feed")
        recent = eng.answer("feed", TCCSQuery(patient, 1, h.graph.t_max, k))
        print(f"rolling day +{extra_day}: retained days=1..{h.graph.t_max} "
              f"(window={keep_days}), index {h.nbytes} B "
              f"(was {bytes_before} B untrimmed), "
              f"cohort over retained window {len(recent.vertices)}")
        assert h.graph.t_max <= keep_days   # timeline stays bounded
    s = eng.stats()
    print(f"[stats] retentions={s['registry']['retentions']} "
          f"auto_trims={s['engine']['counters'].get('auto_trims', 0)} "
          f"cache rehomes={s['cache']['rehomes']}")

# -- warm restart: the persistent store survives the process (§13) -------
# Replay the same feed with a store_dir. Process A builds, trims to the
# retention window and ingests the backlog — every epoch writing through
# to disk as it lands. Then it "dies", and process B reopens the store:
# no register_graph, no rebuild — the index is promoted from disk, the
# answers are bit-identical, and ingestion continues at the next epoch.
store_dir = tempfile.mkdtemp(prefix="contact-feed-store-")
with ServingEngine(EngineConfig(max_batch=64, flush_ms=2.0,
                                store_dir=store_dir)) as eng:
    eng.register_graph("feed", g0)
    eng.warmup("feed")
    for f in eng.set_retention("feed",
                               RetentionPolicy(window=keep_days)).values():
        f.result(timeout=120)
    eng.ingest("feed", [tuple(e) for e in backlog.tolist()], wait=True)
    h = eng.registry.get("feed")
    window_q = TCCSQuery(patient, 1, h.graph.t_max, k)
    cohort_before = eng.answer("feed", window_q)
    st = eng.store.stats()
    print(f"\nprocess A exits at epoch {h.epoch} "
          f"(days 1..{h.graph.t_max} retained); store holds "
          f"{st['commits']} commits ({st['commits_delta']} deltas)")

with ServingEngine(EngineConfig(max_batch=64, flush_ms=2.0,
                                store_dir=store_dir)) as eng:
    h2 = eng.warmup("feed")       # no register_graph: adopted from disk
    assert h2.source == "disk", "expected a warm promote, got a rebuild"
    cohort_after = eng.answer("feed", window_q)
    assert cohort_after.vertices == cohort_before.vertices
    print(f"process B: epoch {h2.epoch} promoted from disk in "
          f"{h2.build_seconds * 1e3:.0f} ms (no rebuild), cohort "
          f"{len(cohort_after.vertices)} bit-identical "
          f"(route={cohort_after.provenance.route})")
    day_edges = gen_contact_network(n_people, 1, seed=200)
    t_now = eng.registry.resolve_graph("feed").t_max
    eng.ingest("feed", [(int(u), int(v), t_now + 1) for u, v in
                        zip(day_edges.src, day_edges.dst)], wait=True)
    h3 = eng.registry.get("feed")
    assert h3.epoch == h2.epoch + 1
    print(f"process B keeps ingesting: day {t_now + 1} landed "
          f"(epoch {h3.epoch}, days 1..{h3.graph.t_max})")
shutil.rmtree(store_dir, ignore_errors=True)
