"""End-to-end LM training example with checkpoint/restart.

Default (CPU-friendly): a ~25M-parameter glm4-family model, 200 steps.
For the ~100M-parameter run on a real machine:

    PYTHONPATH=src python examples/train_lm.py --hundred-m

Both exercise the full production path: data pipeline -> jitted train step
(AdamW, clipping, schedule) -> async checkpoints -> auto-resume.
"""

import argparse
import dataclasses
import sys

import jax.numpy as jnp

from repro.configs import base as cbase
from repro.configs.base import ArchSpec, LM_SHAPES, LM_SKIPS
from repro.models.transformer import LMConfig
from repro.launch.train import main as train_main


def register_example_arch(hundred_m: bool):
    if hundred_m:
        cfg = LMConfig("lm-100m", n_layer=12, d_model=768, n_head=12, n_kv=4,
                       d_ff=2048, vocab=8192, d_head=64,
                       dtype=jnp.float32, remat=False)
    else:
        cfg = LMConfig("lm-25m", n_layer=6, d_model=512, n_head=8, n_kv=4,
                       d_ff=1408, vocab=4096, d_head=64,
                       dtype=jnp.float32, remat=False)
    print(f"model: {cfg.param_count/1e6:.1f}M params")
    spec = ArchSpec(id="lm-example", family="lm-dense", model_cfg=cfg,
                    smoke_cfg=cfg, shapes=dict(LM_SHAPES), skips=dict(LM_SKIPS))
    cbase.register(spec)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()
    register_example_arch(args.hundred_m)
    train_main(["--arch", "lm-example", "--steps", str(args.steps),
                "--batch", str(args.batch), "--seq", str(args.seq),
                "--ckpt-dir", "/tmp/repro_lm_ckpt", "--resume", "auto",
                "--lr", "1e-3", "--log-every", "20"])
