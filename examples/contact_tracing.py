"""Contact tracing with historical k-core search (paper §1, Applications).

    PYTHONPATH=src python examples/contact_tracing.py

Given a confirmed infection and a day window, TCCS returns the *cohesive*
exposure cohort — people who were in the k-core component of the patient
during that window (repeated mutual contact), not merely anyone ever met.

Query API v2 turns the per-patient follow-up into ONE ``WindowSweep``: the
incubation sweep (every 7-day window ending on day d) is a single engine
call — one device launch for all windows — instead of a client-side loop
of point queries. EDGES mode then yields the actual contact edges of the
peak-day cohort for the tracers to walk.

Set ``REPRO_EXAMPLE_SCALE=tiny`` (CI smoke) to shrink the network.
"""

import os
import time

import numpy as np

from repro.core import ResultMode, TCCSQuery, WindowSweep
from repro.core.temporal_graph import gen_contact_network
from repro.core.kcore import k_max
from repro.serving import EngineConfig, ServingEngine

TINY = os.environ.get("REPRO_EXAMPLE_SCALE") == "tiny"
n_people, days, n_patients = (120, 12, 3) if TINY else (400, 30, 5)

g = gen_contact_network(n_people, days, seed=7)
k = max(2, int(0.25 * k_max(g)))   # moderate cohesion: most patients have cohorts
print(f"contact network: {n_people} people, {days} days, {g.m} contacts, k={k}")

with ServingEngine(EngineConfig(max_batch=64, flush_ms=2.0)) as eng:
    eng.register_graph("contacts", g)
    t0 = time.perf_counter()
    handle = eng.warmup("contacts")
    print(f"index built in {time.perf_counter()-t0:.2f}s "
          f"({handle.nbytes/1e3:.0f} KB)")

    rng = np.random.default_rng(0)
    patients = rng.integers(0, n_people, n_patients)
    windows = [(end_day - 6, end_day) for end_day in range(7, days + 1)]
    for patient in patients:
        # incubation-window sweep: one engine call, one device launch
        t0 = time.perf_counter()
        traj = eng.sweep("contacts", WindowSweep(int(patient), k, windows))
        dt = (time.perf_counter() - t0) * 1e3
        active = {r.query.te: r.num_vertices for r in traj if r.num_vertices}
        peak = max(active.items(), key=lambda kv: kv[1]) if active else None
        print(f"patient {patient:3d}: {len(active)} active windows "
              f"({dt:.1f} ms sweep)"
              f"{f', peak cohort {peak[1]} on day {peak[0]}' if peak else ''}")
        if peak:
            # drill down: the peak cohort's actual contact edges
            day = peak[0]
            detail = eng.answer("contacts", TCCSQuery(
                int(patient), day - 6, day, k, ResultMode.EDGES))
            assert detail.vertices == traj[day - 7].vertices
            print(f"             day {day}: {detail.num_edges} member "
                  f"contacts among {detail.num_vertices} people "
                  f"(route={detail.provenance.route})")
