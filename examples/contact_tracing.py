"""Contact tracing with historical k-core search (paper §1, Applications).

    PYTHONPATH=src python examples/contact_tracing.py

Given a confirmed infection and a day window, TCCS returns the *cohesive*
exposure cohort — people who were in the k-core component of the patient
during that window (repeated mutual contact), not merely anyone ever met.
One PECB index answers all (patient x window) follow-ups in microseconds.
"""

import time

import numpy as np

from repro.core.temporal_graph import gen_contact_network
from repro.core.pecb_index import build_pecb_index
from repro.core.kcore import k_max

n_people, days = 400, 30
g = gen_contact_network(n_people, days, seed=7)
k = max(2, int(0.25 * k_max(g)))   # moderate cohesion: most patients have cohorts
print(f"contact network: {n_people} people, {days} days, {g.m} contacts, k={k}")

t0 = time.perf_counter()
index = build_pecb_index(g, k)
print(f"index built in {time.perf_counter()-t0:.2f}s "
      f"({index.nbytes()/1e3:.0f} KB)")

rng = np.random.default_rng(0)
patients = rng.integers(0, n_people, 5)
for patient in patients:
    # incubation-window sweep: every 7-day window that ends on day d
    exposed_by_day = {}
    t0 = time.perf_counter()
    for end_day in range(7, days + 1):
        cohort = index.query(int(patient), end_day - 6, end_day)
        if cohort:
            exposed_by_day[end_day] = len(cohort)
    dt = (time.perf_counter() - t0) * 1e3
    peak = max(exposed_by_day.items(), key=lambda kv: kv[1]) if exposed_by_day else None
    print(f"patient {patient:3d}: {len(exposed_by_day)} active windows "
          f"({dt:.1f} ms total){f', peak cohort {peak[1]} on day {peak[0]}' if peak else ''}")
