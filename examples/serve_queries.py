"""Serving-engine demo: contact-tracing traffic through Query API v2.

A health authority traces exposure cohorts on a contact network: "who was
in the temporal k-core component of case u during days [ts, te]?". Traffic
is mixed — two cohort densities (k=8 loose, k=10 tight), an initial sweep
of fresh cases, then follow-up waves where many tracers re-check the same
hot cases over canonical exposure windows (cache hits), plus sporadic
single look-ups (straggler batches the planner routes to host Algorithm 1)
and periodic SUBGRAPH drill-downs on hot cases (full-mode device
launches). One ServingEngine serves all of it through typed specs: the
registry memoizes ONE k-stratified index per workload that answers every
supported k (DESIGN.md §14) — so the k=8 and k=10 cohorts share a single
build AND share device batches (mixed-k lanes, each query carrying its
own k); batched misses run on the device plane in power-of-two buckets;
every result carries provenance (route, batch shape, timings).

    PYTHONPATH=src python examples/serve_queries.py

Set ``REPRO_EXAMPLE_SCALE=tiny`` (CI smoke) to shrink the traffic volume
(the network keeps its density so both cohort k's stay non-trivial).
"""

import os
import time

import numpy as np

from repro.core import ResultMode, TCCSQuery
from repro.serving import EngineConfig, ServingEngine
from repro.core.temporal_graph import gen_contact_network

TINY = os.environ.get("REPRO_EXAMPLE_SCALE") == "tiny"
N_WAVES, N_FRESH = (3, 10) if TINY else (8, 40)


def main():
    g = gen_contact_network(n=120, days=10, seed=7, meetings_per_day=240)
    print(f"[setup] contact network: n={g.n} m={g.m} days={g.t_max}")

    cfg = EngineConfig(max_batch=64, flush_ms=3.0, host_threshold=8,
                       cache_capacity=2048)
    rng = np.random.default_rng(0)
    hot_cases = rng.integers(0, g.n, 10)       # index cases many tracers watch
    # canonical exposure windows tracers all use (days [ts, te])
    windows = [(d, min(d + 6, g.t_max)) for d in (1, 3, 4)]

    def hot_spec(k):
        u = int(rng.choice(hot_cases))
        ts, te = windows[int(rng.integers(len(windows)))]
        return TCCSQuery(u, ts, te, k)

    def fresh_spec(k):
        u = int(rng.integers(0, g.n))
        ts = int(rng.integers(1, g.t_max))
        return TCCSQuery(u, ts, min(ts + int(rng.integers(1, 7)), g.t_max), k)

    with ServingEngine(cfg) as eng:
        eng.register_graph("contacts", g)
        # ONE warmup, one stratified build: both cohort densities (and
        # every other supported k) are served from the same resident handle
        h = eng.warmup("contacts")
        print(f"[warmup] stratified index built in {h.build_seconds:.2f}s "
              f"({h.pecb.num_nodes} forest nodes, "
              f"supported_ks={h.supported_ks})")

        futures = []
        t0 = time.perf_counter()

        # -- phase 1: morning sweep — every hot case at BOTH densities in a
        # single submit: the planner forms mixed-k device batches, k=8 and
        # k=10 specs riding the same launch
        specs = [TCCSQuery(int(u), *w, k)
                 for k in (8, 10) for u in hot_cases for w in windows]
        specs += [fresh_spec(k) for k in (8, 10) for _ in range(N_FRESH)]
        futures += eng.submit_specs("contacts", specs)
        eng.flush()
        eng.drain()                            # results land, cache fills

        # -- phase 2: follow-up waves — tracers re-check hot cases -------
        for wave in range(N_WAVES):
            k = 8 if wave % 3 else 10
            n_req = int(rng.integers(15, 24 if TINY else 50))
            specs = [hot_spec(k) if rng.random() < 0.5 else fresh_spec(k)
                     for _ in range(n_req)]
            if wave % 2:                       # a drill-down on a hot case:
                specs.append(TCCSQuery(        # induced subgraph, same batch
                    int(rng.choice(hot_cases)), *windows[0], k,
                    ResultMode.SUBGRAPH))
            futures += eng.submit_specs("contacts", specs)
            if wave % 5 == 0:                  # a lone tracer's single query
                futures.append(eng.submit_spec("contacts", TCCSQuery(
                    int(rng.integers(0, g.n)), 1, g.t_max, 8)))
                eng.flush()
        eng.flush()
        results = [f.result(timeout=120) for f in futures]
        dt = time.perf_counter() - t0

        sizes = np.asarray([r.num_vertices for r in results])
        routes = {}
        for r in results:
            routes[r.provenance.route] = routes.get(r.provenance.route, 0) + 1
        print(f"\n[serve] {len(results)} queries in {dt:.3f}s "
              f"-> {len(results)/dt:,.0f} q/s")
        print(f"[serve] cohort sizes: median={int(np.median(sizes))} "
              f"max={int(sizes.max())} empty={(sizes == 0).sum()}")
        print(f"[serve] result routes: {routes}")
        subs = [r for r in results if r.query.mode is ResultMode.SUBGRAPH]
        for r in subs[:3]:
            print(f"[serve] drill-down case {r.query.u} days "
                  f"[{r.query.ts},{r.query.te}]: {r.num_vertices} people, "
                  f"{r.num_edges} contacts (route={r.provenance.route})")

        snap = eng.stats()
        e2e = snap["engine"]["latency"]["e2e"]
        print(f"[latency] e2e p50={e2e['p50_ms']:.2f}ms "
              f"p95={e2e['p95_ms']:.2f}ms p99={e2e['p99_ms']:.2f}ms "
              f"(mean {e2e['mean_ms']:.2f}ms)")
        print("[stats]")
        print(eng.format_stats())

        # spot-check exactness against host Algorithm 1 — the SAME resident
        # handle answers both cohort densities
        hs = eng.registry.get("contacts")
        u0, (ts0, te0) = int(hot_cases[0]), windows[0]
        for k in (8, 10):
            got = eng.answer("contacts", TCCSQuery(u0, ts0, te0, k))
            assert got.vertices == \
                hs.pecb.answer(TCCSQuery(u0, ts0, te0, k)).vertices
        print("[verify] engine results == Algorithm 1 at k=8 and k=10 "
              "from one index")


if __name__ == "__main__":
    main()
