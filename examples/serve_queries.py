"""End-to-end serving driver (the paper's deployment kind): build the PECB
index offline, serve batched TCCS queries with the device engine, verify
exactness, report throughput.

    PYTHONPATH=src python examples/serve_queries.py
"""

from repro.launch.serve import main

if __name__ == "__main__":
    main(["--workload", "cm_like", "--queries", "2048", "--batch", "256"])
