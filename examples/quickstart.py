"""Quickstart: build a PECB index and answer TCCS queries via Query API v2.

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's running example (Figure 1 / Example 4.14) through
the typed query surface — vertices, the member-edge set, and the induced
temporal subgraph of the component — then a random workload with oracle
verification on every result mode, and finally the k-stratified index
(DESIGN.md §14): ONE build answering *every* supported k, mixed-k
batches included.

Set ``REPRO_EXAMPLE_SCALE=tiny`` (CI smoke) to shrink the random workload.
"""

import os
import warnings

import numpy as np

from repro.core import InvalidQueryError, ResultMode, TCCSQuery
from repro.core.temporal_graph import TemporalGraph, gen_temporal_graph
from repro.core.batch_query import batch_query_mixed_np
from repro.core.pecb_index import build_pecb_index, build_stratified_index
from repro.core.kcore import tccs_oracle, tccs_oracle_edges

TINY = os.environ.get("REPRO_EXAMPLE_SCALE") == "tiny"

# --- the paper's Figure 1 graph (v1..v8 -> 0..7) -------------------------
g = TemporalGraph.from_edges(8, [
    (0, 1, 4), (0, 2, 4), (1, 2, 4),      # triangle v1,v2,v3 at t=4
    (2, 7, 2), (3, 4, 3),
    (5, 6, 4), (5, 7, 5), (6, 7, 5),      # triangle v6,v7,v8
    (1, 3, 6), (1, 4, 6), (4, 5, 7),
])
index = build_pecb_index(g, k=2)

# Example 4.14: query vertex v2, window [3, 5] -> component {v1, v2, v3}
res = index.answer(TCCSQuery(u=1, ts=3, te=5, k=2))
print("TCCS(v2, [3,5], k=2) =", sorted(f"v{v+1}" for v in res.vertices))
assert res.vertices == {0, 1, 2}

# the same query in SUBGRAPH mode: the induced temporal component
sub = index.answer(TCCSQuery(1, 3, 5, 2, ResultMode.SUBGRAPH))
print(f"  induced subgraph: {sub.num_vertices} vertices, "
      f"{sub.subgraph.m} temporal edges "
      f"{[(int(a), int(b), int(t)) for a, b, t in zip(sub.subgraph.src, sub.subgraph.dst, sub.subgraph.t)]}")
assert sub.edges.vertex_projection() == res.vertices

# Example 2.3: window [4, 5] has two 2-core components
r2 = index.answer(TCCSQuery(6, 4, 5, 2))
print("TCCS(v7, [4,5], k=2) =", sorted(f"v{v+1}" for v in r2.vertices))

# windows beyond t_max canonicalize: same answer, same cache key
wide = TCCSQuery(1, 3, 999, 2).canonical(g.t_max)
assert wide == TCCSQuery(1, 3, g.t_max, 2)

# malformed queries fail loudly at the boundary (no silent empty sets)
for bad in (TCCSQuery(1, 5, 3, 2), TCCSQuery(99, 3, 5, 2), TCCSQuery(1, 3, 5, 1)):
    try:
        index.answer(bad)
        raise AssertionError("InvalidQueryError expected")
    except InvalidQueryError as e:
        print(f"  rejected {bad.u, bad.ts, bad.te, bad.k}: {e}")

# the legacy positional shim still answers (deprecated, now warning)
with warnings.catch_warnings():
    warnings.simplefilter("ignore", DeprecationWarning)
    assert index.query(1, 3, 5) == {0, 1, 2}

# --- a random temporal graph, verified against brute force ---------------
n, m, t_max, n_checks = (60, 600, 20, 40) if TINY else (200, 3000, 60, 200)
g2 = gen_temporal_graph(n=n, m=m, t_max=t_max, seed=1)
idx2 = build_pecb_index(g2, k=4)
rng = np.random.default_rng(0)
checked = 0
for _ in range(n_checks):
    u = int(rng.integers(0, g2.n))
    ts = int(rng.integers(1, g2.t_max + 1))
    te = int(rng.integers(ts, g2.t_max + 1))
    r = idx2.answer(TCCSQuery(u, ts, te, 4, ResultMode.EDGES))
    assert r.vertices == tccs_oracle(g2, 4, u, ts, te)
    assert r.edges.edge_ids() == tccs_oracle_edges(g2, 4, u, ts, te)
    checked += 1
print(f"random graph: {checked} queries verified against the oracle "
      "(vertices + member edges)")
print(f"index: {idx2.num_nodes} forest nodes, {idx2.nbytes()/1e3:.1f} KB "
      f"for {g2.m} temporal edges")

# --- one k-stratified build serves EVERY k (DESIGN.md §14) ---------------
sx = build_stratified_index(g2)          # default policy: ks = 2..k_max
print(f"stratified index: supported_ks={sx.supported_ks}, "
      f"{sx.num_nodes} forest nodes, {sx.nbytes()/1e3:.1f} KB — one build")

# point queries pick their k per spec; answers match per-k builds exactly
u, ts, te = 7, 2, g2.t_max - 2
for k in sx.supported_ks[:3] + sx.supported_ks[-1:]:
    r = sx.answer(TCCSQuery(u, ts, te, int(k)))
    assert r.vertices == tccs_oracle(g2, int(k), u, ts, te)
assert sx.answer(TCCSQuery(u, ts, te, 4)).vertices == \
    idx2.answer(TCCSQuery(u, ts, te, 4)).vertices

# cores are nested: the component only shrinks as k rises
sizes = [len(sx.answer(TCCSQuery(u, 1, g2.t_max, int(k))).vertices)
         for k in sx.supported_ks]
assert all(a >= b for a, b in zip(sizes, sizes[1:]))
print(f"k-monotone components from v{u}: sizes {sizes}")

# a MIXED-k batch on the device plane: one launch, per-query k
mixed = [(u, ts, te, int(k)) for k in sx.supported_ks[:4]]
for vs, (qu, qts, qte, qk) in zip(batch_query_mixed_np(sx, mixed), mixed):
    assert vs == tccs_oracle(g2, qk, qu, qts, qte)
print(f"mixed-k device batch of {len(mixed)} queries "
      f"(k={[q[3] for q in mixed]}) verified against the oracle")

# a k above the graph's degeneracy is exactly empty — answered on the
# host without any stratum (route "trivial")
big = sx.answer(TCCSQuery(u, ts, te, sx.k_max_graph + 3))
assert big.vertices == set() and big.provenance.route == "trivial"
print(f"k={sx.k_max_graph + 3} > k_max={sx.k_max_graph}: trivially empty")
