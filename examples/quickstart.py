"""Quickstart: build a PECB index and answer TCCS queries.

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's running example (Figure 1 / Example 4.14), then a
random workload with oracle verification.
"""

import numpy as np

from repro.core.temporal_graph import TemporalGraph, gen_temporal_graph
from repro.core.pecb_index import build_pecb_index
from repro.core.kcore import tccs_oracle

# --- the paper's Figure 1 graph (v1..v8 -> 0..7) -------------------------
g = TemporalGraph.from_edges(8, [
    (0, 1, 4), (0, 2, 4), (1, 2, 4),      # triangle v1,v2,v3 at t=4
    (2, 7, 2), (3, 4, 3),
    (5, 6, 4), (5, 7, 5), (6, 7, 5),      # triangle v6,v7,v8
    (1, 3, 6), (1, 4, 6), (4, 5, 7),
])
index = build_pecb_index(g, k=2)

# Example 4.14: query vertex v2, window [3, 5] -> component {v1, v2, v3}
result = index.query(1, 3, 5)
print("TCCS(v2, [3,5], k=2) =", sorted(f"v{v+1}" for v in result))
assert result == {0, 1, 2}

# Example 2.3: window [4, 5] has two 2-core components
print("TCCS(v7, [4,5], k=2) =", sorted(f"v{v+1}" for v in index.query(6, 4, 5)))

# --- a random temporal graph, verified against brute force ---------------
g2 = gen_temporal_graph(n=200, m=3000, t_max=60, seed=1)
idx2 = build_pecb_index(g2, k=4)
rng = np.random.default_rng(0)
checked = 0
for _ in range(200):
    u = int(rng.integers(0, g2.n))
    ts = int(rng.integers(1, g2.t_max + 1))
    te = int(rng.integers(ts, g2.t_max + 1))
    assert idx2.query(u, ts, te) == tccs_oracle(g2, 4, u, ts, te)
    checked += 1
print(f"random graph: {checked} queries verified against the oracle")
print(f"index: {idx2.num_nodes} forest nodes, {idx2.nbytes()/1e3:.1f} KB "
      f"for {g2.m} temporal edges")
