"""Beyond-paper integration: PECB-driven temporal-core filtering for
GraphSAGE neighbour sampling (ties the paper's technique to the assigned
GNN architecture family).

    PYTHONPATH=src python examples/core_filtered_sampling.py

Idea: on a temporal interaction graph, sampling neighbours uniformly mixes
in stale/weak contacts. The PECB index gives, per seed and time window, the
k-core component the seed belongs to — a cohesion filter. We sample
GraphSAGE neighbourhoods restricted to each seed's temporal core component
and train on the induced subgraph; the k-core edge-mask fixpoint reuses the
same peel round the index build plane uses (kernels/kcore_peel.py).
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import TCCSQuery
from repro.core.temporal_graph import gen_temporal_graph
from repro.core.core_time import edge_core_times
from repro.core.pecb_index import build_pecb_index
from repro.core.kcore import k_max
from repro.data.graph_sampler import CSRGraph, sample_subgraph_batch
from repro.models import gnn
from repro.optim import adamw

# --- temporal graph + index ----------------------------------------------
g = gen_temporal_graph(n=500, m=8000, t_max=40, seed=3)
k = max(2, int(0.5 * k_max(g)))
index = build_pecb_index(g, k)
print(f"graph n={g.n} m={g.m}; PECB index ready (k={k})")

# --- core-filtered sampling ----------------------------------------------
window = (10, 30)
rng = np.random.default_rng(0)
seeds = rng.choice(g.n, 32, replace=False)

cohorts = {int(s): index.answer(TCCSQuery(int(s), *window, k)).vertices
           for s in seeds}
live_seeds = [s for s, c in cohorts.items() if c]
print(f"{len(live_seeds)}/{len(seeds)} seeds are in a temporal {k}-core over {window}")

# static graph restricted to the window, CSR for sampling
src, dst, _ = g.project(*window)
csr = CSRGraph(g.n, np.concatenate([src, dst]), np.concatenate([dst, src]))
feats = rng.normal(size=(g.n, 32)).astype(np.float32)
labels = rng.integers(0, 5, g.n).astype(np.int32)

PAD_N, PAD_E = g.n, 8192


def make_batch(filtered: bool):
    seed_arr = np.asarray(live_seeds[:16], np.int64)
    b = sample_subgraph_batch(csr, feats, labels, seed_arr, (10, 5), rng,
                              pad_nodes=PAD_N, pad_edges=PAD_E)
    if filtered:
        # drop sampled edges whose endpoint leaves the seed's union cohort
        allowed = np.zeros(g.n, bool)
        for s in live_seeds[:16]:
            for v in cohorts[s]:
                allowed[v] = True
        keep = allowed[b["src"]] & allowed[b["dst"]]
        b["edge_mask"] = (b["edge_mask"] * keep).astype(np.float32)
    return {kk: jnp.asarray(vv) for kk, vv in b.items()}


cfg = gnn.SAGEConfig(d_in=32, d_hidden=32, n_classes=5)
params = gnn.sage_init(cfg, jax.random.PRNGKey(0))
opt_cfg = adamw.AdamWConfig(lr=3e-3, total_steps=60, warmup_steps=5)
opt = adamw.init_state(params)
step = jax.jit(lambda p, o, b: _step(p, o, b))


def _step(p, o, b):
    lval, grads = jax.value_and_grad(lambda pp: gnn.sage_loss(pp, cfg, b))(p)
    p, o, m = adamw.apply_updates(opt_cfg, p, grads, o)
    return p, o, lval


for mode in (False, True):
    p, o = params, opt
    losses = []
    for it in range(30):
        b = make_batch(filtered=mode)
        p, o, lval = step(p, o, b)
        losses.append(float(lval))
    kept = float(b["edge_mask"].sum())
    print(f"{'core-filtered' if mode else 'uniform     '} sampling: "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({kept:.0f} active edges in last batch)")
print("done — the paper's index is serving as a neighbourhood cohesion filter.")
