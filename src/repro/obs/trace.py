"""Query-lifecycle spans with cross-thread context propagation
(DESIGN.md §11.1-§11.3).

A :class:`Span` is one timed operation (a query's end-to-end life, its wait
in the batcher queue, one device launch, one background index refresh).
Spans form trees: every span carries ``(trace_id, span_id, parent_id)``,
where ``trace_id`` is the root's span id, so a whole tree can be recovered
from a flat buffer. Two propagation rules (§11.2):

* **Within a thread** — entering a span as a context manager makes it the
  thread-local *current* span; spans started without an explicit parent
  nest under it.
* **Across threads** — context never propagates implicitly (a batcher
  worker serves interleaved requests from many callers; thread identity
  means nothing). The *producer* captures ``span.ctx`` and hands it over
  explicitly: the engine attaches the open root span to each
  :class:`~repro.serving.batcher.Request`, and epoch mutations pass the
  ingest/retain span's context into the registry so the FIFO refresh
  worker parents its refresh spans correctly.

Finished spans are recorded into the :class:`Tracer`'s bounded,
lock-protected ring buffer (oldest dropped first, ``dropped`` counted —
tracing must never grow without bound under sustained load). Open spans
are not resident anywhere except with their owner, so an abandoned span
costs nothing. A disabled tracer hands out the :data:`NULL_SPAN`
singleton, making every instrumentation site a few attribute lookups.

The :class:`SlowQueryLog` hangs off the root-span finish path: a completed
query whose duration crosses the threshold captures its full span tree
(scanned from the ring buffer by trace id) plus the canonical query spec.
"""

from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import NamedTuple

from .locks import named_lock


class SpanContext(NamedTuple):
    """The portable identity of a span: what crosses a thread boundary."""

    trace_id: str
    span_id: str


#: Process-wide span-id source. ``next()`` on ``itertools.count`` is atomic
#: under the GIL, so ids are unique across every tracer and thread.
_IDS = itertools.count(1)


def _next_id() -> str:
    return format(next(_IDS), "x")


#: Sentinel: "use the thread-local current span" (vs None = explicit root).
_IMPLICIT = object()


class Span:
    """One timed operation. Created by :meth:`Tracer.start_span`; recorded
    into the tracer's ring buffer on :meth:`end` (idempotent)."""

    __slots__ = ("name", "cat", "trace_id", "span_id", "parent_id",
                 "t_start", "t_end", "tid", "thread_name", "attrs",
                 "_tracer", "_ended")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 trace_id: str, span_id: str, parent_id: str | None,
                 t_start: float, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.t_start = t_start
        self.t_end: float | None = None
        t = threading.current_thread()
        self.tid = t.ident or 0
        self.thread_name = t.name
        self.attrs = attrs
        self._ended = False

    # -- identity --------------------------------------------------------
    @property
    def ctx(self) -> SpanContext:
        return SpanContext(self.trace_id, self.span_id)

    @property
    def ids(self) -> tuple[str | None, str | None]:
        """(trace_id, span_id) — the pair stamped into ``Provenance``."""
        return self.trace_id, self.span_id

    @property
    def duration_s(self) -> float:
        end = self.t_end if self.t_end is not None else time.perf_counter()
        return max(0.0, end - self.t_start)

    # -- mutation --------------------------------------------------------
    def set(self, key: str, value) -> "Span":
        self.attrs[key] = value
        return self

    def child(self, name: str, *, cat: str | None = None,
              t0: float | None = None, **attrs) -> "Span":
        """Start a child span (explicit parent = self; never thread-local)."""
        return self._tracer.start_span(name, parent=self,
                                       cat=cat or self.cat, t0=t0, **attrs)

    def end(self, t: float | None = None) -> None:
        if self._ended:
            return
        self._ended = True
        self.t_end = t if t is not None else time.perf_counter()
        if self.t_end < self.t_start:      # retrospective spans clamp
            self.t_end = self.t_start
        self._tracer._record(self)

    # -- context-manager use (thread-local current) ----------------------
    def __enter__(self) -> "Span":
        self._tracer._push(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self._tracer._pop(self)
        if exc is not None:
            self.attrs["error"] = repr(exc)
        self.end()

    def to_dict(self) -> dict:
        return {
            "name": self.name, "cat": self.cat,
            "trace_id": self.trace_id, "span_id": self.span_id,
            "parent_id": self.parent_id,
            "t_start": self.t_start, "t_end": self.t_end,
            "duration_ms": self.duration_s * 1e3,
            "tid": self.tid, "thread": self.thread_name,
            "attrs": dict(self.attrs),
        }

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, trace={self.trace_id}, "
                f"id={self.span_id}, parent={self.parent_id}, "
                f"dur={self.duration_s*1e3:.3f}ms)")


class _NullSpan:
    """The do-nothing span a disabled tracer hands out. ``ctx``/``ids``
    are None-shaped so instrumentation sites never branch on enablement."""

    __slots__ = ()
    ctx = None
    ids = (None, None)
    name = cat = trace_id = span_id = parent_id = None
    attrs: dict = {}
    duration_s = 0.0

    def set(self, key, value):
        return self

    def child(self, name, **kw):
        return self

    def end(self, t=None):
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def to_dict(self) -> dict:
        return {}


NULL_SPAN = _NullSpan()


class Tracer:
    """Span factory + bounded ring buffer of finished spans.

    Thread-safe throughout: span *starts* touch only thread-local state
    (and an atomic id counter); span *ends* append to the ring under one
    lock. ``capacity`` bounds resident memory; overflow drops the oldest
    span and increments ``dropped`` — the export is a window, never a
    leak. ``enabled=False`` short-circuits every start to
    :data:`NULL_SPAN` (the off-switch costs one attribute check).
    """

    def __init__(self, capacity: int = 16384, enabled: bool = True):
        if capacity < 1:
            raise ValueError(f"tracer capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.enabled = enabled
        self._lock = named_lock("tracer")
        self._spans: deque[Span] = deque()
        self.dropped = 0
        self._local = threading.local()
        #: perf_counter origin: Chrome export timestamps are relative to it
        self.t0 = time.perf_counter()

    # -- thread-local current span ---------------------------------------
    def current(self) -> Span | None:
        stack = getattr(self._local, "stack", None)
        return stack[-1] if stack else None

    def _push(self, span: Span) -> None:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        stack.append(span)

    def _pop(self, span: Span) -> None:
        stack = getattr(self._local, "stack", None)
        if stack and stack[-1] is span:
            stack.pop()

    # -- span lifecycle ---------------------------------------------------
    def start_span(self, name: str, *, parent=_IMPLICIT, cat: str = "serving",
                   t0: float | None = None, **attrs):
        """Start a span.

        ``parent`` is a :class:`Span`, a :class:`SpanContext`, ``None``
        (an explicit root — cross-thread producers must *choose*), or
        omitted (nest under the thread-local current span, if any).
        ``t0`` backdates the start (retrospective queue-wait spans).
        """
        if not self.enabled:
            return NULL_SPAN
        if parent is _IMPLICIT:
            parent = self.current()
        if parent is None or parent is NULL_SPAN:
            span_id = _next_id()
            return Span(self, name, cat, span_id, span_id, None,
                        t0 if t0 is not None else time.perf_counter(), attrs)
        if isinstance(parent, Span):
            parent = parent.ctx
        return Span(self, name, cat, parent.trace_id, _next_id(),
                    parent.span_id,
                    t0 if t0 is not None else time.perf_counter(), attrs)

    def span(self, name: str, **kw):
        """``with tracer.span("stage"): ...`` convenience — same arguments
        as :meth:`start_span`; the context manager pushes/pops the
        thread-local current span and ends it on exit."""
        return self.start_span(name, **kw)

    def _record(self, span: Span) -> None:
        with self._lock:
            if len(self._spans) >= self.capacity:
                self._spans.popleft()
                self.dropped += 1
            self._spans.append(span)

    # -- reading ----------------------------------------------------------
    def spans(self, name: str | None = None,
              trace_id: str | None = None) -> list[Span]:
        """Snapshot of finished spans, oldest first, optionally filtered."""
        with self._lock:
            out = list(self._spans)
        if name is not None:
            out = [s for s in out if s.name == name]
        if trace_id is not None:
            out = [s for s in out if s.trace_id == trace_id]
        return out

    def trace_tree(self, trace_id: str) -> list[dict]:
        """Every finished span of one trace as dicts (slow-query capture).
        The ring may have dropped early spans of an old trace — the
        capture is best-effort by design, bounded either way."""
        return [s.to_dict() for s in self.spans(trace_id=trace_id)]

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self.dropped = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._spans)

    def stats(self) -> dict:
        with self._lock:
            return {"enabled": self.enabled, "capacity": self.capacity,
                    "spans": len(self._spans), "dropped": self.dropped}


class SlowQueryLog:
    """Bounded log of queries whose end-to-end span crossed a latency
    threshold (DESIGN.md §11.5).

    ``threshold_ms=None`` disables the log entirely (the default: the
    engine always constructs one, the config decides whether it bites).
    Each entry captures the root span, the *full span tree* re-scanned
    from the tracer's ring buffer, and the canonical query spec — enough
    to answer "where did this one slow query spend its time" without
    replaying anything.
    """

    def __init__(self, threshold_ms: float | None = None,
                 tracer: Tracer | None = None, cap: int = 256):
        self.threshold_ms = threshold_ms
        self.tracer = tracer
        self.cap = cap
        self._lock = named_lock("slowlog")
        self._entries: deque[dict] = deque(maxlen=cap)
        self.observed = 0

    @property
    def enabled(self) -> bool:
        return self.threshold_ms is not None

    def observe(self, root_span, query=None) -> bool:
        """Called as a root query span finishes; returns True if logged."""
        if self.threshold_ms is None or root_span is NULL_SPAN:
            return False
        dur_ms = root_span.duration_s * 1e3
        if dur_ms < self.threshold_ms:
            return False
        entry = {
            "trace_id": root_span.trace_id,
            "span_id": root_span.span_id,
            "duration_ms": dur_ms,
            "query": repr(query) if query is not None else None,
            "attrs": dict(root_span.attrs),
            "spans": (self.tracer.trace_tree(root_span.trace_id)
                      if self.tracer is not None else []),
        }
        with self._lock:
            self._entries.append(entry)
            self.observed += 1
        return True

    def entries(self) -> list[dict]:
        with self._lock:
            return list(self._entries)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def format(self) -> str:
        """Human-readable report: one block per slow query, children
        indented under the root with per-span durations."""
        lines = []
        for e in self.entries():
            lines.append(f"slow query {e['duration_ms']:.3f}ms "
                         f"trace={e['trace_id']} {e['query'] or ''}")
            by_parent: dict = {}
            for s in e["spans"]:
                by_parent.setdefault(s["parent_id"], []).append(s)

            def walk(parent_id, depth):
                for s in by_parent.get(parent_id, []):
                    lines.append(f"  {'  ' * depth}{s['name']:<12} "
                                 f"{s['duration_ms']:9.3f}ms  "
                                 f"[{s['thread']}]")
                    walk(s["span_id"], depth + 1)

            walk(None, 0)
        return "\n".join(lines) if lines else "(no slow queries)"
