"""Unified metrics registry: counters, gauges, histograms, stat sources
(DESIGN.md §11.4).

One object absorbs everything the serving plane counts or times:

* **counters** — monotonically increasing ints (cache hits, routed
  queries, jit compiles);
* **gauges** — point-in-time values, either set directly or registered as
  callables resolved at snapshot time (resident device count, compiled
  program count);
* **histograms** — :class:`LatencyHistogram` per stage (queue wait,
  device exec, end-to-end), summarized as p50/p95/p99/mean with linear
  interpolation;
* **sources** — pluggable callables returning stat dicts (the result
  cache's and index registry's ``stats()``), pulled into the same
  snapshot so one export carries the whole serving plane.

``snapshot()`` is the single read surface;
:func:`repro.obs.export.metrics_to_json` round-trips it. The serving
engine's ``EngineMetrics`` subclasses this registry, so every existing
``count``/``observe`` call site feeds the unified surface unchanged.
"""

from __future__ import annotations

import math
import random
from typing import Callable

from .locks import named_lock


class LatencyHistogram:
    """Latency samples (seconds) with percentile summaries.

    Keeps exact samples up to ``cap``; beyond that, new samples replace a
    uniformly random slot (classic reservoir), so long benches keep an
    unbiased view without unbounded memory. ``count``/``total`` stay exact.

    Thread-safe: ``add`` and the readers share one internal lock —
    batcher workers, caller threads resolving cache hits, and the stats
    reader all touch the same object (the §11.4 audit gave the histogram
    its own lock instead of relying on callers to serialize).

    Percentiles interpolate linearly between adjacent order statistics
    (the numpy ``"linear"`` convention) rather than rounding to the
    nearest rank, so p99 is stable at small sample counts instead of
    snapping between extreme samples.
    """

    def __init__(self, cap: int = 65536, seed: int = 0):
        self._cap = cap
        self._rng = random.Random(seed)
        self._lock = named_lock("histogram")
        self._samples: list[float] = []
        self.count = 0
        self.total = 0.0

    def add(self, seconds: float) -> None:
        with self._lock:
            self.count += 1
            self.total += seconds
            if len(self._samples) < self._cap:
                self._samples.append(seconds)
            else:
                j = self._rng.randrange(self.count)
                if j < self._cap:
                    self._samples[j] = seconds

    @staticmethod
    def _pct(sorted_samples: list[float], q: float) -> float:
        """Linear-interpolated percentile of pre-sorted samples."""
        if not sorted_samples:
            return 0.0
        n = len(sorted_samples)
        pos = min(max(q, 0.0), 100.0) / 100.0 * (n - 1)
        lo = int(math.floor(pos))
        frac = pos - lo
        if frac <= 0.0 or lo + 1 >= n:
            return sorted_samples[lo]
        return sorted_samples[lo] + frac * (sorted_samples[lo + 1]
                                            - sorted_samples[lo])

    def _sorted_snapshot(self) -> tuple[list[float], int, float]:
        with self._lock:
            return sorted(self._samples), self.count, self.total

    def percentile(self, q: float) -> float:
        s, _, _ = self._sorted_snapshot()
        return self._pct(s, q)

    def summary(self) -> dict:
        ms = 1e3
        s, count, total = self._sorted_snapshot()
        return {
            "count": count,
            "mean_ms": (total / count * ms) if count else 0.0,
            "p50_ms": self._pct(s, 50) * ms,
            "p95_ms": self._pct(s, 95) * ms,
            "p99_ms": self._pct(s, 99) * ms,
            "max_ms": (s[-1] * ms) if s else 0.0,
        }


class MetricsRegistry:
    """Thread-safe registry of counters + gauges + per-stage latency
    histograms + external stat sources, behind one snapshot surface."""

    def __init__(self):
        self._lock = named_lock("metrics")
        self._counters: dict[str, int] = {}
        self._gauges: dict[str, object] = {}          # value or callable
        self._hists: dict[str, LatencyHistogram] = {}
        self._sources: dict[str, Callable[[], dict]] = {}

    # -- counters ---------------------------------------------------------
    def count(self, name: str, inc: int = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + inc

    def counter(self, name: str) -> int:
        with self._lock:
            return self._counters.get(name, 0)

    # -- gauges -----------------------------------------------------------
    def gauge(self, name: str, value) -> None:
        """Set a point-in-time gauge. ``value`` may be a number or a
        zero-arg callable resolved lazily at snapshot time."""
        with self._lock:
            self._gauges[name] = value

    def gauge_value(self, name: str):
        with self._lock:
            v = self._gauges.get(name)
        return v() if callable(v) else v

    # -- histograms -------------------------------------------------------
    def observe(self, stage: str, seconds: float) -> None:
        # get-or-create under the registry lock; the sample lands under
        # the histogram's own lock so concurrent observers of one stage
        # don't serialize on the whole registry
        with self._lock:
            h = self._hists.get(stage)
            if h is None:
                h = self._hists[stage] = LatencyHistogram()
        h.add(seconds)

    def histogram(self, stage: str) -> LatencyHistogram | None:
        with self._lock:
            return self._hists.get(stage)

    # -- sources ----------------------------------------------------------
    def register_source(self, name: str, fn: Callable[[], dict]) -> None:
        """Attach an external stats provider (``cache.stats``,
        ``registry.stats``): its dict is pulled into every snapshot under
        ``sources[name]``."""
        with self._lock:
            self._sources[name] = fn

    def remove_source(self, name: str) -> None:
        with self._lock:
            self._sources.pop(name, None)

    # -- read surface -----------------------------------------------------
    def snapshot(self, include_sources: bool = True) -> dict:
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._hists)
            sources = dict(self._sources)
        snap = {
            "counters": counters,
            # callables resolve outside the registry lock: a source or
            # gauge may take its own lock (cache/registry stats do)
            "gauges": {k: (v() if callable(v) else v)
                       for k, v in gauges.items()},
            "latency": {k: h.summary() for k, h in hists.items()},
        }
        if include_sources:
            snap["sources"] = {k: fn() for k, fn in sources.items()}
        return snap

    def reset(self) -> None:
        """Clear counters, gauges and histograms; registered sources stay
        (they describe live objects, not accumulated state)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()

    def format(self) -> str:
        snap = self.snapshot(include_sources=False)
        lines = []
        for name in sorted(snap["counters"]):
            lines.append(f"  {name:<24} {snap['counters'][name]}")
        for name in sorted(snap["gauges"]):
            lines.append(f"  {name:<24} {snap['gauges'][name]}")
        for stage in sorted(snap["latency"]):
            s = snap["latency"][stage]
            lines.append(
                f"  {stage:<24} n={s['count']:<7} mean={s['mean_ms']:.3f}ms "
                f"p50={s['p50_ms']:.3f}ms p95={s['p95_ms']:.3f}ms "
                f"p99={s['p99_ms']:.3f}ms"
            )
        return "\n".join(lines)
