"""Named locks, the declared lock hierarchy, and the runtime lock witness
(DESIGN.md §12.2).

The serving plane is a small zoo of locks: the engine's batcher table, the
index registry's entry map, each micro-batcher's condition, the result
cache, the persistent index store's counter lock, the metrics registry,
every latency histogram, the tracer ring, the slow-query log, and the
checkpoint manager's worker slot. Nothing used to
*declare* how they may nest — PR 5 shipped a latent refresh-worker race and
PR 6 retrofitted a lock onto ``LatencyHistogram`` after the fact. This
module makes the discipline explicit and machine-checkable:

* :data:`LOCK_HIERARCHY` is the **declared acquisition order**: a thread
  holding lock at rank *i* may only acquire locks of strictly greater rank.
  Any program whose acquisitions respect one total order cannot deadlock on
  these locks (a wait-for cycle needs at least one rank inversion).
* :func:`named_lock` / :func:`named_condition` are drop-in factories the
  subsystems use instead of bare ``threading.Lock()`` /
  ``threading.Condition()``. In production they return the plain stdlib
  primitive — zero overhead. With the witness enabled (the
  ``REPRO_LOCK_WITNESS`` env var, set by the CI analysis job around the
  fast test suite) they return instrumented wrappers that report every
  acquisition to the process-wide :data:`WITNESS`.
* :class:`LockWitness` records the **acquisition edges** actually taken
  (outer held → inner acquired, with owning thread names so a report
  identifies the subsystem) and cross-checks them against the declared
  hierarchy: rank inversions, undeclared locks, and cycles in the observed
  edge graph are violations. ``tests/conftest.py`` fails the suite on any.

The static lock pass (``repro.analysis.passes_locks``) checks the same
hierarchy at the AST level — nesting it can see without running anything —
and the witness covers what static analysis cannot: nesting through
callbacks, listener indirection, and cross-module call chains.

The hierarchy lives here (next to the locks it ranks) rather than in
``pyproject.toml``: the witness must not depend on a config file being
readable at import time. The analysis config maps repo lock *sites*
(module/class/attribute) onto these level names.
"""

from __future__ import annotations

import os
import threading

#: Declared acquisition order, outermost first. A thread may acquire a lock
#: only while every lock it already holds has a strictly smaller rank.
#: Ordering rationale:
#:   engine    — ServingEngine._lock (batcher table, retention policies)
#:   registry  — IndexRegistry._lock (entries, graphs, epochs, pending)
#:   batcher   — MicroBatcher._cond (pending queue; workers count flushes
#:               into metrics while holding it)
#:   cache     — ResultCache._lock (LRU map, epoch floors)
#:   store     — IndexStore._lock (commit/load counters *only*: every byte
#:               of segment file I/O runs outside it; store code counts
#:               into metrics, so store ranks above metrics, and registry
#:               workers persist/demote while logically inside the
#:               registry plane, so it ranks below registry)
#:   metrics   — MetricsRegistry._lock (counters/gauges/hist table; the
#:               registry worker counts evictions under its own lock, so
#:               metrics must rank below registry)
#:   histogram — LatencyHistogram._lock (sample reservoir)
#:   slowlog   — SlowQueryLog._lock (entry ring)
#:   tracer    — Tracer._lock (finished-span ring; Span.end may be called
#:               under any of the above, so the tracer ranks below them)
#:   checkpoint— CheckpointManager._lock (worker slot + last error)
LOCK_HIERARCHY: tuple[str, ...] = (
    "engine", "registry", "batcher", "cache", "store", "metrics",
    "histogram", "slowlog", "tracer", "checkpoint",
)

_ENV_FLAG = "REPRO_LOCK_WITNESS"


def witness_enabled() -> bool:
    """True when the process-wide witness is armed (env flag). Checked at
    lock *construction* time: objects built before the flag flips keep
    plain locks, which is why the CI job sets the env var around the whole
    pytest invocation."""
    return os.environ.get(_ENV_FLAG, "") not in ("", "0", "false", "no")


class LockWitness:
    """Records lock-acquisition edges per thread and checks them against a
    declared hierarchy.

    Thread-safe; the witness's own bookkeeping lock is a plain
    ``threading.Lock`` (it is not itself witnessed — it nests strictly
    innermost and is never held across user code). Violations are
    deduplicated by (kind, outer, inner) so a hot loop cannot grow the
    report without bound.
    """

    def __init__(self, hierarchy: tuple[str, ...] = LOCK_HIERARCHY):
        self.hierarchy = tuple(hierarchy)
        self._ranks = {name: i for i, name in enumerate(self.hierarchy)}
        self._mu = threading.Lock()
        self._tls = threading.local()
        # (outer, inner) -> {"count": int, "threads": set[str]}
        self._edges: dict[tuple[str, str], dict] = {}
        # (kind, outer, inner) -> {"count": int, "threads": set[str]}
        self._violations: dict[tuple[str, str | None, str], dict] = {}
        self.acquisitions = 0

    # -- per-thread hold stack -------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def held(self) -> tuple[str, ...]:
        """The calling thread's current hold stack, outermost first."""
        return tuple(self._stack())

    # -- instrumentation callbacks ---------------------------------------
    def on_acquire(self, name: str) -> None:
        st = self._stack()
        tname = threading.current_thread().name
        with self._mu:
            self.acquisitions += 1
            if st:
                outer = st[-1]
                edge = self._edges.setdefault(
                    (outer, name), {"count": 0, "threads": set()})
                edge["count"] += 1
                edge["threads"].add(tname)
                ro = self._ranks.get(outer)
                ri = self._ranks.get(name)
                if ro is None or ri is None:
                    bad = outer if ro is None else name
                    self._note("undeclared-lock", outer, name, tname,
                               f"lock {bad!r} is not in the declared "
                               f"hierarchy")
                elif ri <= ro:
                    self._note("lock-order", outer, name, tname,
                               f"acquired {name!r} (rank {ri}) while "
                               f"holding {outer!r} (rank {ro}); the "
                               "hierarchy requires strictly increasing "
                               "rank")
            elif name not in self._ranks:
                self._note("undeclared-lock", None, name, tname,
                           f"lock {name!r} is not in the declared "
                           f"hierarchy")
        st.append(name)

    def on_release(self, name: str) -> None:
        st = self._stack()
        for i in range(len(st) - 1, -1, -1):
            if st[i] == name:
                del st[i]
                return

    def _note(self, kind: str, outer: str | None, inner: str,
              thread: str, message: str) -> None:
        v = self._violations.setdefault(
            (kind, outer, inner),
            {"kind": kind, "outer": outer, "inner": inner,
             "message": message, "count": 0, "threads": set()})
        v["count"] += 1
        v["threads"].add(thread)

    # -- reading ----------------------------------------------------------
    def edges(self) -> list[dict]:
        with self._mu:
            return [
                {"outer": o, "inner": i, "count": e["count"],
                 "threads": sorted(e["threads"])}
                for (o, i), e in sorted(self._edges.items())
            ]

    def violations(self) -> list[dict]:
        with self._mu:
            return [dict(v, threads=sorted(v["threads"]))
                    for v in self._violations.values()]

    def _find_cycle(self) -> list[str] | None:
        """One cycle in the observed edge graph, if any (DFS). Rank
        inversions already imply one, but undeclared locks can form a
        cycle the rank check never sees."""
        with self._mu:
            adj: dict[str, list[str]] = {}
            for (o, i) in self._edges:
                adj.setdefault(o, []).append(i)
        state: dict[str, int] = {}          # 1 = on stack, 2 = done
        path: list[str] = []

        def visit(node: str) -> list[str] | None:
            state[node] = 1
            path.append(node)
            for nxt in adj.get(node, ()):
                if state.get(nxt) == 1:
                    return path[path.index(nxt):] + [nxt]
                if state.get(nxt) is None:
                    cyc = visit(nxt)
                    if cyc is not None:
                        return cyc
            path.pop()
            state[node] = 2
            return None

        for node in list(adj):
            if state.get(node) is None:
                cyc = visit(node)
                if cyc is not None:
                    return cyc
        return None

    def check(self) -> list[dict]:
        """Deduplicated problems: rank inversions, undeclared locks, and
        any cycle in the observed acquisition-edge graph. Empty means the
        run respected the declared hierarchy."""
        problems = self.violations()
        cycle = self._find_cycle()
        if cycle is not None:
            problems.append({
                "kind": "lock-cycle",
                "cycle": cycle,
                "message": "observed acquisition edges form a cycle "
                           f"(potential deadlock): {' -> '.join(cycle)}",
            })
        return problems

    def report(self) -> dict:
        """JSON-able summary (written as a CI artifact)."""
        return {
            "hierarchy": list(self.hierarchy),
            "acquisitions": self.acquisitions,
            "edges": self.edges(),
            "problems": self.check(),
        }

    def reset(self) -> None:
        with self._mu:
            self._edges.clear()
            self._violations.clear()
            self.acquisitions = 0


#: Process-wide witness instance the instrumented wrappers report into.
WITNESS = LockWitness()


class WitnessLock:
    """A ``threading.Lock`` reporting acquisitions to a witness."""

    __slots__ = ("name", "_witness", "_inner")

    def __init__(self, name: str, witness: LockWitness):
        self.name = name
        self._witness = witness
        self._inner = threading.Lock()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._witness.on_acquire(self.name)
        return got

    def release(self) -> None:
        self._witness.on_release(self.name)
        self._inner.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def __enter__(self) -> "WitnessLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> bool:
        self.release()
        return False

    def __repr__(self) -> str:
        return f"WitnessLock({self.name!r})"


class WitnessCondition:
    """A ``threading.Condition`` whose monitor acquisitions report to a
    witness. ``wait`` releases and re-acquires the underlying lock inside
    the stdlib condition; the witness keeps the level on the waiter's hold
    stack throughout — the waiting thread still logically owns the monitor
    section and acquires nothing else while blocked."""

    __slots__ = ("name", "_witness", "_cond")

    def __init__(self, name: str, witness: LockWitness):
        self.name = name
        self._witness = witness
        self._cond = threading.Condition()

    def acquire(self, *args) -> bool:
        got = self._cond.acquire(*args)
        if got:
            self._witness.on_acquire(self.name)
        return got

    def release(self) -> None:
        self._witness.on_release(self.name)
        self._cond.release()

    def __enter__(self) -> "WitnessCondition":
        self.acquire()
        return self

    def __exit__(self, *exc) -> bool:
        self.release()
        return False

    def wait(self, timeout: float | None = None) -> bool:
        return self._cond.wait(timeout)

    def wait_for(self, predicate, timeout: float | None = None):
        return self._cond.wait_for(predicate, timeout)

    def notify(self, n: int = 1) -> None:
        self._cond.notify(n)

    def notify_all(self) -> None:
        self._cond.notify_all()

    def __repr__(self) -> str:
        return f"WitnessCondition({self.name!r})"


def named_lock(name: str, witness: LockWitness | None = None):
    """A lock carrying a hierarchy level name.

    Returns a plain ``threading.Lock`` unless the witness is armed
    (``REPRO_LOCK_WITNESS``) or an explicit ``witness`` is passed — the
    production fast path pays nothing for the instrumentation hook."""
    w = witness if witness is not None else (
        WITNESS if witness_enabled() else None)
    if w is None:
        return threading.Lock()
    return WitnessLock(name, w)


def named_condition(name: str, witness: LockWitness | None = None):
    """Condition-variable analogue of :func:`named_lock`."""
    w = witness if witness is not None else (
        WITNESS if witness_enabled() else None)
    if w is None:
        return threading.Condition()
    return WitnessCondition(name, w)
