"""Export surfaces: Chrome trace-event JSON and the metrics snapshot
round-trip (DESIGN.md §11.6).

The trace export emits the Trace Event Format's JSON-object form —
``{"traceEvents": [...]}`` with complete (``"ph": "X"``) duration events —
which loads directly into Perfetto or ``chrome://tracing``. Parent/child
structure is carried twice: implicitly by same-thread nesting (how the
viewers render stacks) and explicitly in each event's ``args``
(``trace_id``/``span_id``/``parent_id``), so the span tree survives
cross-thread hops that the viewers' per-track stacking cannot express.

:func:`validate_chrome_trace` is the schema gate the test suite and the
CI bench smoke run over every exported file: shape, required fields,
types, and non-negative timestamps.
"""

from __future__ import annotations

import json
from typing import Iterable

#: Chrome trace-event phases this exporter emits (complete, metadata).
_EMITTED_PHASES = ("X", "M")
#: Phases accepted by the validator (a superset: instant/counter events
#: may be merged in from other tools).
_VALID_PHASES = ("X", "M", "i", "I", "C", "B", "E")


def chrome_trace_events(spans: Iterable, t0: float = 0.0,
                        pid: int = 1) -> list[dict]:
    """Flatten finished spans into Chrome trace events.

    ``t0`` is the timestamp origin (the tracer's ``t0``): event ``ts`` are
    microseconds since it. One ``thread_name`` metadata event is emitted
    per distinct thread so the viewer labels tracks."""
    events: list[dict] = []
    threads: dict[int, str] = {}
    for s in spans:
        if s.t_end is None:
            continue
        threads.setdefault(s.tid, s.thread_name)
        args = {"trace_id": s.trace_id, "span_id": s.span_id,
                "parent_id": s.parent_id}
        args.update({k: _jsonable(v) for k, v in s.attrs.items()})
        events.append({
            "name": s.name,
            "cat": s.cat,
            "ph": "X",
            "ts": max(0.0, (s.t_start - t0) * 1e6),
            "dur": max(0.0, (s.t_end - s.t_start) * 1e6),
            "pid": pid,
            "tid": s.tid,
            "args": args,
        })
    for tid, name in threads.items():
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid, "args": {"name": name}})
    return events


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (tuple, list)):
        return [_jsonable(x) for x in v]
    return repr(v)


def trace_document(tracer, extra: dict | None = None) -> dict:
    """The JSON-object-format trace document for one tracer."""
    doc = {
        "traceEvents": chrome_trace_events(tracer.spans(), t0=tracer.t0),
        "displayTimeUnit": "ms",
        "otherData": {"dropped_spans": tracer.dropped,
                      **(extra or {})},
    }
    return doc


def write_chrome_trace(path: str, tracer, extra: dict | None = None) -> dict:
    """Write the tracer's ring buffer as Chrome trace JSON; returns the
    document (already validated — an unloadable export is a bug here, not
    in the viewer)."""
    doc = trace_document(tracer, extra)
    validate_chrome_trace(doc)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def validate_chrome_trace(doc) -> int:
    """Validate a trace document against the Chrome trace-event schema
    (JSON-object form). Raises ``ValueError`` on the first violation;
    returns the number of events otherwise."""
    if isinstance(doc, list):            # JSON-array form is also legal
        events = doc
    elif isinstance(doc, dict):
        if "traceEvents" not in doc:
            raise ValueError("trace document missing 'traceEvents'")
        events = doc["traceEvents"]
    else:
        raise ValueError(f"trace document must be dict or list, "
                         f"got {type(doc).__name__}")
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        if not isinstance(ev.get("name"), str):
            raise ValueError(f"event {i} missing string 'name'")
        ph = ev.get("ph")
        if ph not in _VALID_PHASES:
            raise ValueError(f"event {i} has invalid phase {ph!r}")
        if not isinstance(ev.get("pid"), int):
            raise ValueError(f"event {i} missing int 'pid'")
        if not isinstance(ev.get("tid"), int):
            raise ValueError(f"event {i} missing int 'tid'")
        if ph == "X":
            ts, dur = ev.get("ts"), ev.get("dur")
            if not isinstance(ts, (int, float)) or ts < 0:
                raise ValueError(f"event {i} 'ts' must be a number >= 0")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"event {i} 'dur' must be a number >= 0")
        if "args" in ev and not isinstance(ev["args"], dict):
            raise ValueError(f"event {i} 'args' must be an object")
    # the whole document must survive a JSON round trip (numpy scalars or
    # other exotic values hiding in args fail here, not in the viewer)
    json.loads(json.dumps(doc if isinstance(doc, dict) else events))
    return len(events)


# ----------------------------------------------------------------------
# Metrics snapshot export
# ----------------------------------------------------------------------

def metrics_to_json(snapshot: dict, indent: int | None = None) -> str:
    """Serialize a :meth:`MetricsRegistry.snapshot` dict. Tuple keys in
    sources (the index registry's ``resident`` list holds ``(workload,
    k)`` tuples as *values*, fine; but e.g. ``epochs`` keys are strings)
    are not expected — a non-string key raises, keeping the export an
    honest round-trip rather than a lossy ``str()`` coercion."""
    return json.dumps(_jsonable_tree(snapshot), indent=indent,
                      allow_nan=False, sort_keys=True)


def metrics_from_json(text: str) -> dict:
    return json.loads(text)


def _jsonable_tree(v):
    if isinstance(v, dict):
        out = {}
        for k, val in v.items():
            if not isinstance(k, str):
                raise ValueError(f"metrics snapshot key {k!r} is not a "
                                 "string; exportable snapshots need "
                                 "string keys")
            out[k] = _jsonable_tree(val)
        return out
    if isinstance(v, (tuple, list)):
        return [_jsonable_tree(x) for x in v]
    if isinstance(v, bool) or v is None or isinstance(v, (int, float, str)):
        return v
    # numpy scalars and friends: collapse to their python value if they
    # quack like one, else repr
    item = getattr(v, "item", None)
    if callable(item):
        try:
            return _jsonable_tree(item())
        except (TypeError, ValueError):
            pass
    return repr(v)
