"""Serving-plane observability (DESIGN.md §11): query-lifecycle tracing,
unified metrics, and export surfaces.

Three modules, deliberately dependency-free (stdlib + numpy only) so every
layer — core, serving, launch, benchmarks — can import them without cycles:

* :mod:`repro.obs.trace` — spans with explicit parent/child context that
  propagate across thread boundaries (submit -> batcher worker -> device
  launch; ingest -> FIFO refresh worker), recorded into a bounded
  lock-protected ring buffer, plus the slow-query log.
* :mod:`repro.obs.registry` — :class:`MetricsRegistry`: counters, gauges,
  latency histograms and pluggable stat *sources* (cache/registry stats)
  behind one snapshot-and-export surface. The serving engine's
  ``EngineMetrics`` is a thin subclass.
* :mod:`repro.obs.export` — Chrome trace-event JSON (loadable in Perfetto /
  ``chrome://tracing``) with a schema validator, and the metrics snapshot
  JSON round-trip.
* :mod:`repro.obs.locks` — the declared lock hierarchy, ``named_lock`` /
  ``named_condition`` factories every subsystem uses, and the runtime
  :class:`LockWitness` that records acquisition edges during tests and
  cross-checks them against the hierarchy (DESIGN.md §12.2).
"""

from .locks import (LOCK_HIERARCHY, WITNESS, LockWitness, named_condition,
                    named_lock, witness_enabled)
from .trace import (NULL_SPAN, SlowQueryLog, Span, SpanContext, Tracer)
from .registry import LatencyHistogram, MetricsRegistry
from .export import (chrome_trace_events, metrics_from_json,
                     metrics_to_json, validate_chrome_trace,
                     write_chrome_trace)

__all__ = [
    "Tracer", "Span", "SpanContext", "SlowQueryLog", "NULL_SPAN",
    "MetricsRegistry", "LatencyHistogram",
    "LOCK_HIERARCHY", "LockWitness", "WITNESS",
    "named_lock", "named_condition", "witness_enabled",
    "chrome_trace_events", "write_chrome_trace", "validate_chrome_trace",
    "metrics_to_json", "metrics_from_json",
]
