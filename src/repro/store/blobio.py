"""Atomic tmp-rename + crc32 blob primitives (DESIGN.md §13.1).

The durable-write idiom the checkpoint manager proved out — write to a
pid-suffixed temp file in the same directory, flush + fsync, then
``os.rename`` into place so a crash mid-write can never corrupt the last
good file — extracted here so :mod:`repro.checkpoint.manager` and the
segment store share one implementation. Same for the per-array integrity
envelope: every serialized array carries dtype, shape and a crc32 of its
raw bytes, verified on the way back in.

Nothing here takes a lock: callers run these on background workers, and
the static lock pass (``lock-blocking-call``) bars file I/O under any
hierarchy lock anyway.
"""

from __future__ import annotations

import os
import zlib

import numpy as np


def atomic_write(path: str, data: bytes, *, tmp: str | None = None,
                 fsync: bool = True) -> None:
    """Write ``data`` to ``path`` atomically: temp file, optional fsync,
    rename. ``tmp`` overrides the temp name (the checkpoint manager keeps
    its historical ``step_<n>.tmp-<pid>`` naming); the default is
    ``<path>.tmp-<pid>`` in the same directory, so the rename never
    crosses a filesystem. ``fsync=False`` is for pointer files whose loss
    is recoverable (a stale pointer only costs a directory walk)."""
    if tmp is None:
        tmp = f"{path}.tmp-{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(data)
        if fsync:
            f.flush()
            os.fsync(f.fileno())
    os.rename(tmp, path)


def array_blob(arr) -> dict:
    """Integrity envelope for one array: raw bytes plus the dtype/shape/crc
    needed to verify and reconstruct them."""
    arr = np.asarray(arr)
    raw = arr.tobytes()
    return {
        "dtype": str(arr.dtype), "shape": arr.shape,
        "crc": zlib.crc32(raw), "raw": raw,
    }


def blob_array(blob: dict, *, label: str = "blob") -> np.ndarray:
    """Reconstruct an :func:`array_blob`; raises ``IOError`` (with
    ``label`` naming the source) when the crc32 does not verify."""
    arr = np.frombuffer(blob["raw"], dtype=blob["dtype"]).reshape(blob["shape"])
    if zlib.crc32(blob["raw"]) != blob["crc"]:
        raise IOError(f"{label} failed crc32 verification")
    return arr


def crc32(buf) -> int:
    """crc32 over any buffer (bytes, memoryview, mmap slice)."""
    return zlib.crc32(buf)
