"""IndexStore: the registry's disk tier (DESIGN.md §13.3, §14.5).

Maps one *workload* registry key to one segment directory (see
:mod:`repro.store.segment`) and speaks the registry's language on both
sides: ``put_handle`` flattens a built
:class:`~repro.serving.registry.IndexHandle` — graph arrays, every
stratum's 14 packed PECB arrays, the stratified core-time table — into
the segment format (as a *delta* against the previous epoch's handle
when one is supplied), and ``load`` mmaps the newest committed epoch
back into host index objects, so a warm restart or an LRU promotion
pays a device upload instead of a multi-second |K|-stratum rebuild.

Stratified block layout: arrays are stored *per stratum* under
``pecb.k{k}.*`` / ``tab.k{k}.*`` names rather than as the handle's
concatenated globals. That choice is what keeps suffix-epoch deltas
working — appending edges grows every stratum's arrays at its own tail,
so per-k blocks classify as suffix writes, while the concatenated form
would shift every block past the first and force a full commit each
epoch. A k_max raise (new stratum) changes the name set, which the
segment layer answers with one full commit — correct and rare. Two
derived pieces are *not* stored: the dense per-k vertex matrices (the
RLE runs in ``tab.k{k}.vptr``/``v_*`` are the authoritative form) and
the version-store endpoint arrays (recomputed on load as
``g.src[edge_id]`` — cheaper to gather than to persist).

Locking: ``self._lock`` (hierarchy level ``"store"``) guards the
counters behind :meth:`stats` and nothing else — every byte of file I/O
runs outside it (the static lock pass bars blocking calls under any
hierarchy lock). Write serialization per key is inherited from the
registry: one key's commits only ever originate from its single cold
build or the single FIFO epoch worker, never both concurrently.
"""

from __future__ import annotations

import dataclasses
import os
import re
import zlib

import numpy as np

from repro.core.core_time import StratifiedCoreTable
from repro.core.pecb_index import PECBIndex, StratifiedPECB
from repro.core.temporal_graph import TemporalGraph
from repro.obs.locks import named_lock
from repro.obs.trace import NULL_SPAN

from .segment import open_latest, write_commit

#: the 14 packed arrays of a PECBIndex, in constructor order
PECB_ARRAYS = (
    "node_u", "node_v", "node_ct", "node_edge",
    "node_live_from", "node_live_to",
    "row_ptr", "ent_ts", "ent_left", "ent_right", "ent_parent",
    "vrow_ptr", "vent_ts", "vent_node",
)
#: per-stratum core-time blocks: version records + localized vertex-run CSR
TAB_ARRAYS = ("edge_id", "ts_from", "ts_to", "ct",
              "vptr", "v_ts_from", "v_ts_to", "v_ct")


@dataclasses.dataclass
class StoredIndex:
    """One stored epoch, rehydrated: everything the registry needs to
    re-mint an :class:`~repro.serving.registry.IndexHandle` minus the
    device mirror (the promoter uploads). Record arrays are read-only
    views into the mmap'd segments wherever the layout allows
    (single-part, single-stratum); the stratified globals are assembled
    by one concatenation pass."""

    key: str
    epoch: int
    build_seconds: float
    graph: TemporalGraph
    pecb: StratifiedPECB
    tab: StratifiedCoreTable | None
    manifest: dict
    recovered: int = 0     # newer, invalid commits skipped on the way here

    @property
    def nbytes(self) -> int:
        return self.pecb.nbytes()


def _safe(name: str) -> str:
    return re.sub(r"[^A-Za-z0-9._-]", "_", name)


def key_dirname(key: str) -> str:
    """Directory name for one workload key: a sanitized readable stem plus
    a crc32 of the exact name (collision-proofing the sanitizer). The
    authoritative key lives in the manifest meta. No k component — the k
    axis collapsed into the stored strata (DESIGN.md §14)."""
    name = str(key)
    return f"{_safe(name)}__{zlib.crc32(name.encode()):08x}"


class IndexStore:
    def __init__(self, root: str, metrics=None, tracer=None, *,
                 max_chain: int = 4, keep_manifests: int = 2,
                 verify: bool = True):
        self.root = str(root)
        os.makedirs(self.root, exist_ok=True)
        self._metrics = metrics
        self.tracer = tracer
        self._max_chain = int(max_chain)
        self._keep = int(keep_manifests)
        self._verify = bool(verify)
        self._lock = named_lock("store")
        self._counters = {
            "commits": 0, "commits_full": 0, "commits_delta": 0,
            "commits_noop": 0, "bytes_written": 0,
            "loads": 0, "load_bytes": 0, "recovered_commits": 0,
        }

    def _span(self, name: str, **attrs):
        if self.tracer is None:
            return NULL_SPAN
        return self.tracer.start_span(name, cat="store", **attrs)

    def _dir(self, key: str) -> str:
        return os.path.join(self.root, key_dirname(key))

    # -- write path ------------------------------------------------------
    def put_handle(self, key: str, handle, prev=None) -> dict:
        """Persist ``handle`` as key's next committed epoch. ``prev`` (the
        handle the epoch lifecycle grew/shrunk ``handle`` from) enables a
        delta commit when it matches the epoch already on disk. Returns
        ``{"mode", "epoch", "bytes_written"}``; ``mode="current"`` means
        the store already holds this epoch and nothing was written (the
        demote-after-write-through case)."""
        dirpath = self._dir(key)
        span = self._span("store_commit", workload=str(key),
                          epoch=handle.epoch)
        try:
            os.makedirs(dirpath, exist_ok=True)
            probe = open_latest(dirpath, load=False)
            on_disk = probe[0] if probe is not None else None
            if on_disk is not None and on_disk["epoch"] == handle.epoch:
                span.set("mode", "current").end()
                self._count(commits_noop=1)
                return {"mode": "current", "epoch": handle.epoch,
                        "bytes_written": 0}
            prev_pair = None
            if (prev is not None and on_disk is not None
                    and on_disk["epoch"] == prev.epoch):
                prev_pair = (on_disk, self._handle_arrays(prev))
            res = write_commit(
                dirpath, self._handle_meta(key, handle),
                self._handle_arrays(handle), prev_pair,
                max_chain=self._max_chain, keep_manifests=self._keep)
        except BaseException as exc:
            span.set("error", repr(exc)).end()
            raise
        span.set("mode", res["mode"]).set("bytes", res["bytes_written"]).end()
        self._count(commits=1, bytes_written=res["bytes_written"],
                    **{f"commits_{res['mode']}": 1})
        if self._metrics is not None:
            self._metrics.count("store_commits")
            self._metrics.count("store_commit_bytes", res["bytes_written"])
        return {"mode": res["mode"], "epoch": handle.epoch,
                "bytes_written": res["bytes_written"]}

    @staticmethod
    def _handle_meta(key: str, handle) -> dict:
        g = handle.graph
        sx = handle.pecb
        return {
            "workload": str(key),
            "epoch": int(handle.epoch),
            "n": int(g.n), "m": int(g.m), "t_max": int(g.t_max),
            "build_seconds": float(handle.build_seconds),
            "ks": [int(k) for k in sx.ks],
            "k_max_graph": int(sx.k_max_graph),
            "has_tab": handle.tab is not None,
        }

    @staticmethod
    def _handle_arrays(handle) -> dict:
        g = handle.graph
        sx: StratifiedPECB = handle.pecb
        out = {"graph.src": g.src, "graph.dst": g.dst, "graph.t": g.t}
        for k in sx.ks:
            view = sx.slice_k(k)
            for f in PECB_ARRAYS:
                out[f"pecb.k{k}.{f}"] = getattr(view, f)
        tab: StratifiedCoreTable | None = handle.tab
        if tab is not None:
            n = tab.n
            for ki, k in enumerate(tab.ks):
                lo, hi = int(tab.kptr[ki]), int(tab.kptr[ki + 1])
                vlo, vhi = ki * n, (ki + 1) * n
                rlo, rhi = int(tab.vptr[vlo]), int(tab.vptr[vhi])
                out[f"tab.k{k}.edge_id"] = tab.edge_id[lo:hi]
                out[f"tab.k{k}.ts_from"] = tab.ts_from[lo:hi]
                out[f"tab.k{k}.ts_to"] = tab.ts_to[lo:hi]
                out[f"tab.k{k}.ct"] = tab.ct[lo:hi]
                # CSR localized to the stratum (subtracting the base makes
                # it epoch-stable under *other* strata growing)
                out[f"tab.k{k}.vptr"] = tab.vptr[vlo:vhi + 1] - tab.vptr[vlo]
                out[f"tab.k{k}.v_ts_from"] = tab.v_ts_from[rlo:rhi]
                out[f"tab.k{k}.v_ts_to"] = tab.v_ts_to[rlo:rhi]
                out[f"tab.k{k}.v_ct"] = tab.v_ct[rlo:rhi]
        return out

    # -- read path -------------------------------------------------------
    def current_epoch(self, key: str) -> int | None:
        """Epoch of the newest structurally valid commit, or ``None`` —
        without loading (or crc-verifying) any array bytes."""
        probe = open_latest(self._dir(key), load=False)
        return None if probe is None else int(probe[0]["epoch"])

    def load(self, key: str) -> StoredIndex | None:
        """mmap the newest valid commit back into host index objects;
        ``None`` when the key has no loadable commit (including a legacy
        per-k directory — those carry no strata and simply miss here)."""
        dirpath = self._dir(key)
        span = self._span("store_open", workload=str(key))
        try:
            got = open_latest(dirpath, verify=self._verify)
            if got is None:
                span.set("outcome", "miss").end()
                return None
            man, arrays, recovered = got
            meta = man["meta"]
            if "ks" not in meta:
                span.set("outcome", "legacy").end()
                return None
            n, m, t_max = meta["n"], meta["m"], meta["t_max"]
            ks = tuple(int(k) for k in meta["ks"])
            g = TemporalGraph(n, arrays["graph.src"], arrays["graph.dst"],
                              arrays["graph.t"])
            tab = None
            if meta.get("has_tab"):
                tab = self._assemble_tab(n, m, t_max, ks, arrays)
            idx = self._assemble_pecb(
                g, m, t_max, ks, int(meta["k_max_graph"]), arrays, tab)
        except BaseException as exc:
            span.set("error", repr(exc)).end()
            raise
        nbytes = sum(int(a.nbytes) for a in arrays.values())
        span.set("epoch", meta["epoch"]).set("bytes", nbytes)
        span.set("recovered", recovered).end()
        self._count(loads=1, load_bytes=nbytes, recovered_commits=recovered)
        if self._metrics is not None:
            self._metrics.count("store_loads")
            self._metrics.count("store_load_bytes", nbytes)
            if recovered:
                self._metrics.count("store_recovered_commits", recovered)
        return StoredIndex(
            key=str(meta["workload"]), epoch=int(meta["epoch"]),
            build_seconds=float(meta.get("build_seconds", 0.0)),
            graph=g, pecb=idx, tab=tab, manifest=man, recovered=recovered)

    @staticmethod
    def _assemble_tab(n: int, m: int, t_max: int, ks: tuple,
                      arrays: dict) -> StratifiedCoreTable:
        """Stratified core-time table from the per-k blocks: record
        globals are one concatenation, the vertex-run CSR re-bases each
        stratum's localized ``vptr`` onto the running offset."""
        K = len(ks)
        blocks = {f: [arrays[f"tab.k{k}.{f}"] for k in ks]
                  for f in TAB_ARRAYS}
        i32 = lambda parts: (np.concatenate(parts).astype(np.int32,
                                                          copy=False)
                             if parts else np.zeros(0, np.int32))
        kptr = np.zeros(K + 1, np.int64)
        for ki in range(K):
            kptr[ki + 1] = kptr[ki] + blocks["edge_id"][ki].shape[0]
        vptr = np.zeros(K * n + 1, np.int64)
        off = 0
        for ki in range(K):
            local = blocks["vptr"][ki]
            vptr[ki * n:(ki + 1) * n + 1] = local.astype(np.int64) + off
            off += int(local[-1]) if local.shape[0] else 0
        return StratifiedCoreTable(
            n, m, t_max, ks, kptr,
            i32(blocks["edge_id"]), i32(blocks["ts_from"]),
            i32(blocks["ts_to"]), i32(blocks["ct"]),
            vptr, i32(blocks["v_ts_from"]), i32(blocks["v_ts_to"]),
            i32(blocks["v_ct"]))

    @staticmethod
    def _assemble_pecb(g: TemporalGraph, m: int, t_max: int, ks: tuple,
                       k_max_graph: int, arrays: dict,
                       tab: StratifiedCoreTable | None) -> StratifiedPECB:
        """Stratified index from the per-k blocks: each stratum's mmap'd
        arrays become a per-k :class:`PECBIndex` view and
        ``StratifiedPECB.from_parts`` re-packs them — bit-identical to
        the handle that was persisted (the per-k blocks ARE the packed
        layout's blocks). Version-store endpoints are recomputed by one
        gather over the graph arrays instead of being stored."""
        if tab is None:
            raise ValueError(
                "stratified commit lacks its core-time table; cannot "
                "rebuild the version store")
        indices = [
            PECBIndex(g.n, m, t_max, k,
                      *(arrays[f"pecb.k{k}.{f}"] for f in PECB_ARRAYS),
                      versions=None)
            for k in ks]
        eid = tab.edge_id
        return StratifiedPECB.from_parts(
            tab, indices, k_max_graph,
            ver_src=np.asarray(g.src)[eid].astype(np.int32),
            ver_dst=np.asarray(g.dst)[eid].astype(np.int32),
            ver_t=np.asarray(g.t)[eid].astype(np.int32))

    def keys(self) -> list[str]:
        """Every workload key with at least one valid *stratified* commit
        on disk (legacy per-k directories are skipped)."""
        out = []
        for entry in sorted(os.listdir(self.root)):
            probe = open_latest(os.path.join(self.root, entry), load=False)
            if probe is not None and "ks" in probe[0]["meta"]:
                out.append(str(probe[0]["meta"]["workload"]))
        return out

    def load_graph(self, name: str):
        """``(graph, epoch)`` of workload ``name``'s newest stored epoch —
        the warm path for ``resolve_graph`` on an unregistered name — or
        ``None``. Graph arrays are *copied* out of the mapping: the
        adopted graph outlives any one commit's files. Legacy per-k
        directories still qualify here (their graph arrays are identical),
        so adoption survives a store written before the k collapse."""
        best = None
        for entry in sorted(os.listdir(self.root)):
            dirpath = os.path.join(self.root, entry)
            probe = open_latest(dirpath, load=False)
            if probe is None or probe[0]["meta"]["workload"] != name:
                continue
            if best is None or probe[0]["epoch"] > best[0]["epoch"]:
                best = (probe[0], dirpath)
        if best is None:
            return None
        man, dirpath = best
        from .segment import load_arrays
        arrays = load_arrays(dirpath, man,
                             names={"graph.src", "graph.dst", "graph.t"},
                             verify=self._verify)
        g = TemporalGraph(man["meta"]["n"],
                          arrays["graph.src"].copy(),
                          arrays["graph.dst"].copy(),
                          arrays["graph.t"].copy())
        return g, int(man["epoch"])

    # -- accounting ------------------------------------------------------
    def _count(self, **deltas) -> None:
        with self._lock:
            for name, d in deltas.items():
                self._counters[name] += int(d)

    def stats(self) -> dict:
        with self._lock:
            out = dict(self._counters)
        out["root"] = self.root
        return out
