"""IndexStore: the registry's disk tier (DESIGN.md §13.3).

Maps one ``(workload, k)`` registry key to one segment directory (see
:mod:`repro.store.segment`) and speaks the registry's language on both
sides: ``put_handle`` flattens a built
:class:`~repro.serving.registry.IndexHandle` — graph arrays, the 14
packed PECB arrays, the version store, the core-time table — into the
segment format (as a *delta* against the previous epoch's handle when
one is supplied), and ``load`` mmaps the newest committed epoch back
into real host index objects, so a warm restart or an LRU promotion
pays a device upload instead of a multi-second rebuild.

Locking: ``self._lock`` (hierarchy level ``"store"``) guards the
counters behind :meth:`stats` and nothing else — every byte of file I/O
runs outside it (the static lock pass bars blocking calls under any
hierarchy lock). Write serialization per key is inherited from the
registry: one key's commits only ever originate from its single cold
build or the single FIFO epoch worker, never both concurrently.
"""

from __future__ import annotations

import dataclasses
import os
import re
import zlib

import numpy as np

from repro.core.core_time import CoreTimeTable
from repro.core.pecb_index import PECBIndex
from repro.core.query_api import VersionStore
from repro.core.temporal_graph import TemporalGraph
from repro.obs.locks import named_lock
from repro.obs.trace import NULL_SPAN

from .segment import open_latest, write_commit

#: the 14 packed arrays of a PECBIndex, in constructor order
PECB_ARRAYS = (
    "node_u", "node_v", "node_ct", "node_edge",
    "node_live_from", "node_live_to",
    "row_ptr", "ent_ts", "ent_left", "ent_right", "ent_parent",
    "vrow_ptr", "vent_ts", "vent_node",
)
VERSION_ARRAYS = ("edge_id", "ts_from", "ts_to", "ct", "src", "dst", "t")
TAB_ARRAYS = ("edge_id", "ts_from", "ts_to", "ct", "vertex_ct")


@dataclasses.dataclass
class StoredIndex:
    """One stored epoch, rehydrated: everything the registry needs to
    re-mint an :class:`~repro.serving.registry.IndexHandle` minus the
    device mirror (the promoter uploads). Arrays are read-only views into
    the mmap'd segments wherever the layout allows (single-part)."""

    key: tuple[str, int]
    epoch: int
    build_seconds: float
    graph: TemporalGraph
    pecb: PECBIndex
    tab: CoreTimeTable | None
    manifest: dict
    recovered: int = 0     # newer, invalid commits skipped on the way here

    @property
    def nbytes(self) -> int:
        return self.pecb.nbytes()


def _safe(name: str) -> str:
    return re.sub(r"[^A-Za-z0-9._-]", "_", name)


def key_dirname(key: tuple[str, int]) -> str:
    """Directory name for one (workload, k) key: a sanitized readable stem
    plus a crc32 of the exact name (collision-proofing the sanitizer) and
    the k. The authoritative key lives in the manifest meta."""
    name, k = key
    return f"{_safe(name)}__{zlib.crc32(name.encode()):08x}__k{int(k)}"


class IndexStore:
    def __init__(self, root: str, metrics=None, tracer=None, *,
                 max_chain: int = 4, keep_manifests: int = 2,
                 verify: bool = True):
        self.root = str(root)
        os.makedirs(self.root, exist_ok=True)
        self._metrics = metrics
        self.tracer = tracer
        self._max_chain = int(max_chain)
        self._keep = int(keep_manifests)
        self._verify = bool(verify)
        self._lock = named_lock("store")
        self._counters = {
            "commits": 0, "commits_full": 0, "commits_delta": 0,
            "commits_noop": 0, "bytes_written": 0,
            "loads": 0, "load_bytes": 0, "recovered_commits": 0,
        }

    def _span(self, name: str, **attrs):
        if self.tracer is None:
            return NULL_SPAN
        return self.tracer.start_span(name, cat="store", **attrs)

    def _dir(self, key) -> str:
        return os.path.join(self.root, key_dirname(key))

    # -- write path ------------------------------------------------------
    def put_handle(self, key, handle, prev=None) -> dict:
        """Persist ``handle`` as key's next committed epoch. ``prev`` (the
        handle the epoch lifecycle grew/shrunk ``handle`` from) enables a
        delta commit when it matches the epoch already on disk. Returns
        ``{"mode", "epoch", "bytes_written"}``; ``mode="current"`` means
        the store already holds this epoch and nothing was written (the
        demote-after-write-through case)."""
        dirpath = self._dir(key)
        span = self._span("store_commit", workload=key[0], k=key[1],
                          epoch=handle.epoch)
        try:
            os.makedirs(dirpath, exist_ok=True)
            probe = open_latest(dirpath, load=False)
            on_disk = probe[0] if probe is not None else None
            if on_disk is not None and on_disk["epoch"] == handle.epoch:
                span.set("mode", "current").end()
                self._count(commits_noop=1)
                return {"mode": "current", "epoch": handle.epoch,
                        "bytes_written": 0}
            prev_pair = None
            if (prev is not None and on_disk is not None
                    and on_disk["epoch"] == prev.epoch):
                prev_pair = (on_disk, self._handle_arrays(prev))
            res = write_commit(
                dirpath, self._handle_meta(key, handle),
                self._handle_arrays(handle), prev_pair,
                max_chain=self._max_chain, keep_manifests=self._keep)
        except BaseException as exc:
            span.set("error", repr(exc)).end()
            raise
        span.set("mode", res["mode"]).set("bytes", res["bytes_written"]).end()
        self._count(commits=1, bytes_written=res["bytes_written"],
                    **{f"commits_{res['mode']}": 1})
        if self._metrics is not None:
            self._metrics.count("store_commits")
            self._metrics.count("store_commit_bytes", res["bytes_written"])
        return {"mode": res["mode"], "epoch": handle.epoch,
                "bytes_written": res["bytes_written"]}

    @staticmethod
    def _handle_meta(key, handle) -> dict:
        g = handle.graph
        return {
            "workload": key[0], "k": int(key[1]),
            "epoch": int(handle.epoch),
            "n": int(g.n), "m": int(g.m), "t_max": int(g.t_max),
            "build_seconds": float(handle.build_seconds),
            "has_versions": handle.pecb.versions is not None,
            "has_tab": handle.tab is not None,
        }

    @staticmethod
    def _handle_arrays(handle) -> dict:
        g, idx = handle.graph, handle.pecb
        out = {"graph.src": g.src, "graph.dst": g.dst, "graph.t": g.t}
        for f in PECB_ARRAYS:
            out[f"pecb.{f}"] = getattr(idx, f)
        if idx.versions is not None:
            for f in VERSION_ARRAYS:
                out[f"versions.{f}"] = getattr(idx.versions, f)
        if handle.tab is not None:
            for f in TAB_ARRAYS:
                out[f"tab.{f}"] = getattr(handle.tab, f)
        return out

    # -- read path -------------------------------------------------------
    def current_epoch(self, key) -> int | None:
        """Epoch of the newest structurally valid commit, or ``None`` —
        without loading (or crc-verifying) any array bytes."""
        probe = open_latest(self._dir(key), load=False)
        return None if probe is None else int(probe[0]["epoch"])

    def load(self, key) -> StoredIndex | None:
        """mmap the newest valid commit back into host index objects;
        ``None`` when the key has no loadable commit."""
        dirpath = self._dir(key)
        span = self._span("store_open", workload=key[0], k=key[1])
        try:
            got = open_latest(dirpath, verify=self._verify)
            if got is None:
                span.set("outcome", "miss").end()
                return None
            man, arrays, recovered = got
            meta = man["meta"]
            n, m, t_max = meta["n"], meta["m"], meta["t_max"]
            k = meta["k"]
            g = TemporalGraph(n, arrays["graph.src"], arrays["graph.dst"],
                              arrays["graph.t"])
            versions = None
            if meta.get("has_versions"):
                versions = VersionStore(
                    n, t_max, k,
                    *(arrays[f"versions.{f}"] for f in VERSION_ARRAYS))
            idx = PECBIndex(
                n, m, t_max, k,
                *(arrays[f"pecb.{f}"] for f in PECB_ARRAYS),
                versions=versions)
            tab = None
            if meta.get("has_tab"):
                tab = CoreTimeTable(
                    n, m, t_max,
                    *(arrays[f"tab.{f}"] for f in TAB_ARRAYS))
        except BaseException as exc:
            span.set("error", repr(exc)).end()
            raise
        nbytes = sum(int(a.nbytes) for a in arrays.values())
        span.set("epoch", meta["epoch"]).set("bytes", nbytes)
        span.set("recovered", recovered).end()
        self._count(loads=1, load_bytes=nbytes, recovered_commits=recovered)
        if self._metrics is not None:
            self._metrics.count("store_loads")
            self._metrics.count("store_load_bytes", nbytes)
            if recovered:
                self._metrics.count("store_recovered_commits", recovered)
        return StoredIndex(
            key=(meta["workload"], k), epoch=int(meta["epoch"]),
            build_seconds=float(meta.get("build_seconds", 0.0)),
            graph=g, pecb=idx, tab=tab, manifest=man, recovered=recovered)

    def keys(self) -> list[tuple[str, int]]:
        """Every (workload, k) key with at least one valid commit on disk."""
        out = []
        for entry in sorted(os.listdir(self.root)):
            probe = open_latest(os.path.join(self.root, entry), load=False)
            if probe is not None:
                meta = probe[0]["meta"]
                out.append((meta["workload"], int(meta["k"])))
        return out

    def load_graph(self, name: str):
        """``(graph, epoch)`` of workload ``name``'s newest stored epoch
        across all its k-keys — the warm path for ``resolve_graph`` on an
        unregistered name — or ``None``. Graph arrays are *copied* out of
        the mapping: the adopted graph outlives any one commit's files."""
        best = None
        for entry in sorted(os.listdir(self.root)):
            dirpath = os.path.join(self.root, entry)
            probe = open_latest(dirpath, load=False)
            if probe is None or probe[0]["meta"]["workload"] != name:
                continue
            if best is None or probe[0]["epoch"] > best[0]["epoch"]:
                best = (probe[0], dirpath)
        if best is None:
            return None
        man, dirpath = best
        from .segment import load_arrays
        arrays = load_arrays(dirpath, man,
                             names={"graph.src", "graph.dst", "graph.t"},
                             verify=self._verify)
        g = TemporalGraph(man["meta"]["n"],
                          arrays["graph.src"].copy(),
                          arrays["graph.dst"].copy(),
                          arrays["graph.t"].copy())
        return g, int(man["epoch"])

    # -- accounting ------------------------------------------------------
    def _count(self, **deltas) -> None:
        with self._lock:
            for name, d in deltas.items():
                self._counters[name] += int(d)

    def stats(self) -> dict:
        with self._lock:
            out = dict(self._counters)
        out["root"] = self.root
        return out
