"""Persistent index store (DESIGN.md §13): mmap-able segment files with
atomic manifest commits, giving the serving plane a disk tier.

Three layers, lowest first:

* :mod:`blobio` — the atomic tmp-rename + crc32 write/read primitives,
  extracted from ``checkpoint/manager.py`` so the checkpoint manager and
  the segment store share one durable-write idiom instead of two copies.
* :mod:`segment` — the on-disk format for one workload key: alloc-
  rounded append-only segment files holding raw array bytes, plus JSON
  manifests (epoch, per-array dtype/shape/parts/crc32) committed by
  atomic rename. Suffix epochs commit as *deltas* against the resident
  chain; recovery walks manifests newest-first to the last valid commit.
* :mod:`index_store` — :class:`IndexStore`, the registry-facing tier:
  ``put_handle`` persists a built :class:`~repro.serving.registry.IndexHandle`
  (write-through on build, delta on refresh/trim, demote on eviction),
  ``load`` mmaps a stored epoch back into host index objects so a warm
  restart pays a device upload instead of a rebuild.
"""

from .blobio import array_blob, atomic_write, blob_array
from .index_store import IndexStore, StoredIndex
from .segment import StoreCorruption

__all__ = [
    "IndexStore", "StoredIndex", "StoreCorruption",
    "array_blob", "atomic_write", "blob_array",
]
