"""On-disk segment/manifest format for one stored workload key
(DESIGN.md §13.2).

One key directory holds:

* ``seg_<seq>.bin`` — append-only segment files: raw array bytes at
  alloc-rounded offsets (:data:`ALIGN`), nothing else. Segments are
  immutable once renamed into place; a commit only ever *adds* a file.
* ``manifest_<seq>.json`` — one manifest per commit: epoch, scalar meta,
  and for every logical array its dtype, shape and **part list** — each
  part naming a segment file, byte offset, length and crc32. A full
  commit's arrays are single parts in the commit's own segment; a delta
  commit's arrays reference the prior chain (``reuse``), add a head/tail
  part around it (``prefix``/``suffix``), or carry a replacement part
  (``full``), per :func:`repro.core.streaming.array_delta`.
* ``latest`` — pointer to the newest manifest, rewritten last. Purely an
  optimization: recovery never trusts it, it walks manifests newest-first
  and serves the first one that validates.

Commit order is segment → fsync → rename, manifest → fsync → rename,
pointer. The *manifest rename is the commit point*: a crash anywhere
earlier leaves only ignorable temp files or an orphaned (unreferenced)
segment, and a crash between manifest and pointer still exposes the new
commit to the recovery walk. Loading mmaps each referenced segment and
slices parts out of it — single-part arrays are zero-copy views; the rare
multi-part array (a suffix chain) is concatenated, paying one copy of
that array only.

Single writer per key directory is assumed (the registry serializes
builds per key and runs epoch mutations on one FIFO worker); concurrent
writers from separate processes cannot corrupt a commit (every rename is
atomic) but may waste segments.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import time

import numpy as np

from repro.core.streaming import array_delta

from .blobio import atomic_write, crc32

MANIFEST_FORMAT = 1

#: allocation granularity for array offsets inside a segment file: keeps
#: every part naturally aligned for any dtype the index planes use and
#: cache-line aligned for the mmap read path
ALIGN = 64

_SEG_RE = re.compile(r"^seg_(\d{8})\.bin$")
_MAN_RE = re.compile(r"^manifest_(\d{8})\.json$")


class StoreCorruption(IOError):
    """A manifest or segment failed validation (bad json, missing or
    short segment file, crc mismatch). Recovery catches this and walks
    back to the previous commit."""


def _align(off: int) -> int:
    return (off + ALIGN - 1) // ALIGN * ALIGN


def next_seq(dirpath: str) -> int:
    """1 + the largest sequence number any file in the directory carries —
    including orphaned segments from interrupted commits, so a recovered
    writer never reuses (and silently overwrites) a crashed commit's
    names."""
    seq = 0
    for name in os.listdir(dirpath):
        m = _SEG_RE.match(name) or _MAN_RE.match(name)
        if m:
            seq = max(seq, int(m.group(1)))
    return seq + 1


def list_manifests(dirpath: str) -> list[tuple[int, str]]:
    """(seq, filename) of every manifest, newest first."""
    out = []
    for name in os.listdir(dirpath):
        m = _MAN_RE.match(name)
        if m:
            out.append((int(m.group(1)), name))
    out.sort(reverse=True)
    return out


# ----------------------------------------------------------------------
# commit
# ----------------------------------------------------------------------

@dataclasses.dataclass
class _Pending:
    """A part whose bytes go into the commit's own segment; the offset is
    assigned at layout time."""
    raw: np.ndarray   # flat uint8 view of the bytes to write


def write_commit(dirpath: str, meta: dict, arrays: dict,
                 prev: tuple[dict, dict] | None = None, *,
                 max_chain: int = 4, keep_manifests: int = 2) -> dict:
    """Commit ``arrays`` (name -> ndarray) + scalar ``meta`` as the key's
    next epoch. ``prev = (prev_manifest, prev_arrays)`` enables the delta
    path: arrays unchanged since ``prev`` reuse its parts,
    prefix/suffix-grown arrays write only their changed bytes. Falls back
    to a full commit when the delta would not pay — the referenced chain
    would exceed ``max_chain`` distinct segments, or the delta writes no
    fewer bytes than a full rewrite. Returns
    ``{"mode", "seq", "epoch", "bytes_written", "segments"}``."""
    seq = next_seq(dirpath)
    seg_name = f"seg_{seq:08d}.bin"
    entries = mode = None
    if prev is not None:
        prev_man, prev_arrays = prev
        entries, delta_bytes, chain = _delta_entries(
            prev_man, prev_arrays, arrays, seg_name)
        full_bytes = sum(int(np.asarray(a).nbytes) for a in arrays.values())
        # take the delta whenever it writes strictly less than a full
        # rewrite AND keeps the referenced chain short (chain length bounds
        # both open-time validation work and the blast radius of one lost
        # segment); otherwise compact to a fresh full commit
        if len(chain) > max_chain or delta_bytes >= full_bytes:
            entries = None
        else:
            mode = "delta"
    if entries is None:
        mode = "full"
        entries = {
            name: {"dtype": str(np.asarray(a).dtype),
                   "shape": list(np.asarray(a).shape),
                   "parts": [_Pending(_flat_bytes(a))]}
            for name, a in arrays.items()
        }
    written = _write_segment(dirpath, seg_name, entries)
    segments = sorted({p["segment"] for e in entries.values()
                       for p in e["parts"]})
    man = {
        "format": MANIFEST_FORMAT,
        "seq": seq,
        "mode": mode,
        "epoch": int(meta.get("epoch", 0)),
        "meta": meta,
        "arrays": entries,
        "segments": segments,
        "written_at": time.time(),
    }
    man_name = f"manifest_{seq:08d}.json"
    atomic_write(os.path.join(dirpath, man_name),
                 json.dumps(man, sort_keys=True).encode())
    atomic_write(os.path.join(dirpath, "latest"), man_name.encode(),
                 fsync=False)
    _gc(dirpath, keep_manifests)
    return {"mode": mode, "seq": seq, "epoch": man["epoch"],
            "bytes_written": written, "segments": segments}


def _flat_bytes(a) -> np.ndarray:
    return np.ascontiguousarray(a).reshape(-1).view(np.uint8)


def _delta_entries(prev_man: dict, prev_arrays: dict, arrays: dict,
                   seg_name: str):
    """Per-array delta classification against the previous commit. The
    new name set may gain arrays (a suffix epoch can raise the graph's
    k-max, adding fresh per-k blocks — those write in full while the
    existing blocks still delta); *losing* arrays degrades to a full
    commit by inflating the chain."""
    if not set(prev_man["arrays"]) <= set(arrays):
        return {}, 0, set(range(10_000))  # force the full path
    entries: dict = {}
    delta_bytes = 0
    chain = {seg_name}
    for name, arr in arrays.items():
        arr = np.asarray(arr)
        p_ent = prev_man["arrays"].get(name)
        d = (array_delta(prev_arrays.get(name), arr)
             if p_ent is not None else "full")
        if p_ent is None:
            raw = _flat_bytes(arr)
            delta_bytes += raw.nbytes
            parts = [_Pending(raw)]
        elif d == "reuse":
            parts = [dict(p) for p in p_ent["parts"]]
        elif d == "suffix":
            prev_n = sum(p["nbytes"] for p in p_ent["parts"])
            tail = _flat_bytes(arr)[prev_n:]
            delta_bytes += tail.nbytes
            parts = [dict(p) for p in p_ent["parts"]] + [_Pending(tail)]
        elif d == "prefix":
            prev_n = sum(p["nbytes"] for p in p_ent["parts"])
            head = _flat_bytes(arr)[:arr.nbytes - prev_n]
            delta_bytes += head.nbytes
            parts = [_Pending(head)] + [dict(p) for p in p_ent["parts"]]
        else:
            raw = _flat_bytes(arr)
            delta_bytes += raw.nbytes
            parts = [_Pending(raw)]
        for p in parts:
            if not isinstance(p, _Pending):
                chain.add(p["segment"])
        entries[name] = {"dtype": str(arr.dtype), "shape": list(arr.shape),
                         "parts": parts}
    return entries, delta_bytes, chain


def _write_segment(dirpath: str, seg_name: str, entries: dict) -> int:
    """Lay pending parts out at alloc-rounded offsets, write the segment
    atomically, and replace each ``_Pending`` with its concrete part
    descriptor. Returns bytes written. When nothing is pending (a pure
    reuse delta) no segment file is created at all."""
    pending: list[tuple[dict, int, _Pending]] = []
    off = 0
    for ent in entries.values():
        for i, p in enumerate(ent["parts"]):
            if isinstance(p, _Pending):
                off = _align(off)
                pending.append((ent, i, p, off))
                off += p.raw.nbytes
    if not pending:
        return 0
    buf = bytearray(off)
    for ent, i, p, at in pending:
        buf[at:at + p.raw.nbytes] = p.raw.tobytes()
        ent["parts"][i] = {"segment": seg_name, "offset": at,
                           "nbytes": p.raw.nbytes, "crc": crc32(p.raw)}
    atomic_write(os.path.join(dirpath, seg_name), bytes(buf))
    return len(buf)


def _gc(dirpath: str, keep_manifests: int) -> None:
    """Drop manifests beyond the ``keep_manifests`` newest, then every
    segment no kept manifest references (orphans from interrupted commits
    included). Failures are ignored — GC is advisory, correctness rests
    on the commit protocol alone."""
    manifests = list_manifests(dirpath)
    keep, drop = manifests[:keep_manifests], manifests[keep_manifests:]
    referenced: set[str] = set()
    for _, name in keep:
        try:
            with open(os.path.join(dirpath, name)) as f:
                referenced.update(json.load(f).get("segments", ()))
        except (OSError, ValueError):
            pass
    for _, name in drop:
        try:
            os.remove(os.path.join(dirpath, name))
        except OSError:
            pass
    for name in os.listdir(dirpath):
        if _SEG_RE.match(name) and name not in referenced:
            try:
                os.remove(os.path.join(dirpath, name))
            except OSError:
                pass


# ----------------------------------------------------------------------
# open / recover
# ----------------------------------------------------------------------

def read_manifest(dirpath: str, name: str) -> dict:
    """Parse + structurally validate one manifest; :class:`StoreCorruption`
    on any defect (truncated json, missing segment, short segment)."""
    path = os.path.join(dirpath, name)
    try:
        with open(path, "rb") as f:
            man = json.loads(f.read().decode())
    except (OSError, ValueError) as exc:
        raise StoreCorruption(f"unreadable manifest {path}: {exc}") from exc
    if not isinstance(man, dict) or man.get("format") != MANIFEST_FORMAT:
        raise StoreCorruption(f"manifest {path}: bad format marker")
    sizes = {}
    for seg in man.get("segments", ()):
        sp = os.path.join(dirpath, seg)
        if not os.path.exists(sp):
            raise StoreCorruption(f"manifest {path}: missing segment {seg}")
        sizes[seg] = os.path.getsize(sp)
    try:
        for aname, ent in man["arrays"].items():
            need = int(np.prod(ent["shape"], dtype=np.int64)
                       ) * np.dtype(ent["dtype"]).itemsize
            have = 0
            for p in ent["parts"]:
                if p["offset"] + p["nbytes"] > sizes[p["segment"]]:
                    raise StoreCorruption(
                        f"manifest {path}: part of {aname!r} overruns "
                        f"segment {p['segment']}")
                have += p["nbytes"]
            if have != need:
                raise StoreCorruption(
                    f"manifest {path}: {aname!r} parts sum to {have} bytes, "
                    f"shape needs {need}")
    except (KeyError, TypeError, ValueError) as exc:
        raise StoreCorruption(f"manifest {path}: malformed: {exc}") from exc
    return man


def load_arrays(dirpath: str, man: dict, names=None, *,
                verify: bool = True) -> dict:
    """mmap the manifest's segments and materialize its arrays (or just
    ``names``). Single-part arrays are zero-copy views into the mapping;
    ``verify`` checks every part's crc32 (paging the bytes in — still far
    cheaper than a rebuild)."""
    maps: dict[str, np.ndarray] = {}
    out: dict[str, np.ndarray] = {}
    for aname, ent in man["arrays"].items():
        if names is not None and aname not in names:
            continue
        views = []
        for p in ent["parts"]:
            seg = p["segment"]
            if seg not in maps:
                maps[seg] = np.memmap(os.path.join(dirpath, seg),
                                      dtype=np.uint8, mode="r")
            view = maps[seg][p["offset"]:p["offset"] + p["nbytes"]]
            if verify and crc32(view) != p["crc"]:
                raise StoreCorruption(
                    f"segment {seg} failed crc32 verification for "
                    f"{aname!r} (epoch {man.get('epoch')})")
            views.append(view)
        flat = views[0] if len(views) == 1 else np.concatenate(views)
        out[aname] = flat.view(np.dtype(ent["dtype"])).reshape(ent["shape"])
    return out


def open_latest(dirpath: str, *, verify: bool = True,
                load: bool = True):
    """Newest valid commit: ``(manifest, arrays, recovered)`` — or ``None``
    when the directory holds no loadable commit at all. ``recovered``
    counts newer manifests that failed validation and were skipped (the
    crash-recovery walk). ``load=False`` validates structure only and
    returns ``(manifest, None, recovered)`` (cheap epoch probes)."""
    if not os.path.isdir(dirpath):
        return None
    recovered = 0
    for _, name in list_manifests(dirpath):
        try:
            man = read_manifest(dirpath, name)
            if not load:
                return man, None, recovered
            return man, load_arrays(dirpath, man, verify=verify), recovered
        except StoreCorruption:
            recovered += 1
            continue
    return None
