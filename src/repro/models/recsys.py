"""MIND — Multi-Interest Network with Dynamic routing  [arXiv:1904.08030].

The hot path is the sparse embedding lookup over a 10^6–10^9-row item table.
JAX has no native EmbeddingBag: the lookup is built from ``jnp.take`` +
``jax.ops.segment_sum`` (the system requirement, not a stub), with a
vocab-parallel ``shard_map`` variant in runtime/sharding.py for the
row-sharded table.

Structure:
  item table (V, d) -> behavior embeddings (B, H, d)
  -> B2I dynamic capsule routing (3 iters) -> K=4 interest capsules (B, K, d)
  -> label-aware attention (train) / max-interest scoring (serve).
Training uses sampled softmax with in-batch negatives.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class MINDConfig:
    name: str = "mind"
    n_items: int = 8_388_608       # 2**23 rows (spec: 10^6–10^9)
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    hist_len: int = 50
    pow_p: float = 2.0             # label-aware attention sharpness


def mind_init(cfg: MINDConfig, key):
    k1, k2 = jax.random.split(key)
    return {
        "item_embed": (jax.random.normal(k1, (cfg.n_items, cfg.embed_dim), jnp.float32) * 0.02),
        "S": (jax.random.normal(k2, (cfg.embed_dim, cfg.embed_dim), jnp.float32)
              / np.sqrt(cfg.embed_dim)),
    }


def abstract_params(cfg: MINDConfig):
    return jax.eval_shape(lambda: mind_init(cfg, jax.random.PRNGKey(0)))


def embedding_bag(table, ids, mask=None):
    """take + masked mean — the manual EmbeddingBag (sum/mean modes)."""
    emb = jnp.take(table, ids, axis=0)               # (..., H, d)
    if mask is None:
        return emb
    return emb * mask[..., None]


def squash(x, axis=-1, eps=1e-9):
    n2 = jnp.sum(x * x, axis=axis, keepdims=True)
    return (n2 / (1.0 + n2)) * x / jnp.sqrt(n2 + eps)


def b2i_routing(cfg: MINDConfig, behavior, mask):
    """Behavior-to-interest dynamic routing.

    behavior: (B, H, d); mask: (B, H). Returns interests (B, K, d).
    Routing logits are deterministically initialized from a fixed hash of
    the position (paper uses random init; fixed seed keeps steps pure).
    """
    B, H, d = behavior.shape
    K = cfg.n_interests
    # low-discrepancy fixed init (stands in for the paper's random init)
    init = jnp.sin(jnp.arange(K)[:, None] * 12.9898 + jnp.arange(H)[None, :] * 78.233) * 0.01
    blog = jnp.broadcast_to(init[None], (B, K, H))
    ew = behavior                                     # already (B, H, d)

    def one_iter(blog, _):
        w = jax.nn.softmax(blog, axis=1)              # over interests
        w = w * mask[:, None, :]
        z = jnp.einsum("bkh,bhd->bkd", w, ew)         # weighted sum
        u = squash(z)
        blog2 = blog + jnp.einsum("bkd,bhd->bkh", u, ew)
        return blog2, u

    # python loop (3 iters): keeps every iteration visible to cost_analysis
    # (XLA tallies a while/scan body once regardless of trip count)
    u = None
    for _ in range(cfg.capsule_iters):
        blog, u = one_iter(blog, None)
    return u                                          # (B, K, d)


def user_interests(params, cfg: MINDConfig, hist_ids, hist_mask, take_fn=None):
    """hist_ids: (B, H) int32; hist_mask: (B, H) f32 -> (B, K, d)."""
    take_fn = take_fn or (lambda t, i: jnp.take(t, i, axis=0))
    emb = take_fn(params["item_embed"], hist_ids) * hist_mask[..., None]
    emb = emb @ params["S"]                           # bilinear capsule map
    return b2i_routing(cfg, emb, hist_mask)


def label_aware_attention(cfg: MINDConfig, interests, target_emb):
    """interests (B,K,d) x target (B,d) -> user vector (B,d)."""
    att = jnp.einsum("bkd,bd->bk", interests, target_emb)
    att = jax.nn.softmax(cfg.pow_p * att, axis=-1)
    return jnp.einsum("bk,bkd->bd", att, interests)


def mind_loss(params, cfg: MINDConfig, batch, take_fn=None):
    """Sampled softmax with in-batch negatives.

    batch: hist_ids (B,H), hist_mask (B,H), target_id (B,).
    """
    tf = take_fn or (lambda t, i: jnp.take(t, i, axis=0))
    interests = user_interests(params, cfg, batch["hist_ids"], batch["hist_mask"], take_fn)
    tgt = tf(params["item_embed"], batch["target_id"])                 # (B, d)
    user = label_aware_attention(cfg, interests, tgt)
    logits = user @ tgt.T                              # (B, B) in-batch scores
    labels = jnp.arange(logits.shape[0])
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def mind_serve(params, cfg: MINDConfig, batch, take_fn=None, cand_take_fn=None):
    """Online scoring: max-over-interests dot with per-user candidates.

    batch: hist_ids (B,H), hist_mask (B,H), cand_ids (B, C) -> scores (B, C).
    """
    ctf = cand_take_fn or take_fn or (lambda t, i: jnp.take(t, i, axis=0))
    interests = user_interests(params, cfg, batch["hist_ids"], batch["hist_mask"], take_fn)
    cand = ctf(params["item_embed"], batch["cand_ids"])                # (B, C, d)
    scores = jnp.einsum("bkd,bcd->bkc", interests, cand)
    return jnp.max(scores, axis=1)


def mind_retrieval(params, cfg: MINDConfig, batch, take_fn=None, cand_take_fn=None):
    """One user against a 10^6 candidate slab: batched dot, not a loop.

    batch: hist_ids (1,H), hist_mask (1,H), cand_ids (C,) -> scores (C,).
    """
    ctf = cand_take_fn or take_fn or (lambda t, i: jnp.take(t, i, axis=0))
    interests = user_interests(params, cfg, batch["hist_ids"], batch["hist_mask"], take_fn)
    cand = ctf(params["item_embed"], batch["cand_ids"])                # (C, d)
    scores = jnp.einsum("kd,cd->kc", interests[0], cand)
    return jnp.max(scores, axis=0)
