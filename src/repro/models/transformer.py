"""Decoder-only LM family: dense and MoE, GQA + RoPE, scan-over-layers.

Covers the five assigned LM architectures (dbrx-132b, qwen2-moe-a2.7b,
glm4-9b, codeqwen1.5-7b, qwen1.5-110b). Pure JAX pytrees — no framework
dependency. Layers are stacked on axis 0 and executed with ``lax.scan`` so
the lowered HLO stays one-layer-sized regardless of depth (critical for the
512-device dry-run compiles) and so a future ``pipe`` mesh axis can shard
the scanned dimension.

MoE uses sort-based token dispatch with a static capacity bound
(MaxText-style): top-k routing -> argsort by expert -> positioned scatter
into an (E, C, d) buffer -> batched expert GEMMs -> weighted combine. The
dispatch is gather/scatter (≈0 FLOPs in HLO), so compiled FLOPs track
*active* parameters — keeping the MODEL_FLOPS/HLO_FLOPs roofline ratio
honest (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0          # shared (always-on) experts, qwen2-moe style
    capacity_factor: float = 1.25
    # groups > 1 = hierarchical *local* dispatch (beyond-paper §Perf lever):
    # tokens are split into G groups aligned with the DP sharding and each
    # group routes/sorts/scatters into its own (E, C/G, d) buffer. The
    # scatter then partitions along the group dim under SPMD instead of
    # replicating a (E*C, d) buffer on every device (which cost ~22 GB/layer
    # of all-gather for qwen2-moe in the baseline dry-run). Routing results
    # are identical; only the capacity bound becomes group-local
    # (DeepSpeed-MoE-style local groups).
    groups: int = 1
    # pad_experts adds never-routed dummy experts so the expert count
    # divides the TP axis (qwen2-moe: 60 -> 64 on a 16-way mesh), unlocking
    # true expert parallelism instead of the expert-TP fallback. Dummy
    # router logits are masked to -inf; their capacity slots stay empty
    # (6.7% slot overhead for 60 -> 64).
    pad_experts: int = 0

    @property
    def e_total(self) -> int:
        return self.n_experts + self.pad_experts


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layer: int
    d_model: int
    n_head: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 128
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    moe: MoEConfig | None = None
    dtype: Any = jnp.bfloat16
    remat: bool = True
    # unroll=True replaces lax.scan with a python loop over stacked layers.
    # Same math; bigger HLO. Used by the dry-run so cost_analysis counts
    # every layer (XLA tallies a while-loop body once, regardless of trip
    # count) and so remat recompute shows up in HLO_FLOPs.
    unroll: bool = False

    @property
    def param_count(self) -> int:
        """Total parameters (embedding + blocks + head), exact."""
        d, dh = self.d_model, self.d_head
        attn = d * dh * (self.n_head + 2 * self.n_kv) + self.n_head * dh * d
        if self.qkv_bias:
            attn += dh * (self.n_head + 2 * self.n_kv)
        if self.moe is None:
            ffn = 3 * d * self.d_ff
        else:
            ffn = (
                self.moe.n_experts * 3 * d * self.moe.d_ff_expert
                + self.moe.n_shared * 3 * d * self.moe.d_ff_expert
                + d * self.moe.n_experts    # router
            )
        block = attn + ffn + 2 * d          # two RMSNorm gains
        return self.vocab * d * 2 + self.n_layer * block + d

    @property
    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k + shared only)."""
        if self.moe is None:
            return self.param_count
        d = self.d_model
        inactive = (self.moe.n_experts - self.moe.top_k) * 3 * d * self.moe.d_ff_expert
        return self.param_count - self.n_layer * inactive


# ----------------------------------------------------------------------
# initialization
# ----------------------------------------------------------------------

def _dense_init(key, shape, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_layer_params(cfg: LMConfig, key) -> dict:
    d, dh, hq, hk = cfg.d_model, cfg.d_head, cfg.n_head, cfg.n_kv
    ks = jax.random.split(key, 12)
    p = {
        "ln1": jnp.ones((d,), jnp.float32),
        "ln2": jnp.ones((d,), jnp.float32),
        "wq": _dense_init(ks[0], (d, hq * dh), cfg.dtype),
        "wk": _dense_init(ks[1], (d, hk * dh), cfg.dtype),
        "wv": _dense_init(ks[2], (d, hk * dh), cfg.dtype),
        "wo": _dense_init(ks[3], (hq * dh, d), cfg.dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), cfg.dtype)
        p["bk"] = jnp.zeros((hk * dh,), cfg.dtype)
        p["bv"] = jnp.zeros((hk * dh,), cfg.dtype)
    if cfg.moe is None:
        p["ffn"] = {
            "wi": _dense_init(ks[4], (d, cfg.d_ff), cfg.dtype),
            "wg": _dense_init(ks[5], (d, cfg.d_ff), cfg.dtype),
            "wo": _dense_init(ks[6], (cfg.d_ff, d), cfg.dtype),
        }
    else:
        e, f = cfg.moe.e_total, cfg.moe.d_ff_expert
        p["moe"] = {
            "router": _dense_init(ks[7], (d, e), jnp.float32),
            "wi": _dense_init(ks[8], (e, d, f), cfg.dtype),
            "wg": _dense_init(ks[9], (e, d, f), cfg.dtype),
            "wo": _dense_init(ks[10], (e, f, d), cfg.dtype),
        }
        if cfg.moe.n_shared:
            s = cfg.moe.n_shared
            p["moe"]["shared_wi"] = _dense_init(ks[11], (s, d, f), cfg.dtype)
            p["moe"]["shared_wg"] = _dense_init(ks[11], (s, d, f), cfg.dtype)
            p["moe"]["shared_wo"] = _dense_init(ks[11], (s, f, d), cfg.dtype)
    return p


def init_params(cfg: LMConfig, key) -> dict:
    k_emb, k_head, k_layers = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layer)
    layers = [init_layer_params(cfg, k) for k in layer_keys]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *layers)
    return {
        "embed": _dense_init(k_emb, (cfg.vocab, cfg.d_model), cfg.dtype, scale=0.02),
        "head": _dense_init(k_head, (cfg.d_model, cfg.vocab), cfg.dtype),
        "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
        "layers": stacked,
    }


def abstract_params(cfg: LMConfig) -> dict:
    """ShapeDtypeStruct pytree (no allocation) — dry-run input."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


# ----------------------------------------------------------------------
# building blocks
# ----------------------------------------------------------------------

# Activation-sharding hook (§Perf lever, read at trace time): when set to a
# NamedSharding for the (B, S, d) residual stream, every block boundary is
# pinned with with_sharding_constraint. Without it GSPMD propagates the
# FSDP 'data' sharding of wo's output dim INTO the activations — which
# collides with batch-over-'data' and forced ~19 GB/layer/device of f32
# activation all-gathers in the qwen1.5-110b dry-run (EXPERIMENTS.md §Perf).
ACT_SHARDING = None


def set_activation_sharding(sharding):
    global ACT_SHARDING
    ACT_SHARDING = sharding


def _constrain(x):
    if ACT_SHARDING is not None and x.ndim == 3:
        return jax.lax.with_sharding_constraint(x, ACT_SHARDING)
    return x


# MoE dispatch-buffer sharding hook (§Perf lever): a pair of NamedShardings
# for the (E, C, d) dispatch buffer and the (E, C, f) expert intermediate.
# Pinning capacity over the DP axes and f over TP makes XLA *gather the
# (small) expert weights* instead of psum-ing the (huge) activation
# partials — the baseline expert-TP plan all-reduced an (E, C, d) tensor
# per expert GEMM (~38 GB/layer/device for qwen2-moe).
MOE_SHARDING = None


def set_moe_sharding(sharding_pair):
    global MOE_SHARDING
    MOE_SHARDING = sharding_pair


def _constrain_moe(x, which: int):
    if MOE_SHARDING is not None:
        return jax.lax.with_sharding_constraint(x, MOE_SHARDING[which])
    return x


# Weight-gather hook (§Perf lever): ZeRO-3 semantics made explicit. FSDP
# shards weights over 'data'; at *use* the weight must be all-gathered and
# the contraction kept local — otherwise GSPMD may instead psum the (much
# larger) activation partials over 'data' (qwen2-moe baseline: ~38 GB/layer
# of (E, C, ·) f32 all-reduces vs ~65 MB/layer of gathered expert weights).
# The hook maps a call-site tag to the gathered-at-use NamedSharding.
WEIGHT_USE_SHARDING = None


def set_weight_use_sharding(table):
    global WEIGHT_USE_SHARDING
    WEIGHT_USE_SHARDING = table


def _use_w(p, key, tag):
    w = p[key]
    if WEIGHT_USE_SHARDING is not None and tag in WEIGHT_USE_SHARDING:
        return jax.lax.with_sharding_constraint(w, WEIGHT_USE_SHARDING[tag])
    return w


def rms_norm(x, gain, eps=1e-5):
    x32 = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * scale * gain).astype(x.dtype)


def rope(x, positions, theta):
    """x: (B, S, H, dh); positions: (B, S) or (S,).

    Angles are computed in f32 (position precision), but the rotation
    arithmetic runs in x.dtype — keeping the (B,S,H,dh)-sized intermediates
    bf16 halves the attention-side collective/HBM traffic the dry-run
    attributed to f32 rope tensors (EXPERIMENTS.md §Perf cell 1).
    """
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs    # (B, S, half)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def gqa_attention(q, k, v, *, causal: bool, kv_len_mask=None):
    """q: (B,S,Hq,dh); k,v: (B,T,Hkv,dh). Grouped-query full attention.

    KV heads are expanded to q-head count with a constant-index ``take``
    (repeat_kv). This keeps every attention tensor sharded over the q-head
    dim under TP: a (Hkv, G) reshape factorization defeats GSPMD when
    Hkv < mesh model size (glm4 has Hkv=2 on a 16-way axis) and silently
    replicated the (B,H,S,T) score tensor — 17 GB/device in the dry-run.
    """
    B, S, Hq, dh = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    if Hq != Hkv:
        head_map = jnp.arange(Hq, dtype=jnp.int32) // (Hq // Hkv)
        k = jnp.take(k, head_map, axis=2)
        v = jnp.take(v, head_map, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32)
    scores = scores / np.sqrt(dh)
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        scores = jnp.where(mask[None, None], scores, -1e30)
    if kv_len_mask is not None:                      # decode: positions < len
        scores = jnp.where(kv_len_mask[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, v)
    return out.reshape(B, S, Hq * dh)


def attention_block(p, cfg: LMConfig, x, positions, *, cache=None, cache_len=None):
    """Returns (out, new_cache). cache: dict(k=(B,T,Hkv,dh), v=...)."""
    B, S, d = x.shape
    q = x @ _use_w(p, "wq", "attn.wq")
    k = x @ _use_w(p, "wk", "attn.wk")
    v = x @ _use_w(p, "wv", "attn.wv")
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_head, cfg.d_head)
    k = k.reshape(B, S, cfg.n_kv, cfg.d_head)
    v = v.reshape(B, S, cfg.n_kv, cfg.d_head)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if cache is None:
        out = gqa_attention(q, k, v, causal=True)
        new_cache = None
    else:
        T = cache["k"].shape[1]
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cache_len, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cache_len, axis=1)
        valid = jnp.arange(T)[None, :] <= cache_len    # (1, T) — includes new token
        valid = jnp.broadcast_to(valid, (B, T))
        out = gqa_attention(q, ck, cv, causal=False, kv_len_mask=valid)
        new_cache = {"k": ck, "v": cv}
    # keep the residual-stream dtype stable (a f32 cache must not promote
    # the bf16 carry: lax.scan requires a fixed carry type)
    return (out @ _use_w(p, "wo", "attn.wo")).astype(x.dtype), new_cache


def dense_ffn(p, x):
    h = jax.nn.silu(x @ _use_w(p, "wg", "ffn.wg")) * (x @ _use_w(p, "wi", "ffn.wi"))
    return h @ _use_w(p, "wo", "ffn.wo")


def _moe_group(p, mcfg: MoEConfig, xt, capacity: int):
    """Sort-based dispatch + expert GEMMs for one token group (Tg, d)."""
    Tg, d = xt.shape
    E, K, C = mcfg.e_total, mcfg.top_k, capacity

    logits = xt.astype(jnp.float32) @ p["router"]            # (Tg, E)
    if mcfg.pad_experts:
        pad_mask = jnp.arange(E) >= mcfg.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)                      # (Tg, K)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    # flatten assignments, stable-sort by expert id
    flat_e = eidx.reshape(-1)                                 # (Tg*K,)
    flat_t = jnp.repeat(jnp.arange(Tg, dtype=jnp.int32), K)
    order = jnp.argsort(flat_e, stable=True)
    se, st = flat_e[order], flat_t[order]
    # position within expert = index - start of that expert's run
    starts = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype), side="left")
    pos = jnp.arange(Tg * K, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    keep = pos < C
    dest = jnp.where(keep, se.astype(jnp.int32) * C + pos, E * C)   # overflow row

    # dispatch: (E*C+1, d) buffer; dropped tokens land in the dummy row
    buf = jnp.zeros((E * C + 1, d), xt.dtype).at[dest].set(xt[st])
    h = _constrain_moe(buf[: E * C].reshape(E, C, d), 0)

    # expert GEMMs (batched over E -> MXU)
    hg = _constrain_moe(jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, _use_w(p, "wg", "moe.wg"))), 1)
    hi = _constrain_moe(jnp.einsum("ecd,edf->ecf", h, _use_w(p, "wi", "moe.wi")), 1)
    ho = _constrain_moe(jnp.einsum("ecf,efd->ecd", hg * hi, _use_w(p, "wo", "moe.wo")), 0)
    ho = ho.reshape(E * C, d)

    # combine: route expert outputs back to tokens with gate weights
    gflat = gate.reshape(-1)[order]                           # aligned with se/st
    contrib = jnp.where(keep[:, None], ho[jnp.clip(dest, 0, E * C - 1)], 0.0)
    out = jnp.zeros((Tg, d), xt.dtype).at[st].add(contrib * gflat[:, None].astype(xt.dtype))

    # auxiliary load-balance loss (Switch-style)
    me = jnp.mean(jax.nn.one_hot(eidx, E, dtype=jnp.float32), axis=(0, 1))
    ce = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(me * ce)
    return out, aux


# Full-dispatch override hook (§Perf): when set, routes the whole routed-
# expert path through an alternative implementation (e.g. the explicit
# shard_map all-to-all dispatch in runtime/moe_a2a.py).
MOE_IMPL = None


def set_moe_impl(fn):
    global MOE_IMPL
    MOE_IMPL = fn


def moe_ffn(p, cfg: LMConfig, x):
    """Capacity-bounded MoE; grouped local dispatch when moe.groups > 1."""
    if MOE_IMPL is not None:
        return MOE_IMPL(p, cfg, x)
    mcfg = cfg.moe
    B, S, d = x.shape
    T = B * S
    G = mcfg.groups if T % max(mcfg.groups, 1) == 0 else 1
    Tg = T // G
    C = max(1, min(int(np.ceil(Tg * mcfg.top_k / mcfg.n_experts
                               * mcfg.capacity_factor)), Tg))
    C = int(np.ceil(C / 32)) * 32   # DP-divisible capacity: lets the (E,C,·)
                                    # dispatch tensors shard C over the mesh
    xt = x.reshape(T, d)
    if G == 1:
        out, aux = _moe_group(p, mcfg, xt, C)
    else:
        xg = xt.reshape(G, Tg, d)
        out, auxes = jax.vmap(_moe_group, in_axes=(None, None, 0, None))(
            p, mcfg, xg, C)
        out = out.reshape(T, d)
        aux = jnp.mean(auxes)

    if mcfg.n_shared:
        hs = jax.nn.silu(jnp.einsum("td,sdf->tsf", xt, _use_w(p, "shared_wg", "moe.shared_wg")))
        hi_s = jnp.einsum("td,sdf->tsf", xt, _use_w(p, "shared_wi", "moe.shared_wi"))
        out = out + jnp.einsum("tsf,sfd->td", hs * hi_s, _use_w(p, "shared_wo", "moe.shared_wo"))

    return out.reshape(B, S, d), aux


# ----------------------------------------------------------------------
# full model
# ----------------------------------------------------------------------

def _layer_fn(cfg: LMConfig, x, lp, positions, cache=None, cache_len=None):
    a, new_cache = attention_block(lp, cfg, rms_norm(x, lp["ln1"]), positions,
                                   cache=cache, cache_len=cache_len)
    x = _constrain(x + a)
    h = rms_norm(x, lp["ln2"])
    if cfg.moe is None:
        f, aux = dense_ffn(lp["ffn"], h), jnp.float32(0.0)
    else:
        f, aux = moe_ffn(lp["moe"], cfg, h)
    return _constrain(x + f), aux, new_cache


def forward(params, cfg: LMConfig, tokens):
    """tokens (B, S) -> logits (B, S, vocab) in f32, plus aux losses."""
    B, S = tokens.shape
    x = _constrain(params["embed"][tokens])
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]

    def body(x, lp):
        y, aux, _ = _layer_fn(cfg, x, lp, positions)
        return y, aux

    body_fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.unroll:
        auxes = []
        for i in range(cfg.n_layer):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, aux = body_fn(x, lp)
            auxes.append(aux)
        auxes = jnp.stack(auxes)
    else:
        x, auxes = jax.lax.scan(body_fn, x, params["layers"])
    x = rms_norm(x, params["ln_f"])
    logits = (x @ params["head"]).astype(jnp.float32)
    return logits, jnp.sum(auxes)


def loss_fn(params, cfg: LMConfig, tokens, labels, aux_weight=0.01):
    logits, aux = forward(params, cfg, tokens)
    # Vocab-parallel-safe cross entropy: logsumexp is a reduction over the
    # (model-sharded) vocab dim and the label logit is a one-hot contraction
    # — both partition under SPMD without all-gathering the (B,S,V) logits
    # (take_along_axis would; it cost 100GB/device of temps in the dry-run).
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = (labels[..., None] == jnp.arange(cfg.vocab, dtype=labels.dtype)).astype(logits.dtype)
    label_logit = jnp.sum(logits * onehot, axis=-1)
    nll = logz - label_logit
    return jnp.mean(nll) + aux_weight * aux


def init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None) -> dict:
    dtype = dtype or cfg.dtype
    shape = (cfg.n_layer, batch, max_len, cfg.n_kv, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def abstract_cache(cfg: LMConfig, batch: int, max_len: int) -> dict:
    shape = (cfg.n_layer, batch, max_len, cfg.n_kv, cfg.d_head)
    sds = jax.ShapeDtypeStruct(shape, cfg.dtype)
    return {"k": sds, "v": sds}


def decode_step(params, cfg: LMConfig, tokens, cache, cache_len):
    """One decode step. tokens (B, 1); cache (L, B, T, Hkv, dh) x2;
    cache_len scalar int32. Returns (logits (B, vocab), new_cache)."""
    B = tokens.shape[0]
    x = params["embed"][tokens]
    positions = jnp.full((B, 1), cache_len, jnp.int32)

    def body(x, layer):
        lp, ck, cv = layer
        y, _aux, nc = _layer_fn(cfg, x, lp, positions,
                                cache={"k": ck, "v": cv}, cache_len=cache_len)
        return y, (nc["k"], nc["v"])

    if cfg.unroll:
        nks, nvs = [], []
        for i in range(cfg.n_layer):
            layer = jax.tree.map(lambda a: a[i], (params["layers"], cache["k"], cache["v"]))
            x, (nk_i, nv_i) = body(x, layer)
            nks.append(nk_i); nvs.append(nv_i)
        nk, nv = jnp.stack(nks), jnp.stack(nvs)
    else:
        x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["ln_f"])
    logits = (x[:, 0] @ params["head"]).astype(jnp.float32)
    return logits, {"k": nk, "v": nv}
