"""GNN architecture family: MeshGraphNet, GraphSAGE, NequIP, MACE.

Message passing is expressed over an explicit edge list with
``jax.ops.segment_sum`` / ``segment_max`` scatter-reduces (JAX has no sparse
SpMM beyond BCOO — the segment formulation IS the system here, per the
assignment notes), so a single substrate serves all four archs and every
input shape (full-graph, sampled-minibatch, batched molecules).

Unified graph batch (dict of arrays):
    node_feat : (n, d_feat) f32     input features (or species one-hot)
    pos       : (n, 3)      f32     positions (geometric archs)
    src, dst  : (E,)        int32   directed edges (doubled for undirected)
    edge_feat : (E, d_e)    f32     (meshgraphnet)
    seed_mask : (n,)        bool    loss restricted to seeds (minibatch)
    labels    : (n,) int32 / targets f32

All models expose ``init(cfg, key)`` and ``forward(params, cfg, batch)`` and
a scalar ``loss(params, cfg, batch)``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import equivariant as eq

# Node-state sharding hook (§Perf lever, read at trace time). The baseline
# GNN distribution replicates node state on every device (edges sharded,
# psum-aggregated) — every node update is recomputed 512x and node-space
# tensors dominate HLO bytes. When set, aggregated node tensors are pinned
# to row sharding over the mesh, distributing node compute and storage; the
# per-layer price is one all-gather of the node state for the next edge
# gather (EXPERIMENTS.md §Perf).
NODE_SHARDING = None


def set_node_sharding(sharding):
    global NODE_SHARDING
    NODE_SHARDING = sharding


def _constrain_nodes(x):
    if NODE_SHARDING is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        ns = NODE_SHARDING
        spec = P(ns.spec[0], *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(ns.mesh, spec))
    return x


def segment_mean(vals, ids, num: int):
    s = jax.ops.segment_sum(vals, ids, num_segments=num)
    c = jax.ops.segment_sum(jnp.ones((vals.shape[0],), vals.dtype), ids, num_segments=num)
    return s / jnp.maximum(c, 1.0)[(...,) + (None,) * (vals.ndim - 1)]


def _mlp_init(key, dims, dtype=jnp.float32):
    ks = jax.random.split(key, len(dims) - 1)
    return [
        {
            "w": (jax.random.normal(k, (a, b), jnp.float32) / np.sqrt(a)).astype(dtype),
            "b": jnp.zeros((b,), dtype),
        }
        for k, (a, b) in zip(ks, zip(dims[:-1], dims[1:]))
    ]


def _mlp(params, x, act=jax.nn.relu, final_act=False):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1 or final_act:
            x = act(x)
    return x


def _layernorm(x, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps)


# ======================================================================
# MeshGraphNet  [arXiv:2010.03409]
# ======================================================================

@dataclasses.dataclass(frozen=True)
class MGNConfig:
    name: str = "meshgraphnet"
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    d_node_in: int = 8
    d_edge_in: int = 4
    d_out: int = 3


def mgn_init(cfg: MGNConfig, key):
    h = cfg.d_hidden
    hidden = [h] * cfg.mlp_layers
    ks = jax.random.split(key, 3 + 2 * cfg.n_layers)
    p = {
        "enc_node": _mlp_init(ks[0], [cfg.d_node_in] + hidden + [h]),
        "enc_edge": _mlp_init(ks[1], [cfg.d_edge_in] + hidden + [h]),
        "dec": _mlp_init(ks[2], [h] + hidden + [cfg.d_out]),
        "layers": [
            {
                "edge_mlp": _mlp_init(ks[3 + 2 * i], [3 * h] + hidden + [h]),
                "node_mlp": _mlp_init(ks[4 + 2 * i], [2 * h] + hidden + [h]),
            }
            for i in range(cfg.n_layers)
        ],
    }
    return p


def mgn_forward(params, cfg: MGNConfig, batch):
    n = batch["node_feat"].shape[0]
    src, dst = batch["src"], batch["dst"]
    mask = batch.get("edge_mask")
    mask = mask[:, None] if mask is not None else 1.0
    x = _layernorm(_mlp(params["enc_node"], batch["node_feat"]))
    e = _layernorm(_mlp(params["enc_edge"], batch["edge_feat"])) * mask
    for lyr in params["layers"]:
        msg_in = jnp.concatenate([e, x[src], x[dst]], axis=-1)
        e = (e + _layernorm(_mlp(lyr["edge_mlp"], msg_in))) * mask
        agg = _constrain_nodes(jax.ops.segment_sum(e, dst, num_segments=n))
        x = x + _layernorm(_mlp(lyr["node_mlp"], jnp.concatenate([x, agg], axis=-1)))
    return _mlp(params["dec"], x)


def mgn_loss(params, cfg: MGNConfig, batch):
    out = mgn_forward(params, cfg, batch)
    return jnp.mean((out - batch["target"]) ** 2)


# ======================================================================
# GraphSAGE (mean aggregator)  [arXiv:1706.02216]
# ======================================================================

@dataclasses.dataclass(frozen=True)
class SAGEConfig:
    name: str = "graphsage-reddit"
    n_layers: int = 2
    d_hidden: int = 128
    d_in: int = 602
    n_classes: int = 41


def sage_init(cfg: SAGEConfig, key):
    ks = jax.random.split(key, cfg.n_layers + 1)
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1)
    layers = []
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(ks[i])
        layers.append({
            "w_self": (jax.random.normal(k1, (dims[i], cfg.d_hidden)) / np.sqrt(dims[i])),
            "w_neigh": (jax.random.normal(k2, (dims[i], cfg.d_hidden)) / np.sqrt(dims[i])),
            "b": jnp.zeros((cfg.d_hidden,)),
        })
    head = (jax.random.normal(ks[-1], (cfg.d_hidden, cfg.n_classes)) / np.sqrt(cfg.d_hidden))
    return {"layers": layers, "head": head}


def sage_forward(params, cfg: SAGEConfig, batch):
    n = batch["node_feat"].shape[0]
    src, dst = batch["src"], batch["dst"]
    mask = batch.get("edge_mask")
    x = batch["node_feat"]
    for i, lyr in enumerate(params["layers"]):
        if mask is not None:
            msum = jax.ops.segment_sum(x[src] * mask[:, None], dst, num_segments=n)
            cnt = jax.ops.segment_sum(mask, dst, num_segments=n)
            agg = msum / jnp.maximum(cnt, 1.0)[:, None]
        else:
            agg = segment_mean(x[src], dst, n)
        agg = _constrain_nodes(agg)
        x = x @ lyr["w_self"] + agg @ lyr["w_neigh"] + lyr["b"]
        x = jax.nn.relu(x)
        x = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-6)
    return x @ params["head"]


def sage_loss(params, cfg: SAGEConfig, batch):
    logits = sage_forward(params, cfg, batch)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1).squeeze(-1)
    w = batch["seed_mask"].astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(w.sum(), 1.0)


# ======================================================================
# NequIP (Cartesian-irrep adaptation, l_max=2)  [arXiv:2101.03164]
# ======================================================================

@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    d_hidden: int = 32          # channels per irrep
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    d_species: int = 16
    radial_hidden: int = 64
    bf16_state: bool = False    # §Perf: bf16 node irreps (halves gather bytes)


def _interaction_init(key, C, n_rbf, radial_hidden, n_weight_blocks):
    """Radial MLP emitting per-path channel weights + irrep channel mixers."""
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    n_out = n_weight_blocks * C * eq.N_PATHS
    return {
        "radial": _mlp_init(k1, [n_rbf, radial_hidden, n_out]),
        "mix_s": (jax.random.normal(k2, (C, C)) / np.sqrt(C)),
        "mix_v": (jax.random.normal(k3, (C, C)) / np.sqrt(C)),
        "mix_t": (jax.random.normal(k4, (C, C)) / np.sqrt(C)),
        "gates": _mlp_init(k5, [C, 2 * C]),
    }


def nequip_init(cfg: NequIPConfig, key):
    C = cfg.d_hidden
    ks = jax.random.split(key, cfg.n_layers + 2)
    return {
        "embed": _mlp_init(ks[0], [cfg.d_species, C]),
        "layers": [
            _interaction_init(ks[1 + i], C, cfg.n_rbf, cfg.radial_hidden, 3)
            for i in range(cfg.n_layers)
        ],
        "readout": _mlp_init(ks[-1], [C, C, 1]),
    }


def _interaction(lyr, C, s, V, T, src, dst, rbf, rhat, Y2, n, mask=None):
    """One equivariant message-passing layer (shared by NequIP and MACE)."""
    rw = _mlp(lyr["radial"], rbf)                     # (E, 3*C*N_PATHS)
    rw = rw.reshape(rbf.shape[0], 3, C, eq.N_PATHS)
    if mask is not None:
        rw = rw * mask[:, None, None, None]           # padded edges: no message
    s_e, V_e, T_e = s[src], V[src], T[src]
    m_s = jnp.einsum("ecp,ecp->ec", eq.tp_to_scalar(s_e, V_e, T_e, rhat, Y2), rw[:, 0])
    m_v = jnp.einsum("ecip,ecp->eci", eq.tp_to_vector(s_e, V_e, T_e, rhat, Y2), rw[:, 1])
    m_t = jnp.einsum("ecijp,ecp->ecij", eq.tp_to_tensor(s_e, V_e, T_e, rhat, Y2), rw[:, 2])
    a_s = _constrain_nodes(jax.ops.segment_sum(m_s, dst, num_segments=n))
    a_v = _constrain_nodes(jax.ops.segment_sum(m_v, dst, num_segments=n))
    a_t = _constrain_nodes(jax.ops.segment_sum(m_t, dst, num_segments=n))
    s2 = s + a_s @ lyr["mix_s"]
    V2 = V + jnp.einsum("nci,cd->ndi", a_v, lyr["mix_v"])
    T2 = T + jnp.einsum("ncij,cd->ndij", a_t, lyr["mix_t"])
    gates = _mlp(lyr["gates"], s2)
    return eq.gated_nonlin(s2, V2, T2, gates)


def nequip_forward(params, cfg: NequIPConfig, batch, n_graphs: int | None = None):
    n = batch["node_feat"].shape[0]
    ng = n_graphs if n_graphs is not None else batch["energy_target"].shape[0]
    C = cfg.d_hidden
    src, dst = batch["src"], batch["dst"]
    rvec = batch["pos"][src] - batch["pos"][dst]
    d, rhat, Y2 = eq.edge_basis(rvec)
    rbf = eq.bessel_rbf(d, cfg.n_rbf, cfg.cutoff)
    s = _mlp(params["embed"], batch["node_feat"])
    V = jnp.zeros((n, C, 3))
    T = jnp.zeros((n, C, 3, 3))
    for lyr in params["layers"]:
        s, V, T = _interaction(lyr, C, s, V, T, src, dst, rbf, rhat, Y2, n,
                               mask=batch.get("edge_mask"))
        if cfg.bf16_state:
            s, V, T = (x.astype(jnp.bfloat16) for x in (s, V, T))
    atom_e = _mlp(params["readout"], s.astype(jnp.float32))[:, 0]  # (n,)
    energy = jax.ops.segment_sum(atom_e, batch["graph_id"], num_segments=ng)
    return energy, (s, V, T)


def nequip_loss(params, cfg: NequIPConfig, batch):
    def energy_fn(pos):
        energy, _ = nequip_forward(params, cfg, {**batch, "pos": pos})
        return jnp.sum(energy), energy

    (tot, energy), neg_forces = jax.value_and_grad(energy_fn, has_aux=True)(batch["pos"])
    e_loss = jnp.mean((energy - batch["energy_target"]) ** 2)
    f_loss = jnp.mean((-neg_forces - batch["force_target"]) ** 2)
    return e_loss + 10.0 * f_loss


# ======================================================================
# MACE (Cartesian adaptation, correlation order 3)  [arXiv:2206.07697]
# ======================================================================

@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    d_hidden: int = 128
    l_max: int = 2
    correlation_order: int = 3
    n_rbf: int = 8
    cutoff: float = 5.0
    d_species: int = 16
    radial_hidden: int = 64
    bf16_state: bool = False    # §Perf: bf16 node irreps (halves gather bytes)


def mace_init(cfg: MACEConfig, key):
    C = cfg.d_hidden
    ks = jax.random.split(key, 2 * cfg.n_layers + 2)
    layers = []
    for i in range(cfg.n_layers):
        base = _interaction_init(ks[1 + 2 * i], C, cfg.n_rbf, cfg.radial_hidden, 3)
        k = ks[2 + 2 * i]
        kb = jax.random.split(k, 6)
        # B-basis projections back to C channels (orders 2 and 3)
        base["prod"] = {
            "s2": (jax.random.normal(kb[0], (3 * C, C)) / np.sqrt(3 * C)),
            "v2": (jax.random.normal(kb[1], (2 * C, C)) / np.sqrt(2 * C)),
            "t2": (jax.random.normal(kb[2], (2 * C, C)) / np.sqrt(2 * C)),
            "s3": (jax.random.normal(kb[3], (3 * C, C)) / np.sqrt(3 * C)),
            "v3": (jax.random.normal(kb[4], (2 * C, C)) / np.sqrt(2 * C)),
            "t3": (jax.random.normal(kb[5], (2 * C, C)) / np.sqrt(2 * C)),
        }
        layers.append(base)
    return {
        "embed": _mlp_init(ks[0], [cfg.d_species, C]),
        "layers": layers,
        "readout": _mlp_init(ks[-1], [C, C, 1]),
    }


def mace_forward(params, cfg: MACEConfig, batch, n_graphs: int | None = None):
    n = batch["node_feat"].shape[0]
    ng = n_graphs if n_graphs is not None else batch["energy_target"].shape[0]
    C = cfg.d_hidden
    src, dst = batch["src"], batch["dst"]
    rvec = batch["pos"][src] - batch["pos"][dst]
    d, rhat, Y2 = eq.edge_basis(rvec)
    rbf = eq.bessel_rbf(d, cfg.n_rbf, cfg.cutoff)
    s = _mlp(params["embed"], batch["node_feat"])
    V = jnp.zeros((n, C, 3))
    T = jnp.zeros((n, C, 3, 3))
    for lyr in params["layers"]:
        s, V, T = _interaction(lyr, C, s, V, T, src, dst, rbf, rhat, Y2, n,
                               mask=batch.get("edge_mask"))
        # higher-order (correlation 2 and 3) products of the aggregate — the
        # MACE A->B basis, Cartesian form
        s2b, v2b, t2b = eq.correlation_products(s, V, T)
        s3b, v3b, t3b = eq.correlation_products(s2b @ lyr["prod"]["s2"],
                                                jnp.einsum("nki,kc->nci", v2b, lyr["prod"]["v2"]),
                                                jnp.einsum("nkij,kc->ncij", t2b, lyr["prod"]["t2"]))
        s = s + s2b @ lyr["prod"]["s2"] + s3b @ lyr["prod"]["s3"]
        V = V + jnp.einsum("nki,kc->nci", v2b, lyr["prod"]["v2"]) \
              + jnp.einsum("nki,kc->nci", v3b, lyr["prod"]["v3"])
        T = T + jnp.einsum("nkij,kc->ncij", t2b, lyr["prod"]["t2"]) \
              + jnp.einsum("nkij,kc->ncij", t3b, lyr["prod"]["t3"])
        if cfg.bf16_state:
            s, V, T = (x.astype(jnp.bfloat16) for x in (s, V, T))
    atom_e = _mlp(params["readout"], s.astype(jnp.float32))[:, 0]
    energy = jax.ops.segment_sum(atom_e, batch["graph_id"], num_segments=ng)
    return energy, (s, V, T)


def mace_loss(params, cfg: MACEConfig, batch):
    def energy_fn(pos):
        energy, _ = mace_forward(params, cfg, {**batch, "pos": pos})
        return jnp.sum(energy), energy

    (tot, energy), neg_forces = jax.value_and_grad(energy_fn, has_aux=True)(batch["pos"])
    e_loss = jnp.mean((energy - batch["energy_target"]) ** 2)
    f_loss = jnp.mean((-neg_forces - batch["force_target"]) ** 2)
    return e_loss + 10.0 * f_loss
