"""Cartesian-irrep E(3)-equivariant building blocks (l_max = 2).

TPU adaptation note (DESIGN.md §3/§6): NequIP/MACE formulate tensor products
in the spherical-harmonic basis with Clebsch–Gordan coefficient tables —
sparse, irregular contractions that map poorly to the MXU. We instead carry
features as *Cartesian* irreps:

    scalars  s  : (n, C)
    vectors  V  : (n, C, 3)
    2-tensors T : (n, C, 3, 3)   (traceless symmetric <=> l = 2)

and build all couplings from dot / outer / matrix products, which are dense
einsums (MXU-friendly) and exactly equivariant under O(3) rotations (we omit
parity-odd cross-product paths; see DESIGN.md). This is the Cartesian
atomic-cluster-expansion route (CACE, arXiv:2312.15460) applied to the
NequIP/MACE layer structure. Equivariance is property-tested under random
rotations in tests/test_models.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

I3 = jnp.eye(3)


def traceless_sym(M):
    """Project (., 3, 3) onto traceless-symmetric (the l=2 irrep)."""
    Ms = 0.5 * (M + jnp.swapaxes(M, -1, -2))
    tr = jnp.trace(Ms, axis1=-2, axis2=-1)[..., None, None]
    return Ms - tr * I3 / 3.0


def edge_basis(rvec, eps=1e-6):
    """Unit vector and l=2 Cartesian basis of edge vectors (E, 3).

    Grad-safe at r = 0 (zero-length edges get rhat ~ 0, not NaN), which
    matters because forces are computed as -dE/dpos through this function.
    """
    d2 = jnp.sum(rvec * rvec, axis=-1, keepdims=True)
    d = jnp.sqrt(d2 + eps * eps)
    rhat = rvec / d
    Y2 = rhat[..., :, None] * rhat[..., None, :] - I3 / 3.0     # (E, 3, 3)
    return d[..., 0], rhat, Y2


def bessel_rbf(d, n_rbf: int, cutoff: float):
    """Radial Bessel basis with smooth polynomial cutoff (NequIP eq. 8)."""
    d = jnp.maximum(d, 1e-9)
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * np.pi * d[..., None] / cutoff) / d[..., None]
    x = jnp.clip(d / cutoff, 0.0, 1.0)
    p = 6  # polynomial envelope order
    env = 1.0 - ((p + 1) * (p + 2) / 2) * x**p + p * (p + 2) * x**(p + 1) - (p * (p + 1) / 2) * x**(p + 2)
    return basis * env[..., None]


# -- tensor-product paths (all O(3)-equivariant, parity-even) -------------
# Each path maps (edge-gathered sender irreps, edge basis) -> messages.

def tp_to_scalar(s, V, T, rhat, Y2):
    """Paths landing in the scalar irrep: (E, C) each."""
    p0 = s
    p1 = jnp.einsum("eci,ei->ec", V, rhat)
    p2 = jnp.einsum("ecij,eij->ec", T, Y2)
    return jnp.stack([p0, p1, p2], axis=-1)        # (E, C, 3 paths)


def tp_to_vector(s, V, T, rhat, Y2):
    """Paths landing in the vector irrep: (E, C, 3) each."""
    p0 = s[..., None] * rhat[:, None, :]
    p1 = V
    p2 = jnp.einsum("ecij,ej->eci", T, rhat)
    return jnp.stack([p0, p1, p2], axis=-1)        # (E, C, 3, 3 paths)


def tp_to_tensor(s, V, T, rhat, Y2):
    """Paths landing in the l=2 irrep: (E, C, 3, 3) each."""
    p0 = s[..., None, None] * Y2[:, None]
    p1 = traceless_sym(V[..., :, None] * rhat[:, None, None, :])
    p2 = T
    return jnp.stack([p0, p1, p2], axis=-1)        # (E, C, 3, 3, 3 paths)


N_PATHS = 3  # per output irrep


def gated_nonlin(s, V, T, gates):
    """Equivariant nonlinearity: silu on scalars, sigmoid-gated V and T.

    gates: (n, 2C) extra scalar channels (one gate per V and T channel).
    """
    C = s.shape[-1]
    gV = jax.nn.sigmoid(gates[..., :C])
    gT = jax.nn.sigmoid(gates[..., C:])
    return jax.nn.silu(s), V * gV[..., None], T * gT[..., None, None]


# -- correlation products (MACE A->B basis, orders 2 and 3) ----------------

def correlation_products(s, V, T):
    """Pairwise (order-2) equivariant products of a feature set with itself.

    Returns extra (scalars, vectors, tensors) channel blocks.
    """
    s2 = s * s
    vv = jnp.einsum("nci,nci->nc", V, V)
    tt = jnp.einsum("ncij,ncij->nc", T, T)
    sV = s[..., None] * V
    tV = jnp.einsum("ncij,ncj->nci", T, V)
    sT = s[..., None, None] * T
    vvT = traceless_sym(V[..., :, None] * V[..., None, :])
    return (
        jnp.concatenate([s2, vv, tt], axis=-1),        # (n, 3C) scalars
        jnp.concatenate([sV, tV], axis=-2),            # (n, 2C, 3) vectors
        jnp.concatenate([sT, vvT], axis=-3),           # (n, 2C, 3, 3) tensors
    )
