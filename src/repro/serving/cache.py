"""Per-query LRU result cache (DESIGN.md §7.3).

TCCS answers are immutable for a frozen index, so a result cache in front of
the planner is exact, never stale: key = (index key, canonical spec key),
value = the :class:`TCCSResult`. Canonicalization (query_api) means every
window clamped to ``[1, t_max]`` and every empty window share one entry.
Real query streams are heavily skewed (contact tracing re-queries the same
hot cases; the bench workloads draw vertices from a Zipf), which is what
makes an LRU worthwhile before any device work.

When the index registry evicts a (workload, k) pair, the engine's eviction
listener calls :meth:`ResultCache.purge_index` so stale keys for dead
handles stop occupying LRU capacity (they could never be hit *wrongly* —
results are immutable — but they crowd out live entries).

Thread-safe; the engine consults it on the submit path (caller thread) and
fills it from batcher worker threads.
"""

from __future__ import annotations

import threading
from collections import OrderedDict


class ResultCache:
    """LRU map ``key -> frozenset`` with hit/miss accounting.

    ``capacity <= 0`` disables caching (every ``get`` misses, ``put`` drops).
    """

    def __init__(self, capacity: int = 4096):
        self.capacity = capacity
        self._lock = threading.Lock()
        self._data: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.purges = 0

    def get(self, key):
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.hits += 1
                return self._data[key]
            self.misses += 1
            return None

    def put(self, key, value: frozenset) -> None:
        if self.capacity <= 0:
            return
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self._data[key] = value
                return
            self._data[key] = value
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)
                self.evictions += 1

    def purge_index(self, index_key) -> int:
        """Drop every entry whose key belongs to ``index_key`` (an evicted
        (workload, k) pair). Engine cache keys are ``(index_key, spec_key)``
        tuples; foreign-shaped keys are left alone. Returns purge count."""
        with self._lock:
            dead = [k for k in self._data
                    if isinstance(k, tuple) and len(k) == 2
                    and k[0] == index_key]
            for k in dead:
                del self._data[k]
            self.purges += len(dead)
            return len(dead)

    def purge_window(self, index_key, ts_lo: int, ts_hi: int) -> int:
        """Targeted invalidation for a streaming epoch refresh: drop only
        ``index_key`` entries whose canonical window intersects
        ``[ts_lo, ts_hi]`` (the appended timestamp range).

        Every other entry stays — a window with ``te < ts_lo`` contains no
        appended edge, so its cached answer is *still exact* in the new
        epoch (this is what makes suffix epochs cheap on the serving path:
        in the common case the purge count is zero, versus
        :meth:`purge_index` dropping the key's whole working set). Spec
        keys are ``(u, ts, te, k, mode)``; the canonical empty-window
        marker (``ts > te``) never intersects. Returns the purge count."""
        with self._lock:
            dead = []
            for k in self._data:
                if not (isinstance(k, tuple) and len(k) == 2
                        and k[0] == index_key):
                    continue
                spec = k[1]
                if not (isinstance(spec, tuple) and len(spec) >= 3):
                    continue
                ts, te = spec[1], spec[2]
                if ts <= te and te >= ts_lo and ts <= ts_hi:
                    dead.append(k)
            for k in dead:
                del self._data[k]
            self.purges += len(dead)
            return len(dead)

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def stats(self) -> dict:
        with self._lock:
            return {
                "size": len(self._data),
                "capacity": self.capacity,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "purges": self.purges,
            }
