"""Per-query LRU result cache (DESIGN.md §7.3).

TCCS answers are immutable for a frozen index, so a result cache in front of
the planner is exact, never stale: key = (index key, canonical spec key),
value = the :class:`TCCSResult`. Canonicalization (query_api) means every
window clamped to ``[1, t_max]`` and every empty window share one entry.
Real query streams are heavily skewed (contact tracing re-queries the same
hot cases; the bench workloads draw vertices from a Zipf), which is what
makes an LRU worthwhile before any device work.

When the index registry evicts a (workload, k) pair, the engine's eviction
listener calls :meth:`ResultCache.purge_index` so stale keys for dead
handles stop occupying LRU capacity (they could never be hit *wrongly* —
results are immutable — but they crowd out live entries).

Thread-safe; the engine consults it on the submit path (caller thread) and
fills it from batcher worker threads.
"""

from __future__ import annotations

import threading
from collections import OrderedDict


class ResultCache:
    """LRU map ``key -> frozenset`` with hit/miss accounting.

    ``capacity <= 0`` disables caching (every ``get`` misses, ``put`` drops).
    """

    def __init__(self, capacity: int = 4096):
        self.capacity = capacity
        self._lock = threading.Lock()
        self._data: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.purges = 0

    def get(self, key):
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.hits += 1
                return self._data[key]
            self.misses += 1
            return None

    def put(self, key, value: frozenset) -> None:
        if self.capacity <= 0:
            return
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self._data[key] = value
                return
            self._data[key] = value
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)
                self.evictions += 1

    def purge_index(self, index_key) -> int:
        """Drop every entry whose key belongs to ``index_key`` (an evicted
        (workload, k) pair). Engine cache keys are ``(index_key, spec_key)``
        tuples; foreign-shaped keys are left alone. Returns purge count."""
        with self._lock:
            dead = [k for k in self._data
                    if isinstance(k, tuple) and len(k) == 2
                    and k[0] == index_key]
            for k in dead:
                del self._data[k]
            self.purges += len(dead)
            return len(dead)

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def stats(self) -> dict:
        with self._lock:
            return {
                "size": len(self._data),
                "capacity": self.capacity,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "purges": self.purges,
            }
