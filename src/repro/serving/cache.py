"""Per-query LRU result cache (DESIGN.md §7.3).

TCCS answers are immutable for a frozen index, so a result cache in front of
the planner is exact, never stale: key = (index key, canonical spec key),
value = the whole :class:`repro.core.query_api.TCCSResult` (canonical spec,
vertices, mode payload, provenance — cache hits are re-stamped
``route="cache"`` on a copy by the engine). Canonicalization (query_api)
means every window clamped to ``[1, t_max]`` and every empty window share
one entry. Real query streams are heavily skewed (contact tracing
re-queries the same hot cases; the bench workloads draw vertices from a
Zipf), which is what makes an LRU worthwhile before any device work.

When the index registry evicts a workload's stratified index, the engine's
eviction listener calls :meth:`ResultCache.purge_index` so stale keys for
dead handles stop occupying LRU capacity (they could never be hit
*wrongly* — results are immutable — but they crowd out live entries). The
k axis lives inside the canonical spec key, not the index key, so ONE
workload-level purge clears the results of every k stratum at once — and
touches nothing cached for other workloads (regression-tested). Streaming epochs
invalidate through :meth:`purge_window`: suffix appends drop nothing (every
cached canonical window predates the append); retention trims drop exactly
the windows that touch the expired prefix and *rehome* the survivors into
the shifted timeline (DESIGN.md §10.3).

Thread-safe; the engine consults it on the submit path (caller thread) and
fills it from batcher worker threads.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict

from repro.obs.locks import named_lock

#: spec-key mode values whose results embed absolute timestamps / edge ids
#: (EdgeSet.t / edge_id, subgraph timestamps) — never rehomed across a
#: retention shift, always dropped (see purge_window).
_PAYLOAD_MODES = ("edges", "subgraph")


class ResultCache:
    """LRU map ``key -> TCCSResult`` with hit/miss accounting.

    ``capacity <= 0`` disables caching (every ``get`` misses, ``put`` drops).
    """

    def __init__(self, capacity: int = 4096):
        self.capacity = capacity
        self._lock = named_lock("cache")
        self._data: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.purges = 0
        self.rehomes = 0
        self.gated = 0
        # per-index-key epoch floor (retention trims): fills carrying an
        # older epoch are dropped inside the put lock, see raise_floor
        self._floors: dict = {}

    def get(self, key):
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.hits += 1
                return self._data[key]
            self.misses += 1
            return None

    def raise_floor(self, index_key, epoch: int) -> None:
        """Raise the epoch floor for fills under ``index_key`` (retention
        trims, DESIGN.md §10.3): once raised, a :meth:`put` carrying an
        older ``epoch`` is dropped *inside the cache lock* — atomic with
        :meth:`purge_window` — closing the check-then-put race where a
        batch or sweep bound to a pre-trim handle finishes after the
        trim's purge+rehome and would write pre-shift windows into the
        shifted key space. A stale fill that lands *before* the floor is
        raised is safe either way: the subsequent purge/rehome treats it
        like any other resident entry. Floors only ever rise."""
        with self._lock:
            cur = self._floors.get(index_key)
            if cur is None or epoch > cur:
                self._floors[index_key] = epoch

    def put(self, key, value, *, epoch: int | None = None) -> None:
        """Store a :class:`TCCSResult` (or any immutable payload) under
        ``key``, evicting LRU entries past ``capacity`` — every capacity
        eviction increments ``evictions`` (regression-pinned: ``stats()``
        must not under-report). ``epoch`` (the handle's epoch, passed by
        the planner and the engine's sweeps) is checked against the
        index key's retention floor; below-floor fills are dropped and
        counted as ``gated``."""
        if self.capacity <= 0:
            return
        with self._lock:
            if (epoch is not None and isinstance(key, tuple)
                    and len(key) == 2):
                floor = self._floors.get(key[0])
                if floor is not None and epoch < floor:
                    self.gated += 1
                    return
            if key in self._data:
                self._data.move_to_end(key)
                self._data[key] = value
                return
            self._data[key] = value
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)
                self.evictions += 1

    def purge_index(self, index_key) -> int:
        """Drop every entry whose key belongs to ``index_key`` (an evicted
        workload). Engine cache keys are ``(index_key, spec_key)`` tuples
        with k inside the spec key, so one call clears every k stratum's
        results; foreign-shaped keys are left alone. Returns purge count."""
        with self._lock:
            dead = [k for k in self._data
                    if isinstance(k, tuple) and len(k) == 2
                    and k[0] == index_key]
            for k in dead:
                del self._data[k]
            self.purges += len(dead)
            return len(dead)

    def purge_window(self, index_key, ts_lo: int, ts_hi: int,
                     shift: int = 0) -> int:
        """Targeted invalidation for a streaming epoch swap: drop only
        ``index_key`` entries whose canonical window intersects
        ``[ts_lo, ts_hi]``.

        *Suffix append* (``shift == 0``, range = the appended timestamps):
        every other entry stays — a window with ``te < ts_lo`` contains no
        appended edge, so its cached answer is *still exact* in the new
        epoch (this is what makes suffix epochs cheap on the serving path:
        in the common case the purge count is zero, versus
        :meth:`purge_index` dropping the key's whole working set).

        *Prefix expiry* (``shift = t_cut - 1 > 0``, range = the expired
        prefix ``[1, t_cut - 1]``): windows touching the expired prefix are
        dropped — exactly those, nothing more — but the survivors cannot
        simply stay: the retained epoch's timeline is *shifted*, so an
        untouched key ``(u, ts, te, ...)`` would collide with a different
        window of the new epoch. Surviving VERTICES/COUNT entries are
        therefore **rehomed**: re-keyed to ``(u, ts - shift, te - shift,
        ...)`` with the stored result's canonical spec shifted to match
        (exact — the surviving window projects the identical subgraph, and
        a vertex set carries no timestamps). EDGES/SUBGRAPH entries embed
        absolute timestamps and edge ids in their payloads, so they are
        dropped rather than rewritten. LRU order is preserved.

        Spec keys are ``(u, ts, te, k, mode)``; the canonical empty-window
        marker (``ts > te``) never intersects and is rehomed as-is (it is
        coordinate-free). Returns the purge count (``rehomes`` counts the
        re-keyed survivors in :meth:`stats`)."""
        with self._lock:
            if not shift:
                # suffix-append path (§9.3): delete-in-place only — the
                # common case purges nothing, and must not pay a full
                # OrderedDict rebuild per refresh on a warm cache
                dead = [k for k in self._data
                        if isinstance(k, tuple) and len(k) == 2
                        and k[0] == index_key
                        and isinstance(k[1], tuple) and len(k[1]) >= 3
                        and k[1][1] <= k[1][2]
                        and k[1][2] >= ts_lo and k[1][1] <= ts_hi]
                for k in dead:
                    del self._data[k]
                self.purges += len(dead)
                return len(dead)
            n_dead = n_rehomed = 0
            rebuilt: OrderedDict = OrderedDict()
            for k, v in self._data.items():
                if not (isinstance(k, tuple) and len(k) == 2
                        and k[0] == index_key
                        and isinstance(k[1], tuple) and len(k[1]) >= 3):
                    rebuilt[k] = v              # foreign key: untouched
                    continue
                spec = k[1]
                ts, te = spec[1], spec[2]
                if ts <= te and te >= ts_lo and ts <= ts_hi:
                    n_dead += 1                 # window touches the range
                    continue
                if shift and ts <= te:
                    if len(spec) >= 5 and spec[4] in _PAYLOAD_MODES:
                        n_dead += 1             # payload embeds timestamps
                        continue
                    new_spec = (spec[0], ts - shift, te - shift) + spec[3:]
                    q = getattr(v, "query", None)
                    if q is not None:
                        v = dataclasses.replace(
                            v, query=dataclasses.replace(
                                q, ts=ts - shift, te=te - shift))
                    rebuilt[(k[0], new_spec)] = v
                    n_rehomed += 1
                    continue
                rebuilt[k] = v                  # empty-window marker / no shift
            self._data = rebuilt
            self.purges += n_dead
            self.rehomes += n_rehomed
            return n_dead

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def stats(self) -> dict:
        with self._lock:
            return {
                "size": len(self._data),
                "capacity": self.capacity,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "purges": self.purges,
                "rehomes": self.rehomes,
                "gated": self.gated,
            }
