"""Per-query LRU result cache (DESIGN.md §7.3).

TCCS answers are immutable for a frozen index, so a result cache in front of
the planner is exact, never stale: key = (index key, u, ts, te), value = the
frozen vertex set. Real query streams are heavily skewed (contact tracing
re-queries the same hot cases; the bench workloads draw vertices from a
Zipf), which is what makes an LRU worthwhile before any device work.

Thread-safe; the engine consults it on the submit path (caller thread) and
fills it from batcher worker threads.
"""

from __future__ import annotations

import threading
from collections import OrderedDict


class ResultCache:
    """LRU map ``key -> frozenset`` with hit/miss accounting.

    ``capacity <= 0`` disables caching (every ``get`` misses, ``put`` drops).
    """

    def __init__(self, capacity: int = 4096):
        self.capacity = capacity
        self._lock = threading.Lock()
        self._data: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key):
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.hits += 1
                return self._data[key]
            self.misses += 1
            return None

    def put(self, key, value: frozenset) -> None:
        if self.capacity <= 0:
            return
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self._data[key] = value
                return
            self._data[key] = value
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)
                self.evictions += 1

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def stats(self) -> dict:
        with self._lock:
            return {
                "size": len(self._data),
                "capacity": self.capacity,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
            }
