"""TCCS serving engine (DESIGN.md §7): shape-bucketed micro-batching,
host/device query planning, per-query LRU result caching, a memoizing
(workload, k) index registry, and batch-dim-sharded device execution.

Quick start::

    from repro.serving import EngineConfig, ServingEngine

    with ServingEngine(EngineConfig(max_batch=256, flush_ms=2.0)) as eng:
        fut = eng.submit("cm_like", k=3, u=17, ts=4, te=90)
        print(sorted(fut.result()))      # == PECBIndex.query(17, 4, 90)
"""

from .batcher import MicroBatcher, Request
from .cache import ResultCache
from .engine import EngineConfig, ServingEngine
from .executor import PAD_QUERY, ShardedExecutor, bucket_size, pad_queries
from .metrics import EngineMetrics, LatencyHistogram
from .planner import QueryPlanner
from .registry import IndexHandle, IndexRegistry

__all__ = [
    "EngineConfig", "ServingEngine",
    "MicroBatcher", "Request",
    "QueryPlanner", "ShardedExecutor", "bucket_size", "pad_queries",
    "PAD_QUERY", "ResultCache", "IndexHandle", "IndexRegistry",
    "EngineMetrics", "LatencyHistogram",
]
