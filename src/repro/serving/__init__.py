"""TCCS serving engine (DESIGN.md §7, §8): shape-bucketed micro-batching,
host/device query planning, per-query LRU result caching, a memoizing
per-workload registry of k-stratified indexes (one build serves every k),
and batch-dim-sharded device execution, all behind the typed Query API v2
surface.

Quick start::

    from repro.core import ResultMode, TCCSQuery, WindowSweep
    from repro.serving import EngineConfig, ServingEngine

    with ServingEngine(EngineConfig(max_batch=256, flush_ms=2.0)) as eng:
        res = eng.answer("cm_like", TCCSQuery(u=17, ts=4, te=90, k=3))
        print(sorted(res.vertices), res.provenance.route)
        cohort = eng.answer("cm_like", TCCSQuery(17, 4, 90, 3,
                                                 ResultMode.SUBGRAPH))
        print(cohort.subgraph.m, "member edges")
        traj = eng.sweep("cm_like", WindowSweep(u=17, k=3,
                                                windows=[(d, d + 6)
                                                         for d in range(1, 80)]))

Streaming graphs ingest through the same engine (DESIGN.md §9)::

        eng.ingest("cm_like", [(u, v, t), ...])   # suffix edges, t > t_max

refreshing resident indexes incrementally in the background while queries
keep resolving against the old epoch until the atomic handle swap. The
retention plane (DESIGN.md §10) bounds a long-running deployment's
memory::

        eng.set_retention("cm_like", RetentionPolicy(window=90, slack=7))

auto-trimming the expired prefix on ingest (or explicitly via
``eng.retain(name, t_cut)``): resident indexes *shrink* to the retained
window — bit-identical to a cold build of the trimmed edge list — and
cached answers for surviving windows are rehomed into the shifted
timeline.

The positional ``submit``/``submit_many``/``query`` signatures remain as
shims resolving with the vertex frozenset; each now emits
``DeprecationWarning`` at the call site.
"""

from repro.core.query_api import (EdgeSet, InvalidQueryError, Provenance,
                                  ResultMode, TCCSBackend, TCCSQuery,
                                  TCCSResult, WindowSweep)

from .batcher import MicroBatcher, Request
from .cache import ResultCache
from .engine import EngineConfig, RetentionPolicy, ServingEngine
from .executor import PAD_QUERY, ShardedExecutor, bucket_size, pad_queries
from .metrics import EngineMetrics, LatencyHistogram
from .planner import QueryPlanner
from .registry import IndexHandle, IndexRegistry

__all__ = [
    "EngineConfig", "RetentionPolicy", "ServingEngine",
    "MicroBatcher", "Request",
    "QueryPlanner", "ShardedExecutor", "bucket_size", "pad_queries",
    "PAD_QUERY", "ResultCache", "IndexHandle", "IndexRegistry",
    "EngineMetrics", "LatencyHistogram",
    # query API v2 (re-exported from repro.core.query_api)
    "TCCSQuery", "TCCSResult", "ResultMode", "WindowSweep",
    "InvalidQueryError", "Provenance", "EdgeSet", "TCCSBackend",
]
