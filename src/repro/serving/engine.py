"""TCCS serving engine: the user-facing facade (DESIGN.md §7).

Wires the subsystem together::

    submit(workload, k, u, ts, te)
        -> registry.get_nowait(workload, k)   (memoized handle, or kick off
                                               the background build; a cold
                                               key never blocks the caller)
        -> result cache probe                 (hit: resolve immediately)
        -> per-handle micro-batcher           (shape-bucketed batching;
                                               cold keys enqueue when the
                                               build future resolves)
        -> planner                            (host Alg 1 | sharded device)
        -> future resolves with frozenset of component vertices

Results are always identical to ``PECBIndex.query`` (Algorithm 1) — the
engine only changes *where and when* the answer is computed, never *what*;
tests assert exact equality across every route.

Thread-safety: ``submit`` may be called from any number of caller threads;
each index handle owns one batcher worker thread; the registry serializes
builds per key. ``close()`` (or the context manager) drains and stops all
workers.
"""

from __future__ import annotations

import dataclasses
import time
from concurrent.futures import Future
from threading import Lock
from typing import Iterable, Sequence

from .batcher import MicroBatcher, Request
from .cache import ResultCache
from .executor import ShardedExecutor
from .metrics import EngineMetrics
from .planner import QueryPlanner
from .registry import IndexHandle, IndexRegistry


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 256         # micro-batch flush size == largest bucket
    flush_ms: float = 2.0        # max time a request waits for batchmates
    min_bucket: int = 8          # smallest padded batch shape
    host_threshold: int = 8      # batches below this run host Algorithm 1
    cache_capacity: int = 4096   # LRU result-cache entries (<=0 disables)
    registry_capacity: int = 8   # resident (workload, k) index pairs


class ServingEngine:
    def __init__(self, config: EngineConfig | None = None, *,
                 registry: IndexRegistry | None = None, devices=None):
        self.config = config or EngineConfig()
        cfg = self.config
        if not 1 <= cfg.min_bucket <= cfg.max_batch:
            raise ValueError(
                f"need 1 <= min_bucket <= max_batch, got min_bucket="
                f"{cfg.min_bucket} max_batch={cfg.max_batch}")
        self.metrics = EngineMetrics()
        self.cache = ResultCache(cfg.cache_capacity)
        self._owns_registry = registry is None
        self.registry = registry if registry is not None else IndexRegistry(
            cfg.registry_capacity, metrics=self.metrics)
        self.executor = ShardedExecutor(devices)
        self.planner = QueryPlanner(
            self.executor, self.cache, self.metrics,
            host_threshold=cfg.host_threshold, min_bucket=cfg.min_bucket,
            max_batch=cfg.max_batch)
        # key -> (handle the batcher's execute_fn is bound to, batcher)
        self._batchers: dict[tuple[str, int], tuple[IndexHandle, MicroBatcher]] = {}
        self._lock = Lock()
        self._closed = False
        self.registry.add_evict_listener(self._on_index_evicted)

    # -- graph/index management -----------------------------------------
    def register_graph(self, name: str, g) -> None:
        self.registry.register_graph(name, g)

    def warmup(self, workload: str, k: int) -> IndexHandle:
        """Build the (workload, k) index and pre-compile every bucket shape,
        so no live request pays a build or an XLA compile."""
        handle = self.registry.get(workload, k)
        if handle.pecb.num_nodes == 0:
            return handle  # host-only route, nothing to compile
        cfg = self.config
        b = cfg.min_bucket
        while True:
            bucket = self.executor.final_bucket(
                min(b, cfg.max_batch), cfg.min_bucket, cfg.max_batch)
            self.executor.run(handle.device, [0], [1], [0], bucket)
            if b >= cfg.max_batch:
                break
            b *= 2
        return handle

    def prefetch(self, workload: str, k: int) -> Future:
        """Kick off (or join) the background index build; never blocks."""
        return self.registry.get_async(workload, k)

    # -- query paths -----------------------------------------------------
    def submit(self, workload: str, k: int, u: int, ts: int, te: int) -> Future:
        return self.submit_many(workload, k, [(u, ts, te)])[0]

    def submit_many(self, workload: str, k: int,
                    queries: Iterable[Sequence[int]]) -> list[Future]:
        """One future per (u, ts, te), in input order. Cache hits resolve
        before this returns; misses resolve when their batch flushes. A cold
        (workload, k) never blocks the caller: the index builds on the
        registry's background pool and the misses are enqueued when the
        handle future resolves."""
        if self._closed:
            raise RuntimeError("engine is closed")
        key = (workload, int(k))
        # probe only: don't schedule a build until a cache miss proves one
        # is needed (a fully-cached stream must not rebuild an evicted index)
        handle = self.registry.get_nowait(workload, k, start_build=False)
        t0 = time.perf_counter()
        futures: list[Future] = []
        misses: list[Request] = []
        for (u, ts, te) in queries:
            u, ts, te = int(u), int(ts), int(te)
            fut: Future = Future()
            futures.append(fut)
            self.metrics.count("queries")
            hit = self.cache.get((key, u, ts, te))
            if hit is not None:
                self.metrics.count("cache_hits")
                fut.set_result(hit)
                self.metrics.observe("e2e", time.perf_counter() - t0)
            else:
                self.metrics.count("cache_misses")
                misses.append(Request(u, ts, te, fut, t_submit=t0))
        if misses:
            if handle is not None:
                self._batcher_for(handle).submit_many(misses)
            else:
                self.metrics.count("cold_submits")
                self._submit_when_built(workload, k, misses)
        return futures

    def _submit_when_built(self, workload: str, k: int,
                           misses: list[Request]) -> None:
        """Chain a batch of misses onto the pending index build."""
        def on_built(handle_fut: Future) -> None:
            try:
                handle = handle_fut.result()
                self._batcher_for(handle).submit_many(misses)
            except BaseException as exc:  # build failed or engine closed
                for req in misses:
                    if not req.future.done():
                        req.future.set_exception(exc)
        self.registry.get_async(workload, k).add_done_callback(on_built)

    def query(self, workload: str, k: int, u: int, ts: int, te: int,
              timeout: float | None = 60.0) -> frozenset:
        """Synchronous convenience wrapper (one-request batch)."""
        return self.submit(workload, k, u, ts, te).result(timeout=timeout)

    # -- lifecycle -------------------------------------------------------
    def _batcher_for(self, handle: IndexHandle) -> MicroBatcher:
        """Batcher bound to exactly this handle. If the registry evicted and
        rebuilt the key, the old batcher (bound to the dead handle) is
        closed and replaced, so closures never pin evicted indexes."""
        stale = None
        with self._lock:
            if self._closed:          # close() may have raced past submit's check
                raise RuntimeError("engine is closed")
            entry = self._batchers.get(handle.key)
            if entry is not None and entry[0] is handle:
                return entry[1]
            if entry is not None:
                stale = entry[1]
            cfg = self.config
            b = MicroBatcher(
                self.planner.bind(handle),
                max_batch=cfg.max_batch, flush_ms=cfg.flush_ms,
                name=f"batcher-{handle.key[0]}-k{handle.key[1]}",
                metrics=self.metrics)
            self._batchers[handle.key] = (handle, b)
        if stale is not None:
            stale.close()
        return b

    def _on_index_evicted(self, key: tuple[str, int],
                          handle: IndexHandle) -> None:
        """Registry eviction hook: retire the batcher (and its worker
        thread) bound to the evicted handle."""
        with self._lock:
            entry = self._batchers.get(key)
            if entry is None or entry[0] is not handle:
                return
            del self._batchers[key]
        entry[1].close()

    def flush(self) -> None:
        with self._lock:
            batchers = [b for (_, b) in self._batchers.values()]
        for b in batchers:
            b.flush()

    def drain(self, timeout: float | None = 60.0) -> None:
        with self._lock:
            batchers = [b for (_, b) in self._batchers.values()]
        for b in batchers:
            b.drain(timeout=timeout)

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            batchers = [b for (_, b) in self._batchers.values()]
        self.registry.remove_evict_listener(self._on_index_evicted)
        for b in batchers:
            b.close()
        if self._owns_registry:
            self.registry.close(wait=True)

    def __enter__(self) -> "ServingEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- observability ---------------------------------------------------
    def stats(self) -> dict:
        return {
            "engine": self.metrics.snapshot(),
            "cache": self.cache.stats(),
            "registry": self.registry.stats(),
            "devices": self.executor.num_devices,
            "compiled_programs": self.executor.compile_count(),
        }

    def format_stats(self) -> str:
        s = self.stats()
        lines = [self.metrics.format()]
        lines.append(f"  cache                    {s['cache']}")
        lines.append(f"  registry                 resident={s['registry']['resident']} "
                     f"builds={s['registry']['builds']} evictions={s['registry']['evictions']}")
        lines.append(f"  devices={s['devices']} compiled_programs={s['compiled_programs']}")
        return "\n".join(lines)
