"""TCCS serving engine: the user-facing facade (DESIGN.md §7, §8).

Wires the subsystem together::

    submit_spec(workload, TCCSQuery(u, ts, te, k, mode))
        -> validate + canonicalize            (InvalidQueryError at the
                                               boundary; clamped windows
                                               share one cache key; empty
                                               windows resolve instantly)
        -> registry.get_nowait(workload)      (memoized k-stratified handle
                                               serving EVERY supported k, or
                                               kick off the background
                                               build; a cold workload never
                                               blocks the caller)
        -> result cache probe                 (hit: resolve immediately,
                                               re-stamped route="cache")
        -> per-handle micro-batcher           (shape-bucketed batching;
                                               cold workloads enqueue when
                                               the build future resolves)
        -> planner                            (host typed answer | sharded
                                               device; per-query k rides as
                                               a device operand — a mixed-k
                                               batch is ONE launch of ONE
                                               compiled program)
        -> future resolves with a TCCSResult

The index plane is k-agnostic (DESIGN.md §14): one workload maps to one
:class:`StratifiedPECB` handle whose strata cover ``handle.supported_ks``,
so a batch mixing k=2 and k=5 queries shares a handle, a batcher, a
device mirror and a compiled program. Queries for a k above the graph's
k-max are answered exactly empty host-side; an in-range k outside the
registry's strata policy raises :class:`InvalidQueryError` onto the
query's future.

``sweep(workload, WindowSweep(u, k, windows))`` answers one vertex over
many sliding windows in a single device launch (the contact-tracing
trajectory query); cache-hot windows are skipped, misses share one
``window_sweep`` program run against the k stratum's own device block
(``IndexHandle.stratum_device``) so a single-k sweep never pays
propagation over the other |K|-1 strata.

``ingest(workload, edges)`` is the streaming entry point (DESIGN.md §9):
suffix edges extend the graph epoch, resident indexes refresh
incrementally in the background (bit-identical to a cold rebuild), and
queries keep being answered — against the *old* epoch's handle, with its
own window canonicalization — until the refreshed handle is atomically
swapped in. Result-cache invalidation is *targeted*
(``ResultCache.purge_window``): only entries whose window intersects the
appended timestamp range are dropped, which for suffix appends is none.

``retain(workload, t_cut)`` / ``set_retention(workload, RetentionPolicy)``
are the bounded-memory leg (DESIGN.md §10): prefix expiry shrinks resident
indexes to the retained window in the background (auto-trimmed on ingest
under a policy), cached windows touching the expired prefix are purged and
the survivors rehomed into the shifted timeline, and cache fills from
pre-trim handles are gated by a per-key epoch floor so the shifted key
space never aliases stale coordinates.

Results are always identical to ``PECBIndex.answer`` (Algorithm 1 plus the
version-store edge derivation) — the engine only changes *where and when*
the answer is computed, never *what*; tests assert exact equality across
every route. The positional ``submit``/``submit_many``/``query`` signatures
remain as thin shims whose futures resolve with the component vertex
frozenset, exactly as before v2; each emits ``DeprecationWarning`` at the
call site.

Thread-safety: ``submit*`` may be called from any number of caller threads;
each index handle owns one batcher worker thread; the registry serializes
builds per key and refreshes on one FIFO worker. ``close()`` (or the
context manager) drains and stops all workers.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from concurrent.futures import Future
from typing import Iterable, Sequence

from repro.core.query_api import (InvalidQueryError, Provenance, TCCSQuery,
                                  TCCSResult, WindowSweep, empty_result)
from repro.obs.export import write_chrome_trace
from repro.obs.locks import named_lock
from repro.obs.trace import SlowQueryLog, Tracer

from .batcher import MicroBatcher, Request
from .cache import ResultCache
from .executor import ShardedExecutor
from .metrics import EngineMetrics
from .planner import QueryPlanner, assemble_device_results
from .registry import IndexHandle, IndexRegistry


def _vertices_future(inner: Future) -> Future:
    """Legacy-shim adapter: a future resolving with ``result.vertices``."""
    outer: Future = Future()

    def _done(f: Future) -> None:
        exc = f.exception()
        if exc is not None:
            outer.set_exception(exc)
        else:
            outer.set_result(f.result().vertices)

    inner.add_done_callback(_done)
    return outer


@dataclasses.dataclass(frozen=True)
class RetentionPolicy:
    """Sliding-window retention for one workload (DESIGN.md §10.4).

    ``window`` is the number of trailing timestamps to keep. ``slack`` is
    trim hysteresis: the auto-trim fires only once ``t_max`` exceeds
    ``window + slack``, then cuts back to exactly ``window`` — every trim
    is a full (cheap, but not free) shrink refresh plus a cache rehome, so
    slack amortizes one trim over several ingests instead of shaving one
    timestamp per day. ``every`` evaluates the policy only on every N-th
    ingest of the workload (a second, coarser period knob)."""

    window: int
    slack: int = 0
    every: int = 1

    def __post_init__(self):
        if self.window < 1:
            raise ValueError(f"retention window must be >= 1, got {self.window}")
        if self.slack < 0:
            raise ValueError(f"retention slack must be >= 0, got {self.slack}")
        if self.every < 1:
            raise ValueError(f"retention every must be >= 1, got {self.every}")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 256         # micro-batch flush size == largest bucket
    flush_ms: float = 2.0        # max time a request waits for batchmates
    min_bucket: int = 8          # smallest padded batch shape
    host_threshold: int = 8      # batches below this run host Algorithm 1
    cache_capacity: int = 4096   # LRU result-cache entries (<=0 disables)
    registry_capacity: int = 8   # resident workload indexes (all-k each)
    trace: bool = True           # record query-lifecycle spans (§11)
    trace_buffer: int = 16384    # finished-span ring capacity
    slow_query_ms: float | None = None  # slow-query log threshold (off=None)
    store_dir: str | None = None  # persistent index store root (§13; off=None)


class ServingEngine:
    def __init__(self, config: EngineConfig | None = None, *,
                 registry: IndexRegistry | None = None, devices=None):
        self.config = config or EngineConfig()
        cfg = self.config
        if not 1 <= cfg.min_bucket <= cfg.max_batch:
            raise ValueError(
                f"need 1 <= min_bucket <= max_batch, got min_bucket="
                f"{cfg.min_bucket} max_batch={cfg.max_batch}")
        self.metrics = EngineMetrics()
        # one tracer per engine (DESIGN.md §11.1): queries, background
        # builds/refreshes and compile events all record into this ring
        self.tracer = Tracer(cfg.trace_buffer, enabled=cfg.trace)
        self.slow_queries = SlowQueryLog(cfg.slow_query_ms,
                                         tracer=self.tracer)
        self.cache = ResultCache(cfg.cache_capacity)
        self._owns_registry = registry is None
        # persistent index store (DESIGN.md §13): only wired when this
        # engine owns its registry — a shared registry's store is its
        # owner's call (and its handles may already be backed elsewhere)
        self.store = None
        if self._owns_registry and cfg.store_dir is not None:
            from repro.store import IndexStore
            self.store = IndexStore(cfg.store_dir, metrics=self.metrics,
                                    tracer=self.tracer)
        self.registry = registry if registry is not None else IndexRegistry(
            cfg.registry_capacity, metrics=self.metrics,
            tracer=self.tracer, store=self.store)
        self.executor = ShardedExecutor(devices, metrics=self.metrics,
                                        tracer=self.tracer)
        self.planner = QueryPlanner(
            self.executor, self.cache, self.metrics,
            host_threshold=cfg.host_threshold, min_bucket=cfg.min_bucket,
            max_batch=cfg.max_batch)
        # workload -> (handle the batcher's execute_fn is bound to, batcher)
        self._batchers: dict[str, tuple[IndexHandle, MicroBatcher]] = {}
        self._lock = named_lock("engine")
        self._closed = False
        # retention state: per-workload policy + ingest tick. The epoch
        # floor gating cache fills (a handle older than the last retention
        # trim must not fill the cache: its canonical windows are in the
        # pre-shift timeline and would collide with the shifted epoch's
        # keys — unlike suffix epochs, where stale writes stay exact and
        # are welcome) lives in the cache itself (ResultCache.raise_floor)
        # so the drop is atomic with put/purge under the cache lock.
        self._retention: dict[str, RetentionPolicy] = {}
        self._ingest_ticks: dict[str, int] = {}
        self.registry.add_evict_listener(self._on_index_evicted)
        self.registry.add_refresh_listener(self._on_index_refreshed)
        self.registry.add_retention_listener(self._on_index_retained)
        # unified metrics surface (DESIGN.md §11.4): one snapshot covers
        # the engine's counters/latency plus the cache and registry stat
        # planes, exportable as JSON via repro.obs.export.metrics_to_json
        self.metrics.register_source("cache", self.cache.stats)
        self.metrics.register_source("registry", self.registry.stats)
        if self.store is not None:
            self.metrics.register_source("store", self.store.stats)

    # -- graph/index management -----------------------------------------
    def register_graph(self, name: str, g) -> None:
        self.registry.register_graph(name, g)

    def warmup(self, workload: str, k: int | None = None, *,
               sweep: bool = False, full: bool = False,
               sweep_ks=None) -> IndexHandle:
        """Build the workload's k-stratified index and pre-compile every
        bucket shape of the vertex-mask program, so no live request pays a
        build or an XLA compile — for *any* k the handle supports (the
        programs take k as a device operand, so one warmup covers every k
        mix). ``sweep=True`` / ``full=True`` additionally warm the
        window-sweep / mixed-k full-mode (EDGES) programs for callers that
        will use those paths; the sweep program runs against per-stratum
        mirrors, so with ``sweep=True`` pass ``sweep_ks`` to bound the
        warm to the ks you will actually sweep (default: every supported
        k — |K| compiles per bucket). The ``k`` argument is deprecated
        and ignored."""
        if k is not None:
            warnings.warn(
                "ServingEngine.warmup(workload, k) is deprecated: one "
                "stratified index serves every k — warmup(workload) warms "
                "all of them", DeprecationWarning, stacklevel=2)
        handle = self.registry.get(workload)
        if handle.pecb.num_nodes == 0:
            return handle  # host-only route, nothing to compile
        cfg = self.config
        b = cfg.min_bucket
        while True:
            bucket = self.executor.final_bucket(
                min(b, cfg.max_batch), cfg.min_bucket, cfg.max_batch)
            self.executor.run(handle.device, [0], [1], [0], bucket)
            if sweep:
                for sk in (handle.supported_ks if sweep_ks is None
                           else sweep_ks):
                    self.executor.run_sweep(handle.stratum_device(sk), 0,
                                            [1], [0], bucket)
            if full:
                self.executor.run_full_mixed(handle.device, [0], [1], [0],
                                             [0], bucket)
            if b >= cfg.max_batch:
                break
            b *= 2
        return handle

    def prefetch(self, workload: str, k: int | None = None) -> Future:
        """Kick off (or join) the background index build; never blocks.
        The ``k`` argument is deprecated and ignored (the build covers
        every supported k)."""
        if k is not None:
            warnings.warn(
                "ServingEngine.prefetch(workload, k) is deprecated: one "
                "stratified build serves every k — prefetch(workload)",
                DeprecationWarning, stacklevel=2)
        return self.registry.get_async(workload)

    # -- streaming ingest -------------------------------------------------
    def ingest(self, workload: str, edges,
               wait: bool = False, timeout: float | None = 120.0) -> dict:
        """Append suffix ``edges`` to ``workload``'s graph and refresh its
        resident stratified index incrementally in the background.

        Non-blocking by default: returns ``{workload: Future}`` for the
        resident index being refreshed (empty when none is resident
        — the next cold build simply sees the new epoch). Queries keep
        resolving throughout a refresh, pinned to the old epoch's handle;
        the swap is atomic and the refresh listener retires the old
        batcher and runs the targeted cache purge. ``wait=True`` blocks
        until every refresh has landed."""
        if self._closed:
            raise RuntimeError("engine is closed")
        self.metrics.count("ingests")
        # the ingest span parents every background index_refresh (and any
        # auto-trim's index_retention) scheduled here: the span *context*
        # crosses into the FIFO worker explicitly (DESIGN.md §11.2)
        span = self.tracer.start_span("ingest", parent=None, cat="epoch",
                                      workload=workload)
        try:
            futures = self.registry.extend_graph(workload, edges,
                                                 parent=span.ctx)
            trims = self._auto_trim(workload, parent=span.ctx)
            # a trim future supersedes the same key's refresh future: the
            # FIFO refresh worker runs the suffix refresh first, so the trim
            # future resolving implies both steps landed
            futures = {**futures, **trims}
            span.set("refreshes", len(futures))
            if wait:
                for f in futures.values():
                    f.result(timeout=timeout)
            return futures
        except BaseException as exc:
            span.set("error", repr(exc))
            raise
        finally:
            span.end()

    # -- sliding-window retention -----------------------------------------
    def set_retention(self, workload: str,
                      policy: RetentionPolicy | int | None) -> dict:
        """Install (or, with ``None``, remove) a sliding-window
        :class:`RetentionPolicy` for ``workload``; a bare int is shorthand
        for ``RetentionPolicy(window=policy)``. Every subsequent
        :meth:`ingest` of the workload re-evaluates the policy (subject to
        ``policy.every``) and auto-trims the expired prefix in the
        background — and the policy is evaluated once right here, so a
        workload already over its window starts trimming immediately;
        the returned ``{workload: Future}`` dict (usually empty) lets
        callers wait for that first trim to land."""
        if isinstance(policy, int):
            policy = RetentionPolicy(window=policy)
        with self._lock:
            if policy is None:
                self._retention.pop(workload, None)
                return {}
            self._retention[workload] = policy
        return self._auto_trim(workload, tick=False)

    def retention_policy(self, workload: str) -> RetentionPolicy | None:
        with self._lock:
            return self._retention.get(workload)

    def retain(self, workload: str, t_cut: int, wait: bool = False,
               timeout: float | None = 120.0) -> dict:
        """Manually expire the prefix below ``t_cut`` (see
        :meth:`IndexRegistry.retain`): the resident index shrinks in the
        background, queries keep resolving against the old epoch until the
        atomic swap, expired cache windows are purged and surviving ones
        rehomed into the shifted timeline. Returns ``{workload: Future}``
        like :meth:`ingest`."""
        if self._closed:
            raise RuntimeError("engine is closed")
        self.metrics.count("retentions")
        span = self.tracer.start_span("retain", parent=None, cat="epoch",
                                      workload=workload, t_cut=int(t_cut))
        try:
            futures = self._begin_trim(workload, t_cut, parent=span.ctx)
            span.set("trims", len(futures))
            if wait:
                for f in futures.values():
                    f.result(timeout=timeout)
            return futures
        except BaseException as exc:
            span.set("error", repr(exc))
            raise
        finally:
            span.end()

    def _begin_trim(self, workload: str, t_cut: int, parent=None) -> dict:
        """Schedule a registry trim and raise the cache floor for every
        affected key *at initiation* (to the epoch the trim just bumped
        to), not only at swap time: if the trim never swaps — the key is
        evicted mid-queue, or a racing cold build catches up first — the
        retention listener never fires, yet pre-trim handles must still
        be barred from filling the cache with pre-shift windows."""
        futures = self.registry.retain(workload, t_cut, parent=parent)
        if futures:
            epoch = self.registry.stats()["epochs"].get(workload, 0)
            for key in futures:
                self.cache.raise_floor(key, epoch)
        return futures

    def _auto_trim(self, workload: str, tick: bool = True,
                   parent=None) -> dict:
        """Evaluate the workload's retention policy; trim when ``t_max``
        overflows ``window + slack`` (cutting back to exactly ``window``)."""
        with self._lock:
            pol = self._retention.get(workload)
            if pol is None:
                return {}
            if tick:
                self._ingest_ticks[workload] = n = \
                    self._ingest_ticks.get(workload, 0) + 1
                if n % pol.every:
                    return {}
        try:
            g = self.registry.resolve_graph(workload)
        except KeyError:
            return {}
        if g.t_max <= pol.window + pol.slack:
            return {}
        self.metrics.count("auto_trims")
        return self._begin_trim(workload, g.t_max - pol.window + 1,
                                parent=parent)

    # -- query paths: v2 typed surface -----------------------------------
    def submit_spec(self, workload: str, spec: TCCSQuery) -> Future:
        """Future resolving with a :class:`TCCSResult`. Malformed specs
        (``ts > te``, out-of-range ``u``, ``k < 2``) raise
        :class:`InvalidQueryError` here, at the boundary."""
        return self.submit_specs(workload, [spec])[0]

    def submit_specs(self, workload: str,
                     specs: Iterable[TCCSQuery]) -> list[Future]:
        """One TCCSResult future per spec, in input order; specs may mix k
        values *and* result modes freely — every k shares the workload's
        one stratified index, one batcher and one compiled program (k is a
        device operand), so a mixed-k batch is still a single launch. A
        batch launches the full-mode program iff any of its members wants
        EDGES/SUBGRAPH."""
        return self._submit_specs(workload, list(specs), lenient=False)

    def answer(self, workload: str, spec: TCCSQuery,
               timeout: float | None = 60.0) -> TCCSResult:
        """Synchronous v2 convenience wrapper."""
        return self.submit_spec(workload, spec).result(timeout=timeout)

    # -- query paths: legacy positional shims ----------------------------
    def submit(self, workload: str, k: int, u: int, ts: int, te: int) -> Future:
        """Deprecated shim over :meth:`submit_spec`; resolves with the
        vertex frozenset and keeps the lenient pre-v2 semantics (malformed
        windows answer the empty set instead of raising). Emits
        :class:`DeprecationWarning`."""
        warnings.warn(
            "ServingEngine.submit(workload, k, u, ts, te) is deprecated; "
            "use submit_spec(workload, TCCSQuery(u, ts, te, k))",
            DeprecationWarning, stacklevel=2)
        return self._submit_legacy(workload, k, [(u, ts, te)])[0]

    def submit_many(self, workload: str, k: int,
                    queries: Iterable[Sequence[int]]) -> list[Future]:
        """Deprecated shim: one vertex-frozenset future per (u, ts, te), in
        input order, lenient validation. Cache hits resolve before this
        returns; misses resolve when their batch flushes. Emits
        :class:`DeprecationWarning`."""
        warnings.warn(
            "ServingEngine.submit_many(workload, k, queries) is deprecated; "
            "use submit_specs(workload, [TCCSQuery(...), ...])",
            DeprecationWarning, stacklevel=2)
        return self._submit_legacy(workload, k, queries)

    def _submit_legacy(self, workload: str, k: int,
                       queries: Iterable[Sequence[int]]) -> list[Future]:
        specs = [TCCSQuery(int(u), int(ts), int(te), int(k))
                 for (u, ts, te) in queries]
        inner = self._submit_specs(workload, specs, lenient=True)
        return [_vertices_future(f) for f in inner]

    # -- the shared submit core ------------------------------------------
    def _submit_specs(self, workload: str, specs: list[TCCSQuery],
                      *, lenient: bool) -> list[Future]:
        """Validate/canonicalize, short-circuit trivial queries and cache
        hits, batch the misses (all ks together — one handle serves them).
        A cold workload never blocks the caller: the index builds on the
        registry's background pool and the misses are enqueued when the
        handle future resolves."""
        if self._closed:
            raise RuntimeError("engine is closed")
        key = str(workload)
        # probe only: don't schedule a build until a cache miss proves one
        # is needed (a fully-cached stream must not rebuild an evicted index)
        handle = self.registry.get_nowait(workload, start_build=False)
        g = None
        if handle is not None:
            # epoch pinning: canonicalize against the graph the resident
            # index was built for. During a streaming refresh the registry
            # may already hold a newer graph epoch; clamping to the
            # handle's t_max keeps window semantics and answers consistent
            # with the index that will serve them (and those answers stay
            # exact in every later epoch — their windows predate the
            # appended suffix).
            g = handle.graph
        else:
            try:
                g = self.registry.resolve_graph(workload)
            except KeyError:
                pass  # unknown workload: surface as the build future's error
        # validate every spec before creating any future (all-or-nothing:
        # a boundary error must not leave earlier futures dangling)
        prepared: list[tuple[TCCSQuery, bool]] = []
        for spec in specs:
            if g is not None:
                if not lenient:
                    spec.validate(n=g.n)
                cq = spec.canonical(g.t_max)
                trivial = cq.is_empty_window or not 0 <= cq.u < g.n
            else:
                if not lenient:
                    spec.validate()
                cq, trivial = spec, False
            prepared.append((cq, trivial))
        t0 = time.perf_counter()
        futures: list[Future] = []
        misses: list[Request] = []
        for (cq, trivial) in prepared:
            fut: Future = Future()
            futures.append(fut)
            self.metrics.count("queries")
            # one root span per query (DESIGN.md §11.2): trivial and cache
            # paths close it here; misses carry the *open* span across the
            # batcher thread boundary and close it from the future's done
            # callback (covering error resolutions too)
            span = self.tracer.start_span(
                "query", parent=None, cat="query", t0=t0,
                workload=workload, k=int(cq.k), u=cq.u, ts=cq.ts, te=cq.te)
            tr, sp = span.ids
            if trivial:
                # an empty window (or lenient out-of-range vertex) needs no
                # index at all — not even a cache slot
                self.metrics.count("trivial_queries")
                span.set("route", "trivial").end()
                fut.set_result(empty_result(
                    cq, g.n, Provenance(route="trivial", index_key=key,
                                        trace_id=tr, span_id=sp)))
                self.metrics.observe("e2e", time.perf_counter() - t0)
                continue
            hit = self.cache.get((key, cq.cache_key()))
            if hit is not None:
                self.metrics.count("cache_hits")
                span.child("cache", t0=t0).end()
                span.set("route", "cache").end()
                fut.set_result(self._stamp_cache_hit(hit, span))
                self.metrics.observe("e2e", time.perf_counter() - t0)
            else:
                self.metrics.count("cache_misses")
                fut.add_done_callback(self._finish_root_span(span, cq))
                misses.append(Request(cq.u, cq.ts, cq.te, fut, t_submit=t0,
                                      spec=cq, span=span))
        if misses:
            if handle is not None:
                self._dispatch_misses(workload, handle, misses)
            else:
                self.metrics.count("cold_submits")
                self._submit_when_built(workload, misses)
        return futures

    def _dispatch_misses(self, workload: str, handle: IndexHandle,
                         misses: list[Request]) -> None:
        """Hand misses to the handle's batcher, riding out retirement
        races: a refresh/eviction listener may close the batcher between
        our probe and the enqueue. On that RuntimeError, re-probe the
        registry — a refreshed key yields the new epoch's handle (the
        already-canonicalized windows stay exact there: they predate the
        appended suffix), an evicted key chains on the rebuild. A swap
        landing between probe and enqueue can also make ``_batcher_for``
        *resurrect* a batcher bound to the retired handle (its retirement
        already ran); the post-enqueue check retires it again so a dead
        epoch never stays pinned — ``MicroBatcher.close`` drains pending
        work first, so the just-enqueued misses still resolve.

        Misses whose k falls outside the handle's strata never reach the
        batcher: they are answered host-side right here (exactly empty
        above the graph's k-max; ``InvalidQueryError`` onto the future for
        an in-range k the strata policy excludes). The partition re-runs
        per retry because an epoch swap can change ``supported_ks`` (a
        retention trim drops strata above the trimmed graph's k-max)."""
        key = str(workload)
        for _ in range(8):   # bounded: each retry needs another swap race
            cur = self.registry.get_nowait(workload, start_build=False)
            if cur is None:
                self.metrics.count("cold_submits")
                self._submit_when_built(workload, misses)
                return
            handle = cur
            supported = set(handle.pecb.supported_ks)
            batchable = []
            for req in misses:
                kq = req.spec.k if req.spec is not None else None
                if kq is None or kq in supported:
                    batchable.append(req)
                elif not req.future.done():
                    self._answer_unsupported_k(key, handle, req)
            if not batchable:
                return
            misses = batchable
            try:
                self._batcher_for(handle).submit_many(batchable)
            except RuntimeError:
                if self._closed:
                    raise
                continue
            latest = self.registry.get_nowait(workload, start_build=False)
            if latest is not None and latest is not handle:
                self._retire_batcher(key, handle)
            return
        raise RuntimeError(
            f"batcher for {key!r} kept closing under submit")

    def _answer_unsupported_k(self, key: str, handle: IndexHandle,
                              req: Request) -> None:
        """Resolve one miss whose k has no stratum in the handle.
        ``StratifiedPECB.answer`` owns the semantics: k above the graph's
        k-max is exactly empty (computed host-side, no index needed), any
        other unsupported k raises ``InvalidQueryError`` — which lands on
        the future, like every other per-query failure."""
        try:
            res = handle.pecb.answer(req.spec)
        except BaseException as exc:
            req.future.set_exception(exc)
            return
        tr, sp = req.span.ids if req.span is not None else (None, None)
        res = dataclasses.replace(res, provenance=dataclasses.replace(
            res.provenance, index_key=key, trace_id=tr, span_id=sp))
        self.cache.put((key, req.spec.cache_key()), res,
                       epoch=handle.epoch)
        self.metrics.count("unsupported_k_queries")
        req.future.set_result(res)

    def _finish_root_span(self, span, cq: TCCSQuery):
        """Done callback closing a miss's root query span. Attached at
        Request creation so *every* resolution path — planner result, batch
        execute_fn failure, build failure, engine close — ends the span and
        feeds the slow-query log exactly once (``Span.end`` is idempotent
        anyway)."""
        def _done(f: Future) -> None:
            exc = f.exception()
            if exc is not None:
                span.set("error", repr(exc))
            span.end()
            self.slow_queries.observe(span, cq)
        return _done

    @staticmethod
    def _stamp_cache_hit(res: TCCSResult, span=None) -> TCCSResult:
        """Re-stamp a cached result with ``route="cache"`` (and, when a
        root query span is passed, that span's trace identity) — on a
        *copy*.

        ``dataclasses.replace`` shallow-copies, which would share the
        mutable ``timings`` dict between the stored result and every hit
        handed to callers (threads mutating one would corrupt the other,
        and the stored provenance itself); the dict is copied explicitly so
        the cached original stays pristine."""
        tr, sp = span.ids if span is not None else (None, None)
        if res.provenance is None:
            return dataclasses.replace(res, provenance=Provenance(
                route="cache", trace_id=tr, span_id=sp))
        prov = dataclasses.replace(res.provenance, route="cache",
                                   trace_id=tr, span_id=sp,
                                   timings=dict(res.provenance.timings))
        return dataclasses.replace(res, provenance=prov)

    # -- window sweeps ----------------------------------------------------
    def sweep(self, workload: str, ws: WindowSweep,
              timeout: float | None = 120.0) -> list[TCCSResult]:
        """Answer one vertex over many windows — cache-hot windows are
        served from the LRU, the remaining windows share device
        ``window_sweep`` launches (or a host loop for straggler sweeps and
        empty forests). Blocking: the sweep is a throughput API; a cold
        index is built first (use :meth:`prefetch` to hide that)."""
        if self._closed:
            raise RuntimeError("engine is closed")
        handle = self.registry.get(workload, timeout=timeout)
        g, key = handle.graph, handle.key
        specs = ws.specs()
        for s in specs:
            s.validate(n=g.n)
        self.metrics.count("queries", len(specs))
        t0 = time.perf_counter()
        # one root span for the whole sweep (it is a single logical query);
        # each device launch / host loop is a child, and every non-cached
        # window's provenance links back to this root
        span = self.tracer.start_span(
            "sweep", parent=None, cat="query", t0=t0,
            workload=workload, k=int(ws.k), u=int(ws.u), windows=len(specs))
        tr, sp = span.ids
        results: list = [None] * len(specs)
        misses: list[tuple[int, TCCSQuery]] = []
        for i, s in enumerate(specs):
            cq = s.canonical(g.t_max)
            if cq.is_empty_window:
                self.metrics.count("trivial_queries")
                results[i] = empty_result(
                    cq, g.n, Provenance(route="trivial", index_key=key,
                                        trace_id=tr, span_id=sp))
                continue
            hit = self.cache.get((key, cq.cache_key()))
            if hit is not None:
                self.metrics.count("cache_hits")
                results[i] = self._stamp_cache_hit(hit, span)
            else:
                self.metrics.count("cache_misses")
                misses.append((i, cq))
        cfg = self.config
        # an unsupported k routes host: above the graph's k-max every
        # window is exactly empty (answered without an index); an in-range
        # k outside the strata policy raises InvalidQueryError — the sweep
        # is synchronous, so it surfaces to the caller directly
        k_on_device = ws.k in handle.pecb.supported_ks
        if misses and (handle.pecb.num_nodes == 0 or not k_on_device
                       or len(misses) < cfg.host_threshold):
            es = span.child("execute", route="host")
            for i, cq in misses:
                res = handle.pecb.answer(cq)
                res = dataclasses.replace(res, provenance=dataclasses.replace(
                    res.provenance, index_key=key, trace_id=tr, span_id=sp))
                results[i] = res
                self.cache.put((key, cq.cache_key()), res,
                               epoch=handle.epoch)
            es.end()
            self.metrics.count("host_batches")
            self.metrics.count("host_queries", len(misses))
        elif misses:
            store = handle.pecb.versions
            # single-k launch: carve the stratum's block out of the fused
            # mixed-k mirror (lazy per-handle memo) so sweep propagation
            # pays for one stratum's nodes, not all |K|; ``u`` is a plain
            # row of the sliced per-vertex CSR
            sdix = handle.stratum_device(int(ws.k))
            for c0 in range(0, len(misses), cfg.max_batch):
                chunk = misses[c0:c0 + cfg.max_batch]
                bucket = self.executor.final_bucket(
                    len(chunk), cfg.min_bucket, cfg.max_batch)
                ts = [cq.ts for _, cq in chunk]
                te = [cq.te for _, cq in chunk]
                t1 = time.perf_counter()
                vmask = self.executor.run_sweep(sdix, int(ws.u), ts, te,
                                                bucket)
                dt = time.perf_counter() - t1
                span.child("execute", route="sweep", bucket=bucket,
                           t0=t1).end()
                prov = Provenance(route="sweep", backend="pecb-device-sweep",
                                  index_key=key, batch_size=len(chunk),
                                  bucket=bucket, timings={"exec_s": dt},
                                  trace_id=tr, span_id=sp)
                chunk_res = assemble_device_results(
                    store, [cq for _, cq in chunk], vmask, None, prov)
                for (i, cq), res in zip(chunk, chunk_res):
                    results[i] = res
                    self.cache.put((key, cq.cache_key()), res,
                                   epoch=handle.epoch)
                self.metrics.count("sweep_launches")
                self.metrics.count("sweep_windows", len(chunk))
                self.metrics.count("sweep_padded_slots", bucket - len(chunk))
                self.metrics.observe("sweep_exec", dt)
        span.end()
        self.metrics.observe("sweep_e2e", time.perf_counter() - t0)
        return results

    def _submit_when_built(self, workload: str,
                           misses: list[Request]) -> None:
        """Chain a batch of misses onto the pending index build."""
        def on_built(handle_fut: Future) -> None:
            try:
                handle = handle_fut.result()
                self._dispatch_misses(workload, handle, misses)
            except BaseException as exc:  # build failed or engine closed
                for req in misses:
                    if not req.future.done():
                        req.future.set_exception(exc)
        self.registry.get_async(workload).add_done_callback(on_built)

    def query(self, workload: str, k: int, u: int, ts: int, te: int,
              timeout: float | None = 60.0) -> frozenset:
        """Deprecated synchronous shim (one-request batch); prefer
        :meth:`answer`. Emits :class:`DeprecationWarning`."""
        warnings.warn(
            "ServingEngine.query(workload, k, u, ts, te) is deprecated; "
            "use answer(workload, TCCSQuery(u, ts, te, k))",
            DeprecationWarning, stacklevel=2)
        return self._submit_legacy(
            workload, k, [(u, ts, te)])[0].result(timeout=timeout)

    # -- lifecycle -------------------------------------------------------
    def _batcher_for(self, handle: IndexHandle) -> MicroBatcher:
        """Batcher bound to exactly this handle. If the registry evicted and
        rebuilt the key, the old batcher (bound to the dead handle) is
        closed and replaced, so closures never pin evicted indexes."""
        stale = None
        with self._lock:
            if self._closed:          # close() may have raced past submit's check
                raise RuntimeError("engine is closed")
            entry = self._batchers.get(handle.key)
            if entry is not None and entry[0] is handle:
                return entry[1]
            if entry is not None:
                stale = entry[1]
            cfg = self.config
            b = MicroBatcher(
                self.planner.bind(handle),
                max_batch=cfg.max_batch, flush_ms=cfg.flush_ms,
                name=f"batcher-dispatch-{handle.key}",
                metrics=self.metrics)
            self._batchers[handle.key] = (handle, b)
        if stale is not None:
            stale.close()
        return b

    def _on_index_evicted(self, key: str, handle: IndexHandle) -> None:
        """Registry eviction hook: retire the batcher (and its worker
        thread) bound to the evicted handle, and purge the dead handle's
        result-cache entries — ONE workload-level purge clears every k
        stratum's results, because the cache key is (workload, spec key)
        and k lives inside the spec key."""
        purged = self.cache.purge_index(key)
        if purged:
            self.metrics.count("cache_purged", purged)
        self._retire_batcher(key, handle)

    def _on_index_retained(self, key: str, old: IndexHandle,
                           new: IndexHandle, t_cut: int) -> None:
        """Registry retention hook (prefix-expiry trim landed). Ordering:
        (1) raise the cache's epoch floor (idempotent with the raise at
        trim initiation; atomic with puts under the cache lock, so a
        still-running batch or sweep bound to a pre-trim handle either
        writes before the purge — and is rehomed/dropped by it like any
        resident entry — or is gated); (2) retire the old batcher so new
        submissions bind the trimmed handle; (3) purge cached windows
        that touch the expired prefix and rehome the survivors into the
        shifted timeline (``shift = t_cut - 1``)."""
        self.cache.raise_floor(key, new.epoch)
        self._retire_batcher(key, old)
        purged = self.cache.purge_window(key, 1, t_cut - 1, shift=t_cut - 1)
        if purged:
            self.metrics.count("cache_purged_retention", purged)

    def _on_index_refreshed(self, key: str, old: IndexHandle,
                            new: IndexHandle) -> None:
        """Registry refresh hook (streaming epoch landed): run the
        *targeted* cache purge — only results whose canonical window
        intersects the appended range ``(old.t_max, new.t_max]`` — and
        retire the old epoch's batcher so new submissions bind the
        refreshed handle. For suffix appends every cached canonical window
        satisfies ``te <= old.t_max``, so the expected purge count is zero:
        the whole warm working set survives the epoch."""
        purged = self.cache.purge_window(
            key, old.graph.t_max + 1, new.graph.t_max)
        if purged:
            self.metrics.count("cache_purged_targeted", purged)
        self._retire_batcher(key, old)

    def _retire_batcher(self, key: str, handle: IndexHandle) -> None:
        with self._lock:
            entry = self._batchers.get(key)
            if entry is None or entry[0] is not handle:
                return
            del self._batchers[key]
        entry[1].close()

    def flush(self) -> None:
        with self._lock:
            batchers = [b for (_, b) in self._batchers.values()]
        for b in batchers:
            b.flush()

    def drain(self, timeout: float | None = 60.0) -> None:
        with self._lock:
            batchers = [b for (_, b) in self._batchers.values()]
        for b in batchers:
            b.drain(timeout=timeout)

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            batchers = [b for (_, b) in self._batchers.values()]
        self.registry.remove_evict_listener(self._on_index_evicted)
        self.registry.remove_refresh_listener(self._on_index_refreshed)
        self.registry.remove_retention_listener(self._on_index_retained)
        for b in batchers:
            b.close()
        if self._owns_registry:
            self.registry.close(wait=True)

    def __enter__(self) -> "ServingEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- observability ---------------------------------------------------
    def export_trace(self, path: str, extra: dict | None = None) -> dict:
        """Write the tracer's finished-span ring as Chrome trace-event JSON
        (loadable in Perfetto / ``chrome://tracing``); returns the
        validated document. Works on a live engine — the export is a
        snapshot of whatever has finished so far."""
        return write_chrome_trace(path, self.tracer, extra=extra)

    def stats(self) -> dict:
        return {
            "engine": self.metrics.snapshot(include_sources=False),
            "cache": self.cache.stats(),
            "registry": self.registry.stats(),
            "store": self.store.stats() if self.store is not None else None,
            "devices": self.executor.num_devices,
            "compiled_programs": self.executor.compile_count(),
            "trace": self.tracer.stats(),
            "slow_queries": len(self.slow_queries),
        }

    def format_stats(self) -> str:
        s = self.stats()
        lines = [self.metrics.format()]
        lines.append(f"  cache                    {s['cache']}")
        lines.append(f"  registry                 resident={s['registry']['resident']} "
                     f"builds={s['registry']['builds']} evictions={s['registry']['evictions']}")
        lines.append(f"  devices={s['devices']} compiled_programs={s['compiled_programs']}")
        return "\n".join(lines)
