"""Per-stage serving metrics (DESIGN.md §7.5, §11.4).

The engine is instrumented at every pipeline stage: queue wait inside the
micro-batcher, planner routing, host/device execution, end-to-end request
latency. Latencies go into :class:`repro.obs.LatencyHistogram` (exact
samples up to a cap, then uniform reservoir replacement) and are
summarized as p50/p95/p99/mean with linear interpolation; everything
countable (cache hits, routed queries, padded slots, flushes by cause,
jit compiles) goes into monotonically increasing counters.

Since the §11 observability refactor, :class:`EngineMetrics` is a thin
subclass of :class:`repro.obs.MetricsRegistry` — the unified registry
that also carries gauges (device count, compiled programs) and pluggable
stat sources (the result cache's and index registry's ``stats()``), so
one ``snapshot()`` (and one ``repro.obs.export.metrics_to_json``) covers
the whole serving plane. Every pre-§11 call site (``count``, ``observe``,
``counter``, ``snapshot()["counters"|"latency"]``) is unchanged.

All methods are thread-safe: the batcher worker threads, the caller
threads resolving cache hits, and the stats reader all touch the same
object — and the histograms carry their own lock, so direct
``LatencyHistogram.add`` calls are safe too.
"""

from __future__ import annotations

from repro.obs.registry import LatencyHistogram, MetricsRegistry

__all__ = ["EngineMetrics", "LatencyHistogram"]


class EngineMetrics(MetricsRegistry):
    """The serving engine's metrics sink: a :class:`MetricsRegistry` kept
    under its historical name so engine/batcher/planner/registry call
    sites (and tests) read naturally."""
