"""Per-stage serving metrics (DESIGN.md §7.5).

The engine is instrumented at every pipeline stage: queue wait inside the
micro-batcher, planner routing, host/device execution, end-to-end request
latency. Latencies go into :class:`LatencyHistogram` (exact samples up to a
cap, then uniform reservoir replacement) and are summarized as
p50/p95/p99/mean; everything countable (cache hits, routed queries, padded
slots, flushes by cause) goes into monotonically increasing counters.

All methods are thread-safe: the batcher worker threads, the caller threads
resolving cache hits, and the stats reader all touch the same object.
"""

from __future__ import annotations

import random
import threading


class LatencyHistogram:
    """Latency samples (seconds) with percentile summaries.

    Keeps exact samples up to ``cap``; beyond that, new samples replace a
    uniformly random slot (classic reservoir), so long benches keep an
    unbiased view without unbounded memory. ``count``/``total`` stay exact.
    """

    def __init__(self, cap: int = 65536, seed: int = 0):
        self._cap = cap
        self._rng = random.Random(seed)
        self._samples: list[float] = []
        self.count = 0
        self.total = 0.0

    def add(self, seconds: float) -> None:
        self.count += 1
        self.total += seconds
        if len(self._samples) < self._cap:
            self._samples.append(seconds)
        else:
            j = self._rng.randrange(self.count)
            if j < self._cap:
                self._samples[j] = seconds

    @staticmethod
    def _pct(sorted_samples: list[float], q: float) -> float:
        if not sorted_samples:
            return 0.0
        n = len(sorted_samples)
        i = min(n - 1, max(0, int(round(q / 100.0 * (n - 1)))))
        return sorted_samples[i]

    def percentile(self, q: float) -> float:
        return self._pct(sorted(self._samples), q)

    def summary(self) -> dict:
        ms = 1e3
        s = sorted(self._samples)    # one sort feeds every percentile
        return {
            "count": self.count,
            "mean_ms": (self.total / self.count * ms) if self.count else 0.0,
            "p50_ms": self._pct(s, 50) * ms,
            "p95_ms": self._pct(s, 95) * ms,
            "p99_ms": self._pct(s, 99) * ms,
            "max_ms": (s[-1] * ms) if s else 0.0,
        }


class EngineMetrics:
    """Thread-safe registry of counters + per-stage latency histograms."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, int] = {}
        self._hists: dict[str, LatencyHistogram] = {}

    def count(self, name: str, inc: int = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + inc

    def observe(self, stage: str, seconds: float) -> None:
        with self._lock:
            h = self._hists.get(stage)
            if h is None:
                h = self._hists[stage] = LatencyHistogram()
            h.add(seconds)

    def counter(self, name: str) -> int:
        with self._lock:
            return self._counters.get(name, 0)

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "counters": dict(self._counters),
                "latency": {k: h.summary() for k, h in self._hists.items()},
            }

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._hists.clear()

    def format(self) -> str:
        snap = self.snapshot()
        lines = []
        for name in sorted(snap["counters"]):
            lines.append(f"  {name:<24} {snap['counters'][name]}")
        for stage in sorted(snap["latency"]):
            s = snap["latency"][stage]
            lines.append(
                f"  {stage:<24} n={s['count']:<7} mean={s['mean_ms']:.3f}ms "
                f"p50={s['p50_ms']:.3f}ms p95={s['p95_ms']:.3f}ms "
                f"p99={s['p99_ms']:.3f}ms"
            )
        return "\n".join(lines)
