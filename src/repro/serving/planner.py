"""Query planner: route batches to the host or device plane, build typed
results, fill the cache (DESIGN.md §7.2, §8).

The two query planes have opposite cost shapes. Algorithm 1 on the host is
O(answer size) per query with zero launch overhead — unbeatable for a
straggler batch of three. The device plane pays a fixed launch (and, cold,
a compile) but amortizes to microseconds per query at depth. The planner
picks per flushed batch:

* ``B < host_threshold``  -> host loop over the backend's typed ``answer``;
* otherwise               -> pad to the power-of-two bucket and launch the
  sharded device engine — the vertex-mask program for VERTICES/COUNT-only
  batches, the full-mode program (vertex + version-membership masks) when
  any request in the batch wants EDGES/SUBGRAPH.

An empty forest (k above the graph's k-max) always routes host: every
answer is the empty set and a device launch would compile a program to
compute nothing.

Every result is a :class:`repro.core.query_api.TCCSResult` carrying the
canonical spec it answered and :class:`Provenance` (route, index key,
batch/bucket shape, stage timings). After execution the planner writes
every (index key, canonical spec key) -> result into the LRU cache, so
repeats are resolved on the submit path without ever reaching a batcher.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.pecb_index import StratifiedPECB
from repro.core.query_api import (Provenance, ResultMode, TCCSQuery,
                                  build_result)

from .batcher import Request
from .executor import ShardedExecutor

_EDGE_MODES = (ResultMode.EDGES, ResultMode.SUBGRAPH)


def assemble_device_results(store, specs, vmask, vermask,
                            prov: Provenance) -> list:
    """Typed results from device masks — the single owner of mask-to-result
    assembly, shared by the planner's device branch and the engine's window
    sweeps. ``vermask`` may be None (no full-mode launch): edge modes then
    derive their payload host-side from the version store."""
    results = []
    for i, s in enumerate(specs):
        vertices = frozenset(np.nonzero(vmask[i])[0].tolist())
        edge_set = (store.select(np.nonzero(vermask[i])[0])
                    if vermask is not None and s.mode in _EDGE_MODES
                    else None)
        results.append(build_result(s, vertices, store, prov,
                                    edge_set=edge_set))
    return results


class QueryPlanner:
    def __init__(self, executor: ShardedExecutor, cache, metrics,
                 *, host_threshold: int = 8, min_bucket: int = 8,
                 max_batch: int = 256):
        self.executor = executor
        self.cache = cache
        self.metrics = metrics
        self.host_threshold = host_threshold
        self.min_bucket = min_bucket
        self.max_batch = max_batch

    def route(self, handle, batch_size: int) -> str:
        if handle.pecb.num_nodes == 0:
            return "host"
        if batch_size < self.host_threshold:
            return "host"
        return "device"

    def bind(self, handle):
        """The ``execute_fn`` a batcher calls for this index handle."""
        return lambda batch: self.execute(handle, batch)

    @staticmethod
    def _spec_of(r: Request, k: int) -> TCCSQuery:
        # bare requests (tests, legacy callers) carry no spec: VERTICES mode
        return r.spec if r.spec is not None else TCCSQuery(r.u, r.ts, r.te, k)

    @staticmethod
    def _trace_pre_exec(batch: list[Request], route: str,
                        t_exec: float) -> None:
        """Hang the retrospective ``queue`` span and the ``route`` decision
        span off each request's root span (the engine attached it on the
        caller thread; bare legacy requests carry none). The queue span is
        backdated to the batcher enqueue — by the time the worker runs a
        batch, the wait is already history."""
        for r in batch:
            if r.span is None:
                continue
            t_enq = r.t_enqueue or r.t_submit
            r.span.child("queue", t0=t_enq).end(t_exec)
            r.span.child("route", t0=t_exec, route=route).end(t_exec)
            r.span.set("route", route)

    def execute(self, handle, batch: list[Request]) -> list:
        b = len(batch)
        # bare requests carry no spec and need a default k: the smallest
        # supported stratum (a per-k PECBIndex handle keeps its own k)
        k = getattr(handle.pecb, "k", None)
        if k is None:
            ks = handle.pecb.supported_ks
            k = min(ks) if ks else 2
        specs = [self._spec_of(r, k) for r in batch]
        store = handle.pecb.versions
        route = self.route(handle, b)
        # a promoted handle (mmap'd from the persistent store, never
        # rebuilt) stamps route="disk" on its answers' provenance; the
        # execution plane still follows `route` — provenance records where
        # the *index* came from, `backend` keeps the execution detail
        src_disk = getattr(handle, "source", "build") == "disk"
        t0 = time.perf_counter()
        self._trace_pre_exec(batch, route, t0)
        if route == "host":
            results = []
            for r, s in zip(batch, specs):
                es = (r.span.child("execute", route="host")
                      if r.span is not None else None)
                res = handle.pecb.answer(s)
                if es is not None:
                    es.end()
                # provenance links to the ROOT query span: the whole tree
                # is recoverable from the trace id
                tr, sp = r.span.ids if r.span is not None else (None, None)
                prov = dataclasses.replace(
                    res.provenance, index_key=handle.key, batch_size=b,
                    trace_id=tr, span_id=sp)
                if src_disk:
                    prov = dataclasses.replace(prov, route="disk")
                results.append(dataclasses.replace(res, provenance=prov))
            self.metrics.observe("host_exec", time.perf_counter() - t0)
            self.metrics.count("host_batches")
            self.metrics.count("host_queries", b)
        else:
            bucket = self.executor.final_bucket(b, self.min_bucket,
                                                self.max_batch)
            # on a stratified index the per-query k enters as the entry
            # *slot* k_index(k) * n + u — batch_query's vertex-CSR lookup
            # is the only place u appears, so the mixed-k batch shares the
            # per-k path's compiled program (unsupported ks were answered
            # host-side before batching; k_index raising here is a bug)
            pecb = handle.pecb
            mixed = isinstance(pecb, StratifiedPECB)
            if mixed:
                u = [pecb.k_index(s.k) * pecb.n + s.u for s in specs]
            else:
                u = [s.u for s in specs]
            ts = [s.ts for s in specs]
            te = [s.te for s in specs]
            need_edges = (store is not None
                          and any(s.mode in _EDGE_MODES for s in specs))
            t_exec = time.perf_counter()
            exec_spans = [r.span.child("execute", route="device",
                                       bucket=bucket, t0=t_exec)
                          if r.span is not None else None for r in batch]
            if need_edges and mixed:
                # the version arrays are the one index space shared across
                # strata — the kq operand scopes the edge payload per query
                vmask, vermask = self.executor.run_full_mixed(
                    handle.device, u, ts, te, [s.k for s in specs], bucket)
            elif need_edges:
                vmask, vermask = self.executor.run_full(
                    handle.device, u, ts, te, bucket)
            else:
                vmask = self.executor.run(handle.device, u, ts, te, bucket)
                vermask = None
            dt = time.perf_counter() - t0
            t_end = time.perf_counter()
            for es in exec_spans:
                if es is not None:
                    es.end(t_end)
            prov = Provenance(route="disk" if src_disk else "device",
                              backend="pecb-device" + ("-full" if need_edges else ""),
                              index_key=handle.key, batch_size=b,
                              bucket=bucket, timings={"exec_s": dt})
            results = assemble_device_results(store, specs, vmask, vermask,
                                              prov)
            # per-result provenance copies link each answer to its root
            # query span (one launch, many traces)
            results = [
                dataclasses.replace(res, provenance=dataclasses.replace(
                    res.provenance, trace_id=r.span.ids[0],
                    span_id=r.span.ids[1]))
                if r.span is not None else res
                for r, res in zip(batch, results)]
            self.metrics.observe("device_exec", dt)
            self.metrics.count("device_batches")
            self.metrics.count("device_queries", b)
            self.metrics.count("device_padded_slots", bucket - b)
        # the handle's epoch rides along so the cache's retention-epoch
        # floor can drop fills from pre-trim handles atomically with the
        # trim's purge+rehome (DESIGN.md §10.3)
        epoch = getattr(handle, "epoch", None)
        for s, res in zip(specs, results):
            self.cache.put((handle.key, s.cache_key()), res, epoch=epoch)
        return results
