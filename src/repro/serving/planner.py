"""Query planner: route batches to the host or device plane, fill the cache
(DESIGN.md §7.2).

The two query planes have opposite cost shapes. Algorithm 1 on the host is
O(answer size) per query with zero launch overhead — unbeatable for a
straggler batch of three. The device plane pays a fixed launch (and, cold,
a compile) but amortizes to microseconds per query at depth. The planner
picks per flushed batch:

* ``B < host_threshold``  -> host loop over ``PECBIndex.query``;
* otherwise               -> pad to the power-of-two bucket and launch the
  sharded device engine.

An empty forest (k above the graph's k-max) always routes host: every
answer is the empty set and a device launch would compile a program to
compute nothing.

After execution the planner writes every (u, ts, te) -> result into the LRU
cache, so repeats are resolved on the submit path without ever reaching a
batcher.
"""

from __future__ import annotations

import time

import numpy as np

from .batcher import Request
from .executor import ShardedExecutor


class QueryPlanner:
    def __init__(self, executor: ShardedExecutor, cache, metrics,
                 *, host_threshold: int = 8, min_bucket: int = 8,
                 max_batch: int = 256):
        self.executor = executor
        self.cache = cache
        self.metrics = metrics
        self.host_threshold = host_threshold
        self.min_bucket = min_bucket
        self.max_batch = max_batch

    def route(self, handle, batch_size: int) -> str:
        if handle.pecb.num_nodes == 0:
            return "host"
        if batch_size < self.host_threshold:
            return "host"
        return "device"

    def bind(self, handle):
        """The ``execute_fn`` a batcher calls for this index handle."""
        return lambda batch: self.execute(handle, batch)

    def execute(self, handle, batch: list[Request]) -> list[frozenset]:
        b = len(batch)
        route = self.route(handle, b)
        t0 = time.perf_counter()
        if route == "host":
            results = [frozenset(handle.pecb.query(r.u, r.ts, r.te))
                       for r in batch]
            self.metrics.observe("host_exec", time.perf_counter() - t0)
            self.metrics.count("host_batches")
            self.metrics.count("host_queries", b)
        else:
            bucket = self.executor.final_bucket(b, self.min_bucket,
                                                self.max_batch)
            u = [r.u for r in batch]
            ts = [r.ts for r in batch]
            te = [r.te for r in batch]
            mask = self.executor.run(handle.device, u, ts, te, bucket)
            results = [frozenset(np.nonzero(mask[i])[0].tolist())
                       for i in range(b)]
            self.metrics.observe("device_exec", time.perf_counter() - t0)
            self.metrics.count("device_batches")
            self.metrics.count("device_queries", b)
            self.metrics.count("device_padded_slots", bucket - b)
        for r, res in zip(batch, results):
            self.cache.put((handle.key, r.u, r.ts, r.te), res)
        return results
