"""Index registry: build, memoize, evict (PECB, Device) index pairs
(DESIGN.md §7.4).

One engine serves many (workload, k) combinations concurrently — a contact
tracer asks k=2 and k=3 over the same graph, a dashboard watches five
graphs. Index construction is the offline plane (seconds); queries are the
online plane (microseconds). The registry keeps that split honest: the
first request for a (workload, k) pays the build once, everyone after gets
the memoized handle; capacity-bounded LRU eviction drops cold indexes.

Graphs resolve by name: either registered explicitly (``register_graph``)
or one of the named bench workloads (``BENCH_WORKLOADS``). Builds are
serialized per key (a per-key lock) so a thundering herd on a cold key
builds exactly once, while builds of *different* keys proceed in parallel.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict

from repro.core.temporal_graph import BENCH_WORKLOADS, TemporalGraph, bench_graph
from repro.core.core_time import edge_core_times
from repro.core.pecb_index import PECBIndex, build_pecb_index
from repro.core.batch_query import DeviceIndex, to_device


@dataclasses.dataclass(frozen=True)
class IndexHandle:
    """A built (workload, k) index pair: host arrays + device mirror."""

    key: tuple[str, int]          # (workload name, k)
    graph: TemporalGraph
    pecb: PECBIndex
    device: DeviceIndex
    build_seconds: float

    @property
    def nbytes(self) -> int:
        return self.pecb.nbytes()


class IndexRegistry:
    def __init__(self, capacity: int = 8, metrics=None, on_evict=None):
        assert capacity >= 1
        self.capacity = capacity
        self._metrics = metrics
        # evict listeners: called as cb(key, handle) after an entry leaves
        # the registry (outside the registry lock). A list, not a slot:
        # several engines may share one registry (the bench does), and each
        # needs to retire its own batcher on eviction.
        self._evict_listeners: list = []
        if on_evict is not None:
            self._evict_listeners.append(on_evict)
        self._graphs: dict[str, TemporalGraph] = {}
        self._entries: "OrderedDict[tuple[str, int], IndexHandle]" = OrderedDict()
        self._lock = threading.Lock()
        self._build_locks: dict[tuple[str, int], threading.Lock] = {}
        self.builds = 0
        self.evictions = 0

    def add_evict_listener(self, cb) -> None:
        with self._lock:
            self._evict_listeners.append(cb)

    def remove_evict_listener(self, cb) -> None:
        with self._lock:
            if cb in self._evict_listeners:
                self._evict_listeners.remove(cb)

    # -- graph sources --------------------------------------------------
    def register_graph(self, name: str, g: TemporalGraph) -> None:
        """Bind ``name`` to a graph, immutably: indexes, cached results and
        batchers are all keyed by name, so silently rebinding a name would
        keep serving answers for the old graph. Re-registering the *same*
        object is a no-op; a different one raises — publish new snapshots
        under new names (e.g. ``"contacts@2026-07-31"``)."""
        with self._lock:
            prev = self._graphs.get(name)
            if prev is not None and prev is not g:
                raise ValueError(
                    f"graph name {name!r} is already bound; names are "
                    "immutable — register the new snapshot under a new name")
            self._graphs[name] = g

    def resolve_graph(self, name: str) -> TemporalGraph:
        with self._lock:
            if name in self._graphs:
                return self._graphs[name]
        if name in BENCH_WORKLOADS:
            g = bench_graph(name)
            # concurrent cold builds of different k race to generate the
            # same bench graph: first registration wins, losers adopt it
            # (bench_graph is deterministic, so either copy is identical)
            with self._lock:
                return self._graphs.setdefault(name, g)
        raise KeyError(
            f"unknown workload {name!r}: register_graph() it or use one of "
            f"{sorted(BENCH_WORKLOADS)}"
        )

    # -- handle lookup ---------------------------------------------------
    def get(self, workload: str, k: int) -> IndexHandle:
        key = (workload, int(k))
        with self._lock:
            h = self._entries.get(key)
            if h is not None:
                self._entries.move_to_end(key)
                return h
            bl = self._build_locks.setdefault(key, threading.Lock())
        with bl:
            # double-check: another thread may have built while we waited
            with self._lock:
                h = self._entries.get(key)
                if h is not None:
                    self._entries.move_to_end(key)
                    return h
            h = self._build(key)
            evicted = []
            with self._lock:
                self._entries[key] = h
                self._entries.move_to_end(key)
                while len(self._entries) > self.capacity:
                    evicted.append(self._entries.popitem(last=False))
                    self.evictions += 1
                    if self._metrics is not None:
                        self._metrics.count("index_evictions")
            with self._lock:
                listeners = list(self._evict_listeners)
            for (k2, h2) in evicted:
                for cb in listeners:
                    cb(k2, h2)
            return h

    def _build(self, key: tuple[str, int]) -> IndexHandle:
        workload, k = key
        g = self.resolve_graph(workload)
        t0 = time.perf_counter()
        idx = build_pecb_index(g, k, edge_core_times(g, k))
        handle = IndexHandle(key, g, idx, to_device(idx), time.perf_counter() - t0)
        self.builds += 1
        if self._metrics is not None:
            self._metrics.count("index_builds")
            self._metrics.observe("index_build", handle.build_seconds)
        return handle

    def __contains__(self, key: tuple[str, int]) -> bool:
        with self._lock:
            return key in self._entries

    def stats(self) -> dict:
        with self._lock:
            return {
                "resident": list(self._entries),
                "capacity": self.capacity,
                "builds": self.builds,
                "evictions": self.evictions,
                "resident_bytes": sum(h.nbytes for h in self._entries.values()),
            }
