"""Index registry: build, memoize, evict (PECB, Device) index pairs
(DESIGN.md §7.4).

One engine serves many (workload, k) combinations concurrently — a contact
tracer asks k=2 and k=3 over the same graph, a dashboard watches five
graphs. Index construction is the offline plane (seconds); queries are the
online plane (microseconds). The registry keeps that split honest: the
first request for a (workload, k) pays the build once, everyone after gets
the memoized handle; capacity-bounded LRU eviction drops cold indexes.

Builds run on a small background pool and are exposed three ways:

* ``get_async`` — returns a ``Future[IndexHandle]`` immediately; a
  thundering herd on a cold key coalesces onto one pending future, while
  distinct keys build in parallel (bounded by ``build_workers``).
* ``get_nowait`` — non-blocking probe; on a miss it (optionally) kicks off
  the background build and returns ``None`` so the caller's thread never
  blocks behind a multi-second build (the engine's submit path uses this).
* ``get`` — the blocking convenience wrapper (``get_async().result()``).

Each build records per-stage wall times (core times, forest, pack, device
upload) on the handle and into the metrics sink (``index_build_<stage>``).

Graphs resolve by name: either registered explicitly (``register_graph``)
or one of the named bench workloads (``BENCH_WORKLOADS``).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor

from repro.core.temporal_graph import BENCH_WORKLOADS, TemporalGraph, bench_graph
from repro.core.core_time import edge_core_times
from repro.core.ecb_forest import IncrementalBuilder
from repro.core.pecb_index import PECBIndex, pack_index
from repro.core.batch_query import DeviceIndex, to_device


@dataclasses.dataclass(frozen=True)
class IndexHandle:
    """A built (workload, k) index pair: host arrays + device mirror."""

    key: tuple[str, int]          # (workload name, k)
    graph: TemporalGraph
    pecb: PECBIndex
    device: DeviceIndex
    build_seconds: float
    build_stages: dict = dataclasses.field(default_factory=dict, compare=False)

    @property
    def nbytes(self) -> int:
        return self.pecb.nbytes()


class IndexRegistry:
    def __init__(self, capacity: int = 8, metrics=None, on_evict=None,
                 build_workers: int = 2):
        assert capacity >= 1
        self.capacity = capacity
        self._metrics = metrics
        # evict listeners: called as cb(key, handle) after an entry leaves
        # the registry (outside the registry lock). A list, not a slot:
        # several engines may share one registry (the bench does), and each
        # needs to retire its own batcher on eviction.
        self._evict_listeners: list = []
        if on_evict is not None:
            self._evict_listeners.append(on_evict)
        self._graphs: dict[str, TemporalGraph] = {}
        self._entries: "OrderedDict[tuple[str, int], IndexHandle]" = OrderedDict()
        self._lock = threading.Lock()
        self._pending: dict[tuple[str, int], Future] = {}
        self._build_workers = max(1, int(build_workers))
        self._pool: ThreadPoolExecutor | None = None
        self.builds = 0
        self.evictions = 0

    def add_evict_listener(self, cb) -> None:
        with self._lock:
            self._evict_listeners.append(cb)

    def remove_evict_listener(self, cb) -> None:
        with self._lock:
            if cb in self._evict_listeners:
                self._evict_listeners.remove(cb)

    # -- graph sources --------------------------------------------------
    def register_graph(self, name: str, g: TemporalGraph) -> None:
        """Bind ``name`` to a graph, immutably: indexes, cached results and
        batchers are all keyed by name, so silently rebinding a name would
        keep serving answers for the old graph. Re-registering the *same*
        object is a no-op; a different one raises — publish new snapshots
        under new names (e.g. ``"contacts@2026-07-31"``)."""
        with self._lock:
            prev = self._graphs.get(name)
            if prev is not None and prev is not g:
                raise ValueError(
                    f"graph name {name!r} is already bound; names are "
                    "immutable — register the new snapshot under a new name")
            self._graphs[name] = g

    def resolve_graph(self, name: str) -> TemporalGraph:
        with self._lock:
            if name in self._graphs:
                return self._graphs[name]
        if name in BENCH_WORKLOADS:
            g = bench_graph(name)
            # concurrent cold builds of different k race to generate the
            # same bench graph: first registration wins, losers adopt it
            # (bench_graph is deterministic, so either copy is identical)
            with self._lock:
                return self._graphs.setdefault(name, g)
        raise KeyError(
            f"unknown workload {name!r}: register_graph() it or use one of "
            f"{sorted(BENCH_WORKLOADS)}"
        )

    # -- handle lookup ---------------------------------------------------
    def get(self, workload: str, k: int,
            timeout: float | None = None) -> IndexHandle:
        """Blocking lookup: memoized handle, or wait for the build."""
        return self.get_async(workload, k).result(timeout=timeout)

    def get_nowait(self, workload: str, k: int, *,
                   start_build: bool = True) -> IndexHandle | None:
        """Non-blocking probe. On a miss, optionally schedule the
        background build (so a later probe hits) and return ``None``."""
        key = (workload, int(k))
        with self._lock:
            h = self._entries.get(key)
            if h is not None:
                self._entries.move_to_end(key)
                return h
        if start_build:
            self.get_async(workload, k)
        return None

    def get_async(self, workload: str, k: int) -> "Future[IndexHandle]":
        """Future resolving to the built handle; build failures (including
        unknown workloads) surface as the future's exception. Concurrent
        callers of one cold key share a single pending future."""
        key = (workload, int(k))
        with self._lock:
            h = self._entries.get(key)
            if h is not None:
                self._entries.move_to_end(key)
                fut: Future = Future()
                fut.set_result(h)
                return fut
            fut = self._pending.get(key)
            if fut is not None:
                return fut
            fut = Future()
            self._pending[key] = fut
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self._build_workers,
                    thread_name_prefix="index-build")
            # submit under the lock: close() also takes it, so the pool
            # cannot shut down between registering the pending future and
            # scheduling its build
            try:
                self._pool.submit(self._run_build, key, fut)
            except RuntimeError as exc:   # pool raced to shutdown anyway
                self._pending.pop(key, None)
                fut.set_exception(exc)
        return fut

    def _run_build(self, key: tuple[str, int], fut: Future) -> None:
        try:
            handle = self._build(key)
        except BaseException as exc:
            with self._lock:
                self._pending.pop(key, None)
            fut.set_exception(exc)
            return
        evicted = []
        with self._lock:
            self._pending.pop(key, None)
            self._entries[key] = handle
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                evicted.append(self._entries.popitem(last=False))
                self.evictions += 1
                if self._metrics is not None:
                    self._metrics.count("index_evictions")
            listeners = list(self._evict_listeners)
        for (k2, h2) in evicted:
            for cb in listeners:
                cb(k2, h2)
        fut.set_result(handle)

    def _build(self, key: tuple[str, int]) -> IndexHandle:
        workload, k = key
        g = self.resolve_graph(workload)
        stages = {}
        t0 = time.perf_counter()
        tab = edge_core_times(g, k)
        stages["core_times"] = time.perf_counter() - t0
        t1 = time.perf_counter()
        builder = IncrementalBuilder(g, tab).run()
        stages["forest"] = time.perf_counter() - t1
        t1 = time.perf_counter()
        idx = pack_index(g, k, builder)
        stages["pack"] = time.perf_counter() - t1
        t1 = time.perf_counter()
        dev = to_device(idx)
        stages["device"] = time.perf_counter() - t1
        total = time.perf_counter() - t0
        handle = IndexHandle(key, g, idx, dev, total, stages)
        with self._lock:
            # under the lock: concurrent builds of *different* keys would
            # otherwise lose increments (read-modify-write race)
            self.builds += 1
        if self._metrics is not None:
            self._metrics.count("index_builds")
            self._metrics.observe("index_build", total)
            for stage, seconds in stages.items():
                self._metrics.observe(f"index_build_{stage}", seconds)
        return handle

    def close(self, wait: bool = True) -> None:
        """Stop the build pool. Pending futures still resolve when
        ``wait=True`` (builds run to completion)."""
        with self._lock:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=wait)

    def __contains__(self, key: tuple[str, int]) -> bool:
        with self._lock:
            return key in self._entries

    def stats(self) -> dict:
        with self._lock:
            return {
                "resident": list(self._entries),
                "capacity": self.capacity,
                "builds": self.builds,
                "evictions": self.evictions,
                "pending": list(self._pending),
                "resident_bytes": sum(h.nbytes for h in self._entries.values()),
            }
