"""Index registry: build, memoize, evict (StratifiedPECB, Device) index
pairs (DESIGN.md §7.4, §14).

One engine serves many workloads concurrently — a contact tracer asks k=2
and k=3 over the same graph, a dashboard watches five graphs. Index
construction is the offline plane (seconds); queries are the online plane
(microseconds). The registry keeps that split honest: the first request
for a workload pays ONE k-stratified build (`build_stratified_index` —
one fused core-time sweep plus one forest per stratum), and everyone
after gets the memoized handle, which answers *every* supported k;
capacity-bounded LRU eviction drops cold workloads.

Keys are workload names. The pre-stratified registry keyed residency by
``(workload, k)`` and built |K| independent indexes per graph; that key
space is collapsed — the k axis now lives inside the handle
(``handle.supported_ks``), and the legacy two-argument lookups remain as
``DeprecationWarning`` shims that ignore the k.

Which strata a workload gets is the registry's ``ks`` policy: the
default (``None``) covers the graph's full useful range
``default_ks(g)`` = 2..k_max(g); a global tuple or a per-workload
``set_ks`` override bounds |K| for graphs whose degeneracy makes the
full range wasteful. Queries for a k above ``k_max`` are exactly empty
and need no stratum; an in-range k outside the policy raises
``InvalidQueryError`` at answer time.

Builds run on a small background pool and are exposed three ways:

* ``get_async`` — returns a ``Future[IndexHandle]`` immediately; a
  thundering herd on a cold workload coalesces onto one pending future,
  while distinct workloads build in parallel (bounded by
  ``build_workers``).
* ``get_nowait`` — non-blocking probe; on a miss it (optionally) kicks off
  the background build and returns ``None`` so the caller's thread never
  blocks behind a multi-second build (the engine's submit path uses this).
* ``get`` — the blocking convenience wrapper (``get_async().result()``).

Each build records per-stage wall times (stratified core times, forests,
device upload) on the handle and into the metrics sink
(``index_build_<stage>``).

Graphs resolve by name: either registered explicitly (``register_graph``)
or one of the named bench workloads (``BENCH_WORKLOADS``).

Streaming epochs (DESIGN.md §9): ``extend_graph(name, edges)`` appends a
timestamp suffix to a registered graph and *refreshes* the resident
handle incrementally on a dedicated background worker
(``extend_stratified_core_times`` + ``extend_stratified_index`` +
``refresh_device`` — bit-identical to a cold rebuild for every stratum,
at a fraction of the cost; strata the appended edges add, e.g. a raised
k_max under the default policy, are built cold inside the same swap).
Handles are immutable and **epoch-versioned**: the swap into the
registry is atomic under the registry lock, so queries keep being
answered against the old epoch's handle until the refresh lands, and
in-flight batches holding the old handle stay consistent (its graph,
index and device mirror describe one snapshot). Refresh listeners
(``add_refresh_listener``) let the engine retire the old handle's
batcher and run the *targeted* result-cache purge.

Disk tier (DESIGN.md §13): with an :class:`~repro.store.IndexStore`
attached, the registry is durable — cold builds first try *promotion*
(mmap the stored epoch + device upload, no rebuild), landed builds and
epoch swaps are written through (suffix epochs as per-stratum deltas),
LRU eviction *demotes* instead of discarding, and unregistered workload
names resolve from the store's persisted graphs, so a restarted process
warm-opens in well under a second.

Retention (DESIGN.md §10): ``retain(name, t_cut)`` is the epoch
lifecycle's second leg — prefix expiry. It expires edges below ``t_cut``,
rebinds the name to the shifted epoch immediately, and *shrinks* the
resident handle on the same FIFO refresh worker
(``shrink_stratified_core_times`` + ``shrink_stratified_index`` +
``refresh_device`` — bit-identical to a cold build of the trimmed edge
list, at slicing cost; strata above the trimmed graph's k_max drop), so
a long-running ingest+trim loop holds index, table and device-mirror
memory bounded. Retention listeners (``add_retention_listener``) receive
``(key, old, new, t_cut)`` so the engine can purge expired cache windows
and rehome the survivors into the shifted timeline.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor

import numpy as np

from repro.obs.locks import named_lock
from repro.obs.trace import NULL_SPAN
from repro.core.temporal_graph import BENCH_WORKLOADS, TemporalGraph, bench_graph
from repro.core.core_time import (StratifiedCoreTable, _validate_ks,
                                  default_ks, extend_stratified_core_times,
                                  shrink_stratified_core_times,
                                  stratified_core_times)
from repro.core.pecb_index import StratifiedPECB, build_stratified_index
from repro.core.streaming import (extend_stratified_index,
                                  shrink_stratified_index)
from repro.core.batch_query import (DeviceIndex, refresh_device,
                                    stratum_device, to_device)

_K_KEY_DEPRECATION = (
    "per-k registry keys are deprecated: one k-stratified index serves "
    "every k — pass the workload name alone (the k argument is ignored; "
    "check handle.supported_ks)")


def _coerce_key(key) -> str:
    """Workload key from either the modern string or the legacy
    ``(workload, k)`` tuple (DeprecationWarning — the k axis lives inside
    the handle now)."""
    if isinstance(key, tuple):
        warnings.warn(_K_KEY_DEPRECATION, DeprecationWarning, stacklevel=3)
        return str(key[0])
    return str(key)


@dataclasses.dataclass(frozen=True)
class IndexHandle:
    """One workload's built k-stratified index: host arrays + device mirror.

    ``pecb`` answers every k in :attr:`supported_ks` (and every
    ``k > k_max(graph)`` exactly empty); ``device`` is the fused mixed-k
    mirror served by one compiled program per bucket shape. ``epoch``
    counts suffix extensions of the workload's graph; ``tab`` is the
    epoch's stratified core-time table, retained so the next refresh can
    extend every stratum in place."""

    key: str                      # workload name
    graph: TemporalGraph
    pecb: StratifiedPECB
    device: DeviceIndex
    build_seconds: float
    build_stages: dict = dataclasses.field(default_factory=dict, compare=False)
    epoch: int = 0
    tab: StratifiedCoreTable | None = dataclasses.field(default=None,
                                                        compare=False)
    # how the host arrays got here: "build" (cold construction or epoch
    # refresh) vs "disk" (promoted from the persistent store — mmap + device
    # upload, no rebuild). The planner stamps this onto result provenance.
    source: str = dataclasses.field(default="build", compare=False)
    # lazy per-k slices of the fused mirror for single-k launches (the
    # window sweep) — see :meth:`stratum_device`
    _stratum_dev: dict = dataclasses.field(default_factory=dict,
                                           compare=False, repr=False)

    @property
    def supported_ks(self) -> tuple:
        return self.pecb.supported_ks

    def stratum_device(self, k: int) -> DeviceIndex:
        """Stratum ``k``'s block of :attr:`device` as a standalone per-k
        mirror (``batch_query.stratum_device``), so single-k launches pay
        propagation on one stratum's nodes instead of all |K|. Memoized
        for the handle's lifetime — handles are immutable and swapped
        whole per epoch, so the memo can never go stale; the unlocked
        dict is a benign race (two threads may slice the same block, one
        result wins). Raises ``KeyError`` for an unsupported k."""
        k = int(k)
        dev = self._stratum_dev.get(k)
        if dev is None:
            dev = stratum_device(self.device, self.pecb, k)
            self._stratum_dev[k] = dev
        return dev

    @property
    def nbytes(self) -> int:
        return self.pecb.nbytes()

    @property
    def tab_nbytes(self) -> int:
        """Bytes retained for the refresh path: the stratified core-time
        table — per-k record blocks plus the run-length-encoded vertex
        core times. This replaces what used to be |K| per-handle dense
        ``(t_max+1, n)`` matrices and |K| version stores; the RLE strata
        are the memory lever behind the one-build-serves-every-k claim
        (asserted by the construction bench). Kept out of :attr:`nbytes`
        so the paper's index-size comparison stays undistorted, but
        surfaced in the registry's ``resident_tab_bytes`` stat because it
        is real, per-handle resident memory."""
        if self.tab is None:
            return 0
        return self.tab.nbytes()


class IndexRegistry:
    def __init__(self, capacity: int = 8, metrics=None, on_evict=None,
                 build_workers: int = 2, tracer=None, store=None, *,
                 ks=None):
        if capacity < 1:
            raise ValueError(f"registry capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._metrics = metrics
        # optional repro.store.IndexStore: the disk tier (DESIGN.md §13.4).
        # With a store attached, cold builds first try *promotion* (mmap the
        # stored epoch + device upload — no rebuild), every landed build /
        # refresh / trim is written through (deltas for epoch steps), and
        # LRU eviction demotes instead of discarding. All store I/O runs on
        # the background build/refresh workers, never under the registry
        # lock, and a store failure only costs durability — the build path
        # proceeds as if no store were attached.
        self._store = store
        # optional repro.obs.trace.Tracer: background builds / refreshes /
        # retention trims record spans (the engine passes its tracer when
        # it owns the registry). Epoch mutations accept an explicit parent
        # SpanContext so refresh spans nest under the ingest/retain span
        # that scheduled them — across the FIFO worker thread boundary
        # (DESIGN.md §11.2).
        self.tracer = tracer
        # strata policy: which ks each workload's one stratified build
        # covers. None = the full useful range default_ks(g) (2..k_max);
        # a tuple bounds |K| globally; set_ks() overrides per workload.
        self._default_ks = None if ks is None else _validate_ks(ks)
        self._ks_policy: dict[str, tuple] = {}
        # evict listeners: called as cb(key, handle) after an entry leaves
        # the registry (outside the registry lock). A list, not a slot:
        # several engines may share one registry (the bench does), and each
        # needs to retire its own batcher on eviction.
        self._evict_listeners: list = []
        if on_evict is not None:
            self._evict_listeners.append(on_evict)
        # refresh listeners: called as cb(key, old_handle, new_handle) after
        # an epoch refresh atomically swapped the resident handle
        self._refresh_listeners: list = []
        # retention listeners: called as cb(key, old_handle, new_handle,
        # t_cut) after a retention trim atomically swapped the resident
        # handle (the engine runs the shifted cache purge/rehome here)
        self._retention_listeners: list = []
        self._graphs: dict[str, TemporalGraph] = {}
        self._epochs: dict[str, int] = {}
        self._entries: "OrderedDict[str, IndexHandle]" = OrderedDict()
        self._lock = named_lock("registry")
        self._pending: dict[str, Future] = {}
        self._build_workers = max(1, int(build_workers))
        self._pool: ThreadPoolExecutor | None = None
        # refreshes run on their own single worker: FIFO, so chained
        # extend_graph calls refresh each workload in epoch order
        self._refresh_pool: ThreadPoolExecutor | None = None
        self.builds = 0
        self.evictions = 0
        self.refreshes = 0
        self.retentions = 0
        self.promotions = 0      # cold builds answered from the disk tier
        self.demotions = 0       # evictions preserved into the disk tier

    def add_evict_listener(self, cb) -> None:
        with self._lock:
            self._evict_listeners.append(cb)

    def remove_evict_listener(self, cb) -> None:
        with self._lock:
            if cb in self._evict_listeners:
                self._evict_listeners.remove(cb)

    def add_refresh_listener(self, cb) -> None:
        with self._lock:
            self._refresh_listeners.append(cb)

    def remove_refresh_listener(self, cb) -> None:
        with self._lock:
            if cb in self._refresh_listeners:
                self._refresh_listeners.remove(cb)

    def add_retention_listener(self, cb) -> None:
        with self._lock:
            self._retention_listeners.append(cb)

    def remove_retention_listener(self, cb) -> None:
        with self._lock:
            if cb in self._retention_listeners:
                self._retention_listeners.remove(cb)

    def _span(self, name: str, parent=None, **attrs):
        """Background-plane span, or the inert NULL_SPAN when untraced."""
        if self.tracer is None:
            return NULL_SPAN
        return self.tracer.start_span(name, parent=parent, cat="index",
                                      **attrs)

    # -- strata policy ----------------------------------------------------
    def set_ks(self, workload: str, ks) -> None:
        """Pin the strata the next (re)build of ``workload`` covers.
        ``None`` reverts to the registry default. Raises while the
        workload is resident or building — the policy must not fork from
        what the resident handle actually serves."""
        with self._lock:
            if workload in self._entries or workload in self._pending:
                raise RuntimeError(
                    f"cannot change ks policy for resident workload "
                    f"{workload!r}; evict or close first")
            if ks is None:
                self._ks_policy.pop(workload, None)
            else:
                self._ks_policy[workload] = _validate_ks(ks)

    def _ks_for(self, workload: str, g: TemporalGraph) -> tuple:
        with self._lock:
            explicit = self._ks_policy.get(workload, self._default_ks)
        return default_ks(g) if explicit is None else explicit

    # -- graph sources --------------------------------------------------
    def register_graph(self, name: str, g: TemporalGraph) -> None:
        """Bind ``name`` to a graph, immutably: indexes, cached results and
        batchers are all keyed by name, so silently rebinding a name would
        keep serving answers for the old graph. Re-registering the *same*
        object is a no-op; a different one raises — publish new snapshots
        under new names (e.g. ``"contacts@2026-07-31"``), or grow the bound
        graph with suffix edges through :meth:`extend_graph` (the epoch
        plane keeps every derived artifact consistent)."""
        with self._lock:
            prev = self._graphs.get(name)
            if prev is not None and prev is not g:
                raise ValueError(
                    f"graph name {name!r} is already bound; names are "
                    "immutable — register the new snapshot under a new name")
            self._graphs[name] = g

    def resolve_graph(self, name: str) -> TemporalGraph:
        with self._lock:
            if name in self._graphs:
                return self._graphs[name]
        # warm-restart adoption: a store holding this workload's persisted
        # epochs rebinds the name (and its epoch counter) from disk, so a
        # restarted process can keep serving — and keep ingesting — a graph
        # the previous process registered, without re-registration
        if self._store is not None:
            try:
                got = self._store.load_graph(name)
            except Exception:
                got = None   # adoption is best-effort; fall through
            if got is not None:
                g, epoch = got
                with self._lock:
                    if name not in self._graphs:
                        self._graphs[name] = g
                        self._epochs[name] = epoch
                    return self._graphs[name]
        if name in BENCH_WORKLOADS:
            g = bench_graph(name)
            # concurrent cold builds of different workloads race to generate
            # the same bench graph: first registration wins, losers adopt it
            # (bench_graph is deterministic, so either copy is identical)
            with self._lock:
                return self._graphs.setdefault(name, g)
        raise KeyError(
            f"unknown workload {name!r}: register_graph() it or use one of "
            f"{sorted(BENCH_WORKLOADS)}"
        )

    # -- streaming epochs -------------------------------------------------
    def extend_graph(self, name: str, edges,
                     parent=None) -> dict[str, "Future[IndexHandle]"]:
        """Append suffix ``edges`` to workload ``name`` and refresh its
        resident stratified index incrementally in the background.

        The graph rebind and epoch bump happen immediately (new cold builds
        see the new epoch); the resident handle keeps serving until its
        refreshed replacement is atomically swapped in. Returns a
        ``{workload: Future}`` dict (at most one entry), resolving with the
        refreshed handle. Suffix violations (historical timestamps, unknown
        vertices) raise here, before anything is mutated. ``parent`` (a
        span or SpanContext) parents the background ``index_refresh`` span
        under the caller's trace (DESIGN.md §11.2).
        """
        with self._lock:
            g = self._graphs.get(name)
        if g is None:
            g = self.resolve_graph(name)
        g2 = g.extend(edges)                 # raises on non-suffix input
        futures: dict = {}
        with self._lock:
            if self._graphs.get(name) is not g:
                raise RuntimeError(
                    f"concurrent extend_graph({name!r}); serialize ingests")
            if g2 is g:                      # empty append: nothing to do
                return {}
            self._graphs[name] = g2
            epoch = self._epochs.get(name, 0) + 1
            self._epochs[name] = epoch
            handle = self._entries.get(name)
            if handle is not None and self._refresh_pool is None:
                self._refresh_pool = ThreadPoolExecutor(
                    max_workers=1, thread_name_prefix="registry-refresh")
            if handle is not None:
                fut: Future = Future()
                futures[name] = fut
                self._refresh_pool.submit(
                    self._run_refresh, name, handle, g2, epoch, fut, parent)
        return futures

    def _run_refresh(self, key: str, old: IndexHandle, g2: TemporalGraph,
                     epoch: int, fut: Future, parent=None) -> None:
        span = self._span("index_refresh", parent=parent,
                          workload=key, epoch=epoch)
        try:
            # re-read the resident handle: the FIFO worker guarantees every
            # previously scheduled epoch mutation has landed, so a chain
            # like retain -> extend must grow from the *trimmed* handle the
            # shrink just swapped in, not the pre-trim handle captured at
            # schedule time (whose graph g2 no longer suffix-extends).
            # Chained suffix ingests also benefit: each refresh grows from
            # the latest epoch instead of re-deriving from the oldest.
            with self._lock:
                cur = self._entries.get(key)
            if cur is not None and cur.epoch >= epoch:
                span.set("outcome", "superseded").end()
                fut.set_result(cur)      # a newer epoch already landed
                return
            if cur is not None and cur.epoch > old.epoch:
                old = cur
            stages = {}
            t0 = time.perf_counter()
            if old.tab is None:
                raise RuntimeError(
                    f"handle {key!r} carries no stratified core-time table; "
                    "cannot refresh incrementally")
            ks = self._ks_for(key, g2)
            t1 = time.perf_counter()
            tab2 = extend_stratified_core_times(g2, old.tab, ks)
            stages["core_times"] = time.perf_counter() - t1
            span.child("core_times", t0=t1).end()
            t1 = time.perf_counter()
            idx2 = extend_stratified_index(g2, old.pecb, ks, strata=tab2)
            stages["forest"] = time.perf_counter() - t1
            span.child("forest", t0=t1).end()
            t1 = time.perf_counter()
            dev2, upload = refresh_device(old.pecb, old.device, idx2)
            stages["device"] = time.perf_counter() - t1
            span.child("device", t0=t1).end()
            total = time.perf_counter() - t0
            handle = IndexHandle(key, g2, idx2, dev2, total, stages,
                                 epoch=epoch, tab=tab2)
        except BaseException as exc:
            # failures must be observable even when nobody holds the future
            # (the build-race catch-up path): a failed refresh otherwise
            # leaves the registry silently serving the pre-ingest epoch
            if self._metrics is not None:
                self._metrics.count("index_refresh_failures")
            span.set("error", repr(exc)).end()
            fut.set_exception(exc)
            return
        swapped, replaced, listeners = self._swap_epoch_handle(
            key, old, handle, epoch, kind="refresh")
        if self._metrics is not None:
            self._metrics.count("index_refreshes")
            self._metrics.observe("index_refresh", total)
            for stage, seconds in stages.items():
                self._metrics.observe(f"index_refresh_{stage}", seconds)
            self._metrics.count("refresh_upload_bytes",
                                upload["uploaded_bytes"])
            self._metrics.count("refresh_reused_bytes",
                                upload["reused_bytes"])
        span.set("swapped", swapped).end()
        if swapped:
            # delta commit against the epoch the store already holds (the
            # replaced handle was written through when it landed); runs on
            # this FIFO worker, so per-key commits stay strictly ordered
            self._persist(key, handle, prev=replaced)
            for cb in listeners:
                cb(key, replaced, handle)
        fut.set_result(handle)

    def _swap_epoch_handle(self, key: str, grown_from: IndexHandle,
                           handle: IndexHandle, epoch: int, kind: str):
        """Atomic epoch-handle swap shared by refresh and shrink workers.

        Replaces the handle the worker grew from, or — chained epoch
        mutations: a prior worker may have already swapped a lower-epoch
        handle in — any resident handle of an older epoch. An eviction
        race (no resident entry) drops the new handle; the next cold
        build sees the new graph. Returns ``(swapped, replaced handle,
        listener snapshot)``; listeners are dispatched by the caller,
        outside the lock."""
        with self._lock:
            cur = self._entries.get(key)
            swapped = (cur is grown_from
                       or (cur is not None and cur.epoch < epoch))
            if swapped:
                self._entries[key] = handle
                self._entries.move_to_end(key)
            if kind == "refresh":
                self.refreshes += 1
                listeners = list(self._refresh_listeners)
            else:
                self.retentions += 1
                listeners = list(self._retention_listeners)
        return swapped, cur, listeners

    # -- retention (prefix expiry) ----------------------------------------
    def retain(self, name: str, t_cut: int,
               parent=None) -> dict[str, "Future[IndexHandle]"]:
        """Expire every edge of workload ``name`` with timestamp
        ``< t_cut`` and shrink the resident stratified index to the
        shifted retained epoch in the background (DESIGN.md §10).

        Mirrors :meth:`extend_graph`: the graph rebind and epoch bump are
        immediate (new cold builds see the trimmed epoch), the resident
        handle keeps serving until its shrunk replacement is atomically
        swapped in, and the returned ``{workload: Future}`` resolves with
        the swapped handle (``None`` if the workload was evicted before
        its trim ran). Trims share the single FIFO refresh worker with
        suffix refreshes, so an ``extend_graph`` + ``retain`` chain lands
        in order: the shrink always runs against the fully caught-up
        resident handle. ``t_cut <= 1`` trims nothing and returns ``{}``.
        """
        with self._lock:
            g = self._graphs.get(name)
        if g is None:
            g = self.resolve_graph(name)
        g2 = g.expire_before(t_cut)
        futures: dict = {}
        with self._lock:
            if self._graphs.get(name) is not g:
                raise RuntimeError(
                    f"concurrent extend/retain on {name!r}; serialize "
                    "epoch mutations")
            if g2 is g:                      # nothing expires: no-op
                return {}
            self._graphs[name] = g2
            epoch = self._epochs.get(name, 0) + 1
            self._epochs[name] = epoch
            if name in self._entries and self._refresh_pool is None:
                self._refresh_pool = ThreadPoolExecutor(
                    max_workers=1, thread_name_prefix="registry-refresh")
            if name in self._entries:
                fut: Future = Future()
                futures[name] = fut
                self._refresh_pool.submit(
                    self._run_shrink, name, g, g2, int(t_cut), epoch, fut,
                    parent)
        return futures

    def _run_shrink(self, key: str, g_old: TemporalGraph, g2: TemporalGraph,
                    t_cut: int, epoch: int, fut: Future,
                    parent=None) -> None:
        """FIFO-worker body of one (workload, trim). Unlike ``_run_refresh``
        (which grows from the handle captured at schedule time — valid
        because extending from *any* older suffix epoch works), the shrink
        re-reads the resident handle here: the FIFO worker guarantees
        every previously scheduled refresh has landed, so the resident
        handle describes exactly the pre-cut binding ``g_old``."""
        span = self._span("index_retention", parent=parent,
                          workload=key, epoch=epoch, t_cut=t_cut)
        try:
            with self._lock:
                cur = self._entries.get(key)
            if cur is None:
                span.set("outcome", "evicted").end()
                fut.set_result(None)     # evicted mid-queue: next cold
                return                   # build sees the trimmed epoch
            if cur.epoch >= epoch or cur.graph is g2:
                span.set("outcome", "superseded").end()
                fut.set_result(cur)      # a cold build already caught up
                return
            stages = {}
            t0 = time.perf_counter()
            # expiry can only lower coreness, so the target strata are a
            # subset of the resident ones under the default policy; an
            # explicit policy intersects with what is actually resident
            # (strata that were never built cannot be shrunk — and expiry
            # cannot create the need for one)
            ks = tuple(k for k in self._ks_for(key, g2)
                       if k in cur.pecb.supported_ks)
            if cur.graph is g_old and cur.tab is not None:
                t1 = time.perf_counter()
                tab2 = shrink_stratified_core_times(g2, cur.tab, ks)
                stages["core_times"] = time.perf_counter() - t1
                span.child("core_times", t0=t1).end()
                t1 = time.perf_counter()
                idx2 = shrink_stratified_index(g2, cur.pecb, ks,
                                               strata=tab2)
                stages["forest"] = time.perf_counter() - t1
                span.child("forest", t0=t1).end()
            else:
                # resident handle does not describe the pre-cut epoch (a
                # cold-build race stored an intermediate snapshot): fall
                # back to an exact cold build of the trimmed graph
                ks = self._ks_for(key, g2)
                t1 = time.perf_counter()
                tab2 = stratified_core_times(g2, ks)
                stages["core_times"] = time.perf_counter() - t1
                span.child("core_times", t0=t1, cold=True).end()
                t1 = time.perf_counter()
                idx2 = build_stratified_index(g2, ks, strata=tab2)
                stages["forest"] = time.perf_counter() - t1
                span.child("forest", t0=t1, cold=True).end()
            t1 = time.perf_counter()
            dev2, upload = refresh_device(cur.pecb, cur.device, idx2)
            stages["device"] = time.perf_counter() - t1
            span.child("device", t0=t1).end()
            total = time.perf_counter() - t0
            handle = IndexHandle(key, g2, idx2, dev2, total, stages,
                                 epoch=epoch, tab=tab2)
        except BaseException as exc:
            if self._metrics is not None:
                self._metrics.count("index_retention_failures")
            span.set("error", repr(exc)).end()
            fut.set_exception(exc)
            return
        swapped, replaced, listeners = self._swap_epoch_handle(
            key, cur, handle, epoch, kind="retention")
        if self._metrics is not None:
            self._metrics.count("index_retentions")
            self._metrics.observe("index_retention", total)
            for stage, seconds in stages.items():
                self._metrics.observe(f"index_retention_{stage}", seconds)
            self._metrics.count("retention_freed_bytes",
                                upload["freed_bytes"])
        span.set("swapped", swapped).end()
        if swapped:
            # prefix-expiry epochs rarely delta (arrays shrink and shift),
            # but put_handle still avoids a rewrite when nothing changed
            self._persist(key, handle, prev=replaced)
            for cb in listeners:
                cb(key, replaced, handle, t_cut)
        fut.set_result(handle)

    # -- handle lookup ---------------------------------------------------
    def get(self, workload: str, k: int | None = None,
            timeout: float | None = None) -> IndexHandle:
        """Blocking lookup: memoized handle, or wait for the build. The
        handle answers every supported k; passing ``k`` is deprecated."""
        if k is not None:
            warnings.warn(_K_KEY_DEPRECATION, DeprecationWarning,
                          stacklevel=2)
        return self.get_async(workload).result(timeout=timeout)

    def get_nowait(self, workload: str, k: int | None = None, *,
                   start_build: bool = True) -> IndexHandle | None:
        """Non-blocking probe. On a miss, optionally schedule the
        background build (so a later probe hits) and return ``None``."""
        if k is not None:
            warnings.warn(_K_KEY_DEPRECATION, DeprecationWarning,
                          stacklevel=2)
        key = str(workload)
        with self._lock:
            h = self._entries.get(key)
            if h is not None:
                self._entries.move_to_end(key)
                return h
        if start_build:
            self.get_async(key)
        return None

    def get_async(self, workload: str,
                  k: int | None = None) -> "Future[IndexHandle]":
        """Future resolving to the built handle; build failures (including
        unknown workloads) surface as the future's exception. Concurrent
        callers of one cold workload share a single pending future."""
        if k is not None:
            warnings.warn(_K_KEY_DEPRECATION, DeprecationWarning,
                          stacklevel=2)
        key = str(workload)
        with self._lock:
            h = self._entries.get(key)
            if h is not None:
                self._entries.move_to_end(key)
                fut: Future = Future()
                fut.set_result(h)
                return fut
            fut = self._pending.get(key)
            if fut is not None:
                return fut
            fut = Future()
            self._pending[key] = fut
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self._build_workers,
                    thread_name_prefix="build-pool")
            # submit under the lock: close() also takes it, so the pool
            # cannot shut down between registering the pending future and
            # scheduling its build
            try:
                self._pool.submit(self._run_build, key, fut)
            except RuntimeError as exc:   # pool raced to shutdown anyway
                self._pending.pop(key, None)
                fut.set_exception(exc)
        return fut

    def _run_build(self, key: str, fut: Future) -> None:
        try:
            handle = self._build(key)
        except BaseException as exc:
            with self._lock:
                self._pending.pop(key, None)
            fut.set_exception(exc)
            return
        # write-through *before* the future resolves: once any caller has
        # seen the handle, a crash (even kill -9) must find this epoch on
        # disk — that ordering is what the CI warm-restart smoke kills
        self._persist(key, handle)
        evicted = []
        catchup = None
        with self._lock:
            self._pending.pop(key, None)
            self._entries[key] = handle
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                evicted.append(self._entries.popitem(last=False))
                self.evictions += 1
                if self._metrics is not None:
                    self._metrics.count("index_evictions")
            listeners = list(self._evict_listeners)
            # an extend_graph that ran while this build was in flight found
            # no resident entry to refresh; catch the stored handle up to
            # the current epoch now, or it would serve pre-ingest data
            # until the next ingest
            cur_g = self._graphs.get(key)
            if (cur_g is not None and cur_g is not handle.graph
                    and self._entries.get(key) is handle):
                if self._refresh_pool is None:
                    self._refresh_pool = ThreadPoolExecutor(
                        max_workers=1, thread_name_prefix="registry-refresh")
                # capture the pool under the lock: close() nulls the
                # attribute, and the build future must resolve regardless
                catchup = (self._refresh_pool, handle, cur_g,
                           self._epochs.get(key, 0))
        for (k2, h2) in evicted:
            self._demote(k2, h2)
            for cb in listeners:
                cb(k2, h2)
        fut.set_result(handle)
        if catchup is not None:
            pool, stale, cur_g, epoch = catchup
            try:
                pool.submit(self._run_refresh, key, stale, cur_g, epoch,
                            Future())
            except RuntimeError:
                pass   # registry closing: stale data is moot

    def _build(self, key: str) -> IndexHandle:
        workload = key
        g = self.resolve_graph(workload)
        with self._lock:
            # re-read graph and epoch together: an extend_graph between the
            # resolve and here must not yield a new epoch number stamped on
            # an old graph (or vice versa)
            g = self._graphs.get(workload, g)
            epoch = self._epochs.get(workload, 0)
        ks = self._ks_for(workload, g)
        if self._store is not None:
            promoted = self._promote(key, g, epoch, ks)
            if promoted is not None:
                return promoted
        span = self._span("index_build", workload=workload,
                          num_strata=len(ks), epoch=epoch)
        stages = {}
        try:
            t0 = time.perf_counter()
            tab = stratified_core_times(g, ks)
            stages["core_times"] = time.perf_counter() - t0
            span.child("core_times", t0=t0).end()
            t1 = time.perf_counter()
            idx = build_stratified_index(g, ks, strata=tab)
            stages["forest"] = time.perf_counter() - t1
            span.child("forest", t0=t1).end()
            t1 = time.perf_counter()
            dev = to_device(idx)
            stages["device"] = time.perf_counter() - t1
            span.child("device", t0=t1).end()
            total = time.perf_counter() - t0
        except BaseException as exc:
            span.set("error", repr(exc)).end()
            raise
        span.end()
        handle = IndexHandle(key, g, idx, dev, total, stages,
                             epoch=epoch, tab=tab)
        with self._lock:
            # under the lock: concurrent builds of *different* workloads
            # would otherwise lose increments (read-modify-write race)
            self.builds += 1
        if self._metrics is not None:
            self._metrics.count("index_builds")
            self._metrics.observe("index_build", total)
            for stage, seconds in stages.items():
                self._metrics.observe(f"index_build_{stage}", seconds)
        return handle

    # -- disk tier (DESIGN.md §13.4) --------------------------------------
    def _promote(self, key: str, g: TemporalGraph, epoch: int,
                 ks: tuple) -> IndexHandle | None:
        """Try to answer a cold build from the store: mmap the stored
        epoch, check it describes exactly the graph the build would target
        (same epoch number *and* identical edge arrays — epoch counters
        reset across processes, so the arrays are authoritative) AND the
        strata the current policy asks for, upload to the device, and mint
        a ``source="disk"`` handle. ``None`` on any miss or mismatch — the
        caller falls through to the cold build."""
        workload = key
        span = self._span("index_promote", workload=workload, epoch=epoch)
        try:
            stored = self._store.load(key)
        except Exception as exc:
            if self._metrics is not None:
                self._metrics.count("store_load_failures")
            span.set("error", repr(exc)).end()
            return None
        if stored is None:
            span.set("outcome", "miss").end()
            return None
        sg = stored.graph
        if not (sg.n == g.n and sg.m == g.m
                and np.array_equal(sg.src, g.src)
                and np.array_equal(sg.dst, g.dst)
                and np.array_equal(sg.t, g.t)):
            span.set("outcome", "stale").end()
            return None
        if tuple(stored.pecb.supported_ks) != tuple(ks):
            span.set("outcome", "ks-mismatch").end()
            return None
        stages = {}
        t0 = time.perf_counter()
        try:
            dev = to_device(stored.pecb)
        except Exception as exc:
            if self._metrics is not None:
                self._metrics.count("store_load_failures")
            span.set("error", repr(exc)).end()
            return None
        stages["device"] = total = time.perf_counter() - t0
        span.child("device", t0=t0).end()
        span.set("outcome", "promoted").end()
        with self._lock:
            self.promotions += 1
        if self._metrics is not None:
            self._metrics.count("promotions")
            self._metrics.observe("index_promote", total)
        # the handle binds the *registry's* graph object (identity matters
        # to the epoch lifecycle), the store's mmap-backed index arrays,
        # and the fresh device mirror; build_seconds is the promote cost —
        # that asymmetry vs the cold build is the whole point
        return IndexHandle(key, g, stored.pecb, dev, total, stages,
                           epoch=epoch, tab=stored.tab, source="disk")

    def _persist(self, key: str, handle: IndexHandle,
                 prev: IndexHandle | None = None) -> dict | None:
        """Write ``handle`` through to the store (delta against ``prev``
        when given). Best-effort: failures count a metric and return
        ``None`` — durability degrades, serving does not."""
        if self._store is None:
            return None
        if handle.source == "disk" and prev is None:
            return None     # just promoted from this store: already current
        try:
            return self._store.put_handle(key, handle, prev=prev)
        except Exception as exc:
            if self._metrics is not None:
                self._metrics.count("store_commit_failures")
            if self.tracer is not None:
                self._span("store_commit_failed", workload=key,
                           error=repr(exc)).end()
            return None

    def _demote(self, key: str, handle: IndexHandle) -> None:
        """Eviction hook: preserve the evicted handle's epoch in the store
        (write-through usually already has it — then this is a cheap
        manifest probe, not a rewrite) instead of discarding built work."""
        if self._store is None:
            return
        res = self._persist(key, handle, prev=None)
        if res is None and handle.source != "disk":
            return          # commit failed: nothing preserved
        with self._lock:
            self.demotions += 1
        if self._metrics is not None:
            self._metrics.count("evictions_demoted")
            if res is not None and res["mode"] != "current":
                self._metrics.count("demote_bytes", res["bytes_written"])

    def close(self, wait: bool = True) -> None:
        """Stop the build and refresh pools. Pending futures still resolve
        when ``wait=True`` (builds run to completion)."""
        with self._lock:
            pool, self._pool = self._pool, None
            rpool, self._refresh_pool = self._refresh_pool, None
        if pool is not None:
            pool.shutdown(wait=wait)
        if rpool is not None:
            rpool.shutdown(wait=wait)

    def __contains__(self, key) -> bool:
        key = _coerce_key(key)
        with self._lock:
            return key in self._entries

    def stats(self) -> dict:
        with self._lock:
            return {
                "resident": list(self._entries),
                "capacity": self.capacity,
                "builds": self.builds,
                "evictions": self.evictions,
                "refreshes": self.refreshes,
                "retentions": self.retentions,
                "promotions": self.promotions,
                "demotions": self.demotions,
                "epochs": dict(self._epochs),
                "pending": list(self._pending),
                "supported_ks": {w: list(h.supported_ks)
                                 for w, h in self._entries.items()},
                "resident_bytes": sum(h.nbytes for h in self._entries.values()),
                "resident_tab_bytes": sum(h.tab_nbytes
                                          for h in self._entries.values()),
            }
