"""Shape-bucketed, device-sharded execution of the batched query plane
(DESIGN.md §7.2, §7.6).

``batch_query`` is ``jax.jit``-compiled, and XLA specializes on the batch
shape: a stream of ragged micro-batches (B = 13, 57, 200, ...) would compile
once *per distinct size*. The fix is shape bucketing: pad every batch up to
the next power of two (floored at ``min_bucket``, capped at ``max_batch``),
so a serving process compiles at most ``log2(max_batch / min_bucket) + 1``
programs per index and then never again. Padding lanes use the inert query
``(u=0, ts=1, te=0)``: ``te < ts`` can match nothing (core times are >= 1),
so pad lanes return empty masks and are sliced off before unpacking.

Multi-device: when the process sees more than one JAX device, the (B, n)
propagation shards over the batch dimension with ``jax.sharding`` — a 1-D
``('batch',)`` mesh, queries placed with ``PartitionSpec('batch')``, index
arrays replicated by the partitioner (they are read-only gather operands).
Buckets are sized to multiples of the device count so the placement is
exact. Fallback: with one device (this container: CPU x1) or a bucket not
divisible by the mesh, arrays stay uncommitted and jit runs single-device —
semantics identical, tested by the sharded subprocess suite
(tests/test_distributed.py).
"""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.batch_query import (DeviceIndex, batch_query,
                                    batch_query_full,
                                    batch_query_full_mixed, window_sweep)

#: Inert padding query: te < ts matches no core-time entry (cts are >= 1).
PAD_QUERY = (0, 1, 0)


def bucket_size(b: int, min_bucket: int = 8, max_batch: int = 256) -> int:
    """Smallest power-of-two bucket >= b, floored/capped to the configured
    range. ``b`` beyond ``max_batch`` is the batcher's bug, not ours."""
    if not 1 <= b <= max_batch:
        raise ValueError(f"batch size {b} outside [1, {max_batch}]")
    bucket = max(min_bucket, 1 << (b - 1).bit_length())
    return min(bucket, max_batch)


def pad_queries(u, ts, te, bucket: int):
    """int32[(bucket,)] x3, padded with the inert query."""
    u = np.asarray(u, np.int32)
    ts = np.asarray(ts, np.int32)
    te = np.asarray(te, np.int32)
    b = u.shape[0]
    if b > bucket:
        raise ValueError(f"batch of {b} queries exceeds bucket {bucket}")
    if b == bucket:
        return u, ts, te
    pad = bucket - b
    return (
        np.concatenate([u, np.full(pad, PAD_QUERY[0], np.int32)]),
        np.concatenate([ts, np.full(pad, PAD_QUERY[1], np.int32)]),
        np.concatenate([te, np.full(pad, PAD_QUERY[2], np.int32)]),
    )


class ShardedExecutor:
    """Runs padded query batches on all visible devices.

    One executor per engine; stateless across calls apart from the device
    mesh, so it is safe to share between batcher worker threads (jit
    dispatch is thread-safe).
    """

    def __init__(self, devices=None, *, metrics=None, tracer=None):
        self.devices = list(devices) if devices is not None else jax.devices()
        self.num_devices = len(self.devices)
        # observability sinks (DESIGN.md §11.4): compile events from the
        # jit caches are *recorded*, not inferred — a compile storm shows
        # up as jit_compile_* counters and "compile"-category trace spans
        self.metrics = metrics
        self.tracer = tracer
        if self.num_devices > 1:
            self.mesh = Mesh(np.asarray(self.devices), ("batch",))
            self.batch_sharding = NamedSharding(self.mesh, P("batch"))
        else:
            self.mesh = None
            self.batch_sharding = None

    def _track_compile(self, fn, program: str, bucket: int, t0: float):
        """Called after a jit dispatch: if the program's cache grew, this
        launch paid a compile — count it and record a trace span covering
        the dispatch (on CPU the compile completes synchronously inside
        it, so the span duration is a faithful compile cost)."""
        t1 = time.perf_counter()
        if self.metrics is not None:
            self.metrics.count("jit_compiles")
            self.metrics.count(f"jit_compile_{program}")
            self.metrics.observe("jit_compile", t1 - t0)
        if self.tracer is not None:
            self.tracer.start_span(
                "jit_compile", parent=None, cat="compile", t0=t0,
                program=program, bucket=bucket,
                cache_size=fn._cache_size()).end(t1)

    def _dispatch(self, fn, program: str, bucket: int, args):
        c0 = fn._cache_size()
        t0 = time.perf_counter()
        out = fn(*args)
        if fn._cache_size() > c0:
            self._track_compile(fn, program, bucket, t0)
        return out

    def align(self, bucket: int) -> int:
        """Round a bucket up to a multiple of the device count (no-op for
        power-of-two device counts <= bucket, the common case)."""
        d = self.num_devices
        if d <= 1 or bucket % d == 0:
            return bucket
        return ((bucket + d - 1) // d) * d

    def final_bucket(self, b: int, min_bucket: int, max_batch: int) -> int:
        """The executed batch shape for ``b`` requests: power-of-two bucket,
        aligned to the device count. Single owner of the formula — callers
        use this for padding metrics and pass the result to ``run``."""
        return self.align(bucket_size(b, min_bucket, max_batch))

    def _place(self, up, tsp, tep, bucket):
        if self.batch_sharding is not None and bucket % self.num_devices == 0:
            # the one deliberate upload: padded query arrays onto the
            # batch sharding before dispatch
            # repro: ignore[hot-path-transfer]
            return tuple(jax.device_put(jnp.asarray(a), self.batch_sharding)
                         for a in (up, tsp, tep))
        return jnp.asarray(up), jnp.asarray(tsp), jnp.asarray(tep)

    def run(self, dix: DeviceIndex, u, ts, te, bucket: int) -> np.ndarray:
        """bool[B, n] membership masks for the *unpadded* prefix. ``bucket``
        must come from ``final_bucket`` (already device-aligned)."""
        b = len(u)
        if self.align(bucket) != bucket:
            raise ValueError(f"bucket {bucket} is not device-aligned; "
                             "use final_bucket()")
        qu, qts, qte = self._place(*pad_queries(u, ts, te, bucket), bucket)
        mask = self._dispatch(batch_query, "batch_query", bucket,
                              (dix, qu, qts, qte))
        # repro: ignore[hot-path-transfer] — the measured result download
        return np.asarray(jax.device_get(mask))[:b]

    def run_full(self, dix: DeviceIndex, u, ts, te,
                 bucket: int) -> tuple[np.ndarray, np.ndarray]:
        """(bool[B, n] vertex masks, bool[B, V] version-membership masks)
        for the unpadded prefix — the EDGES/SUBGRAPH-mode launch."""
        b = len(u)
        if self.align(bucket) != bucket:
            raise ValueError(f"bucket {bucket} is not device-aligned; "
                             "use final_bucket()")
        qu, qts, qte = self._place(*pad_queries(u, ts, te, bucket), bucket)
        vmask, vermask = self._dispatch(batch_query_full, "batch_query_full",
                                        bucket, (dix, qu, qts, qte))
        # repro: ignore[hot-path-transfer] — measured result downloads
        return (np.asarray(jax.device_get(vmask))[:b],
                np.asarray(  # repro: ignore[hot-path-transfer] — ditto
                    jax.device_get(vermask))[:b, :dix.num_versions])

    def run_full_mixed(self, dix: DeviceIndex, slot, ts, te, kq,
                       bucket: int) -> tuple[np.ndarray, np.ndarray]:
        """Mixed-k full-mode launch against a *stratified* device index:
        ``slot`` is the per-query entry slot ``k_index(k) * n + u`` and
        ``kq`` the per-query k filtering the shared version arrays — both
        plain device operands, so every k mix shares one compiled program
        per bucket. Returns the same ``(vertex masks, version masks)``
        pair as :meth:`run_full`."""
        b = len(slot)
        if self.align(bucket) != bucket:
            raise ValueError(f"bucket {bucket} is not device-aligned; "
                             "use final_bucket()")
        qs, qts, qte = self._place(*pad_queries(slot, ts, te, bucket), bucket)
        kq = np.asarray(kq, np.int32)
        if kq.shape[0] < bucket:
            # pad lanes are already inert via te < ts; kq=0 matches no
            # stratum, keeping the version mask all-False twice over
            kq = np.concatenate([kq, np.zeros(bucket - b, np.int32)])
        if self.batch_sharding is not None and bucket % self.num_devices == 0:
            # repro: ignore[hot-path-transfer] — padded operand upload
            qkq = jax.device_put(jnp.asarray(kq), self.batch_sharding)
        else:
            qkq = jnp.asarray(kq)
        vmask, vermask = self._dispatch(
            batch_query_full_mixed, "batch_query_full_mixed", bucket,
            (dix, qs, qts, qte, qkq))
        # repro: ignore[hot-path-transfer] — measured result downloads
        return (np.asarray(jax.device_get(vmask))[:b],
                np.asarray(  # repro: ignore[hot-path-transfer] — ditto
                    jax.device_get(vermask))[:b, :dix.num_versions])

    def run_sweep(self, dix: DeviceIndex, u: int, ts, te,
                  bucket: int) -> np.ndarray:
        """bool[W, n] masks of one vertex over W windows in one launch.
        Windows pad with the inert (ts=1, te=0) window; the batch (window)
        dimension shards exactly like ``run``'s."""
        w = len(ts)
        if self.align(bucket) != bucket:
            raise ValueError(f"bucket {bucket} is not device-aligned; "
                             "use final_bucket()")
        _, tsp, tep = pad_queries([u] * w, ts, te, bucket)
        _, qts, qte = self._place(np.zeros(bucket, np.int32), tsp, tep, bucket)
        mask = self._dispatch(window_sweep, "window_sweep", bucket,
                              (dix, jnp.int32(u), qts, qte))
        # repro: ignore[hot-path-transfer] — the measured result download
        return np.asarray(jax.device_get(mask))[:w]

    @staticmethod
    def compile_count() -> int:
        """Number of distinct programs compiled for the batched query plane
        (jit cache entries, summed over the vertex-mask, full-mode and
        window-sweep programs). Bucketing tests assert this stays flat
        across batch sizes within one bucket."""
        return (batch_query._cache_size() + batch_query_full._cache_size()
                + batch_query_full_mixed._cache_size()
                + window_sweep._cache_size())
