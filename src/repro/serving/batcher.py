"""Shape-bucketed micro-batcher (DESIGN.md §7.1).

Heavy traffic arrives as independent single queries; the device plane wants
thousands per launch. The micro-batcher is the adapter: callers get a
``concurrent.futures.Future`` back immediately, a worker thread collects
pending requests and flushes a batch when either

* the batch is full (``max_batch`` requests), or
* the oldest pending request has waited ``flush_ms`` (the latency SLO knob), or
* someone forces a flush (``flush()``, ``drain()``, ``close()``).

One batcher per index handle — requests against different workload
indexes can never share a device launch, so the engine keys batchers by
handle. Downstream shape bucketing (executor.py) pads each flushed batch to
a power of two, so the flush size need not be exact for compile stability.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Callable, Sequence

from repro.obs.locks import named_condition


@dataclasses.dataclass
class Request:
    """One TCCS query in flight.

    ``spec`` is the canonical :class:`repro.core.query_api.TCCSQuery` the
    engine resolved (mode, k, clamped window); the positional ``u/ts/te``
    mirror it for the device plane's array packing and for legacy callers
    that construct bare requests.
    """

    u: int
    ts: int
    te: int
    future: Future
    t_submit: float          # engine submit time (e2e latency anchor)
    t_enqueue: float = 0.0   # batcher enqueue time (queue-wait anchor)
    spec: object | None = None  # canonical TCCSQuery (query API v2)
    # open root query span (repro.obs.trace.Span) riding across the thread
    # boundary: the engine opens it on the caller thread, the planner hangs
    # queue/route/execute children off it on the worker thread (explicit
    # context propagation, DESIGN.md §11.2). None for bare legacy requests.
    span: object | None = None


class MicroBatcher:
    """Collects requests into batches and hands them to ``execute_fn``.

    ``execute_fn(batch) -> list[result]`` runs on the worker thread and must
    return one result per request, in order. The batcher resolves futures
    and records queue-wait / end-to-end latency; a raising ``execute_fn``
    fails every future in the batch (no request is silently dropped).
    """

    def __init__(self, execute_fn: Callable[[list[Request]], list],
                 *, max_batch: int = 256, flush_ms: float = 2.0,
                 name: str = "batcher", metrics=None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self._execute = execute_fn
        self.max_batch = max_batch
        self.flush_s = flush_ms / 1e3
        self._metrics = metrics
        self._pending: deque[Request] = deque()
        self._cond = named_condition("batcher")
        self._stop = False
        self._force_flush = False
        self._inflight = 0
        self._worker = threading.Thread(target=self._loop, daemon=True, name=name)
        self._worker.start()

    # -- producer side ---------------------------------------------------
    def submit(self, req: Request) -> Future:
        return self.submit_many([req])[0]

    def submit_many(self, reqs: Sequence[Request]) -> list[Future]:
        now = time.perf_counter()
        with self._cond:
            if self._stop:
                raise RuntimeError("batcher is closed")
            for r in reqs:
                r.t_enqueue = now
                self._pending.append(r)
            self._cond.notify_all()
        return [r.future for r in reqs]

    def flush(self) -> None:
        """Dispatch whatever is pending without waiting for the deadline.
        A no-op when nothing is pending: the flag must not leak into the
        next batch's deadline wait."""
        with self._cond:
            if self._pending:
                self._force_flush = True
                self._cond.notify_all()

    def drain(self, timeout: float | None = None) -> None:
        """Block until every submitted request has been resolved.

        Raises ``TimeoutError`` only while work is genuinely outstanding.
        The predicate re-check directly before the raise makes that
        contract locally self-evident (and robust to future edits that
        might release the lock inside the loop body); under the current
        single condition lock the loop-top test already guarantees it —
        a deadline racing the worker's final notify re-tests the
        predicate at the top and drains cleanly."""
        end = None if timeout is None else time.perf_counter() + timeout
        with self._cond:
            while self._pending or self._inflight:
                if self._pending:
                    self._force_flush = True
                self._cond.notify_all()
                wait = 0.05
                if end is not None:
                    wait = min(wait, end - time.perf_counter())
                    if wait <= 0:
                        if not (self._pending or self._inflight):
                            return      # emptied at the deadline: drained
                        raise TimeoutError("batcher drain timed out")
                self._cond.wait(timeout=wait)

    def close(self) -> None:
        """Flush remaining work and stop the worker."""
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        self._worker.join()

    @property
    def depth(self) -> int:
        with self._cond:
            return len(self._pending)

    # -- worker side -----------------------------------------------------
    def _count(self, name: str) -> None:
        if self._metrics is not None:
            self._metrics.count(name)

    def _loop(self) -> None:
        while True:
            with self._cond:
                while not self._pending and not self._stop:
                    self._cond.wait()
                if self._stop and not self._pending:
                    return
                deadline = self._pending[0].t_enqueue + self.flush_s
                while (len(self._pending) < self.max_batch
                       and not self._force_flush and not self._stop):
                    remaining = deadline - time.perf_counter()
                    if remaining <= 0:
                        break
                    self._cond.wait(timeout=remaining)
                if len(self._pending) >= self.max_batch:
                    self._count("flush_full")
                elif self._stop:
                    self._count("flush_close")
                elif self._force_flush:
                    self._count("flush_forced")
                else:
                    self._count("flush_deadline")
                take = min(len(self._pending), self.max_batch)
                # Clear the force flag only once this dispatch drains the
                # queue. Clearing unconditionally would (a) swallow a
                # flush() aimed at requests beyond a simultaneously-full
                # batch (they'd sit out a whole deadline), and (b) if the
                # flag were ever set with nothing pending, leak it into the
                # next unrelated batch as a premature, miscounted
                # flush_forced dispatch.
                if take == len(self._pending):
                    self._force_flush = False
                batch = [self._pending.popleft() for _ in range(take)]
                self._inflight += take
            self._run_batch(batch)
            with self._cond:
                self._inflight -= len(batch)
                self._cond.notify_all()

    def _run_batch(self, batch: list[Request]) -> None:
        t0 = time.perf_counter()
        if self._metrics is not None:
            for r in batch:
                self._metrics.observe("queue_wait", t0 - r.t_enqueue)
        try:
            results = self._execute(batch)
            if len(results) != len(batch):
                raise RuntimeError(
                    f"execute_fn returned {len(results)} results for a "
                    f"batch of {len(batch)}")
        except BaseException as e:  # noqa: BLE001 — fail the futures, keep serving
            for r in batch:
                if not r.future.done():
                    r.future.set_exception(e)
            return
        now = time.perf_counter()
        for r, res in zip(batch, results):
            r.future.set_result(res)
            if self._metrics is not None:
                self._metrics.observe("e2e", now - r.t_submit)
