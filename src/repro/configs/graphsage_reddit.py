"""graphsage-reddit [gnn]: 2 layers d_hidden=128 mean aggregator,
sample sizes 25-10.  [arXiv:1706.02216; paper]"""
from ..models.gnn import SAGEConfig
from .base import ArchSpec, GNN_SHAPES, register

SPEC = register(ArchSpec(
    id="graphsage-reddit",
    family="gnn",
    model_cfg=SAGEConfig(n_layers=2, d_hidden=128, n_classes=41),
    smoke_cfg=SAGEConfig(n_layers=2, d_hidden=16, n_classes=5),
    shapes=GNN_SHAPES, skips={},
    source="arXiv:1706.02216; paper",
))
