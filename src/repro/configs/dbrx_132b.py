"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16 experts top-4, fine-grained.  [hf:databricks/dbrx-base; unverified]"""
from ..models.transformer import LMConfig, MoEConfig
from .base import ArchSpec, LM_SHAPES, LM_SKIPS, register

SPEC = register(ArchSpec(
    id="dbrx-132b",
    family="lm-moe",
    model_cfg=LMConfig(
        name="dbrx-132b", n_layer=40, d_model=6144, n_head=48, n_kv=8,
        d_ff=10752, vocab=100352, d_head=128, rope_theta=500_000.0,
        moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752),
    ),
    smoke_cfg=LMConfig(
        name="dbrx-132b-smoke", n_layer=2, d_model=64, n_head=8, n_kv=2,
        d_ff=128, vocab=256, d_head=8, remat=False,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64),
    ),
    shapes=LM_SHAPES, skips=LM_SKIPS,
    source="hf:databricks/dbrx-base; unverified",
))
