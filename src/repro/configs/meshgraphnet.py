"""meshgraphnet [gnn]: 15 layers d_hidden=128 sum aggregator, 2-layer MLPs.
[arXiv:2010.03409; unverified]"""
from ..models.gnn import MGNConfig
from .base import ArchSpec, GNN_SHAPES, register

SPEC = register(ArchSpec(
    id="meshgraphnet",
    family="gnn",
    model_cfg=MGNConfig(n_layers=15, d_hidden=128, mlp_layers=2),
    smoke_cfg=MGNConfig(n_layers=2, d_hidden=16, mlp_layers=2),
    shapes=GNN_SHAPES, skips={},
    source="arXiv:2010.03409; unverified",
))
