"""Config registry: one module per assigned architecture."""

from . import base
from .base import (ArchSpec, REGISTRY, all_cells, get, input_specs,
                   cell_model_cfg, smoke_dims, abstract_params, init_params, model_flops,
                   make_train_step, make_serve_step, param_specs, batch_specs)

_ARCH_MODULES = (
    "dbrx_132b", "qwen2_moe_a2_7b", "glm4_9b", "codeqwen1_5_7b",
    "qwen1_5_110b", "meshgraphnet", "nequip", "graphsage_reddit",
    "mace", "mind",
)


def load_all():
    import importlib
    for m in _ARCH_MODULES:
        importlib.import_module(f"{__name__}.{m}")
    return dict(REGISTRY)


load_all()
