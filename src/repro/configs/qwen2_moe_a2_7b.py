"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60 routed top-4 + 4 shared.  [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from ..models.transformer import LMConfig, MoEConfig
from .base import ArchSpec, LM_SHAPES, LM_SKIPS, register

SPEC = register(ArchSpec(
    id="qwen2-moe-a2.7b",
    family="lm-moe",
    model_cfg=LMConfig(
        name="qwen2-moe-a2.7b", n_layer=24, d_model=2048, n_head=16, n_kv=16,
        d_ff=1408, vocab=151936, d_head=128, qkv_bias=True,
        moe=MoEConfig(n_experts=60, top_k=4, d_ff_expert=1408, n_shared=4),
    ),
    smoke_cfg=LMConfig(
        name="qwen2-moe-smoke", n_layer=2, d_model=64, n_head=4, n_kv=4,
        d_ff=64, vocab=256, d_head=16, qkv_bias=True, remat=False,
        moe=MoEConfig(n_experts=6, top_k=2, d_ff_expert=32, n_shared=1),
    ),
    shapes=LM_SHAPES, skips=LM_SKIPS,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf",
))
