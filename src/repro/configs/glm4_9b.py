"""glm4-9b [dense]: 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552,
RoPE, GQA.  [hf:THUDM/glm-4-9b; hf]"""
from ..models.transformer import LMConfig
from .base import ArchSpec, LM_SHAPES, LM_SKIPS, register

SPEC = register(ArchSpec(
    id="glm4-9b",
    family="lm-dense",
    model_cfg=LMConfig(
        name="glm4-9b", n_layer=40, d_model=4096, n_head=32, n_kv=2,
        d_ff=13696, vocab=151552, d_head=128, qkv_bias=True,
        rope_theta=10_000.0,
    ),
    smoke_cfg=LMConfig(
        name="glm4-smoke", n_layer=2, d_model=64, n_head=4, n_kv=2,
        d_ff=128, vocab=256, d_head=16, qkv_bias=True, remat=False,
    ),
    shapes=LM_SHAPES, skips=LM_SKIPS,
    source="hf:THUDM/glm-4-9b; hf",
))
