"""mace [gnn]: 2 layers d_hidden=128 l_max=2 correlation_order=3 n_rbf=8,
E(3)-ACE higher-order message passing (Cartesian-irrep adaptation).
[arXiv:2206.07697; paper]"""
from ..models.gnn import MACEConfig
from .base import ArchSpec, GNN_SHAPES, register

SPEC = register(ArchSpec(
    id="mace",
    family="gnn",
    model_cfg=MACEConfig(n_layers=2, d_hidden=128, l_max=2,
                         correlation_order=3, n_rbf=8, cutoff=5.0),
    smoke_cfg=MACEConfig(n_layers=1, d_hidden=8, l_max=2,
                         correlation_order=3, n_rbf=4, cutoff=5.0),
    shapes=GNN_SHAPES, skips={},
    source="arXiv:2206.07697; paper",
))
