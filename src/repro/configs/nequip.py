"""nequip [gnn]: 5 layers d_hidden=32 l_max=2 n_rbf=8 cutoff=5, E(3)
tensor-product (Cartesian-irrep adaptation, DESIGN.md §3).
[arXiv:2101.03164; paper]"""
from ..models.gnn import NequIPConfig
from .base import ArchSpec, GNN_SHAPES, register

SPEC = register(ArchSpec(
    id="nequip",
    family="gnn",
    model_cfg=NequIPConfig(n_layers=5, d_hidden=32, l_max=2, n_rbf=8, cutoff=5.0),
    smoke_cfg=NequIPConfig(n_layers=2, d_hidden=8, l_max=2, n_rbf=4, cutoff=5.0),
    shapes=GNN_SHAPES, skips={},
    source="arXiv:2101.03164; paper",
))
