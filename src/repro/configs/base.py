"""Architecture/shape registry: the 10 assigned archs × their shape sets.

Every (arch × shape) cell resolves to:
  * a specialized model config (``cell_model_cfg``),
  * ``input_specs`` — ShapeDtypeStruct stand-ins for every step input
    (weak-type-correct, shardable, no device allocation),
  * a step function (``make_step``) — ``train_step`` for training shapes,
    ``serve_step``/``decode_step`` for inference shapes,
  * partition specs for params / optimizer state / inputs (runtime.sharding).

The full configs are exercised only via the dry-run; smoke tests use the
``smoke_cfg`` reductions.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..models import gnn as gnn_mod
from ..models import recsys as recsys_mod
from ..models import transformer as tfm
from ..optim import adamw
from ..runtime import sharding as shd


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    id: str
    family: str                    # 'lm-dense' | 'lm-moe' | 'gnn' | 'recsys'
    model_cfg: Any
    smoke_cfg: Any
    shapes: dict
    skips: dict                    # shape name -> reason (cell not run)
    source: str = ""               # provenance note


REGISTRY: dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    REGISTRY[spec.id] = spec
    return spec


def get(arch_id: str) -> ArchSpec:
    if not REGISTRY:
        from . import load_all  # circular-safe lazy load
        load_all()
    return REGISTRY[arch_id]


def all_cells(include_skipped: bool = False):
    """Yield (arch_id, shape_name) for every runnable cell."""
    if not REGISTRY:
        from . import load_all
        load_all()
    for aid, spec in REGISTRY.items():
        for shape in spec.shapes:
            if shape in spec.skips and not include_skipped:
                continue
            yield aid, shape


# ----------------------------------------------------------------------
# Shared shape tables (per assignment)
# ----------------------------------------------------------------------

LM_SHAPES = {
    "train_4k":    dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k":  dict(kind="decode", seq=32768, batch=128),
    "long_500k":   dict(kind="decode", seq=524288, batch=1),
}
LM_SKIPS = {
    "long_500k": "pure full (quadratic) attention arch; 512k decode is out of "
                 "scope per the shape definition (skip noted in DESIGN.md §6)",
}

GNN_SHAPES = {
    # e = undirected edge count from the assignment; message passing uses the
    # doubled (directed) arrays, reflected in input_specs.
    "full_graph_sm": dict(kind="train", n=2_708, e=10_556, d_feat=1_433, graphs=1),
    "minibatch_lg":  dict(kind="train", n=169_984, e=168_960, d_feat=602,
                          graphs=1, seeds=1_024, fanout=(15, 10),
                          pool_nodes=232_965, pool_edges=114_615_892),
    "ogb_products":  dict(kind="train", n=2_449_029, e=61_859_140, d_feat=100, graphs=1),
    "molecule":      dict(kind="train", n=30 * 128, e=64 * 128, d_feat=16, graphs=128),
}

RECSYS_SHAPES = {
    "train_batch":    dict(kind="train", batch=65_536),
    "serve_p99":      dict(kind="serve", batch=512, cands=100),
    "serve_bulk":     dict(kind="serve", batch=262_144, cands=100),
    "retrieval_cand": dict(kind="retrieval", batch=1, cands=1_000_000),
}


# ----------------------------------------------------------------------
# Cell -> specialized model config
# ----------------------------------------------------------------------

def cell_model_cfg(spec: ArchSpec, shape_name: str, smoke: bool = False):
    cfg = spec.smoke_cfg if smoke else spec.model_cfg
    dims = spec.shapes[shape_name]
    if spec.family == "gnn":
        d_feat = dims["d_feat"] if not smoke else 8
        if isinstance(cfg, gnn_mod.MGNConfig):
            return dataclasses.replace(cfg, d_node_in=d_feat)
        if isinstance(cfg, gnn_mod.SAGEConfig):
            return dataclasses.replace(cfg, d_in=d_feat)
        if isinstance(cfg, (gnn_mod.NequIPConfig, gnn_mod.MACEConfig)):
            return dataclasses.replace(cfg, d_species=d_feat)
    return cfg


def smoke_dims(spec: ArchSpec, shape_name: str) -> dict:
    """Reduced dims of the same kind, for CPU smoke tests."""
    dims = dict(spec.shapes[shape_name])
    if spec.family.startswith("lm"):
        dims.update(seq=32, batch=2)
    elif spec.family == "gnn":
        graphs = min(dims.get("graphs", 1), 4)
        dims.update(n=24 * graphs, e=48 * graphs, d_feat=8, graphs=graphs)
        dims.pop("seeds", None)
    else:
        dims.update(batch=4)
        if "cands" in dims:
            dims.update(cands=16)
    return dims


# ----------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins per cell
# ----------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def input_specs(spec: ArchSpec, shape_name: str, dims: dict | None = None,
                model_cfg=None) -> dict:
    """Batch inputs for the cell's step function."""
    dims = dims or spec.shapes[shape_name]
    cfg = model_cfg or cell_model_cfg(spec, shape_name)
    kind = dims["kind"]
    if spec.family.startswith("lm"):
        B, S = dims["batch"], dims["seq"]
        if kind == "train":
            return {"tokens": _sds((B, S), jnp.int32), "labels": _sds((B, S), jnp.int32)}
        if kind == "prefill":
            return {"tokens": _sds((B, S), jnp.int32)}
        if kind == "decode":
            return {
                "tokens": _sds((B, 1), jnp.int32),
                "cache": tfm.abstract_cache(cfg, B, S),
                "cache_len": _sds((), jnp.int32),
            }
    if spec.family == "gnn":
        n = dims["n"]
        # directed-doubled edges, padded to a 512 multiple so edge arrays
        # shard evenly over every production mesh; padded edges carry
        # edge_mask = 0 (jraph-style padding, honoured by every model)
        e2 = int(np.ceil(2 * dims["e"] / 512)) * 512
        out = {
            "node_feat": _sds((n, dims["d_feat"]), jnp.float32),
            "src": _sds((e2,), jnp.int32),
            "dst": _sds((e2,), jnp.int32),
            "edge_mask": _sds((e2,), jnp.float32),
        }
        if isinstance(cfg, gnn_mod.MGNConfig):
            out["edge_feat"] = _sds((e2, cfg.d_edge_in), jnp.float32)
            out["target"] = _sds((n, cfg.d_out), jnp.float32)
        elif isinstance(cfg, gnn_mod.SAGEConfig):
            out["labels"] = _sds((n,), jnp.int32)
            out["seed_mask"] = _sds((n,), jnp.bool_)
        else:  # geometric archs
            out["pos"] = _sds((n, 3), jnp.float32)
            out["graph_id"] = _sds((n,), jnp.int32)
            out["energy_target"] = _sds((dims["graphs"],), jnp.float32)
            out["force_target"] = _sds((n, 3), jnp.float32)
        return out
    if spec.family == "recsys":
        B, H = dims["batch"], cfg.hist_len
        out = {"hist_ids": _sds((B, H), jnp.int32), "hist_mask": _sds((B, H), jnp.float32)}
        if kind == "train":
            out["target_id"] = _sds((B,), jnp.int32)
        elif kind == "serve":
            out["cand_ids"] = _sds((B, dims["cands"]), jnp.int32)
        else:  # retrieval
            out["cand_ids"] = _sds((dims["cands"],), jnp.int32)
        return out
    raise ValueError(f"unknown cell {spec.id} x {shape_name}")


def abstract_params(spec: ArchSpec, model_cfg) -> Any:
    if spec.family.startswith("lm"):
        return tfm.abstract_params(model_cfg)
    if spec.family == "gnn":
        init = _GNN_INIT[type(model_cfg)]
        return jax.eval_shape(lambda: init(model_cfg, jax.random.PRNGKey(0)))
    return jax.eval_shape(lambda: recsys_mod.mind_init(model_cfg, jax.random.PRNGKey(0)))


_GNN_INIT = {
    gnn_mod.MGNConfig: gnn_mod.mgn_init,
    gnn_mod.SAGEConfig: gnn_mod.sage_init,
    gnn_mod.NequIPConfig: gnn_mod.nequip_init,
    gnn_mod.MACEConfig: gnn_mod.mace_init,
}
_GNN_LOSS = {
    gnn_mod.MGNConfig: gnn_mod.mgn_loss,
    gnn_mod.SAGEConfig: gnn_mod.sage_loss,
    gnn_mod.NequIPConfig: gnn_mod.nequip_loss,
    gnn_mod.MACEConfig: gnn_mod.mace_loss,
}
_GNN_FWD = {
    gnn_mod.MGNConfig: gnn_mod.mgn_forward,
    gnn_mod.SAGEConfig: gnn_mod.sage_forward,
    gnn_mod.NequIPConfig: gnn_mod.nequip_forward,
    gnn_mod.MACEConfig: gnn_mod.mace_forward,
}


def init_params(spec: ArchSpec, model_cfg, key):
    if spec.family.startswith("lm"):
        return tfm.init_params(model_cfg, key)
    if spec.family == "gnn":
        return _GNN_INIT[type(model_cfg)](model_cfg, key)
    return recsys_mod.mind_init(model_cfg, key)


# ----------------------------------------------------------------------
# step functions
# ----------------------------------------------------------------------

def loss_for(spec: ArchSpec, model_cfg, take_fn=None) -> Callable:
    if spec.family.startswith("lm"):
        return lambda p, b: tfm.loss_fn(p, model_cfg, b["tokens"], b["labels"])
    if spec.family == "gnn":
        base = _GNN_LOSS[type(model_cfg)]
        return lambda p, b: base(p, model_cfg, b)
    return lambda p, b: recsys_mod.mind_loss(p, model_cfg, b, take_fn=take_fn)


def make_train_step(spec: ArchSpec, model_cfg,
                    opt_cfg: adamw.AdamWConfig | None = None,
                    take_fn=None) -> Callable:
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    loss = loss_for(spec, model_cfg, take_fn=take_fn)

    def train_step(params, opt_state, batch):
        lval, grads = jax.value_and_grad(loss)(params, batch)
        params, opt_state, metrics = adamw.apply_updates(opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": lval, **metrics}

    return train_step


def make_serve_step(spec: ArchSpec, shape_name: str, model_cfg,
                    take_fn=None, cand_take_fn=None) -> Callable:
    kind = spec.shapes[shape_name]["kind"]
    if spec.family.startswith("lm"):
        if kind == "prefill":
            def serve_step(params, batch):
                logits, _ = tfm.forward(params, model_cfg, batch["tokens"])
                return logits
            return serve_step
        if kind == "decode":
            def serve_step(params, batch):
                return tfm.decode_step(params, model_cfg, batch["tokens"],
                                       batch["cache"], batch["cache_len"])
            return serve_step
    if spec.family == "recsys":
        if kind == "serve":
            return lambda params, batch: recsys_mod.mind_serve(
                params, model_cfg, batch, take_fn=take_fn, cand_take_fn=cand_take_fn)
        if kind == "retrieval":
            return lambda params, batch: recsys_mod.mind_retrieval(
                params, model_cfg, batch, take_fn=take_fn, cand_take_fn=cand_take_fn)
    if spec.family == "gnn":
        fwd = _GNN_FWD[type(model_cfg)]
        return lambda params, batch: fwd(params, model_cfg, batch)
    raise ValueError(f"no serve step for {spec.id} x {shape_name}")


# ----------------------------------------------------------------------
# partition specs per cell
# ----------------------------------------------------------------------

def param_specs(spec: ArchSpec, params_tree, mesh):
    if spec.family.startswith("lm"):
        return shd.lm_param_spec_tree(params_tree, mesh)
    if spec.family == "gnn":
        return shd.gnn_param_specs(params_tree)
    return shd.mind_param_specs(params_tree)


def batch_specs(spec: ArchSpec, shape_name: str, batch_tree, mesh):
    dims = spec.shapes[shape_name]
    kind = dims["kind"]
    dp = shd.dp_axes(mesh)
    if spec.family.startswith("lm"):
        if kind in ("train", "prefill"):
            return jax.tree.map(lambda _: P(dp, None), batch_tree)
        cfg = cell_model_cfg(spec, shape_name)
        return {
            "tokens": P(dp, None),
            "cache": shd.lm_cache_spec(mesh, cfg.n_kv),
            "cache_len": P(),
        }
    if spec.family == "gnn":
        return shd.gnn_batch_specs(batch_tree, mesh)
    return shd.mind_batch_specs(batch_tree, mesh, retrieval=(kind == "retrieval"))


def opt_specs(spec_tree_params):
    return {"mu": spec_tree_params, "nu": spec_tree_params, "step": P()}


# ----------------------------------------------------------------------
# analytic MODEL_FLOPS per cell (the roofline "useful flops" numerator)
# ----------------------------------------------------------------------

def _mlp_flops(dims: list, rows: float) -> float:
    return sum(2.0 * a * b for a, b in zip(dims[:-1], dims[1:])) * rows


def model_flops(spec: ArchSpec, shape_name: str, dims: dict | None = None,
                model_cfg=None) -> float:
    """Analytic useful FLOPs for one step of this cell (global, all chips).

    LM: the standard 6·N_active·tokens training approximation (+ quadratic
    attention term), 2·N for inference. GNN/recsys: closed forms from the
    layer algebra (documented inline). Training = 3x forward.
    """
    dims = dims or spec.shapes[shape_name]
    cfg = model_cfg or cell_model_cfg(spec, shape_name)
    kind = dims["kind"]
    if spec.family.startswith("lm"):
        B = dims["batch"]
        S = dims["seq"]
        N = cfg.active_param_count
        L, Hq, dh = cfg.n_layer, cfg.n_head, cfg.d_head
        if kind == "train":
            tokens = B * S
            return 6.0 * N * tokens + 3 * (2.0 * L * B * S * S * Hq * dh)  # causal-halved attn fwd=2BS²Hd
        if kind == "prefill":
            tokens = B * S
            return 2.0 * N * tokens + 2.0 * L * B * S * S * Hq * dh
        # decode: stream active params for B tokens + attend over the cache
        return 2.0 * N * B + 4.0 * L * B * S * Hq * dh
    if spec.family == "gnn":
        n, e2 = dims["n"], 2 * dims["e"]
        h = cfg.d_hidden
        fwd = 0.0
        if isinstance(cfg, gnn_mod.MGNConfig):
            hid = [h] * cfg.mlp_layers
            fwd += _mlp_flops([cfg.d_node_in] + hid + [h], n)
            fwd += _mlp_flops([cfg.d_edge_in] + hid + [h], e2)
            fwd += cfg.n_layers * (_mlp_flops([3 * h] + hid + [h], e2)
                                   + _mlp_flops([2 * h] + hid + [h], n))
            fwd += _mlp_flops([h] + hid + [cfg.d_out], n)
        elif isinstance(cfg, gnn_mod.SAGEConfig):
            fwd += 2 * _mlp_flops([cfg.d_in, h], n)            # self+neigh
            fwd += (cfg.n_layers - 1) * 2 * _mlp_flops([h, h], n)
            fwd += _mlp_flops([h, cfg.n_classes], n)
        else:  # NequIP / MACE (Cartesian irreps: sizes 1, 3, 9; 3 paths each)
            C = cfg.d_hidden
            irrep_sz = 1 + 3 + 9
            per_edge = (
                _mlp_flops([cfg.n_rbf, cfg.radial_hidden, 3 * C * 3], 1.0)
                + 2.0 * 3 * C * irrep_sz          # path products + radial weighting
            )
            per_node = 2.0 * C * C * irrep_sz      # channel mixes
            layers = cfg.n_layers
            fwd += layers * (per_edge * e2 + per_node * n)
            if isinstance(cfg, gnn_mod.MACEConfig):
                # correlation products + B-basis projections (orders 2, 3)
                fwd += layers * n * (2.0 * (3 * C) * C + 2 * 2.0 * (2 * C) * C * 3
                                     + 2 * 2.0 * (2 * C) * C * 9) * 2
            fwd += _mlp_flops([C, C, 1], n)
        return 3.0 * fwd if kind == "train" else fwd
    # recsys (MIND)
    B = dims["batch"]
    H, d, K, iters = cfg.hist_len, cfg.embed_dim, cfg.n_interests, cfg.capsule_iters
    fwd = 2.0 * B * H * d * d                 # bilinear S map
    fwd += iters * (2 * 2.0 * B * K * H * d)  # routing einsums
    if kind == "train":
        fwd += 2.0 * B * B * d                # in-batch softmax logits
        return 3.0 * fwd
    C = dims.get("cands", 0)
    fwd += 2.0 * B * K * C * d                # candidate scoring
    return fwd
