"""mind [recsys]: embed_dim=64 n_interests=4 capsule_iters=3 multi-interest
dynamic routing over a sparse item table.  [arXiv:1904.08030; unverified]"""
from ..models.recsys import MINDConfig
from .base import ArchSpec, RECSYS_SHAPES, register

SPEC = register(ArchSpec(
    id="mind",
    family="recsys",
    model_cfg=MINDConfig(n_items=8_388_608, embed_dim=64, n_interests=4,
                         capsule_iters=3, hist_len=50),
    smoke_cfg=MINDConfig(n_items=1024, embed_dim=16, n_interests=4,
                         capsule_iters=3, hist_len=8),
    shapes=RECSYS_SHAPES, skips={},
    source="arXiv:1904.08030; unverified",
))
