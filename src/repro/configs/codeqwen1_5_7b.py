"""codeqwen1.5-7b [dense]: 32L d_model=4096 32H (MHA kv=32) d_ff=13440
vocab=92416, qwen1.5-arch (QKV bias).  [hf:Qwen/CodeQwen1.5-7B; hf]"""
from ..models.transformer import LMConfig
from .base import ArchSpec, LM_SHAPES, LM_SKIPS, register

SPEC = register(ArchSpec(
    id="codeqwen1.5-7b",
    family="lm-dense",
    model_cfg=LMConfig(
        name="codeqwen1.5-7b", n_layer=32, d_model=4096, n_head=32, n_kv=32,
        d_ff=13440, vocab=92416, d_head=128, qkv_bias=True,
        rope_theta=1_000_000.0,
    ),
    smoke_cfg=LMConfig(
        name="codeqwen-smoke", n_layer=2, d_model=64, n_head=4, n_kv=4,
        d_ff=128, vocab=256, d_head=16, qkv_bias=True, remat=False,
    ),
    shapes=LM_SHAPES, skips=LM_SKIPS,
    source="hf:Qwen/CodeQwen1.5-7B; hf",
))
