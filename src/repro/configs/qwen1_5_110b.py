"""qwen1.5-110b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064, QKV bias.  [hf:Qwen/Qwen1.5-0.5B config family; unverified]"""
from ..models.transformer import LMConfig
from .base import ArchSpec, LM_SHAPES, LM_SKIPS, register

SPEC = register(ArchSpec(
    id="qwen1.5-110b",
    family="lm-dense",
    model_cfg=LMConfig(
        name="qwen1.5-110b", n_layer=80, d_model=8192, n_head=64, n_kv=8,
        d_ff=49152, vocab=152064, d_head=128, qkv_bias=True,
        rope_theta=1_000_000.0,
    ),
    smoke_cfg=LMConfig(
        name="qwen110b-smoke", n_layer=2, d_model=64, n_head=8, n_kv=2,
        d_ff=128, vocab=256, d_head=8, qkv_bias=True, remat=False,
    ),
    shapes=LM_SHAPES, skips=LM_SKIPS,
    source="hf:Qwen/Qwen1.5-110B; unverified",
))
