"""Partition-spec policies per architecture family (DESIGN.md §4).

Mesh axes: single-pod ``('data','model')`` = (16,16); multi-pod
``('pod','data','model')`` = (2,16,16).

* **LM** — 2D FSDP×TP: weight matrices shard their d_model-side over
  ``data`` (ZeRO-3; all-gathered at use, reduce-scattered on grads — XLA
  SPMD inserts the collectives) and their head/ffn-side over ``model``
  (Megatron TP). Across pods params are *replicated* (pure DP): no param
  collective ever crosses the slow pod axis. MoE experts shard over
  ``model`` (EP).
* **GNN** — edge-parallel: edge arrays shard over every mesh axis, node
  state is replicated; ``segment_sum`` lowers to local partial sums +
  all-reduce. (The §Perf pass revisits this with node-sharded aggregation.)
* **RecSys** — vocab-parallel embedding: table rows shard over ``model``;
  lookups mask + psum inside a ``shard_map`` (see ``make_vp_take``);
  everything else is data-parallel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def shard_map(f, *, mesh: Mesh, in_specs, out_specs):
    """Version-compatible shard_map with replication checking off.

    ``jax.shard_map`` (with ``check_vma``) only exists on newer JAX; this
    container's 0.4.x has ``jax.experimental.shard_map`` (with
    ``check_rep``). Same semantics either way.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def dp_axes(mesh: Mesh):
    """Axes carrying the batch (data-parallel) dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def all_axes(mesh: Mesh):
    return tuple(mesh.axis_names)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


# ----------------------------------------------------------------------
# LM family
# ----------------------------------------------------------------------

def lm_param_spec_tree(params_tree, mesh: Mesh):
    """PartitionSpec pytree matching the transformer param layout.

    Stacked layer params carry a leading L axis (never sharded: it is the
    scan dimension).
    """

    def spec_for(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        name = keys[-1]
        in_layer = "layers" in keys
        nd = len(leaf.shape)
        if name == "embed":
            return P(None, "model")
        if name == "head":
            return P(None, "model")
        if name in ("ln_f",):
            return P(None)
        if in_layer:
            if name in ("ln1", "ln2"):
                return P(None, None)
            if name in ("wq", "wk", "wv"):
                return P(None, "data", "model")
            if name == "wo" and nd == 3 and "moe" not in keys and "ffn" not in keys:
                return P(None, "model", "data")
            if name in ("bq", "bk", "bv"):
                return P(None, "model")
            if "ffn" in keys:
                if name in ("wi", "wg"):
                    return P(None, "data", "model")
                if name == "wo":
                    return P(None, "model", "data")
            if "moe" in keys:
                model_size = mesh.shape["model"]
                if name == "router":
                    return P(None, "data", None)
                # EP when the expert count divides the model axis (dbrx:
                # 16 % 16); otherwise shard *inside* each expert (expert-TP,
                # qwen2-moe: 60 experts do not divide 16).
                if name in ("wi", "wg"):                     # (L, E, d, f)
                    if leaf.shape[1] % model_size == 0:
                        return P(None, "model", "data", None)
                    return P(None, None, "data", "model")
                if name == "wo":                              # (L, E, f, d)
                    if leaf.shape[1] % model_size == 0:
                        return P(None, "model", None, "data")
                    return P(None, None, "model", "data")
                if name in ("shared_wi", "shared_wg"):        # (L, S, d, f)
                    return P(None, None, "data", "model")
                if name == "shared_wo":                       # (L, S, f, d)
                    return P(None, None, "model", "data")
        raise ValueError(f"no sharding rule for param path {keys} shape {leaf.shape}")

    return jax.tree_util.tree_map_with_path(spec_for, params_tree)


def lm_opt_spec_tree(param_specs):
    """Adam moments share the param sharding; step is replicated."""
    return {"mu": param_specs, "nu": param_specs, "step": P()}


def lm_batch_specs(mesh: Mesh):
    dp = dp_axes(mesh)
    return {"tokens": P(dp, None), "labels": P(dp, None)}


def lm_cache_spec(mesh: Mesh, n_kv: int):
    dp = dp_axes(mesh)
    # (L, B, T, Hkv, dh): batch over DP; kv heads over model only when they
    # divide the axis (pjit input shardings require exact divisibility) —
    # glm4 (kv=2) / dbrx (kv=8) replicate heads across TP.
    head = "model" if n_kv % mesh.shape["model"] == 0 else None
    spec = P(None, dp, None, head, None)
    return {"k": spec, "v": spec}


# ----------------------------------------------------------------------
# GNN family
# ----------------------------------------------------------------------

_GNN_EDGE_KEYS = ("src", "dst", "edge_feat", "edge_mask")
_GNN_NODE_KEYS = ("node_feat", "pos", "target", "labels", "seed_mask",
                  "graph_id", "force_target")


def gnn_batch_specs(batch_tree, mesh: Mesh):
    ax = all_axes(mesh)

    def spec_for(path, leaf):
        name = path[-1].key
        nd = len(leaf.shape)
        if name in _GNN_EDGE_KEYS:
            return P(ax, *([None] * (nd - 1)))    # edge-parallel over all axes
        if name in _GNN_NODE_KEYS or name == "energy_target":
            return P(*([None] * nd))              # replicated node state
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(spec_for, batch_tree)


def gnn_param_specs(params_tree):
    return jax.tree.map(lambda _: P(), params_tree)


# ----------------------------------------------------------------------
# RecSys family
# ----------------------------------------------------------------------

def mind_param_specs(params_tree):
    return {"item_embed": P("model", None), "S": P()}


def mind_batch_specs(batch_tree, mesh: Mesh, retrieval: bool = False):
    dp = dp_axes(mesh)

    def spec_for(path, leaf):
        name = path[-1].key
        nd = len(leaf.shape)
        if retrieval and name == "cand_ids":       # (C,) candidate slab
            return P(dp)                           # dp divides 10^6; 'model' serves the table
        if retrieval:                              # (1, H) user history
            return P(*([None] * nd))
        return P(dp, *([None] * (nd - 1)))

    return jax.tree_util.tree_map_with_path(spec_for, batch_tree)


def make_vp_take(mesh: Mesh, table_axis: str = "model", leading=None):
    """Vocab-parallel EmbeddingBag gather: local take + mask + psum.

    Returns ``take_fn(table, ids) -> (*ids.shape, d)`` usable inside jit:
    the table is row-sharded over ``table_axis``; each shard gathers the
    rows it owns and the partial embeddings are psum'd over the axis.
    ``leading`` shards the first id dimension (typically the DP batch);
    remaining id dims are replicated. Rank-generic: specs are derived from
    ``ids.ndim`` at trace time, so one take_fn serves (B,), (B,H), (B,C).
    """

    def local(table_shard, ids):
        vl = table_shard.shape[0]
        lo = jax.lax.axis_index(table_axis) * vl
        loc = ids - lo
        ok = (loc >= 0) & (loc < vl)
        emb = jnp.take(table_shard, jnp.clip(loc, 0, vl - 1), axis=0)
        emb = jnp.where(ok[..., None], emb, 0.0)
        return jax.lax.psum(emb, table_axis)

    def take_fn(table, ids):
        ids_spec = P(leading, *([None] * (ids.ndim - 1)))
        out_spec = P(leading, *([None] * ids.ndim))
        return shard_map(
            local, mesh=mesh,
            in_specs=(P(table_axis, None), ids_spec),
            out_specs=out_spec,
        )(table, ids)

    return take_fn
