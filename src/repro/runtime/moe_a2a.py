"""Explicit all-to-all MoE dispatch (GShard/DeepSpeed-MoE style) via shard_map.

EXPERIMENTS.md §Perf found that GSPMD cannot be *hinted* into an efficient
plan for the sort-based MoE dispatch — the data-dependent scatter keeps
pulling (E, C, ·)-sized activation collectives (≈38 GB/layer/device for
qwen2-moe). This module replaces the whole dispatch with the explicit
production pattern:

  1. tokens are split over the TP axis too (token-parallel routing):
     each device routes T_local/tp tokens;
  2. each device scatters its tokens into a *local* (E, C_loc, d) buffer;
  3. one `all_to_all` over the TP axis re-groups the expert dim: every
     device receives the (E/tp, C_loc·tp, d) slab for the experts it owns;
  4. local expert GEMMs (weights are EP-sharded: (E/tp, d, f) per device);
  5. `all_to_all` back, local combine, `all_gather` the token chunks.

Per-layer collective volume ≈ 2 dispatch slabs + 2 token gathers
≈ 4·K·cf·T_tp·d bytes per device — ~75x less than the GSPMD baseline for
qwen2-moe (measured in EXPERIMENTS.md §Perf cell 2, iteration 6).

Requires E % tp == 0 (compose with MoEConfig.pad_experts) and
(B·S) % (dp·tp) == 0. Gradients flow through all_to_all/all_gather
natively. Correctness vs the single-device reference dispatch is asserted
in tests/test_distributed.py.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.runtime.sharding import shard_map

from ..models import transformer as tfm


def make_a2a_moe(mesh: Mesh, dp, tp_axis: str = "model"):
    """Returns ``moe_fn(p, cfg, x) -> (out, aux)`` for transformer.MOE_IMPL."""

    tp = mesh.shape[tp_axis]

    def local_fn(router, wi, wg, wo, xt, *, mcfg):
        """Per-device body. xt: (T_dp, d) local-to-dp tokens (replicated over
        tp); wi/wg/wo: (E/tp, d, f) local expert shards."""
        E, K = mcfg.e_total, mcfg.top_k
        e_loc = E // tp
        t_dp, d = xt.shape
        t_tp = t_dp // tp
        rank = jax.lax.axis_index(tp_axis)
        # 1. token-parallel routing: this device handles its token chunk
        xtl = jax.lax.dynamic_slice_in_dim(xt, rank * t_tp, t_tp, axis=0)
        logits = xtl.astype(jnp.float32) @ router              # (t_tp, E)
        if mcfg.pad_experts:
            pad_mask = jnp.arange(E) >= mcfg.n_experts
            logits = jnp.where(pad_mask[None, :], -1e30, logits)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, eidx = jax.lax.top_k(probs, K)
        gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

        # 2. local capacity-bounded scatter (same algebra as _moe_group)
        C = max(1, int(np.ceil(t_tp * K / E * mcfg.capacity_factor)))
        C = int(np.ceil(C / 8)) * 8
        flat_e = eidx.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(t_tp, dtype=jnp.int32), K)
        order = jnp.argsort(flat_e, stable=True)
        se, st = flat_e[order], flat_t[order]
        starts = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype), side="left")
        pos = jnp.arange(t_tp * K, dtype=jnp.int32) - starts[se].astype(jnp.int32)
        keep = pos < C
        dest = jnp.where(keep, se.astype(jnp.int32) * C + pos, E * C)
        buf = jnp.zeros((E * C + 1, d), xt.dtype).at[dest].set(xtl[st])
        buf = buf[: E * C].reshape(E, C, d)

        # 3. exchange: every device ends with its experts' slab from all
        # peers: (E, C, d) -> (E/tp, tp*C, d), capacity grouped by sender
        slab = jax.lax.all_to_all(buf, tp_axis, split_axis=0, concat_axis=1,
                                  tiled=True)

        # 4. local expert GEMMs (MXU; weights never move)
        hg = jax.nn.silu(jnp.einsum("ecd,edf->ecf", slab, wg))
        hi = jnp.einsum("ecd,edf->ecf", slab, wi)
        ho = jnp.einsum("ecf,efd->ecd", hg * hi, wo)            # (e_loc, tp*C, d)

        # 5. exchange back (inverse mapping) + combine
        back = jax.lax.all_to_all(ho, tp_axis, split_axis=1, concat_axis=0,
                                  tiled=True)                   # (E, C, d)
        back = back.reshape(E * C, d)
        gflat = gate.reshape(-1)[order]
        contrib = jnp.where(keep[:, None], back[jnp.clip(dest, 0, E * C - 1)], 0.0)
        outl = jnp.zeros((t_tp, d), xt.dtype).at[st].add(
            contrib * gflat[:, None].astype(xt.dtype))

        # aux load-balance loss (local chunk -> mean over the fleet)
        me = jnp.mean(jax.nn.one_hot(eidx, E, dtype=jnp.float32), axis=(0, 1))
        ce = jnp.mean(probs, axis=0)
        aux = jax.lax.pmean(E * jnp.sum(me * ce), (tp_axis, *(dp if isinstance(dp, tuple) else (dp,))))

        # 6. gather token chunks back (replicated over tp again)
        out = jax.lax.all_gather(outl, tp_axis, axis=0, tiled=True)
        return out, aux

    def moe_fn(p, cfg, x):
        mcfg = cfg.moe
        B, S, d = x.shape
        xt = x.reshape(B * S, d)

        def body(router, wi, wg, wo, xt):
            return local_fn(router, wi, wg, wo, xt, mcfg=mcfg)

        out, aux = shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(tp_axis, None, None), P(tp_axis, None, None),
                      P(tp_axis, None, None), P(dp, None)),
            out_specs=(P(dp, None), P()),
        )(p["router"], p["wi"], p["wg"], p["wo"], xt)

        if mcfg.n_shared:
            hs = jax.nn.silu(jnp.einsum("td,sdf->tsf", xt, p["shared_wg"]))
            hi_s = jnp.einsum("td,sdf->tsf", xt, p["shared_wi"])
            out = out + jnp.einsum("tsf,sfd->td", hs * hi_s, p["shared_wo"])
        return out.reshape(B, S, d), aux

    return moe_fn
