"""Fault tolerance: restart driver, heartbeat/straggler monitor, failure
injection (DESIGN.md §4).

At 1000+ nodes failures are routine, not exceptional. The posture here:

* **Checkpoint/restart** — the training driver wraps every run in
  :class:`RestartingRunner`: any step raising a *recoverable* error rolls
  back to the latest checkpoint and resumes, up to ``max_restarts``; the
  checkpoint cadence bounds lost work.
* **Straggler detection** — :class:`HeartbeatMonitor` keeps an EWMA of
  per-host step latencies; hosts slower than ``threshold x`` median trigger
  a callback (evict/replace in a real deployment; logged + simulated in
  tests since this container is one host).
* **Failure injection** — :class:`FailureInjector` raises scripted faults at
  chosen steps so the restart path is itself under test (tests/test_fault.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np


class RecoverableError(RuntimeError):
    """A fault the runner should recover from (preemption, link flap...)."""


@dataclasses.dataclass
class FailureInjector:
    """Raise scripted failures at given steps (once each)."""

    fail_at: dict[int, str] = dataclasses.field(default_factory=dict)
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RecoverableError(f"injected fault at step {step}: {self.fail_at[step]}")


class HeartbeatMonitor:
    """Per-host step-latency EWMA with straggler callback.

    ``report(host, seconds)`` after every step; a host whose EWMA exceeds
    ``threshold`` x the median EWMA is flagged through ``on_straggler``.
    """

    def __init__(self, n_hosts: int, threshold: float = 2.0,
                 alpha: float = 0.3, on_straggler: Callable[[int, float], None] | None = None):
        self.ewma = np.zeros(n_hosts)
        self.seen = np.zeros(n_hosts, bool)
        self.threshold = threshold
        self.alpha = alpha
        self.on_straggler = on_straggler or (lambda host, ratio: None)
        self.flagged: list[tuple[int, float]] = []

    def report(self, host: int, seconds: float):
        if not self.seen[host]:
            self.ewma[host] = seconds
            self.seen[host] = True
        else:
            self.ewma[host] = self.alpha * seconds + (1 - self.alpha) * self.ewma[host]
        if self.seen.all():
            med = float(np.median(self.ewma))
            ratio = self.ewma[host] / max(med, 1e-9)
            if ratio > self.threshold:
                self.flagged.append((host, ratio))
                self.on_straggler(host, ratio)

    def stragglers(self) -> list[int]:
        return sorted({h for h, _ in self.flagged})


class RestartingRunner:
    """Run a step loop with checkpoint-restart on recoverable faults.

    ``state`` is any pytree; ``step_fn(state, step) -> state``;
    ``save_fn(step, state)`` / ``restore_fn() -> (step, state)`` plug into
    the CheckpointManager.
    """

    def __init__(self, step_fn, save_fn, restore_fn, *,
                 ckpt_every: int = 50, max_restarts: int = 5,
                 injector: FailureInjector | None = None,
                 monitor: HeartbeatMonitor | None = None):
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.injector = injector
        self.monitor = monitor
        self.restarts = 0
        self.steps_lost = 0

    def run(self, state, start_step: int, num_steps: int):
        step = start_step
        end = start_step + num_steps
        while step < end:
            try:
                t0 = time.perf_counter()
                if self.injector is not None:
                    self.injector.check(step)
                state = self.step_fn(state, step)
                if self.monitor is not None:
                    self.monitor.report(0, time.perf_counter() - t0)
                step += 1
                if step % self.ckpt_every == 0:
                    self.save_fn(step, state)
            except RecoverableError:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                restored_step, state = self.restore_fn()
                self.steps_lost += step - restored_step
                step = restored_step
        self.save_fn(step, state)
        return step, state
