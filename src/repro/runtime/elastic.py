"""Elastic scaling: re-mesh a job onto a changed device count.

Checkpoints store arrays in host layout plus *logical* partition specs
(axis names, not device ids), so a restart with a different device pool
only needs a new mesh of the same axis names:

    mesh_old (2,16,16) --checkpoint--> mesh_new (1,16,16) or (4,16,16)

``remesh`` rebuilds NamedShardings for the new mesh and device_puts the
restored host arrays. Divisibility is not required (XLA pads uneven
shards), so odd survivor counts after failures still mount.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def spec_tree_to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def remesh(host_tree, spec_tree, new_mesh: Mesh):
    """Place restored host arrays onto a new mesh under the same logical specs.

    Axis names present in a spec but absent from the new mesh degrade to
    replication (e.g. restoring a multi-pod checkpoint on one pod).
    """
    names = set(new_mesh.axis_names)

    def degrade(spec: P) -> P:
        def keep(part):
            if part is None:
                return None
            if isinstance(part, tuple):
                kept = tuple(a for a in part if a in names)
                return kept if kept else None
            return part if part in names else None
        return P(*(keep(part) for part in spec))

    shardings = jax.tree.map(
        lambda s: NamedSharding(new_mesh, degrade(s)),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
    return jax.tree.map(lambda a, s: jax.device_put(a, s), host_tree, shardings)
