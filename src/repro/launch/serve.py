"""TCCS query-serving driver — thin client of the serving engine
(repro/serving, DESIGN.md §7).

    PYTHONPATH=src python -m repro.launch.serve --workload cm_like --k 3 \\
        --queries 4096 --batch 256 --flush-ms 2

The driver owns nothing but the traffic: it warms the engine (index build +
bucket compiles), replays a random query stream of typed ``TCCSQuery``
specs through ``submit_specs`` (``--mode`` picks the result mode) batched
like independent arrivals, then prints the engine's own per-stage metrics,
compares against the sequential Algorithm 1 baseline, and verifies
exactness on a sample. All batching/routing/caching/sharding policy lives
in the engine.
"""

from __future__ import annotations

import argparse
import time

from repro.core.kcore import k_max
from repro.core.query_api import ResultMode, TCCSQuery
from repro.core.temporal_graph import BENCH_WORKLOADS, bench_graph, random_queries
from repro.serving import EngineConfig, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="cm_like",
                    choices=sorted(BENCH_WORKLOADS))
    ap.add_argument("--k", type=int, default=None)
    ap.add_argument("--queries", type=int, default=2048)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--flush-ms", type=float, default=2.0)
    ap.add_argument("--cache", type=int, default=4096)
    ap.add_argument("--mode", default="vertices",
                    choices=[m.value for m in ResultMode])
    ap.add_argument("--verify", type=int, default=32)
    ap.add_argument("--trace-export", metavar="PATH", default=None,
                    help="write the run's query-lifecycle spans as Chrome "
                         "trace-event JSON (Perfetto / chrome://tracing)")
    ap.add_argument("--slow-query-ms", type=float, default=None,
                    help="log queries slower than this threshold with "
                         "their full span tree")
    ap.add_argument("--store-dir", metavar="DIR", default=None,
                    help="persistent index store root (DESIGN.md §13): "
                         "builds write through to it and a restart "
                         "promotes the stored index instead of rebuilding")
    ap.add_argument("--expect-warm", action="store_true",
                    help="fail unless the warmup index was promoted from "
                         "the store (warm-restart smoke assertion)")
    args = ap.parse_args(argv)

    if args.expect_warm and not args.store_dir:
        ap.error("--expect-warm requires --store-dir")

    if args.batch < 1:
        ap.error("--batch must be >= 1")
    g = bench_graph(args.workload)
    k = args.k or max(2, int(0.7 * k_max(g)))
    cfg = EngineConfig(max_batch=args.batch, flush_ms=args.flush_ms,
                       cache_capacity=args.cache,
                       min_bucket=min(8, args.batch),
                       slow_query_ms=args.slow_query_ms,
                       store_dir=args.store_dir)
    print(f"[engine] workload={args.workload} n={g.n} m={g.m} "
          f"t_max={g.t_max} k={k} config={cfg}")

    with ServingEngine(cfg) as eng:
        t0 = time.perf_counter()
        # edge modes use the full-mode device program: compile it now, not
        # inside the timed replay (one warmup covers every k — the index
        # is k-stratified and k rides as a device operand)
        handle = eng.warmup(args.workload,
                            full=args.mode in ("edges", "subgraph"))
        print(f"[warmup] index {'promoted from store' if handle.source == 'disk' else 'built'} "
              f"in {handle.build_seconds:.2f}s "
              f"(nodes={handle.pecb.num_nodes} size={handle.nbytes/1e6:.2f} MB); "
              f"buckets compiled in {time.perf_counter() - t0 - handle.build_seconds:.2f}s")
        if args.store_dir:
            st = eng.store.stats()
            print(f"[store] root={st['root']} commits={st['commits']} "
                  f"(full={st['commits_full']} delta={st['commits_delta']} "
                  f"noop={st['commits_noop']}) loads={st['loads']} "
                  f"load_bytes={st['load_bytes']} "
                  f"recovered={st['recovered_commits']}")
        if args.expect_warm and handle.source != "disk":
            raise RuntimeError(
                f"--expect-warm: warmup fell back to a cold build "
                f"(source={handle.source!r}) — the store at "
                f"{args.store_dir!r} held no promotable epoch")

        queries = random_queries(g, args.queries, seed=0)
        specs = [TCCSQuery(u, ts, te, k, ResultMode(args.mode))
                 for (u, ts, te) in queries]
        t0 = time.perf_counter()
        futures = []
        for i in range(0, len(specs), args.batch):
            futures += eng.submit_specs(args.workload, specs[i:i + args.batch])
        eng.flush()
        results = [f.result(timeout=120) for f in futures]
        dt = time.perf_counter() - t0
        total = len(queries)
        print(f"[serve] {total} queries in {dt:.3f}s -> {total/dt:,.0f} q/s "
              f"({dt/total*1e6:.1f} us/query)")
        routes = {}
        for r in results:
            routes[r.provenance.route] = routes.get(r.provenance.route, 0) + 1
        print(f"[serve] result routes: {routes}")
        print(eng.format_stats())

        # sequential Algorithm 1 comparison (per-k stratum view)
        ref = handle.pecb.slice_k(k)
        n_seq = min(args.verify * 8, total)
        t0 = time.perf_counter()
        for (u, ts, te) in queries[:n_seq]:
            ref._component_vertices(u, ts, te)
        t_seq = (time.perf_counter() - t0) / n_seq
        print(f"[serve] sequential Alg 1: {t_seq*1e6:.1f} us/query "
              f"(engine speedup {t_seq/(dt/total):.1f}x)")

        # exactness spot check (COUNT mode carries sizes only)
        def matches(i):
            want = ref._component_vertices(*queries[i])
            if results[i].query.mode is ResultMode.COUNT:
                return results[i].num_vertices == len(want)
            return results[i].vertices == frozenset(want)
        bad = sum(not matches(i) for i in range(min(args.verify, total)))
        print(f"[verify] {min(args.verify, total)} queries checked, {bad} mismatches")
        if bad:
            raise RuntimeError(f"{bad} served results disagree with the "
                               "host-side PECB reference")

        if args.slow_query_ms is not None:
            print(f"[slow-queries] threshold={args.slow_query_ms}ms "
                  f"logged={len(eng.slow_queries)}")
            print(eng.slow_queries.format())
        if args.trace_export:
            doc = eng.export_trace(args.trace_export,
                                   extra={"workload": args.workload, "k": k})
            print(f"[trace] {len(doc['traceEvents'])} events -> "
                  f"{args.trace_export} (dropped="
                  f"{doc['otherData']['dropped_spans']})")
        return total / dt


if __name__ == "__main__":
    main()
