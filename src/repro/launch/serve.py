"""TCCS query-serving driver — the paper's end-to-end deployment shape.

    PYTHONPATH=src python -m repro.launch.serve --workload cm_like --k 3 \\
        --queries 4096 --batch 256

Pipeline: build the PECB index on the host (offline plane), ship the packed
arrays to the device, then serve batched TCCS queries with the label-
propagation engine (core/batch_query.py), reporting throughput against the
sequential Algorithm 1 and verifying exactness on a sample.
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro.core.temporal_graph import bench_graph, gen_temporal_graph
from repro.core.core_time import edge_core_times
from repro.core.pecb_index import build_pecb_index
from repro.core.batch_query import to_device, batch_query
from repro.core.kcore import k_max


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="cm_like")
    ap.add_argument("--k", type=int, default=None)
    ap.add_argument("--queries", type=int, default=2048)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--verify", type=int, default=32)
    args = ap.parse_args(argv)

    g = bench_graph(args.workload)
    k = args.k or max(2, int(0.7 * k_max(g)))
    print(f"[build] workload={args.workload} n={g.n} m={g.m} t_max={g.t_max} k={k}")
    t0 = time.perf_counter()
    tab = edge_core_times(g, k)
    idx = build_pecb_index(g, k, tab)
    t_build = time.perf_counter() - t0
    print(f"[build] PECB in {t_build:.2f}s | nodes={idx.num_nodes} "
          f"size={idx.nbytes()/1e6:.2f} MB")

    dix = to_device(idx)
    rng = np.random.default_rng(0)
    B = args.batch
    n_batches = (args.queries + B - 1) // B
    qs = []
    for _ in range(n_batches):
        u = rng.integers(0, g.n, B).astype(np.int32)
        ts = rng.integers(1, g.t_max + 1, B).astype(np.int32)
        te = np.minimum(ts + rng.integers(0, g.t_max, B), g.t_max).astype(np.int32)
        qs.append((jnp.asarray(u), jnp.asarray(ts), jnp.asarray(te)))

    # warmup/compile
    batch_query(dix, *qs[0]).block_until_ready()
    t0 = time.perf_counter()
    outs = []
    for u, ts, te in qs:
        outs.append(batch_query(dix, u, ts, te))
    outs[-1].block_until_ready()
    dt = time.perf_counter() - t0
    total = n_batches * B
    print(f"[serve] {total} queries in {dt:.3f}s -> {total/dt:,.0f} q/s "
          f"({dt/total*1e6:.1f} us/query) at batch={B}")

    # sequential Algorithm 1 comparison
    t0 = time.perf_counter()
    for i in range(min(args.verify * 8, total)):
        u, ts, te = qs[0][0][i % B], qs[0][1][i % B], qs[0][2][i % B]
        idx.query(int(u), int(ts), int(te))
    t_seq = (time.perf_counter() - t0) / min(args.verify * 8, total)
    print(f"[serve] sequential Alg 1: {t_seq*1e6:.1f} us/query "
          f"(batched speedup {t_seq/(dt/total):.1f}x)")

    # exactness spot check
    bad = 0
    mask0 = np.asarray(outs[0])
    for i in range(min(args.verify, B)):
        want = idx.query(int(qs[0][0][i]), int(qs[0][1][i]), int(qs[0][2][i]))
        got = set(np.nonzero(mask0[i])[0].tolist())
        bad += got != want
    print(f"[verify] {args.verify} queries checked, {bad} mismatches")
    assert bad == 0
    return total / dt


if __name__ == "__main__":
    main()
