"""End-to-end training driver with checkpoint/restart and fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --smoke \\
        --steps 50 --ckpt-dir /tmp/ckpt --resume auto

Production posture (DESIGN.md §4): the same driver that runs the reduced
configs on this CPU container issues the full-config pjit step under
``make_production_mesh()`` on a real fleet — only ``--smoke`` and the mesh
factory differ. Fault tolerance is exercised for real here via
``--inject-failure``.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as C
from repro.checkpoint.manager import CheckpointManager
from repro.data.graph_sampler import (CSRGraph, random_powerlaw_graph,
                                      sample_subgraph_batch)
from repro.data.lm_data import TokenStream
from repro.data.recsys_data import InteractionStream
from repro.optim import adamw
from repro.runtime.fault_tolerance import (FailureInjector, HeartbeatMonitor,
                                           RestartingRunner)


def make_batch_fn(spec, cfg, dims):
    """step -> batch dict of device arrays (host data pipeline)."""
    if spec.family.startswith("lm"):
        stream = TokenStream(cfg.vocab, seed=0)

        def fn(step):
            toks, labels = stream.batch(step, dims["batch"], dims["seq"])
            return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        return fn
    if spec.family == "gnn":
        n = dims["n"]
        rng0 = np.random.default_rng(0)
        src, dst = random_powerlaw_graph(n, 6, seed=0)
        e2 = int(np.ceil(max(src.shape[0], 1) / 512)) * 512
        g = CSRGraph(n, src, dst)
        feats = rng0.normal(size=(n, dims["d_feat"])).astype(np.float32)
        labels = rng0.integers(0, getattr(cfg, "n_classes", 5), n).astype(np.int32)

        def fn(step):
            rng = np.random.default_rng(step + 1)
            seeds = rng.choice(n, size=max(n // 8, 2), replace=False)
            b = sample_subgraph_batch(g, feats, labels, seeds, (5, 5), rng,
                                      pad_nodes=n, pad_edges=e2)
            batch = {k: jnp.asarray(v) for k, v in b.items()}
            from ..models import gnn as gnn_mod
            if isinstance(cfg, gnn_mod.MGNConfig):
                batch.pop("labels"); batch.pop("seed_mask")
                batch["edge_feat"] = jnp.asarray(
                    rng.normal(size=(e2, cfg.d_edge_in)).astype(np.float32))
                batch["target"] = jnp.asarray(
                    rng.normal(size=(n, cfg.d_out)).astype(np.float32))
            elif isinstance(cfg, gnn_mod.SAGEConfig):
                pass
            else:
                batch.pop("labels"); batch.pop("seed_mask")
                batch["pos"] = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32) * 2)
                batch["graph_id"] = jnp.zeros(n, jnp.int32)
                batch["energy_target"] = jnp.zeros(1, jnp.float32)
                batch["force_target"] = jnp.zeros((n, 3), jnp.float32)
            return batch
        return fn
    stream = InteractionStream(cfg.n_items, cfg.hist_len, seed=0)
    return lambda step: {k: jnp.asarray(v)
                         for k, v in stream.batch(step, dims["batch"]).items()}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + reduced dims (CPU-runnable)")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", default=None, choices=[None, "auto"])
    ap.add_argument("--inject-failure", type=int, action="append", default=[])
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)

    spec = C.get(args.arch)
    shape_name = args.shape if args.shape in spec.shapes else next(
        s for s, d in spec.shapes.items() if d["kind"] == "train")
    dims = C.smoke_dims(spec, shape_name) if args.smoke else dict(spec.shapes[shape_name])
    if args.batch:
        dims["batch"] = args.batch
    if args.seq:
        dims["seq"] = args.seq
    cfg = C.cell_model_cfg(spec, shape_name, smoke=args.smoke)

    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=max(args.steps, 10),
                                warmup_steps=max(args.steps // 20, 2))
    step_fn = jax.jit(C.make_train_step(spec, cfg, opt_cfg))
    batch_fn = make_batch_fn(spec, cfg, dims)

    params = C.init_params(spec, cfg, jax.random.PRNGKey(0))
    opt = adamw.init_state(params)
    state = {"params": params, "opt": opt}

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if mgr and args.resume == "auto" and mgr.latest_step() is not None:
        start, state, _ = mgr.restore()
        print(f"[resume] from step {start}")
    if mgr and mgr.latest_step() is None:
        mgr.save(start, state, {"arch": args.arch})   # restart anchor

    monitor = HeartbeatMonitor(n_hosts=1, threshold=3.0)
    injector = FailureInjector({s: "cli-injected" for s in args.inject_failure})
    losses = []

    def one_step(state, step):
        batch = batch_fn(step)
        params, opt, metrics = step_fn(state["params"], state["opt"], batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0:
            print(f"step {step:5d} | loss {loss:.4f} | lr {float(metrics['lr']):.2e} "
                  f"| gnorm {float(metrics['grad_norm']):.3f}")
        return {"params": params, "opt": opt}

    if mgr:
        runner = RestartingRunner(
            one_step,
            save_fn=lambda s, st: mgr.save_async(s, st, {"arch": args.arch}),
            restore_fn=lambda: mgr.restore()[:2],
            ckpt_every=args.ckpt_every, injector=injector, monitor=monitor)
        t0 = time.perf_counter()
        end, state = runner.run(state, start, args.steps)
        mgr.wait()
        dt = time.perf_counter() - t0
        print(f"[done] {args.steps} steps in {dt:.1f}s | restarts={runner.restarts} "
              f"steps_lost={runner.steps_lost} | final loss {losses[-1]:.4f} "
              f"(first {losses[0]:.4f})")
    else:
        t0 = time.perf_counter()
        for step in range(start, start + args.steps):
            state = one_step(state, step)
        dt = time.perf_counter() - t0
        print(f"[done] {args.steps} steps in {dt:.1f}s | final loss {losses[-1]:.4f} "
              f"(first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
