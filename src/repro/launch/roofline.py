"""Roofline-term extraction from a compiled dry-run artifact.

All quantities are *per device* (the post-SPMD HLO module is the per-device
program):

  compute_s    = HLO_FLOPs_per_device / peak_FLOP/s
  memory_s     = HLO_bytes_per_device / HBM_bw
  collective_s = collective_result_bytes_per_device / ICI link bw

``collective_result_bytes`` sums the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
in the optimized HLO (result bytes ~= bytes received per device; the
convention is stated in EXPERIMENTS.md). cost_analysis does not report
collective traffic, hence the HLO text parse.
"""

from __future__ import annotations

import re
from collections import defaultdict

from .mesh import HW

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# one shaped result:  bf16[4,128]{1,0}   (layout/annotations optional)
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind result bytes from optimized HLO text."""
    out = defaultdict(int)
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        s = line.strip()
        # result = <shape or tuple> <op>(...)
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^=]*?\)?)\s+([\w\-]+)(?:-start|-done)?\(", s)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        base = op.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES and not op.endswith("-done"):
            out[base] += _shape_bytes(shape_str)
            counts[base] += 1
    return {"bytes": dict(out), "counts": dict(counts),
            "total": int(sum(out.values()))}


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float, *, model_flops_global: float,
                   n_devices: int) -> dict:
    compute_s = flops_per_dev / HW["peak_flops_bf16"]
    memory_s = bytes_per_dev / HW["hbm_bw"]
    collective_s = coll_bytes_per_dev / HW["ici_bw"]
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    hlo_flops_global = flops_per_dev * n_devices
    return {
        **terms,
        "dominant": dominant,
        "model_flops": model_flops_global,
        "hlo_flops_global": hlo_flops_global,
        "useful_flop_ratio": (model_flops_global / hlo_flops_global
                              if hlo_flops_global else 0.0),
        # time lower bound if terms overlap perfectly; fraction of roofline
        "step_time_lb_s": max(terms.values()),
        "roofline_fraction": (compute_s / max(terms.values())
                              if max(terms.values()) > 0 else 0.0),
    }


def analyze_compiled(compiled, *, model_flops_global: float, n_devices: int) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):        # older API returned [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            mem[k] = getattr(ma, k, None)
    except Exception as e:  # CPU backend may not implement it
        mem["error"] = str(e)
    return {
        "flops_per_device": flops,
        "bytes_per_device": byts,
        "collectives": coll,
        "memory": mem,
        "roofline": roofline_terms(flops, byts, coll["total"],
                                   model_flops_global=model_flops_global,
                                   n_devices=n_devices),
    }
