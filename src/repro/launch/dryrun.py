import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"  # noqa: E402 — must precede ANY jax-touching import

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
against the production meshes, and dump roofline inputs.

    PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx-132b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun

Per cell x mesh this prints/records:
  * compiled.memory_analysis()  (per-device bytes: proves it fits)
  * compiled.cost_analysis()    (FLOPs / bytes for the roofline)
  * collective bytes parsed from the optimized HLO (per collective kind)
  * the three roofline terms + dominant bottleneck (launch/roofline.py)
"""

import argparse
import json
import time
import traceback

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs as C
from repro.launch.mesh import make_production_mesh, HW
from repro.launch import roofline
from repro.optim import adamw
from repro.runtime import sharding as shd


def _named(tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


OPTS = ("base", "actshard", "seqshard", "moegroup", "moeshard", "weightgather",
        "expertpad", "moea2a", "nodeshard", "nodeshard_bf16", "opt")
# §Perf variants (see EXPERIMENTS.md §Perf for the hypothesis log):
#   actshard  — pin the LM residual stream to P(dp, None, None)
#   moegroup  — hierarchical local MoE dispatch (groups=32, DP-aligned)
#   nodeshard — GNN node-state row sharding over every mesh axis
#   opt       — all of the applicable levers together


def _apply_opt(spec, cfg, mesh, opt: str):
    import dataclasses as _dc
    from jax.sharding import NamedSharding
    from repro.models import transformer as tfm
    from repro.models import gnn as gnn_mod

    tfm.set_activation_sharding(None)
    tfm.set_moe_sharding(None)
    tfm.set_weight_use_sharding(None)
    tfm.set_moe_impl(None)
    gnn_mod.set_node_sharding(None)
    if opt == "base":
        return cfg
    if spec.family.startswith("lm"):
        if opt in ("actshard", "opt"):
            dp = shd.dp_axes(mesh)
            tfm.set_activation_sharding(NamedSharding(mesh, P(dp, None, None)))
        if opt == "seqshard":
            # Megatron sequence parallelism: the residual stream between
            # blocks shards its SEQUENCE dim over the TP axis — norms and
            # elementwise ops compute 1/16th each; TP boundary collectives
            # become reduce-scatter/all-gather pairs.
            dp = shd.dp_axes(mesh)
            tfm.set_activation_sharding(NamedSharding(mesh, P(dp, "model", None)))
        if opt == "moegroup" and cfg.moe is not None:
            cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe, groups=32))
        if opt in ("expertpad", "moea2a", "opt") and cfg.moe is not None:
            ms = mesh.shape["model"]
            if cfg.moe.e_total % ms != 0:
                pad = ms - (cfg.moe.n_experts % ms)
                cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe, pad_experts=pad))
        if opt == "moea2a" and cfg.moe is not None:
            from repro.runtime.moe_a2a import make_a2a_moe
            tfm.set_moe_impl(make_a2a_moe(mesh, shd.dp_axes(mesh)))
        if opt == "moeshard" and cfg.moe is not None:
            dp = shd.dp_axes(mesh)
            tfm.set_moe_sharding((
                NamedSharding(mesh, P(None, dp, None)),      # (E, C, d)
                NamedSharding(mesh, P(None, dp, "model")),   # (E, C, f)
            ))
        if opt == "weightgather":
            # gathered-at-use weight shardings: the per-layer slice specs
            # (leading L dropped) with 'data' (the FSDP axis) replaced by
            # None — XLA then all-gathers the weight, never the activation.
            ms = mesh.shape["model"]
            ep = cfg.moe is not None and cfg.moe.n_experts % ms == 0
            table = {
                "attn.wq": P(None, "model"), "attn.wk": P(None, "model"),
                "attn.wv": P(None, "model"), "attn.wo": P("model", None),
                "ffn.wi": P(None, "model"), "ffn.wg": P(None, "model"),
                "ffn.wo": P("model", None),
                "moe.wi": P("model", None, None) if ep else P(None, None, "model"),
                "moe.wg": P("model", None, None) if ep else P(None, None, "model"),
                # non-EP wo stays f-TP (matches hg/hi's f-sharding: local
                # contraction + psum over 'model' of the *C-sharded* output —
                # 1.34 GB/layer once moeshard pins C over dp; round-3/4 lessons:
                # d-sharded wo forced a 29.5 GB f-re-gather of hg instead).
                "moe.wo": P("model", None, None) if ep else P(None, "model", None),
                "moe.shared_wi": P(None, None, "model"),
                "moe.shared_wg": P(None, None, "model"),
                "moe.shared_wo": P(None, "model", None),
            }
            tfm.set_weight_use_sharding(
                {k: NamedSharding(mesh, v) for k, v in table.items()})
    if spec.family == "gnn" and opt in ("nodeshard", "nodeshard_bf16", "opt"):
        gnn_mod.set_node_sharding(NamedSharding(mesh, P(shd.all_axes(mesh))))
        if opt in ("nodeshard_bf16", "opt") and hasattr(cfg, "bf16_state"):
            cfg = _dc.replace(cfg, bf16_state=True)
    return cfg


def build_cell(arch_id: str, shape_name: str, mesh, *, variant: str = "base",
               opt: str = "base"):
    """Returns (fn, example_args, in_shardings, out_shardings, meta)."""
    spec = C.get(arch_id)
    dims = spec.shapes[shape_name]
    kind = dims["kind"]
    cfg = C.cell_model_cfg(spec, shape_name)
    cfg = _apply_opt(spec, cfg, mesh, opt)
    if variant == "unroll":
        import dataclasses as _dc
        cfg = _dc.replace(cfg, unroll=True)
    elif variant.startswith("probe"):   # probe2 / probe4: unrolled shallow probes
        import dataclasses as _dc
        cfg = _dc.replace(cfg, unroll=True, n_layer=int(variant[5:]))
    batch = C.input_specs(spec, shape_name, model_cfg=cfg)
    b_specs = C.batch_specs(spec, shape_name, batch, mesh)
    params = C.abstract_params(spec, cfg)
    p_specs = C.param_specs(spec, params, mesh)

    take_fn = cand_take_fn = None
    if spec.family == "recsys":
        dp = shd.dp_axes(mesh)
        if kind == "retrieval":
            take_fn = shd.make_vp_take(mesh, leading=None)
            cand_take_fn = shd.make_vp_take(mesh, leading=dp)
        else:
            take_fn = shd.make_vp_take(mesh, leading=dp)
            cand_take_fn = take_fn

    if kind == "train":
        opt = jax.eval_shape(adamw.init_state, params)
        o_specs = {"mu": p_specs, "nu": p_specs, "step": P()}
        fn = C.make_train_step(spec, cfg, take_fn=take_fn)
        in_sh = (_named(p_specs, mesh), _named(o_specs, mesh), _named(b_specs, mesh))
        out_sh = (_named(p_specs, mesh), _named(o_specs, mesh),
                  _named(jax.tree.map(lambda _: P(), {"loss": 0, "grad_norm": 0, "lr": 0}), mesh))
        args = (params, opt, batch)
    else:
        fn = C.make_serve_step(spec, shape_name, cfg,
                               take_fn=take_fn, cand_take_fn=cand_take_fn)
        in_sh = (_named(p_specs, mesh), _named(b_specs, mesh))
        out_sh = None  # let SPMD choose output layouts for serving
        args = (params, batch)
    meta = {
        "arch": arch_id, "shape": shape_name, "kind": kind,
        "model_flops": C.model_flops(spec, shape_name, model_cfg=cfg),
        "family": spec.family,
    }
    return fn, args, in_sh, out_sh, meta


def _compile_cell(arch_id, shape_name, mesh, variant="base", opt="base"):
    fn, args, in_sh, out_sh, meta = build_cell(arch_id, shape_name, mesh,
                                               variant=variant, opt=opt)
    t0 = time.perf_counter()
    jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
    lowered = jfn.lower(*args)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    return compiled, meta, t_lower, t_compile


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             verbose: bool = True, probes: bool = True,
             opt: str = "base") -> dict:
    """Compile the full config (scan-over-layers: the deployable artifact —
    its memory_analysis is the real footprint) and, for LM archs, two
    shallow *unrolled* probe compiles (L=2, L=4).

    XLA's HloCostAnalysis tallies a while-loop body once regardless of trip
    count, so FLOPs/bytes/collective bytes of the scan build undercount by
    ~L x. Layers are identical, so every cost is affine in L: the probes
    give slope = (cost(4) - cost(2)) / 2 and base = cost(2) - 2*slope, and
    the reported totals are base + n_layer*slope — including remat
    recompute, which the unrolled probes expose honestly.
    """
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(mesh.devices.shape))
    spec = C.get(arch_id)
    compiled, meta, t_lower, t_compile = _compile_cell(arch_id, shape_name, mesh,
                                                       opt=opt)
    rep = roofline.analyze_compiled(compiled,
                                    model_flops_global=meta["model_flops"],
                                    n_devices=n_dev)
    rep.update(meta)
    if probes and spec.family.startswith("lm"):
        L = C.cell_model_cfg(spec, shape_name).n_layer
        probe_reps = {}
        for pv in ("probe2", "probe4"):
            pc, _, _, pt = _compile_cell(arch_id, shape_name, mesh, variant=pv,
                                         opt=opt)
            probe_reps[pv] = roofline.analyze_compiled(
                pc, model_flops_global=meta["model_flops"], n_devices=n_dev)
            probe_reps[pv]["compile_s"] = round(pt, 2)
            del pc
        def affine(key):
            c2 = probe_reps["probe2"][key]
            c4 = probe_reps["probe4"][key]
            slope = (c4 - c2) / 2.0
            return max(c2 - 2.0 * slope + L * slope, 0.0)
        rep["scan_raw"] = {
            "flops_per_device": rep["flops_per_device"],
            "bytes_per_device": rep["bytes_per_device"],
            "collective_bytes": rep["collectives"]["total"],
        }
        rep["flops_per_device"] = affine("flops_per_device")
        rep["bytes_per_device"] = affine("bytes_per_device")
        c2t, c4t = (probe_reps["probe2"]["collectives"]["total"],
                    probe_reps["probe4"]["collectives"]["total"])
        slope = (c4t - c2t) / 2.0
        rep["collectives"]["total"] = max(c2t - 2 * slope + L * slope, 0.0)
        rep["collectives"]["extrapolated"] = True
        rep["roofline"] = roofline.roofline_terms(
            rep["flops_per_device"], rep["bytes_per_device"],
            rep["collectives"]["total"],
            model_flops_global=meta["model_flops"], n_devices=n_dev)
        rep["probes"] = {k: {"flops_per_device": v["flops_per_device"],
                             "bytes_per_device": v["bytes_per_device"],
                             "collective_bytes": v["collectives"]["total"],
                             "compile_s": v["compile_s"]}
                         for k, v in probe_reps.items()}
    rep["mesh"] = "x".join(map(str, mesh.devices.shape)) + ":" + ",".join(mesh.axis_names)
    rep["n_devices"] = n_dev
    rep["lower_s"] = round(t_lower, 2)
    rep["compile_s"] = round(t_compile, 2)
    if verbose:
        mem = rep.get("memory", {})
        r = rep["roofline"]
        print(f"[{rep['mesh']}] {arch_id} x {shape_name}: "
              f"compile {t_compile:.1f}s | "
              f"flops/dev {rep['flops_per_device']:.3e} | "
              f"bytes/dev {rep['bytes_per_device']:.3e} | "
              f"coll/dev {rep['collectives']['total']:.3e}B {rep['collectives']['counts']} | "
              f"terms c={r['compute_s']*1e3:.2f}ms m={r['memory_s']*1e3:.2f}ms "
              f"x={r['collective_s']*1e3:.2f}ms -> {r['dominant']} | "
              f"useful {r['useful_flop_ratio']:.2f} | mem {mem}")
    return rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--out", default=None, help="directory for per-cell JSON records")
    ap.add_argument("--opt", default="base", choices=OPTS,
                    help="§Perf variant (see EXPERIMENTS.md)")
    args = ap.parse_args()

    cells = (list(C.all_cells()) if args.all
             else [(args.arch, args.shape)])
    meshes = [False, True]
    if args.single_pod_only:
        meshes = [False]
    if args.multi_pod_only:
        meshes = [True]

    failures = []
    for arch_id, shape_name in cells:
        for mp in meshes:
            tag = f"{arch_id}__{shape_name}__{'multi' if mp else 'single'}"
            if args.opt != "base":
                tag += f"__{args.opt}"
            out_path = args.out and os.path.join(args.out, tag + ".json")
            if out_path and os.path.exists(out_path):
                print(f"[skip cached] {tag}")
                continue
            try:
                rep = run_cell(arch_id, shape_name, multi_pod=mp, opt=args.opt)
                if out_path:
                    os.makedirs(args.out, exist_ok=True)
                    with open(out_path, "w") as f:
                        json.dump(rep, f, indent=1, default=str)
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"[FAIL] {tag}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(" ", tag, err)
        raise SystemExit(1)
    print("\nDRY-RUN: all requested cells compiled.")


if __name__ == "__main__":
    main()
