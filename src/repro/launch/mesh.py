"""Production mesh construction (per the multi-pod dry-run contract).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware model used for every roofline term (EXPERIMENTS.md).
HW = {
    "peak_flops_bf16": 197e12,     # per chip
    "hbm_bw": 819e9,               # bytes/s per chip
    "ici_bw": 50e9,                # bytes/s per link (~per-chip injection)
    "hbm_bytes": 16e9,
}
