"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dry-run JSONs.

    PYTHONPATH=src python -m repro.launch.report results/dryrun
"""

from __future__ import annotations

import json
import os
import sys


def load(dirpath: str):
    recs = []
    for fn in sorted(os.listdir(dirpath)):
        if fn.endswith(".json"):
            with open(os.path.join(dirpath, fn)) as f:
                recs.append(json.load(f))
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/1e9:.2f}GB" if b >= 1e9 else f"{b/1e6:.1f}MB"


def dryrun_table(recs):
    lines = [
        "| arch | shape | mesh | compile s | flops/dev | bytes/dev | coll B/dev (ops) | arg B/dev | temp B/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        mem = r.get("memory", {})
        counts = r["collectives"].get("counts", {})
        cshort = "+".join(f"{k.split('-')[-1][:4]}:{v}" for k, v in sorted(counts.items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh'].split(':')[0]} "
            f"| {r['compile_s']} | {r['flops_per_device']:.2e} "
            f"| {r['bytes_per_device']:.2e} | {r['collectives']['total']:.2e} ({cshort}) "
            f"| {fmt_bytes(mem.get('argument_size_in_bytes'))} "
            f"| {fmt_bytes(mem.get('temp_size_in_bytes'))} |")
    return "\n".join(lines)


def roofline_table(recs, mesh_filter="16x16"):
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | model TFLOPs | HLO TFLOPs | useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if not r["mesh"].startswith(mesh_filter):
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4f} "
            f"| {rf['memory_s']:.4f} | {rf['collective_s']:.4f} "
            f"| {rf['dominant'].replace('_s','')} "
            f"| {rf['model_flops']/1e12:.1f} | {rf['hlo_flops_global']/1e12:.1f} "
            f"| {rf['useful_flop_ratio']:.2f} | {rf['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def pick_hillclimb(recs):
    """worst roofline fraction / most collective-bound / most paper-like."""
    singles = [r for r in recs if r["mesh"].startswith("16x16")]
    worst = min(singles, key=lambda r: r["roofline"]["roofline_fraction"])
    coll = max(singles, key=lambda r: (r["roofline"]["collective_s"]
                                       / max(r["roofline"]["step_time_lb_s"], 1e-12)))
    return worst, coll


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    recs = load(d)
    print(f"### Dry-run ({len(recs)} cells)\n")
    print(dryrun_table(recs))
    print("\n### Roofline (single-pod 16x16)\n")
    print(roofline_table(recs, "16x16"))
    print("\n### Roofline (multi-pod 2x16x16)\n")
    print(roofline_table(recs, "2x16x16"))
    worst, coll = pick_hillclimb(recs)
    print(f"\nworst roofline fraction: {worst['arch']} x {worst['shape']} "
          f"({worst['roofline']['roofline_fraction']:.3f})")
    print(f"most collective-bound:   {coll['arch']} x {coll['shape']} "
          f"({coll['roofline']['collective_s']:.3f}s of "
          f"{coll['roofline']['step_time_lb_s']:.3f}s)")


if __name__ == "__main__":
    main()
