"""Repo-specific static-analysis suite (DESIGN.md §12).

``python -m repro.analysis`` runs every registered pass over the include
roots from ``[tool.repro-analysis]`` in ``pyproject.toml`` and reports
structured findings; ``--strict`` (the CI gate) exits non-zero on any
finding not in the committed baseline.

Pass families:

* :mod:`~repro.analysis.passes_locks` — lock-order + blocking-call-under-
  lock against the hierarchy declared in :mod:`repro.obs.locks` (whose
  runtime :class:`~repro.obs.locks.LockWitness` covers the dynamic side).
* :mod:`~repro.analysis.passes_jax` — tracing hygiene for jitted code.
* :mod:`~repro.analysis.passes_api` — deprecated shims, metrics bypasses,
  wall-clock misuse, bare asserts.
* :mod:`~repro.analysis.passes_kernels` — Pallas kernel contracts: grid
  divisibility, index_map purity, VMEM budgets, int32 overflow flow and
  device-layout contracts, on the :mod:`~repro.analysis.shapeflow`
  abstract interpreter (runtime counterpart:
  :mod:`repro.kernels.contracts`, armed by ``REPRO_KERNEL_WITNESS=1``).

Adding a pass: write ``(module, config) -> Iterable[Finding]``, register
it in :data:`PASSES` under its rule-family name, document it in DESIGN.md
§12.4, and add positive + negative fixtures under
``tests/fixtures/analysis/``.
"""

from .core import (AnalysisConfig, Baseline, Finding, Module,
                   run_analysis)
from .passes_api import pass_api_discipline
from .passes_jax import pass_jax_hygiene
from .passes_kernels import pass_kernel_contracts
from .passes_locks import pass_lock_discipline

#: name -> pass callable; config ``passes = [...]`` selects a subset.
PASSES = {
    "locks": pass_lock_discipline,
    "jax": pass_jax_hygiene,
    "api": pass_api_discipline,
    "kernels": pass_kernel_contracts,
}

__all__ = [
    "AnalysisConfig", "Baseline", "Finding", "Module", "PASSES",
    "run_analysis", "pass_lock_discipline", "pass_jax_hygiene",
    "pass_api_discipline", "pass_kernel_contracts",
]
