"""API-discipline passes (DESIGN.md §12.3c).

* ``deprecated-shim`` — calls into the PR-3 legacy positional surfaces
  (``index.query(u, ts, te)``, ``engine.submit(workload, k, u, ts, te)``,
  ``engine.submit_many(...)``): the v2 ``TCCSQuery`` surface validates,
  canonicalizes and records provenance; the shims skip all three. The
  ``deprecated-calls`` config maps method name -> the *minimum positional
  arity* that identifies the legacy signature (so ``batcher.submit(req)``
  and ``executor.submit``-style two-arg calls stay clean). Definition
  sites and the ``_component_vertices`` internals are not calls and are
  not flagged; the shim bodies themselves suppress inline.
* ``metrics-direct`` — writes to counter state (``.hits += 1``,
  ``._counters[...] = ...``) outside the owning class: every counter
  mutation must flow through ``MetricsRegistry.count`` so the unified
  snapshot, export and reset surfaces stay truthful.
* ``wallclock-in-traced`` — ``time.time()`` in modules on the
  ``wallclock-modules`` list (the serving + obs planes): span timing and
  latency math there use ``time.perf_counter()`` (monotonic, high
  resolution); mixing in wall-clock reads breaks duration arithmetic
  across NTP steps. Wall-clock metadata (checkpoint ``written_at``) lives
  outside the listed modules.
* ``bare-assert`` — ``assert`` statements in library code: they vanish
  under ``python -O``, so invariants guarding data integrity must raise
  typed errors. (Tests keep their asserts — the include list only covers
  ``src/``.)
* ``per-k-key`` — new code constructing the pre-PR-9 ``(workload, k)``
  registry/store keys: a two-element tuple passed to a key-taking method
  (``get``/``get_nowait``/``get_async``/``load``/``put_handle``/
  ``current_epoch``/``delete``), a positional k after the workload on
  ``get``-family / ``warmup`` / ``prefetch``, or a tuple membership test
  against a registry. The k axis lives *inside* the handle now
  (``handle.supported_ks``); the compat shims that still accept these
  forms suppress inline. Receiver-restricted to registry / store /
  engine-looking names so result-cache keys (legitimately
  ``(index_key, spec_key)`` tuples) stay clean.
"""

from __future__ import annotations

import ast
from typing import Iterable

from .core import AnalysisConfig, Finding, Module, make_finding

#: attribute names that are counter state on metrics-ish objects
_COUNTER_ATTRS = frozenset({"_counters", "_gauges"})

#: key-taking methods of the index plane (registry / disk tier / engine)
_PERK_KEY_METHODS = frozenset({"get", "get_nowait", "get_async", "load",
                               "put_handle", "current_epoch", "delete"})
#: methods where a *positional* second argument is the deprecated k
_PERK_POSITIONAL_METHODS = frozenset({"get", "get_nowait", "get_async",
                                      "warmup", "prefetch"})
#: receiver-name tails that look like the index plane; anything else
#: (caches keyed by (index_key, spec_key) tuples, dicts, ...) stays clean
_PERK_RECEIVER_TAILS = ("registry", "reg", "store", "engine", "eng")


def _dotted(node: ast.AST) -> str | None:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def pass_api_discipline(module: Module,
                        config: AnalysisConfig) -> Iterable[Finding]:
    findings: list[Finding] = []
    wallclock = any(module.dotted == m or module.dotted.startswith(m + ".")
                    for m in config.wallclock_modules)
    # bench floor-asserts and test fixture helpers keep their asserts:
    # they never run under python -O in a context that matters
    assert_exempt = any(module.rel.startswith(p)
                        for p in config.assert_exempt)

    for node in ast.walk(module.tree):
        # -- deprecated-shim ---------------------------------------------
        if isinstance(node, ast.Call) and isinstance(node.func,
                                                     ast.Attribute):
            name = node.func.attr
            min_arity = config.deprecated_calls.get(name)
            if (min_arity is not None and len(node.args) >= min_arity
                    and not _first_arg_is_callable_ref(node)
                    and not _receiver_is_executor(node)):
                findings.append(make_finding(
                    module, "deprecated-shim", node,
                    f".{name}() with {len(node.args)} positional args "
                    "matches a PR-3 legacy shim signature; migrate to "
                    "the TCCSQuery v2 surface (answer/submit_spec)"))

        # -- per-k-key ---------------------------------------------------
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and _receiver_is_index_plane(node)):
            name = node.func.attr
            if (name in _PERK_KEY_METHODS and node.args
                    and isinstance(node.args[0], ast.Tuple)
                    and len(node.args[0].elts) == 2):
                findings.append(make_finding(
                    module, "per-k-key", node,
                    f".{name}() with a (workload, k) tuple key: the "
                    "registry/store key space is workload-only since the "
                    "k-stratified index plane — pass the workload name "
                    "and pick k per query (handle.supported_ks)"))
            elif (name in _PERK_POSITIONAL_METHODS
                  and len(node.args) >= 2
                  and _looks_like_k(node.args[1])):
                findings.append(make_finding(
                    module, "per-k-key", node,
                    f".{name}(workload, k) passes a per-k positional "
                    "key: one k-stratified build serves every k — drop "
                    "the k (it is deprecated and ignored)"))
        if (isinstance(node, ast.Compare) and len(node.ops) == 1
                and isinstance(node.ops[0], (ast.In, ast.NotIn))
                and isinstance(node.left, ast.Tuple)
                and len(node.left.elts) == 2
                and _name_is_index_plane(node.comparators[0])):
            findings.append(make_finding(
                module, "per-k-key", node,
                "(workload, k) membership test against a registry: "
                "residency is keyed by workload alone — test the name "
                "and check handle.supported_ks for the k"))

        # -- metrics-direct ----------------------------------------------
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for tgt in targets:
                base = tgt
                if isinstance(base, ast.Subscript):
                    base = base.value
                if (isinstance(base, ast.Attribute)
                        and base.attr in _COUNTER_ATTRS
                        and not _is_self_write_in_owner(module, base)):
                    findings.append(make_finding(
                        module, "metrics-direct", node,
                        f"direct write to {base.attr!r} bypasses "
                        "MetricsRegistry.count/gauge; counters mutated "
                        "behind the registry's back disappear from "
                        "snapshots and reset()"))

        # -- wallclock-in-traced -----------------------------------------
        if (wallclock and isinstance(node, ast.Call)
                and _dotted(node.func) == "time.time"):
            findings.append(make_finding(
                module, "wallclock-in-traced", node,
                "time.time() in a span-instrumented module; durations "
                "and deadlines here use time.perf_counter() — wall "
                "clock steps (NTP) corrupt latency math"))

        # -- bare-assert --------------------------------------------------
        if isinstance(node, ast.Assert) and not assert_exempt:
            findings.append(make_finding(
                module, "bare-assert", node,
                "assert in library code vanishes under python -O; "
                "raise a typed error for data-integrity invariants"))
    return findings


def _first_arg_is_callable_ref(call: ast.Call) -> bool:
    """``pool.submit(self._run_build, key, ...)`` is ThreadPoolExecutor's
    submit, not the engine shim: its first positional arg is a function
    reference (attribute chain or lambda), where the shim takes a workload
    string/name."""
    if not call.args:
        return False
    first = call.args[0]
    return isinstance(first, (ast.Attribute, ast.Lambda))


def _receiver_is_executor(call: ast.Call) -> bool:
    """``pool.submit(...)`` / ``self._build_pool.submit(...)``: receivers
    named like thread pools are concurrent.futures executors, never the
    engine shim."""
    recv = _dotted(call.func.value) or ""  # type: ignore[union-attr]
    tail = recv.rsplit(".", 1)[-1].lower()
    return "pool" in tail or "executor" in tail


def _receiver_is_index_plane(call: ast.Call) -> bool:
    """``registry.get(...)`` / ``self._store.load(...)`` / ``eng.warmup``:
    the per-k-key rule only fires on receivers whose final name component
    looks like the index plane, so tuple keys of other key spaces (the
    result cache's ``(index_key, spec_key)``) stay clean."""
    recv = _dotted(call.func.value) or ""  # type: ignore[union-attr]
    tail = recv.rsplit(".", 1)[-1].lower().lstrip("_")
    return any(tail == t or tail.endswith("_" + t) or tail.startswith(t)
               for t in _PERK_RECEIVER_TAILS)


def _name_is_index_plane(node: ast.AST) -> bool:
    recv = _dotted(node) or ""
    tail = recv.rsplit(".", 1)[-1].lower().lstrip("_")
    return any(tail == t or tail.endswith("_" + t) or tail.startswith(t)
               for t in _PERK_RECEIVER_TAILS)


def _looks_like_k(node: ast.AST) -> bool:
    """An integer literal or a variable literally named ``k``/``k_``-ish
    in the second positional slot — the deprecated per-k argument. Other
    second positionals (timeouts as floats, option flags) stay clean."""
    if isinstance(node, ast.Constant):
        return isinstance(node.value, int) and not isinstance(node.value,
                                                              bool)
    return isinstance(node, ast.Name) and (
        node.id == "k" or node.id.startswith("k_") or node.id.endswith("_k"))


def _is_self_write_in_owner(module: Module, attr: ast.Attribute) -> bool:
    """``self._counters[...]`` writes inside the class that owns the
    counter dict are the implementation, not a bypass."""
    return (isinstance(attr.value, ast.Name) and attr.value.id == "self")
