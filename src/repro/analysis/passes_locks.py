"""Lock-discipline passes (DESIGN.md §12.3a).

Two rules over the declared hierarchy in :mod:`repro.obs.locks`:

* ``lock-order`` — a ``with``-acquisition of a lock whose hierarchy rank is
  not strictly greater than every lock already held on the static hold
  stack, and calls (while holding a lock) to methods of receivers that are
  *known* to acquire a lock (the ``lock-receivers`` config map: e.g.
  ``_metrics`` methods take the ``metrics`` lock) whose rank does not
  increase.
* ``lock-blocking-call`` — a call matching the blocking-operation table
  (device execution / sync, ``Future.result``, cold index builds, sleeps,
  file I/O) made while any lock is held. Holding a serving-plane lock
  across a device round-trip or a disk write stalls every thread that
  needs the lock for the full device/disk latency — the §7/§9 design keeps
  those strictly outside critical sections.

Lock identity is read straight from the factory calls the subsystems use:
``self._lock = named_lock("registry")`` binds the attribute ``_lock`` (in
that class) to hierarchy level ``"registry"``. Plain ``threading.Lock()``
attributes are treated as level ``None`` — unrankable, so nesting them
under a named lock is itself a finding (``lock-order``: undeclared).

Static limits (the runtime witness covers these): acquisitions through
callbacks/listeners, locks passed across objects, and ``acquire()`` /
``release()`` call pairs (the repo's style is ``with`` blocks; bare
``acquire`` is flagged by ``lock-blocking-call``'s audit list so it gets a
human look).
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.obs.locks import LOCK_HIERARCHY

from .core import (AnalysisConfig, Finding, Module, iter_symbols,
                   make_finding)

_RANKS = {name: i for i, name in enumerate(LOCK_HIERARCHY)}

#: attribute-call suffixes that block: (attr name, human label)
_BLOCKING_ATTRS = {
    "block_until_ready": "device synchronization",
    "item": "device->host scalar sync",
    "result": "Future.result (blocks on async work)",
    "sleep": "sleep",
    "fsync": "disk flush",
}

#: names whose *call* blocks regardless of receiver
_BLOCKING_NAMES = {
    "open": "file I/O",
}

#: dotted calls that block (module alias resolved textually)
_BLOCKING_DOTTED = {
    "jax.device_get": "device->host transfer",
    "jax.device_put": "host->device transfer",
    "time.sleep": "sleep",
    "os.fsync": "disk flush",
}

#: receiver-method calls that perform a cold index build (config may extend)
_BUILD_METHODS = {"_build_index", "build_index", "_run_build"}

_LOCK_FACTORIES = {"named_lock", "named_condition"}


def _dotted(node: ast.AST) -> str | None:
    """``a.b.c`` -> ``"a.b.c"`` for Name/Attribute chains, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _self_attr(node: ast.AST) -> str | None:
    """``self.<attr>`` -> ``attr``, else None."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _collect_lock_attrs(module: Module) -> dict[str, str]:
    """Map ``self.<attr>`` lock attributes to hierarchy level names by
    finding ``self.<attr> = named_lock("<level>")`` assignments (and the
    condition variant) anywhere in the module."""
    out: dict[str, str] = {}
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Assign):
            continue
        call = node.value
        if not (isinstance(call, ast.Call)
                and isinstance(call.func, (ast.Name, ast.Attribute))):
            continue
        fname = (call.func.id if isinstance(call.func, ast.Name)
                 else call.func.attr)
        if fname not in _LOCK_FACTORIES:
            continue
        if not (call.args and isinstance(call.args[0], ast.Constant)
                and isinstance(call.args[0].value, str)):
            continue
        level = call.args[0].value
        for tgt in node.targets:
            attr = _self_attr(tgt)
            if attr is not None:
                out[attr] = level
    return out


def _with_lock_level(item: ast.withitem,
                     lock_attrs: dict[str, str]) -> str | None | bool:
    """Classify a ``with`` item: a level name if it acquires a known named
    lock, ``None`` if it acquires an *unnamed* ``self``-attribute that
    looks like a lock/condition, ``False`` if it is not a lock at all."""
    ctx = item.context_expr
    attr = _self_attr(ctx)
    if attr is None:
        return False
    if attr in lock_attrs:
        return lock_attrs[attr]
    if "lock" in attr.lower() or "cond" in attr.lower():
        return None
    return False


def _blocking_reason(call: ast.Call, config: AnalysisConfig) -> str | None:
    dotted = _dotted(call.func)
    if dotted is not None and dotted in _BLOCKING_DOTTED:
        return _BLOCKING_DOTTED[dotted]
    if isinstance(call.func, ast.Name):
        return _BLOCKING_NAMES.get(call.func.id)
    if isinstance(call.func, ast.Attribute):
        name = call.func.attr
        if name in _BUILD_METHODS:
            return "cold index build"
        if name in _BLOCKING_ATTRS:
            # `.result(...)` / `.block_until_ready(...)` etc. —
            # receiver-agnostic: the point is that *something* waits
            # while the lock is held
            return _BLOCKING_ATTRS[name]
        if name == "join":
            # str.join is ubiquitous; only thread-shaped receivers count
            recv = _dotted(call.func.value) or ""
            if "thread" in recv.lower() or "worker" in recv.lower():
                return "thread join"
    return None


def _receiver_lock_level(call: ast.Call,
                         config: AnalysisConfig) -> tuple[str, str] | None:
    """``self._metrics.count(...)`` -> ("metrics", "_metrics.count") if the
    ``lock-receivers`` config maps ``_metrics`` to a level."""
    if not isinstance(call.func, ast.Attribute):
        return None
    recv = _self_attr(call.func.value)
    if recv is None:
        return None
    level = config.lock_receivers.get(recv)
    if level is None:
        return None
    return level, f"{recv}.{call.func.attr}"


class _FunctionLockWalker(ast.NodeVisitor):
    """Walk one function body tracking the ``with``-lock hold stack.

    Nested function/lambda bodies are *not* analyzed under the outer hold
    stack: they run when called, not where defined (the runtime witness
    catches callbacks that do run under a lock).
    """

    def __init__(self, module: Module, config: AnalysisConfig,
                 lock_attrs: dict[str, str], symbol: str,
                 findings: list[Finding]):
        self.module = module
        self.config = config
        self.lock_attrs = lock_attrs
        self.symbol = symbol
        self.findings = findings
        self.stack: list[tuple[str | None, ast.withitem]] = []

    # -- nested defs are separate scopes ---------------------------------
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        pass

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        pass

    def visit_Lambda(self, node: ast.Lambda) -> None:
        pass

    # -- with blocks -----------------------------------------------------
    def visit_With(self, node: ast.With) -> None:
        acquired = 0
        for item in node.items:
            level = _with_lock_level(item, self.lock_attrs)
            if level is False:
                # not a lock — but `with open(...)` under a held lock is
                # still a blocking call: walk the context expression
                self.visit(item.context_expr)
                continue
            self._check_acquire(level, item.context_expr)
            self.stack.append((level, item))
            acquired += 1
        for stmt in node.body:
            self.visit(stmt)
        for _ in range(acquired):
            self.stack.pop()

    def _check_acquire(self, level: str | None, node: ast.AST) -> None:
        if not self.stack:
            if level is not None and level not in _RANKS:
                self.findings.append(make_finding(
                    self.module, "lock-order", node,
                    f"lock level {level!r} is not in the declared "
                    f"hierarchy {list(LOCK_HIERARCHY)}",
                    symbol=self.symbol))
            return
        outer_level = self.stack[-1][0]
        if level is None:
            self.findings.append(make_finding(
                self.module, "lock-order", node,
                "acquired an unnamed lock while holding "
                f"{outer_level!r}; every lock nested under a hierarchy "
                "lock must itself be a named_lock/named_condition",
                symbol=self.symbol))
            return
        ri = _RANKS.get(level)
        ro = _RANKS.get(outer_level) if outer_level is not None else None
        if ri is None:
            self.findings.append(make_finding(
                self.module, "lock-order", node,
                f"lock level {level!r} is not in the declared hierarchy",
                symbol=self.symbol))
        elif ro is not None and ri <= ro:
            self.findings.append(make_finding(
                self.module, "lock-order", node,
                f"acquired {level!r} (rank {ri}) while holding "
                f"{outer_level!r} (rank {ro}); the declared hierarchy "
                "requires strictly increasing rank "
                f"({' < '.join(LOCK_HIERARCHY)})",
                symbol=self.symbol))

    # -- calls under a held lock -----------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        if self.stack:
            held = self.stack[-1][0]
            reason = _blocking_reason(node, self.config)
            if reason is not None:
                self.findings.append(make_finding(
                    self.module, "lock-blocking-call", node,
                    f"{reason} while holding lock "
                    f"{held if held is not None else '<unnamed>'!r}; "
                    "move the blocking work outside the critical section",
                    symbol=self.symbol))
            recv = _receiver_lock_level(node, self.config)
            if recv is not None:
                level, label = recv
                ri = _RANKS.get(level)
                ro = _RANKS.get(held) if held is not None else None
                if ri is not None and ro is not None and ri <= ro:
                    self.findings.append(make_finding(
                        self.module, "lock-order", node,
                        f"call {label}() acquires {level!r} (rank {ri}) "
                        f"while holding {held!r} (rank {ro}); the "
                        "declared hierarchy requires strictly increasing "
                        "rank", symbol=self.symbol))
        self.generic_visit(node)


def pass_lock_discipline(module: Module,
                         config: AnalysisConfig) -> Iterable[Finding]:
    """``lock-order`` + ``lock-blocking-call`` over one module."""
    lock_attrs = _collect_lock_attrs(module)
    findings: list[Finding] = []
    for symbol, node in iter_symbols(module.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        walker = _FunctionLockWalker(module, config, lock_attrs,
                                     symbol=symbol, findings=findings)
        for stmt in node.body:
            walker.visit(stmt)
    return findings
