"""Kernel-contract passes: device-plane shape/dtype discipline
(DESIGN.md §15.3).

Five rules over every ``pl.pallas_call`` site and device-layout builder,
driven by the :mod:`~repro.analysis.shapeflow` abstract interpreter:

* ``pallas-grid-divisibility`` — a grid element of the form ``x // b``
  silently drops the tail unless ``x`` is provably a multiple of ``b``.
  The proof obligations are discharged symbolically: the repo's padding
  idioms (``int(np.ceil(max(e, 1) / b)) * b``, the ``N + (ceil*b - N)``
  cancellation in label_prop) all normalize to a multiple of ``b``.
* ``pallas-indexmap-closure`` — a BlockSpec index_map closing over a
  local of the enclosing wrapper (a traced value, a mutated Python
  variable) is a staleness/miscompile hazard: index maps must be pure
  functions of the grid indices (module constants are fine).
* ``pallas-vmem-budget`` — sum of block shapes x dtype across in/out
  specs, against the per-platform budget in ``[tool.repro-analysis]``.
  Dims that resolve to constants (parameter defaults, module constants)
  are exact; data-dependent dims use the configured assumed extent.
* ``int32-narrowing`` — dtype-flow for the PR-9 overflow class: a cast
  to int32 whose operand carries a product of non-constant extents
  (``k_index * n + u``, ``K * n + 1``) or is int64-typed is a silent
  wrap waiting for a big enough workload — unless it flows through a
  *checked caster* (a function that raises an ``*Overflow*`` error,
  like ``batch_query._i32``).
* ``layout-contract`` — every array entering ``to_device`` /
  ``_host_layout`` must be declared (dtype+rank) in
  ``repro.kernels.contracts.LAYOUT_CONTRACTS``; construction-site dict
  literals are cross-checked both ways and every value must provably be
  int32 (guarded caster, int32 constructor, or an int32-typed name).

The runtime counterpart (``repro.kernels.contracts``) validates the same
contracts on real arrays when ``REPRO_KERNEL_WITNESS=1`` — static proof
where the AST suffices, a witness where it cannot.
"""

from __future__ import annotations

import ast
from typing import Iterable

from . import shapeflow as sf
from .core import AnalysisConfig, Finding, Module, make_finding

_PALLAS_CALL_NAMES = frozenset({"pl.pallas_call", "pallas.pallas_call",
                                "pallas_call"})
_NARROW_FUNCS = frozenset({"np.int32", "numpy.int32", "jnp.int32"})
_ASARRAY_FUNCS = frozenset({"np.asarray", "numpy.asarray", "np.array",
                            "numpy.array", "jnp.asarray", "jnp.array"})

_layout_contracts_cache: dict | None = None


def _layout_contracts() -> dict:
    """The declared device-layout table, imported lazily so a lint run
    only needs numpy (contracts.py is deliberately jax-free)."""
    global _layout_contracts_cache
    if _layout_contracts_cache is None:
        try:
            from repro.kernels.contracts import LAYOUT_CONTRACTS
            _layout_contracts_cache = dict(LAYOUT_CONTRACTS)
        except Exception:  # pragma: no cover - contracts unimportable
            _layout_contracts_cache = {}
    return _layout_contracts_cache


def _dotted(node: ast.AST) -> str | None:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _kwargs(call: ast.Call) -> dict[str, ast.AST]:
    return {kw.arg: kw.value for kw in call.keywords if kw.arg}


def _local_names(fn: ast.AST) -> set[str]:
    """Parameter + assigned names of a function — what an index_map
    lambda must NOT close over."""
    out: set[str] = set()
    args = getattr(fn, "args", None)
    if args is not None:
        for a in (args.posonlyargs + args.args + args.kwonlyargs):
            out.add(a.arg)
        if args.vararg:
            out.add(args.vararg.arg)
        if args.kwarg:
            out.add(args.kwarg.arg)
    for node in ast.walk(fn):
        targets: list[ast.AST] = []
        if isinstance(node, ast.Assign):
            targets = list(node.targets)
        elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
            targets = [node.target]
        elif isinstance(node, ast.For):
            targets = [node.target]
        for tgt in targets:
            for sub in ast.walk(tgt):
                if isinstance(sub, ast.Name):
                    out.add(sub.id)
    return out


def _resolve(env: sf.Env, node: ast.AST, hops: int = 5) -> ast.AST:
    """Chase ``Name -> its assigned value`` a bounded number of times
    (``grid = (...)`` then ``grid=grid``; ``blocks_kv = Tp // bk``)."""
    while hops and isinstance(node, ast.Name) and node.id in env.value_ast:
        node = env.value_ast[node.id]
        hops -= 1
    return node


def _iter_functions(tree: ast.Module):
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            yield node


def _pallas_sites(fn: ast.AST):
    """Yield ``(inner, outer)``: the ``pl.pallas_call(...)`` call and the
    call applying it to operands (None if not immediately applied)."""
    inners = [node for node in ast.walk(fn)
              if isinstance(node, ast.Call)
              and _dotted(node.func) in _PALLAS_CALL_NAMES]
    if not inners:
        return
    outers: dict[ast.AST, ast.Call] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and node.func in inners:
            outers[node.func] = node
    for inner in inners:
        yield inner, outers.get(inner)


def _spec_list(node: ast.AST | None) -> list[ast.Call]:
    """BlockSpec calls from an in_specs/out_specs value (list or single)."""
    if node is None:
        return []
    elts = node.elts if isinstance(node, (ast.List, ast.Tuple)) else [node]
    return [e for e in elts
            if isinstance(e, ast.Call)
            and (_dotted(e.func) or "").endswith("BlockSpec")]


# ---------------------------------------------------------------------------
# rule: pallas-grid-divisibility
# ---------------------------------------------------------------------------

def _check_grid(module: Module, env: sf.Env, inner: ast.Call,
                findings: list[Finding]) -> None:
    grid = _resolve(env, _kwargs(inner).get("grid"))
    if grid is None:
        return
    elts = grid.elts if isinstance(grid, (ast.Tuple, ast.List)) else [grid]
    for elt in elts:
        node = _resolve(env, elt)
        if not (isinstance(node, ast.BinOp)
                and isinstance(node.op, ast.FloorDiv)):
            continue
        num = env.lin(node.left)
        den = env.lin(node.right)
        if not sf.divides(num, den):
            findings.append(make_finding(
                module, "pallas-grid-divisibility", elt,
                f"grid element {ast.unparse(node)!r}: the numerator is "
                "not provably a multiple of the block size — the tail "
                "iterations are silently dropped; pad with "
                "int(np.ceil(x / b)) * b before dividing"))


# ---------------------------------------------------------------------------
# rule: pallas-indexmap-closure
# ---------------------------------------------------------------------------

def _check_index_maps(module: Module, fn: ast.AST, inner: ast.Call,
                      locals_: set[str], findings: list[Finding]) -> None:
    kw = _kwargs(inner)
    for spec in (_spec_list(kw.get("in_specs"))
                 + _spec_list(kw.get("out_specs"))):
        index_map = None
        if len(spec.args) >= 2:
            index_map = spec.args[1]
        elif "index_map" in _kwargs(spec):
            index_map = _kwargs(spec)["index_map"]
        if not isinstance(index_map, ast.Lambda):
            continue
        for name in sf.free_names(index_map):
            if name in locals_:
                findings.append(make_finding(
                    module, "pallas-indexmap-closure", index_map,
                    f"index_map closes over local {name!r} of the "
                    "enclosing wrapper: index maps must be pure "
                    "functions of the grid indices (closure over traced "
                    "values or per-call Python state miscompiles or "
                    "goes stale across calls)"))


# ---------------------------------------------------------------------------
# rule: pallas-vmem-budget
# ---------------------------------------------------------------------------

def _block_bytes(env: sf.Env, shape_node: ast.AST, itemsize: int,
                 assumed: int) -> int:
    """Estimated bytes of one block: constant dims exact, unresolved dims
    at the assumed extent; non-tuple shapes (``deg.shape``) count as one
    assumed-extent dim."""
    if not isinstance(shape_node, (ast.Tuple, ast.List)):
        return assumed * itemsize
    total = 1
    for dim in shape_node.elts:
        lin = env.lin(dim)
        c = lin.as_const() if lin is not None else None
        total *= c if c is not None and c > 0 else assumed
    return total * itemsize


def _out_shape_dtypes(node: ast.AST | None) -> list[int]:
    """Itemsizes from ``out_shape=`` (ShapeDtypeStruct or list of them)."""
    if node is None:
        return []
    elts = node.elts if isinstance(node, (ast.List, ast.Tuple)) else [node]
    sizes = []
    for e in elts:
        size = 4
        if isinstance(e, ast.Call) and len(e.args) >= 2:
            name = sf.dtype_name(e.args[1])
            if name is not None:
                size = sf.DTYPE_BYTES[name]
        sizes.append(size)
    return sizes


def _check_vmem(module: Module, env: sf.Env, inner: ast.Call,
                outer: ast.Call | None, config: AnalysisConfig,
                findings: list[Finding]) -> None:
    kw = _kwargs(inner)
    assumed = config.vmem_assumed_extent
    budget = config.vmem_budget()
    total = 0

    in_specs = _spec_list(kw.get("in_specs"))
    operands = list(outer.args) if outer is not None else []
    for i, spec in enumerate(in_specs):
        itemsize = 4
        if i < len(operands):
            name = env.dtype_of(operands[i])
            if name is not None:
                itemsize = sf.DTYPE_BYTES[name]
        if spec.args:
            total += _block_bytes(env, spec.args[0], itemsize, assumed)

    out_specs = _spec_list(kw.get("out_specs"))
    out_sizes = _out_shape_dtypes(kw.get("out_shape"))
    for j, spec in enumerate(out_specs):
        itemsize = out_sizes[j] if j < len(out_sizes) else 4
        if spec.args:
            total += _block_bytes(env, spec.args[0], itemsize, assumed)

    if total > budget:
        findings.append(make_finding(
            module, "pallas-vmem-budget", inner,
            f"estimated per-step VMEM {total} B exceeds the "
            f"{config.vmem_platform!r} budget {budget} B (unresolved "
            f"dims assumed {assumed}); shrink the block sizes or raise "
            "the budget in [tool.repro-analysis.vmem-budgets]"))


# ---------------------------------------------------------------------------
# rule: int32-narrowing
# ---------------------------------------------------------------------------

def _is_narrowing_cast(node: ast.Call) -> ast.AST | None:
    """The operand being narrowed to int32, or None."""
    d = _dotted(node.func)
    if d in _NARROW_FUNCS and node.args:
        return node.args[0]
    if d in _ASARRAY_FUNCS and node.args:
        dtype = None
        for arg in node.args[1:]:
            dtype = sf.dtype_name(arg) or dtype
        for kwarg in node.keywords:
            if kwarg.arg == "dtype":
                dtype = sf.dtype_name(kwarg.value)
        if dtype == "int32":
            return node.args[0]
        return None
    if (isinstance(node.func, ast.Attribute) and node.func.attr == "astype"
            and node.args and sf.dtype_name(node.args[0]) == "int32"):
        return node.func.value
    return None


def _contains_narrowing(node: ast.AST) -> bool:
    return any(isinstance(sub, ast.Call)
               and _is_narrowing_cast(sub) is not None
               for sub in ast.walk(node))


def _raises_overflow(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Raise) and node.exc is not None:
            exc = node.exc
            if isinstance(exc, ast.Call):
                exc = exc.func
            name = (_dotted(exc) or "").rsplit(".", 1)[-1]
            if "Overflow" in name:
                return True
    return False


def _collect_casters(tree: ast.Module) -> dict[str, bool]:
    """Module-local narrowing casters: ``name -> guarded`` (guarded =
    the body raises an ``*Overflow*`` error before narrowing). Covers
    ``def _i32(...)``, ``i32 = lambda a: np.asarray(a, np.int32)`` and
    aliases ``i32 = _i32``."""
    casters: dict[str, bool] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and _contains_narrowing(node):
            casters[node.name] = _raises_overflow(node)
    for _ in range(2):  # aliases may precede or follow the definition
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                continue
            tname = node.targets[0].id
            if isinstance(node.value, ast.Lambda) \
                    and _contains_narrowing(node.value):
                casters[tname] = _raises_overflow(node.value)
            elif (isinstance(node.value, ast.Name)
                  and node.value.id in casters):
                casters[tname] = casters[node.value.id]
    return casters


def _is_risky(env: sf.Env, operand: ast.AST) -> str | None:
    """Why a narrowed operand may overflow int32, or None if clean."""
    if sf.int_expr_has_product(operand):
        return ("carries a product of non-constant extents "
                "(the k_index*n + u / K*n+1 packed-offset shape)")
    if env.dtype_of(operand) == "int64":
        return "is int64-typed"
    return None


def _check_narrowing(module: Module, tree_casters: dict[str, bool],
                     fn: ast.AST, env: sf.Env, symbol: str,
                     findings: list[Finding]) -> None:
    if _raises_overflow(fn):
        return  # the checked caster's own implementation
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        operand = _is_narrowing_cast(node)
        if operand is None and isinstance(node.func, ast.Name):
            caster = node.func.id
            if caster in tree_casters and node.args:
                if tree_casters[caster]:
                    continue  # guarded caster call — the fix pattern
                operand = node.args[0]
        if operand is None:
            continue
        why = _is_risky(env, operand)
        if why is not None:
            findings.append(make_finding(
                module, "int32-narrowing", node,
                f"int32 narrowing of an operand that {why}: silent "
                "wrap at scale — widen to int64, or route through a "
                "checked caster that raises a typed *Overflow* error",
                symbol=symbol))


# ---------------------------------------------------------------------------
# rule: layout-contract
# ---------------------------------------------------------------------------

def _value_int32_ok(env: sf.Env, node: ast.AST,
                    casters: dict[str, bool]) -> bool:
    if isinstance(node, ast.IfExp):
        return (_value_int32_ok(env, node.body, casters)
                and _value_int32_ok(env, node.orelse, casters))
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in casters):
        return casters[node.func.id]
    return env.dtype_of(node) == "int32"


def _check_layout_dicts(module: Module, fn: ast.AST, env: sf.Env,
                        casters: dict[str, bool], symbol: str,
                        findings: list[Finding]) -> None:
    table = _layout_contracts()
    if not table:
        return
    for node in ast.walk(fn):
        if not isinstance(node, ast.Dict):
            continue
        keys = [k.value for k in node.keys
                if isinstance(k, ast.Constant) and isinstance(k.value, str)]
        matched = [k for k in keys if k in table]
        if len(matched) < 3:
            continue  # not a device-layout construction site
        for key_node, val in zip(node.keys, node.values):
            if not (isinstance(key_node, ast.Constant)
                    and isinstance(key_node.value, str)):
                continue
            key = key_node.value
            if key not in table:
                findings.append(make_finding(
                    module, "layout-contract", key_node,
                    f"layout array {key!r} is not declared in "
                    "kernels.contracts.LAYOUT_CONTRACTS — declare its "
                    "dtype+rank or rename it", symbol=symbol))
                continue
            if not _value_int32_ok(env, val, casters):
                findings.append(make_finding(
                    module, "layout-contract", val,
                    f"layout value for {key!r} is not provably "
                    f"{table[key][0]}: construct with an int32 dtype or "
                    "route through a checked caster", symbol=symbol))
        missing = sorted(set(table) - set(keys))
        if missing:
            findings.append(make_finding(
                module, "layout-contract", node,
                f"declared layout arrays absent from this construction "
                f"site: {', '.join(missing)} — every contract array "
                "must be built (padded if empty)", symbol=symbol))


# ---------------------------------------------------------------------------
# the pass
# ---------------------------------------------------------------------------

def pass_kernel_contracts(module: Module,
                          config: AnalysisConfig) -> Iterable[Finding]:
    findings: list[Finding] = []
    consts = sf.module_int_consts(module.tree)
    casters = _collect_casters(module.tree)

    for fn in _iter_functions(module.tree):
        env = sf.function_env(fn, consts)
        symbol = fn.name
        locals_ = None
        for inner, outer in _pallas_sites(fn):
            if locals_ is None:
                locals_ = _local_names(fn)
            _check_grid(module, env, inner, findings)
            _check_index_maps(module, fn, inner, locals_, findings)
            _check_vmem(module, env, inner, outer, config, findings)
        _check_narrowing(module, casters, fn, env, symbol, findings)
        _check_layout_dicts(module, fn, env, casters, symbol, findings)
    return findings
