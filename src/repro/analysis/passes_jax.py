"""JAX tracing-hygiene passes (DESIGN.md §12.3b).

A *traced function* is one that runs under ``jax.jit``: decorated with
``@jax.jit`` / ``@jit`` / ``@partial(jax.jit, ...)``, or wrapped at module
level (``fn = jax.jit(g, static_argnums=...)``). Inside one, Python-level
control flow runs at *trace time* against abstract tracers, so:

* ``jit-assert`` — a bare ``assert`` on traced values either always passes
  (trace-time truthiness of an abstract value raises) or silently
  disappears under ``-O``; invariants on device values belong in
  ``checkify`` or host-side wrappers. Any ``assert`` in a traced function
  is flagged.
* ``jit-python-branch`` — ``if``/``while`` on a traced value raises
  ``TracerBoolConversionError`` at trace time — but only sometimes (dead
  branches under concrete shapes hide it). Branching on *static metadata*
  is fine and idiomatic: attributes named in :data:`STATIC_ATTRS`
  (``DeviceIndex.num_nodes`` and friends are aux_data of a registered
  pytree, Python ints at trace time) are allowed; direct branches on array
  parameters are flagged.
* ``jit-host-sync`` — ``.item()`` / ``np.asarray`` / ``jax.device_get`` /
  ``block_until_ready`` inside a traced function forces a trace-time
  round-trip (or fails outright); host materialization belongs in the
  host wrapper.
* ``jit-unhashable-static`` — at a call site of a jitted function with
  ``static_argnums``, passing a list/dict/set/``np.array(...)`` in a
  static position recompiles per call (or raises on unhashable); static
  args must be hashable scalars/tuples.
* ``jit-mutable-closure`` — a traced function reading a module-level
  mutable (list/dict/set) global: the value is baked in at trace time,
  later mutation silently diverges from the compiled program.
* ``hot-path-transfer`` — host<->device transfer calls
  (``jax.device_get`` / ``jax.device_put`` / ``.item()`` /
  ``block_until_ready``) in modules on the configured hot-path list
  (executor/planner/batch_query): every transfer there is either a
  deliberate, measured sync point (suppress it inline with a reason) or a
  latency bug.
"""

from __future__ import annotations

import ast
from typing import Iterable

from .core import (AnalysisConfig, Finding, Module, iter_symbols,
                   make_finding)

#: Attribute names that are static (Python-int) metadata at trace time —
#: aux_data of registered pytrees (DeviceIndex & co), safe to branch on.
STATIC_ATTRS = frozenset({
    "num_nodes", "n", "t_max", "max_node_entries", "max_vert_entries",
    "num_versions", "ndim", "dtype", "shape",
})

_HOST_SYNC_DOTTED = {
    "jax.device_get": "jax.device_get",
    "jax.device_put": "jax.device_put",
    "np.asarray": "np.asarray",
    "np.array": "np.array",
    "numpy.asarray": "numpy.asarray",
    "numpy.array": "numpy.array",
}

_TRANSFER_DOTTED = {"jax.device_get", "jax.device_put"}
_TRANSFER_ATTRS = {"item", "block_until_ready"}


def _dotted(node: ast.AST) -> str | None:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jit_expr(node: ast.AST) -> bool:
    """``jax.jit`` / ``jit`` / ``partial(jax.jit, ...)`` /
    ``functools.partial(jax.jit, ...)``."""
    d = _dotted(node)
    if d in ("jax.jit", "jit"):
        return True
    if isinstance(node, ast.Call):
        f = _dotted(node.func)
        if f in ("partial", "functools.partial") and node.args:
            return _is_jit_expr(node.args[0])
        # jax.jit(g, ...) used as a decorator factory result
        if f in ("jax.jit", "jit"):
            return True
    return False


def _jit_static_argnums(call: ast.Call) -> tuple[int, ...] | None:
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)):
                out = []
                for el in v.elts:
                    if (isinstance(el, ast.Constant)
                            and isinstance(el.value, int)):
                        out.append(el.value)
                return tuple(out)
    return None


def collect_traced(module: Module) -> dict[str, ast.FunctionDef]:
    """Functions that run under jit in this module: decorated defs, plus
    defs wrapped by a module-level ``name = jax.jit(def_name, ...)``."""
    by_name: dict[str, ast.FunctionDef] = {}
    traced: dict[str, ast.FunctionDef] = {}
    for symbol, node in iter_symbols(module.tree):
        if isinstance(node, ast.FunctionDef):
            by_name[node.name] = node
            if any(_is_jit_expr(d) for d in node.decorator_list):
                traced[symbol] = node
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Call):
            continue
        if _dotted(node.func) in ("jax.jit", "jit") and node.args:
            arg = node.args[0]
            if isinstance(arg, ast.Name) and arg.id in by_name:
                traced.setdefault(arg.id, by_name[arg.id])
    return traced


def _param_names(fn: ast.FunctionDef) -> set[str]:
    a = fn.args
    names = [p.arg for p in
             (a.posonlyargs + a.args + a.kwonlyargs)]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return set(names)


def pass_jax_hygiene(module: Module,
                     config: AnalysisConfig) -> Iterable[Finding]:
    findings: list[Finding] = []
    traced = collect_traced(module)
    hot = any(module.dotted == m or module.dotted.startswith(m + ".")
              for m in config.hot_path_modules)

    # -- per traced function ---------------------------------------------
    for symbol, fn in traced.items():
        params = _param_names(fn)
        for node in ast.walk(fn):
            if isinstance(node, ast.Assert):
                findings.append(make_finding(
                    module, "jit-assert", node,
                    f"bare assert inside traced function {fn.name!r}: "
                    "on tracers it raises at trace time (or vanishes "
                    "under -O); validate in the host wrapper or use "
                    "checkify", symbol=symbol))
            elif isinstance(node, (ast.If, ast.While)):
                off = _offending_branch_expr(node.test, params)
                if off is not None:
                    findings.append(make_finding(
                        module, "jit-python-branch", node,
                        f"Python branch on {off!r} inside traced function "
                        f"{fn.name!r}: traced values need lax.cond/"
                        "lax.select; branching is only safe on static "
                        f"metadata attrs {sorted(STATIC_ATTRS)[:4]}...",
                        symbol=symbol))
            elif isinstance(node, ast.Call):
                d = _dotted(node.func)
                if d in _HOST_SYNC_DOTTED:
                    findings.append(make_finding(
                        module, "jit-host-sync", node,
                        f"{_HOST_SYNC_DOTTED[d]} inside traced function "
                        f"{fn.name!r} forces host materialization at "
                        "trace time; hoist it into the host wrapper",
                        symbol=symbol))
                elif (isinstance(node.func, ast.Attribute)
                      and node.func.attr in _TRANSFER_ATTRS):
                    findings.append(make_finding(
                        module, "jit-host-sync", node,
                        f".{node.func.attr}() inside traced function "
                        f"{fn.name!r} is a device sync; traced code "
                        "must stay on device", symbol=symbol))

        # mutable-closure: reads of module-level mutable globals
        mutable_globals = _module_mutable_globals(module)
        local_names = params | _assigned_names(fn)
        for node in ast.walk(fn):
            if (isinstance(node, ast.Name)
                    and isinstance(node.ctx, ast.Load)
                    and node.id in mutable_globals
                    and node.id not in local_names):
                findings.append(make_finding(
                    module, "jit-mutable-closure", node,
                    f"traced function {fn.name!r} reads module-level "
                    f"mutable {node.id!r}; its value is baked in at "
                    "trace time — later mutation silently diverges "
                    "from the compiled program", symbol=symbol))

    # -- unhashable static args at call sites ----------------------------
    jitted_with_static = _jitted_bindings_with_static(module)
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Call):
            continue
        fname = _dotted(node.func)
        if fname not in jitted_with_static:
            continue
        for idx in jitted_with_static[fname]:
            if idx < len(node.args):
                arg = node.args[idx]
                if _is_unhashable_expr(arg):
                    findings.append(make_finding(
                        module, "jit-unhashable-static", arg,
                        f"static arg {idx} of {fname!r} is a mutable/"
                        "array-valued expression; static args must be "
                        "hashable (ints, strings, tuples) or every call "
                        "recompiles"))

    # -- hot-path transfers ----------------------------------------------
    if hot:
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            d = _dotted(node.func)
            label = None
            if d in _TRANSFER_DOTTED:
                label = d
            elif (isinstance(node.func, ast.Attribute)
                    and node.func.attr in _TRANSFER_ATTRS):
                label = f".{node.func.attr}()"
            if label is not None:
                findings.append(make_finding(
                    module, "hot-path-transfer", node,
                    f"{label} in hot-path module {module.dotted}: every "
                    "host<->device transfer here is either a deliberate "
                    "measured sync point (suppress inline with a reason) "
                    "or a latency bug"))
    return findings


def _offending_branch_expr(test: ast.AST, params: set[str]) -> str | None:
    """A parameter read in ``test`` that is not a static-attr access."""
    attr_bases = {id(n.value) for n in ast.walk(test)
                  if isinstance(n, ast.Attribute)}
    for node in ast.walk(test):
        if isinstance(node, ast.Attribute):
            base = node.value
            if (isinstance(base, ast.Name) and base.id in params
                    and node.attr not in STATIC_ATTRS):
                return f"{base.id}.{node.attr}"
        elif (isinstance(node, ast.Name) and node.id in params
              and id(node) not in attr_bases):
            return node.id
    return None


def _module_mutable_globals(module: Module) -> set[str]:
    out: set[str] = set()
    for stmt in module.tree.body:
        targets: list[ast.AST] = []
        value: ast.AST | None = None
        if isinstance(stmt, ast.Assign):
            targets, value = stmt.targets, stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets, value = [stmt.target], stmt.value
        if value is None:
            continue
        if isinstance(value, (ast.List, ast.Dict, ast.Set)) or (
                isinstance(value, ast.Call)
                and _dotted(value.func) in ("list", "dict", "set",
                                            "collections.defaultdict",
                                            "defaultdict")):
            for t in targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
    return out


def _assigned_names(fn: ast.FunctionDef) -> set[str]:
    out: set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            out.add(node.id)
    return out


def _jitted_bindings_with_static(module: Module) -> dict[str, tuple[int, ...]]:
    """``fn = jax.jit(g, static_argnums=(3,))`` -> {"fn": (3,)}; also
    decorated defs with partial(jax.jit, static_argnums=...)."""
    out: dict[str, tuple[int, ...]] = {}
    for node in ast.walk(module.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            call = node.value
            if _dotted(call.func) in ("jax.jit", "jit"):
                nums = _jit_static_argnums(call)
                if nums:
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            out[t.id] = nums
        elif isinstance(node, ast.FunctionDef):
            for dec in node.decorator_list:
                if isinstance(dec, ast.Call) and _is_jit_expr(dec):
                    nums = _jit_static_argnums(dec)
                    if nums:
                        out[node.name] = nums
    return out


def _is_unhashable_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        return _dotted(node.func) in (
            "list", "dict", "set", "np.array", "np.asarray",
            "numpy.array", "numpy.asarray", "jnp.array", "jnp.asarray")
    return False
