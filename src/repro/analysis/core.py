"""Framework core for the repo's static-analysis suite (DESIGN.md §12).

The moving parts:

* :class:`Finding` — one rule violation at one source location, carrying a
  stable :attr:`~Finding.fingerprint` so a baseline survives unrelated
  edits (the fingerprint hashes the rule, the file, the enclosing symbol
  and the *text* of the offending line — not its line number).
* :class:`Module` — a parsed source file: AST, raw lines, and the per-line
  suppression table built from ``# repro: ignore[rule]`` comments (same
  line or the line directly above both suppress).
* :class:`Baseline` — the committed ledger of accepted findings. ``--strict``
  fails on any finding whose fingerprint is not in it; re-generating with
  ``--write-baseline`` is an explicit, reviewed act.
* :class:`AnalysisConfig` — one source of truth shared by the CLI, the
  pytest fixtures and CI, loaded from ``[tool.repro-analysis]`` in
  ``pyproject.toml`` (pass selection, include roots, hot-path module list,
  baseline path).
* :func:`run_analysis` — parse every included file once, hand each
  :class:`Module` to every registered pass, drop suppressed findings,
  sort what remains.

A *pass* is any callable ``(module, config) -> Iterable[Finding]``
registered in ``repro.analysis.PASSES``; §12.4 documents how to add one.
"""

from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import os
import re
from typing import Callable, Iterable, Iterator

try:  # Python 3.11+
    import tomllib
except ModuleNotFoundError:  # pragma: no cover - 3.10 fallback
    import tomli as tomllib  # type: ignore[no-redef]

#: ``# repro: ignore`` (all rules) or ``# repro: ignore[rule-a, rule-b]``.
SUPPRESS_RE = re.compile(
    r"#\s*repro:\s*ignore(?:\[([A-Za-z0-9_,\- ]+)\])?")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str          # e.g. "lock-order"
    path: str          # repo-relative, forward slashes
    line: int          # 1-based
    col: int           # 0-based (ast convention)
    symbol: str        # enclosing "Class.method" / "function" / "<module>"
    message: str
    fingerprint: str = ""

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"[{self.symbol}] {self.message}")


def _fingerprint(rule: str, path: str, symbol: str, line_text: str,
                 occurrence: int) -> str:
    """Stable identity for baselining: independent of line *numbers* so a
    baseline survives edits elsewhere in the file; ``occurrence``
    disambiguates textually identical violations of one rule in one
    symbol."""
    key = "|".join((rule, path, symbol, line_text.strip(), str(occurrence)))
    return hashlib.sha1(key.encode()).hexdigest()[:16]


class Module:
    """A parsed source file plus its suppression table."""

    def __init__(self, path: str, rel: str, source: str):
        self.path = path
        self.rel = rel.replace(os.sep, "/")
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        # line (1-based) -> None (suppress all) | frozenset of rule names
        self.suppressions: dict[int, frozenset[str] | None] = {}
        for i, text in enumerate(self.lines, start=1):
            m = SUPPRESS_RE.search(text)
            if m is None:
                continue
            rules = m.group(1)
            if rules is None:
                self.suppressions[i] = None          # suppress every rule
            else:
                self.suppressions[i] = frozenset(
                    r.strip() for r in rules.split(",") if r.strip())

    @property
    def dotted(self) -> str:
        """``src/repro/serving/engine.py`` -> ``repro.serving.engine``."""
        rel = self.rel
        if rel.startswith("src/"):
            rel = rel[4:]
        return rel[:-3].replace("/", ".") if rel.endswith(".py") else rel

    def suppressed(self, line: int, rule: str) -> bool:
        """True if ``rule`` is suppressed at ``line`` — by a marker on the
        same line or on the line directly above."""
        for at in (line, line - 1):
            if at in self.suppressions:
                rules = self.suppressions[at]
                if rules is None or rule in rules:
                    return True
        return False

    def line_text(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1]
        return ""


def iter_symbols(tree: ast.Module) -> Iterator[tuple[str, ast.AST]]:
    """Yield ``(qualified_name, node)`` for every function/method, plus
    ``("<module>", tree)`` first. Nested defs get ``outer.inner`` names."""
    yield "<module>", tree

    def walk(node: ast.AST, prefix: str) -> Iterator[tuple[str, ast.AST]]:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                name = f"{prefix}{child.name}"
                yield name, child
                yield from walk(child, f"{name}.")
            elif isinstance(child, ast.ClassDef):
                yield from walk(child, f"{prefix}{child.name}.")
            else:
                yield from walk(child, prefix)

    yield from walk(tree, "")


def enclosing_symbol(module: Module, target: ast.AST) -> str:
    """Qualified name of the innermost function/class containing ``target``
    (by position), or ``<module>``."""
    best = "<module>"
    best_span = None
    t_line = getattr(target, "lineno", 0)
    for name, node in iter_symbols(module.tree):
        if node is module.tree:
            continue
        lo = node.lineno
        hi = getattr(node, "end_lineno", lo)
        if lo <= t_line <= hi:
            span = hi - lo
            if best_span is None or span <= best_span:
                best, best_span = name, span
    return best


def make_finding(module: Module, rule: str, node: ast.AST, message: str,
                 symbol: str | None = None) -> Finding:
    line = getattr(node, "lineno", 1)
    col = getattr(node, "col_offset", 0)
    sym = symbol if symbol is not None else enclosing_symbol(module, node)
    return Finding(rule=rule, path=module.rel, line=line, col=col,
                   symbol=sym, message=message)


class Baseline:
    """Committed ledger of accepted findings (JSON).

    Schema: ``{"findings": [{"fingerprint", "rule", "path", "symbol",
    "comment"}]}`` — ``comment`` is the human justification; the CLI
    refuses to write an entry without one unless ``--no-comment`` style
    justification is the empty default (review catches it)."""

    def __init__(self, entries: list[dict] | None = None):
        self.entries = entries or []
        self._by_fp = {e["fingerprint"]: e for e in self.entries}

    @classmethod
    def load(cls, path: str) -> "Baseline":
        if not os.path.exists(path):
            return cls([])
        with open(path) as f:
            data = json.load(f)
        return cls(data.get("findings", []))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"findings": self.entries}, f, indent=2,
                      sort_keys=True)
            f.write("\n")

    def __contains__(self, fingerprint: str) -> bool:
        return fingerprint in self._by_fp

    @classmethod
    def from_findings(cls, findings: Iterable[Finding],
                      comment: str = "") -> "Baseline":
        entries = [{"fingerprint": f.fingerprint, "rule": f.rule,
                    "path": f.path, "symbol": f.symbol,
                    "comment": comment} for f in findings]
        return cls(entries)


@dataclasses.dataclass
class AnalysisConfig:
    """The ``[tool.repro-analysis]`` block — one source of truth for the
    CLI, pytest fixtures and CI."""

    include: tuple[str, ...] = ("src/repro",)
    exclude: tuple[str, ...] = ()
    passes: tuple[str, ...] = ()           # empty = all registered
    baseline: str = "analysis_baseline.json"
    #: dotted module prefixes where host<->device transfers are findings
    hot_path_modules: tuple[str, ...] = ()
    #: dotted module prefixes where ``time.time()`` is a finding (the
    #: tracer's perf_counter clock is the law there)
    wallclock_modules: tuple[str, ...] = ()
    #: receiver attribute name -> lock level it acquires when its locking
    #: methods are called (cross-object nesting the AST cannot infer)
    lock_receivers: dict = dataclasses.field(default_factory=dict)
    #: deprecated shim methods: name -> minimum positional-arg count that
    #: identifies the legacy signature at a call site
    deprecated_calls: dict = dataclasses.field(default_factory=dict)
    #: per-platform VMEM budgets (bytes) for the kernels pass's static
    #: estimator; ``vmem_platform`` selects the active one
    vmem_budgets: dict = dataclasses.field(default_factory=dict)
    vmem_platform: str = "tpu"
    #: extent assumed for block dims the shape-flow interpreter cannot
    #: resolve to a constant (data-dependent dims like a feature width)
    vmem_assumed_extent: int = 2048
    #: path prefixes where ``bare-assert`` does not fire (benchmark floor
    #: asserts, test fixture helpers — not shipped library code)
    assert_exempt: tuple[str, ...] = ()
    #: CLI ``--changed-only``: restrict analysis to these repo-relative
    #: paths. Never read from pyproject — strict CI always runs the tree.
    only_files: frozenset | None = None

    def vmem_budget(self) -> int:
        """Active static-estimator budget (bytes); defaults to the
        runtime witness's 16 MiB when the platform is unconfigured."""
        return int(self.vmem_budgets.get(self.vmem_platform,
                                         16 * 1024 * 1024))

    @classmethod
    def from_pyproject(cls, root: str) -> "AnalysisConfig":
        path = os.path.join(root, "pyproject.toml")
        raw: dict = {}
        if os.path.exists(path):
            with open(path, "rb") as f:
                raw = tomllib.load(f)
        tbl = raw.get("tool", {}).get("repro-analysis", {})
        kw: dict = {}
        if "include" in tbl:
            kw["include"] = tuple(tbl["include"])
        if "exclude" in tbl:
            kw["exclude"] = tuple(tbl["exclude"])
        if "passes" in tbl:
            kw["passes"] = tuple(tbl["passes"])
        if "baseline" in tbl:
            kw["baseline"] = tbl["baseline"]
        if "hot-path-modules" in tbl:
            kw["hot_path_modules"] = tuple(tbl["hot-path-modules"])
        if "wallclock-modules" in tbl:
            kw["wallclock_modules"] = tuple(tbl["wallclock-modules"])
        if "lock-receivers" in tbl:
            kw["lock_receivers"] = dict(tbl["lock-receivers"])
        if "deprecated-calls" in tbl:
            kw["deprecated_calls"] = {k: int(v) for k, v in
                                      tbl["deprecated-calls"].items()}
        if "vmem-budgets" in tbl:
            kw["vmem_budgets"] = {k: int(v) for k, v in
                                  tbl["vmem-budgets"].items()}
        if "vmem-platform" in tbl:
            kw["vmem_platform"] = tbl["vmem-platform"]
        if "vmem-assumed-extent" in tbl:
            kw["vmem_assumed_extent"] = int(tbl["vmem-assumed-extent"])
        if "assert-exempt" in tbl:
            kw["assert_exempt"] = tuple(tbl["assert-exempt"])
        return cls(**kw)


Pass = Callable[[Module, AnalysisConfig], Iterable[Finding]]


def collect_files(root: str, config: AnalysisConfig) -> list[str]:
    out: list[str] = []
    for inc in config.include:
        base = os.path.join(root, inc)
        if os.path.isfile(base):
            out.append(base)
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__",)]
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                full = os.path.join(dirpath, fn)
                rel = os.path.relpath(full, root).replace(os.sep, "/")
                if any(rel.startswith(ex) for ex in config.exclude):
                    continue
                if (config.only_files is not None
                        and rel not in config.only_files):
                    continue
                out.append(full)
    return sorted(set(out))


def run_analysis(root: str, config: AnalysisConfig,
                 passes: dict[str, Pass]) -> list[Finding]:
    """Parse every included file once, run every selected pass, drop
    suppressed findings, fingerprint and sort the survivors."""
    selected = {name: fn for name, fn in passes.items()
                if not config.passes or name in config.passes}
    findings: list[Finding] = []
    for path in collect_files(root, config):
        rel = os.path.relpath(path, root)
        with open(path, encoding="utf-8") as f:
            source = f.read()
        try:
            module = Module(path, rel, source)
        except SyntaxError as e:
            findings.append(Finding(
                rule="syntax-error", path=rel.replace(os.sep, "/"),
                line=e.lineno or 1, col=e.offset or 0,
                symbol="<module>", message=str(e.msg)))
            continue
        for fn in selected.values():
            for f in fn(module, config):
                if not module.suppressed(f.line, f.rule):
                    findings.append(f)
    # fingerprints: occurrence counter over (rule, path, symbol, stripped
    # line text) so identical violations stay distinct but stable

    by_file: dict[str, list[str]] = {}
    counts: dict[tuple, int] = {}
    out: list[Finding] = []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule)):
        if f.path not in by_file:
            full = os.path.join(root, f.path)
            try:
                with open(full, encoding="utf-8") as fh:
                    by_file[f.path] = fh.read().splitlines()
            except OSError:
                by_file[f.path] = []
        lines = by_file[f.path]
        text = lines[f.line - 1] if 1 <= f.line <= len(lines) else ""
        key = (f.rule, f.path, f.symbol, text.strip())
        n = counts.get(key, 0)
        counts[key] = n + 1
        out.append(dataclasses.replace(
            f, fingerprint=_fingerprint(f.rule, f.path, f.symbol, text, n)))
    return out
