"""CLI for the static-analysis suite: ``python -m repro.analysis``.

Exit codes: 0 = clean (or every finding baselined), 1 = non-baselined
findings in ``--strict`` mode, 2 = usage error. Default (non-strict) runs
always exit 0 — they are for humans iterating; CI runs ``--strict``.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from . import PASSES
from .core import AnalysisConfig, Baseline, run_analysis


def changed_files(root: str, base_ref: str) -> frozenset[str]:
    """Repo-relative paths changed vs ``base_ref`` (committed, staged and
    worktree changes alike). Raises ``CalledProcessError`` outside a git
    checkout or on an unknown ref — the caller maps that to exit 2."""
    out = subprocess.run(
        ["git", "diff", "--name-only", base_ref],
        cwd=root, capture_output=True, text=True, check=True).stdout
    return frozenset(line.strip() for line in out.splitlines()
                     if line.strip())


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific concurrency/JAX/API static analysis")
    p.add_argument("--root", default=".",
                   help="repo root holding pyproject.toml (default: cwd)")
    p.add_argument("--strict", action="store_true",
                   help="exit 1 on any finding not in the baseline")
    p.add_argument("--json", dest="json_out", metavar="PATH",
                   help="write findings JSON (CI artifact); '-' = stdout")
    p.add_argument("--baseline", metavar="PATH",
                   help="override the baseline path from pyproject")
    p.add_argument("--write-baseline", action="store_true",
                   help="accept current findings into the baseline file")
    p.add_argument("--passes", metavar="NAMES",
                   help="comma-separated pass subset "
                        f"(available: {', '.join(sorted(PASSES))})")
    p.add_argument("--changed-only", action="store_true",
                   help="analyze only files changed vs --base-ref "
                        "(fast pre-push loop; CI strict runs stay "
                        "full-tree)")
    p.add_argument("--base-ref", default="HEAD", metavar="REF",
                   help="git ref --changed-only diffs against "
                        "(default: HEAD)")
    return p


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    root = os.path.abspath(args.root)
    config = AnalysisConfig.from_pyproject(root)
    if args.passes:
        names = tuple(n.strip() for n in args.passes.split(",") if n.strip())
        unknown = [n for n in names if n not in PASSES]
        if unknown:
            print(f"unknown passes: {', '.join(unknown)} "
                  f"(available: {', '.join(sorted(PASSES))})",
                  file=sys.stderr)
            return 2
        config.passes = names
    if args.changed_only:
        try:
            config.only_files = changed_files(root, args.base_ref)
        except (subprocess.CalledProcessError, OSError) as e:
            detail = getattr(e, "stderr", "") or str(e)
            print(f"--changed-only: git diff vs {args.base_ref!r} failed: "
                  f"{detail.strip()}", file=sys.stderr)
            return 2

    findings = run_analysis(root, config, PASSES)

    baseline_path = os.path.join(
        root, args.baseline if args.baseline else config.baseline)
    baseline = Baseline.load(baseline_path)
    fresh = [f for f in findings if f.fingerprint not in baseline]

    if args.write_baseline:
        Baseline.from_findings(
            findings,
            comment="accepted at baseline write; justify or fix").save(
                baseline_path)
        print(f"baseline: wrote {len(findings)} finding(s) to "
              f"{baseline_path}")

    payload = {
        "findings": [f.to_dict() for f in findings],
        "baselined": sum(1 for f in findings
                         if f.fingerprint in baseline),
        "fresh": len(fresh),
        "passes": sorted(config.passes or PASSES),
    }
    if args.json_out == "-":
        json.dump(payload, sys.stdout, indent=2)
        print()
    elif args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")

    for f in findings:
        marker = "" if f.fingerprint not in baseline else " (baselined)"
        print(f.format() + marker)
    print(f"{len(findings)} finding(s), {len(fresh)} not baselined")

    if args.strict and fresh:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
