"""Shape-flow abstract interpretation for the kernel-contract passes
(DESIGN.md §15.2).

The device plane indexes packed int32 arrays whose correctness XLA cannot
see: a grid of ``ep // slot_block`` silently drops the tail unless ``ep``
was padded to a block multiple, and an ``i32(K * n + 1)`` row pointer
silently wraps past 2**31. Both bugs are *arithmetic* facts about host
Python code, so this module evaluates that arithmetic abstractly:

* :class:`Lin` — an integer expression as a **linear combination of
  monomials** over opaque atoms. ``int(np.ceil(max(e, 1) / b)) * b``
  becomes ``{(ceil((max(e,1))/(b)), b): 1}`` — a monomial that contains
  the factor ``b``, hence provably divisible by ``b``. Crucially the
  representation survives the repo's padding idioms by cancellation:
  ``npad = ceil(N/bn)*bn - N; Np = N + npad`` normalizes to
  ``{(ceil..., bn): 1}`` because the ``N`` terms cancel.
* :class:`Env` — per-function bindings built by walking assignments in
  source order: symbolic integer values (:class:`Lin`), inferred array
  dtypes (``np.pad(x.astype(np.int32), ...)`` -> int32), and the raw
  value AST per name (so a pass can chase ``grid=(B, Np // bn)`` through
  ``Np``'s definition). Reassigned names get fresh atoms keyed by line —
  two reads after the same binding stay equal, reads across a rebinding
  do not.

The interpreter is deliberately *sound for proving, unsound for
refuting*: :func:`divides` answers True only when divisibility is
guaranteed for every concrete valuation; anything it cannot prove is
reported as unproven and the pass decides whether that is a finding.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Iterable

#: dtype-name -> itemsize used by the VMEM estimator
DTYPE_BYTES = {
    "int8": 1, "uint8": 1, "bool": 1, "bool_": 1,
    "int16": 2, "uint16": 2, "float16": 2, "bfloat16": 2,
    "int32": 4, "uint32": 4, "float32": 4,
    "int64": 8, "uint64": 8, "float64": 8,
}

_INT_DTYPES = frozenset({"int8", "int16", "int32", "int64",
                         "uint8", "uint16", "uint32", "uint64"})


def dtype_name(node: ast.AST) -> str | None:
    """``jnp.int32`` / ``np.float32`` / ``"int32"`` -> canonical name."""
    if isinstance(node, ast.Attribute):
        if node.attr in DTYPE_BYTES:
            return node.attr
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value if node.value in DTYPE_BYTES else None
    if isinstance(node, ast.Name) and node.id in DTYPE_BYTES:
        return node.id
    return None


# ---------------------------------------------------------------------------
# symbolic integers
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Lin:
    """Linear combination of monomials: ``terms`` maps a sorted tuple of
    atom strings (the monomial; ``()`` is the constant term) to an int
    coefficient. Atoms are canonical source strings of opaque
    subexpressions (``ceil((N)/(bn))``, ``labels.shape[1]``, ...)."""

    terms: tuple[tuple[tuple[str, ...], int], ...]

    @classmethod
    def of(cls, mapping: dict[tuple[str, ...], int]) -> "Lin":
        items = tuple(sorted((m, c) for m, c in mapping.items() if c != 0))
        return cls(items)

    @classmethod
    def const(cls, c: int) -> "Lin":
        return cls.of({(): c})

    @classmethod
    def atom(cls, key: str) -> "Lin":
        return cls.of({(key,): 1})

    def mapping(self) -> dict[tuple[str, ...], int]:
        return dict(self.terms)

    def __add__(self, other: "Lin") -> "Lin":
        out = self.mapping()
        for m, c in other.terms:
            out[m] = out.get(m, 0) + c
        return Lin.of(out)

    def __sub__(self, other: "Lin") -> "Lin":
        out = self.mapping()
        for m, c in other.terms:
            out[m] = out.get(m, 0) - c
        return Lin.of(out)

    def __mul__(self, other: "Lin") -> "Lin":
        out: dict[tuple[str, ...], int] = {}
        for m1, c1 in self.terms:
            for m2, c2 in other.terms:
                m = tuple(sorted(m1 + m2))
                out[m] = out.get(m, 0) + c1 * c2
        return Lin.of(out)

    def as_const(self) -> int | None:
        if not self.terms:
            return 0
        if len(self.terms) == 1 and self.terms[0][0] == ():
            return self.terms[0][1]
        return None

    def key(self) -> str:
        """Canonical string (used when this value becomes an atom inside a
        bigger opaque expression, e.g. the body of a ceil)."""
        c = self.as_const()
        if c is not None:
            return str(c)
        parts = []
        for m, coef in self.terms:
            mono = "*".join(m) if m else "1"
            parts.append(f"{coef}*{mono}" if coef != 1 or not m else mono)
        return "+".join(parts)


def divides(num: Lin | None, den: Lin | None) -> bool:
    """True iff ``num`` is provably an integer multiple of ``den`` for
    every valuation of the atoms. ``den`` must be a single monomial (a
    positive constant, one atom, or a product); unknown values never
    divide."""
    if num is None or den is None:
        return False
    dc = den.as_const()
    if dc is not None:
        if dc == 0:
            return False
        return all(c % dc == 0 for _, c in num.terms)
    if len(den.terms) != 1:
        return False
    dmono, dcoef = den.terms[0]
    for mono, coef in num.terms:
        remaining = list(mono)
        ok = True
        for a in dmono:
            if a in remaining:
                remaining.remove(a)
            else:
                ok = False
                break
        if not (ok and coef % dcoef == 0):
            return False
    return True


# ---------------------------------------------------------------------------
# the per-function environment
# ---------------------------------------------------------------------------

_NP_CTORS = ("np.zeros", "np.ones", "np.empty", "np.full", "np.asarray",
             "np.array", "numpy.zeros", "numpy.ones", "numpy.empty",
             "numpy.full", "numpy.asarray", "numpy.array",
             "jnp.zeros", "jnp.ones", "jnp.empty", "jnp.full",
             "jnp.asarray", "jnp.array")
_DTYPE_PRESERVING = ("np.pad", "jnp.pad", "np.ascontiguousarray",
                     "np.concatenate", "jnp.concatenate", "np.repeat",
                     "jnp.repeat", "np.where", "jnp.where", "np.diff",
                     "np.cumsum", "jnp.cumsum")


def _dotted(node: ast.AST) -> str | None:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class Env:
    """Bindings built from one function's body (plus module constants):
    ``ints`` (name -> :class:`Lin`), ``dtypes`` (name -> dtype name for
    arrays) and ``value_ast`` (name -> last assigned value node)."""

    def __init__(self, module_consts: dict[str, int] | None = None):
        self.ints: dict[str, Lin] = {}
        self.dtypes: dict[str, str] = {}
        self.value_ast: dict[str, ast.AST] = {}
        if module_consts:
            for name, val in module_consts.items():
                self.ints[name] = Lin.const(val)

    # -- symbolic integer evaluation -------------------------------------
    def lin(self, node: ast.AST) -> Lin | None:
        """Abstract-evaluate an int expression; None for non-int shapes
        (tuples, arrays used as values, ...). Unknown subexpressions
        become atoms, so the result is always usable for divisibility."""
        if isinstance(node, ast.Constant):
            if isinstance(node.value, bool) or not isinstance(node.value,
                                                              int):
                return None
            return Lin.const(node.value)
        if isinstance(node, ast.Name):
            if node.id in self.ints:
                return self.ints[node.id]
            return Lin.atom(node.id)
        if isinstance(node, ast.BinOp):
            left, right = self.lin(node.left), self.lin(node.right)
            if left is None or right is None:
                return Lin.atom(self._key(node))
            if isinstance(node.op, ast.Add):
                return left + right
            if isinstance(node.op, ast.Sub):
                return left - right
            if isinstance(node.op, ast.Mult):
                return left * right
            if isinstance(node.op, ast.FloorDiv):
                if divides(left, right):
                    return self._exact_quotient(left, right)
                return Lin.atom(f"({left.key()})//({right.key()})")
            return Lin.atom(self._key(node))
        if isinstance(node, ast.Call):
            d = _dotted(node.func)
            if d == "int" and len(node.args) == 1:
                return self.lin(node.args[0])
            if d in ("np.ceil", "numpy.ceil", "math.ceil") and node.args:
                arg = node.args[0]
                if (isinstance(arg, ast.BinOp)
                        and isinstance(arg.op, ast.Div)):
                    lk = self._key(arg.left)
                    rk = self._key(arg.right)
                    return Lin.atom(f"ceil(({lk})/({rk}))")
                return Lin.atom(f"ceil({self._key(arg)})")
            return Lin.atom(self._key(node))
        return Lin.atom(self._key(node))

    def _exact_quotient(self, num: Lin, den: Lin) -> Lin:
        dc = den.as_const()
        if dc is not None:
            return Lin.of({m: c // dc for m, c in num.terms})
        dmono, dcoef = den.terms[0]
        out: dict[tuple[str, ...], int] = {}
        for mono, coef in num.terms:
            remaining = list(mono)
            for a in dmono:
                remaining.remove(a)
            m = tuple(sorted(remaining))
            out[m] = out.get(m, 0) + coef // dcoef
        return Lin.of(out)

    def _key(self, node: ast.AST) -> str:
        """Canonical atom key: resolve names through current bindings so
        two reads of the same binding agree, then unparse."""
        if isinstance(node, ast.Name) and node.id in self.ints:
            return self.ints[node.id].key()
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.Add, ast.Sub, ast.Mult, ast.Div)):
            op = {ast.Add: "+", ast.Sub: "-", ast.Mult: "*",
                  ast.Div: "/"}[type(node.op)]
            return f"({self._key(node.left)}){op}({self._key(node.right)})"
        try:
            return ast.unparse(node)
        except Exception:  # pragma: no cover - malformed nodes
            return f"<expr@{getattr(node, 'lineno', 0)}>"

    # -- dtype inference --------------------------------------------------
    def dtype_of(self, node: ast.AST) -> str | None:
        """Best-effort dtype of an array expression; None when unknown."""
        if isinstance(node, ast.Name):
            return self.dtypes.get(node.id)
        if isinstance(node, ast.IfExp):
            a = self.dtype_of(node.body)
            b = self.dtype_of(node.orelse)
            return a if a == b else None
        if isinstance(node, ast.Call):
            # x.astype(np.int32)
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "astype" and node.args):
                return dtype_name(node.args[0])
            d = _dotted(node.func)
            if d in _NP_CTORS:
                for kw in node.keywords:
                    if kw.arg == "dtype":
                        return dtype_name(kw.value)
                # positional dtype: last arg of zeros/full/asarray forms
                for arg in node.args[1:]:
                    dn = dtype_name(arg)
                    if dn is not None:
                        return dn
                return None
            if d in _DTYPE_PRESERVING and node.args:
                return self.dtype_of(node.args[0])
        return None

    # -- construction -----------------------------------------------------
    def bind_assign(self, stmt: ast.AST) -> None:
        targets: list[ast.AST] = []
        value: ast.AST | None = None
        if isinstance(stmt, ast.Assign):
            targets, value = stmt.targets, stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets, value = [stmt.target], stmt.value
        if value is None:
            return
        for tgt in targets:
            if isinstance(tgt, ast.Name):
                self.value_ast[tgt.id] = value
                lin = self.lin(value)
                if lin is not None:
                    self.ints[tgt.id] = lin
                else:
                    self.ints[tgt.id] = Lin.atom(
                        f"{tgt.id}@{getattr(stmt, 'lineno', 0)}")
                dt = self.dtype_of(value)
                if dt is not None:
                    self.dtypes[tgt.id] = dt
                elif tgt.id in self.dtypes:
                    del self.dtypes[tgt.id]
            elif isinstance(tgt, (ast.Tuple, ast.List)):
                if (isinstance(value, (ast.Tuple, ast.List))
                        and len(value.elts) == len(tgt.elts)):
                    # Mp, Kp, Np = (ceil(M/bm)*bm, ...) — element-wise,
                    # each side keeps its arithmetic meaning
                    for el, val in zip(tgt.elts, value.elts):
                        if isinstance(el, ast.Name):
                            self.value_ast[el.id] = val
                            lin = self.lin(val)
                            self.ints[el.id] = lin if lin is not None \
                                else Lin.atom(
                                    f"{el.id}@{getattr(stmt, 'lineno', 0)}")
                            dt = self.dtype_of(val)
                            if dt is not None:
                                self.dtypes[el.id] = dt
                            else:
                                self.dtypes.pop(el.id, None)
                    continue
                # B, N = labels.shape — each name gets a fresh atom
                for i, el in enumerate(tgt.elts):
                    if isinstance(el, ast.Name):
                        self.ints[el.id] = Lin.atom(
                            f"{el.id}@{getattr(stmt, 'lineno', 0)}.{i}")
                        self.value_ast.pop(el.id, None)
                        self.dtypes.pop(el.id, None)


def module_int_consts(tree: ast.Module) -> dict[str, int]:
    """Module-level ``NAME = <int literal>`` constants (block-size
    defaults like ``DEFAULT_SLOT_BLOCK``)."""
    out: dict[str, int] = {}
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            tgt = stmt.targets[0]
            if (isinstance(tgt, ast.Name)
                    and isinstance(stmt.value, ast.Constant)
                    and isinstance(stmt.value.value, int)
                    and not isinstance(stmt.value.value, bool)):
                out[tgt.id] = stmt.value.value
    return out


def function_env(fn: ast.FunctionDef,
                 module_consts: dict[str, int]) -> Env:
    """Environment after abstractly executing ``fn``'s straight-line
    assignments in source order (branch-local assignments included —
    last writer wins, which is sound for the divisibility question
    because every binding is a fresh atom unless provably arithmetic)."""
    env = Env(module_consts)
    # int-typed defaults of keyword parameters (block sizes)
    args = fn.args
    pos = args.posonlyargs + args.args
    for param, default in zip(pos[len(pos) - len(args.defaults):],
                              args.defaults):
        lin = env.lin(default)
        if lin is not None and lin.as_const() is not None:
            env.ints[param.arg] = lin
    for param, default in zip(args.kwonlyargs, args.kw_defaults):
        if default is not None:
            lin = env.lin(default)
            if lin is not None and lin.as_const() is not None:
                env.ints[param.arg] = lin
    for stmt in ast.walk(fn):
        if isinstance(stmt, (ast.Assign, ast.AnnAssign)):
            env.bind_assign(stmt)
    return env


def int_expr_has_product(node: ast.AST) -> bool:
    """True when the expression contains a ``*`` of two non-constant
    operands — the ``k_index * n + u`` / ``K * n + 1`` overflow shape.
    Sequence repetition (``[u] * w``, ``(x,) * n``) is not arithmetic
    and never overflows an element."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Mult):
            if isinstance(sub.left, (ast.List, ast.Tuple)) \
                    or isinstance(sub.right, (ast.List, ast.Tuple)):
                continue
            lc = isinstance(sub.left, ast.Constant)
            rc = isinstance(sub.right, ast.Constant)
            if not lc and not rc:
                return True
    return False


def free_names(lam: ast.Lambda) -> Iterable[str]:
    """Names read inside a lambda body that are not its own parameters."""
    params = {a.arg for a in (lam.args.posonlyargs + lam.args.args
                              + lam.args.kwonlyargs)}
    if lam.args.vararg:
        params.add(lam.args.vararg.arg)
    if lam.args.kwarg:
        params.add(lam.args.kwarg.arg)
    seen: set[str] = set()
    for node in ast.walk(lam.body):
        if (isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load)
                and node.id not in params and node.id not in seen):
            seen.add(node.id)
            yield node.id
