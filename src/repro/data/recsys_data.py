"""Synthetic user-history batches for MIND (offline container).

Users belong to latent taste clusters; histories draw items from a
cluster-specific Zipf slice, so multi-interest routing has real structure
to extract. Deterministic per (seed, step, host).
"""

from __future__ import annotations

import numpy as np


class InteractionStream:
    def __init__(self, n_items: int, hist_len: int, *, n_clusters: int = 32,
                 seed: int = 0, host_id: int = 0):
        self.n_items = n_items
        self.hist_len = hist_len
        self.n_clusters = n_clusters
        self.host_id = host_id
        rng = np.random.default_rng(seed)
        self.cluster_base = rng.integers(0, max(n_items - 1000, 1), n_clusters)

    def batch(self, step: int, batch: int):
        rng = np.random.default_rng(hash(("rec", step, self.host_id)) & 0x7FFFFFFF)
        # each user mixes 1-3 clusters (multi-interest ground truth)
        k = rng.integers(1, 4, batch)
        hist = np.empty((batch, self.hist_len), np.int64)
        target = np.empty(batch, np.int64)
        for i in range(batch):
            cs = rng.integers(0, self.n_clusters, k[i])
            base = self.cluster_base[rng.choice(cs, self.hist_len)]
            hist[i] = (base + rng.zipf(1.8, self.hist_len)) % self.n_items
            target[i] = (self.cluster_base[rng.choice(cs)] + rng.zipf(1.8)) % self.n_items
        mask = np.ones((batch, self.hist_len), np.float32)
        # ids are % n_items, int32-safe; the int64 above only absorbs
        # the unbounded zipf draws pre-modulo
        # repro: ignore[int32-narrowing]
        return {"hist_ids": hist.astype(np.int32), "hist_mask": mask,
                # repro: ignore[int32-narrowing] — same % n_items bound
                "target_id": target.astype(np.int32)}
