"""CSR uniform neighbour sampler (GraphSAGE minibatch_lg pipeline).

Builds a CSR adjacency once, then draws layered fanout samples
(25-10 style) producing the unified padded subgraph-batch format the GNN
models consume: node_feat / src / dst / edge_mask / seed_mask, padded to
static shapes so the jitted train step never recompiles.
"""

from __future__ import annotations

import numpy as np


class CSRGraph:
    def __init__(self, n: int, src: np.ndarray, dst: np.ndarray):
        self.n = n
        order = np.argsort(src, kind="stable")
        self.col = dst[order].astype(np.int32)
        deg = np.bincount(src, minlength=n)
        self.ptr = np.zeros(n + 1, np.int64)
        np.cumsum(deg, out=self.ptr[1:])

    def sample_neighbors(self, nodes: np.ndarray, fanout: int,
                         rng: np.random.Generator):
        """(len(nodes), fanout) neighbour ids, -1 padded."""
        out = np.full((nodes.shape[0], fanout), -1, np.int32)
        for i, v in enumerate(nodes):
            lo, hi = self.ptr[v], self.ptr[v + 1]
            if hi > lo:
                take = rng.integers(lo, hi, size=min(fanout, hi - lo))
                out[i, : take.shape[0]] = self.col[take]
        return out


def sample_subgraph_batch(g: CSRGraph, feats: np.ndarray, labels: np.ndarray,
                          seeds: np.ndarray, fanout: tuple,
                          rng: np.random.Generator,
                          pad_nodes: int | None = None,
                          pad_edges: int | None = None) -> dict:
    """Layered fanout sample -> padded unified GNN batch (numpy arrays)."""
    frontier = seeds.astype(np.int32)
    nodes = [frontier]
    edges_src, edges_dst = [], []
    for f in fanout:
        nb = g.sample_neighbors(frontier, f, rng)
        valid = nb >= 0
        src = nb[valid]
        dst = np.repeat(frontier, valid.sum(axis=1))
        edges_src.append(src)
        edges_dst.append(dst)
        frontier = np.unique(src)
        nodes.append(frontier)
    all_nodes = np.unique(np.concatenate(nodes))
    remap = np.full(g.n, -1, np.int64)
    remap[all_nodes] = np.arange(all_nodes.shape[0])
    src = remap[np.concatenate(edges_src)].astype(np.int32)
    dst = remap[np.concatenate(edges_dst)].astype(np.int32)

    n_sub = all_nodes.shape[0]
    e_sub = src.shape[0]
    pad_nodes = pad_nodes or n_sub
    pad_edges = pad_edges or int(np.ceil(max(e_sub, 1) / 512)) * 512
    if pad_nodes < n_sub or pad_edges < e_sub:
        raise ValueError(
            f"pad budget too small: need >= ({n_sub} nodes, {e_sub} "
            f"edges), got ({pad_nodes}, {pad_edges})")

    node_feat = np.zeros((pad_nodes, feats.shape[1]), np.float32)
    node_feat[:n_sub] = feats[all_nodes]
    lab = np.zeros(pad_nodes, np.int32)
    lab[:n_sub] = labels[all_nodes]
    seed_mask = np.zeros(pad_nodes, bool)
    seed_mask[remap[seeds]] = True
    edge_mask = np.zeros(pad_edges, np.float32)
    edge_mask[:e_sub] = 1.0
    return {
        "node_feat": node_feat,
        "src": np.pad(src, (0, pad_edges - e_sub)),
        "dst": np.pad(dst, (0, pad_edges - e_sub)),
        "edge_mask": edge_mask,
        "labels": lab,
        "seed_mask": seed_mask,
    }


def random_powerlaw_graph(n: int, avg_deg: int, *, seed: int = 0):
    """Synthetic power-law graph in (src, dst) doubled edge-list form."""
    rng = np.random.default_rng(seed)
    m = n * avg_deg // 2
    pop = (np.arange(1, n + 1) ** -0.8)
    pop /= pop.sum()
    a = rng.choice(n, size=m, p=pop).astype(np.int32)
    b = rng.choice(n, size=m, p=pop).astype(np.int32)
    keep = a != b
    a, b = a[keep], b[keep]
    return np.concatenate([a, b]), np.concatenate([b, a])
