"""Deterministic synthetic LM token stream, host-sharded.

Offline container: no downloadable corpora. The stream is a seeded Markov
babbler over the model vocabulary — enough structure that cross-entropy
drops visibly during the example training runs (a pure-uniform stream would
have nothing to learn), fully deterministic per (seed, host, step) so every
data-parallel host can generate its own disjoint shard without coordination
(the production pattern: shard by host id, never ship batches).
"""

from __future__ import annotations

import numpy as np


class TokenStream:
    def __init__(self, vocab: int, *, seed: int = 0, host_id: int = 0,
                 n_hosts: int = 1, order_states: int = 64):
        self.vocab = vocab
        self.host_id = host_id
        self.n_hosts = n_hosts
        rng = np.random.default_rng(seed)
        # a small hidden-state Markov chain emitting vocab tokens
        self.trans = rng.dirichlet(np.ones(order_states) * 0.3, size=order_states)
        self.emit_logits = rng.normal(size=(order_states, vocab)).astype(np.float32) * 2.0
        self._emit_cdf = None

    def _emit_probs(self):
        if self._emit_cdf is None:
            z = np.exp(self.emit_logits - self.emit_logits.max(1, keepdims=True))
            p = z / z.sum(1, keepdims=True)
            self._emit_cdf = np.cumsum(p, axis=1)
        return self._emit_cdf

    def batch(self, step: int, batch: int, seq: int):
        """(tokens, labels) int32[(batch, seq)] for this host at this step."""
        rng = np.random.default_rng(
            (hash(("lm", step, self.host_id, self.n_hosts)) & 0x7FFFFFFF))
        cdf = self._emit_probs()
        s = rng.integers(0, self.trans.shape[0], size=batch)
        toks = np.empty((batch, seq + 1), np.int32)
        for t in range(seq + 1):
            u = rng.random(batch)
            toks[:, t] = (cdf[s] < u[:, None]).sum(axis=1)
            # advance hidden states
            tu = rng.random(batch)
            s = (np.cumsum(self.trans[s], axis=1) < tu[:, None]).sum(axis=1)
        return toks[:, :-1], toks[:, 1:]
