"""AdamW with warmup+cosine schedule and global-norm clipping (pure JAX).

Moments are kept in f32 regardless of param dtype (bf16 params train with
f32 master statistics). The update is fully pytree-generic and shards
trivially under pjit (element-wise ops inherit the param sharding).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_state(params) -> dict:
    return jax.eval_shape(init_state, params)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bc1
        vhat = nu / bc2
        step_out = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_out).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, {"grad_norm": gnorm, "lr": lr}
