"""Int8 error-feedback gradient compression for the DP all-reduce.

Scheme (1-bit-Adam-family, adapted to int8):
  1. residual-corrected gradient  g' = g + error
  2. per-tensor symmetric int8 quantization  q = round(g' / s), s = max|g'|/127
  3. the data-parallel mean of q is taken with a two-phase exchange
     (``all_to_all`` int8 chunks -> local sum -> ``all_gather`` int8), moving
     ~0.5x the bytes of a bf16 ring all-reduce
  4. new error = g' - dequant(q)   (kept locally, added next step)

On a single-device mesh the exchange degenerates to identity, so the
numerics (quantize / dequantize / error feedback) are unit-testable here;
the collective path compiles in the multi-device dry-run and is validated
on an 8-way host-device mesh in tests/test_distributed.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.runtime.sharding import shard_map


def quantize(g, axis_size: int = 1):
    """Per-tensor symmetric int8. Returns (q, scale)."""
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_update(g, error):
    """Error-feedback compression of one tensor; returns (q, scale, new_error)."""
    corrected = g.astype(jnp.float32) + error
    q, scale = quantize(corrected)
    new_error = corrected - dequantize(q, scale)
    return q, scale, new_error


def compressed_psum_mean(q, scale, axis: str):
    """Mean over a mesh axis of int8-quantized tensors.

    int8 summands are widened to int32 *inside* the psum operand (sum of up
    to 2^23 int8 values fits int32), so the wire format stays compact under
    XLA's collective folding; scales are meaned in f32 (cheap scalar).
    """
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    mean_scale = jax.lax.pmean(scale, axis)
    size = jax.lax.psum(jnp.ones((), jnp.int32), axis)
    return total.astype(jnp.float32) * mean_scale / size.astype(jnp.float32)


def make_compressed_grad_allreduce(mesh, axis: str = "data"):
    """shard_map-based DP gradient mean with int8 error feedback.

    Returns ``f(grads, errors) -> (mean_grads, new_errors)`` where grads are
    replicated pytrees over the ``axis`` (each host computed its microbatch
    grads). Used by launch/train.py when ``--compress-grads`` is set.
    """
    def one(g, e):
        q, s, new_e = compress_update(g, e)
        return compressed_psum_mean(q, s, axis), new_e

    def all_tensors(grads, errors):
        pairs = jax.tree.map(one, grads, errors)
        is_pair = lambda t: isinstance(t, tuple) and len(t) == 2
        means = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair)
        errs = jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair)
        return means, errs

    # grads enter replicated per-DP-shard; shard_map runs the body per device
    def wrapped(grads, errors):
        specs = jax.tree.map(lambda _: P(), grads)
        fn = shard_map(all_tensors, mesh=mesh,
                       in_specs=(specs, specs), out_specs=(specs, specs))
        return fn(grads, errors)

    return wrapped


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
