"""Pallas kernel: one masked min-label propagation round (batched TCCS).

The device query plane (core/batch_query.py) runs rounds of

    label[b, x] <- min(label[b, x], label[b, l(x)], label[b, r(x)],
                       label[b, p(x)])          (links masked per query)
    label[b, x] <- min(label[b, x], label[b, label[b, x]])   (pointer jump)

over the (B, N) query-x-forest-node matrix. The binary child bound from the
paper is what fixes the neighbour count at 3, making the round a constant
number of VMEM gathers.

Tiling: grid (B, N/bn). Each step holds one query's full label/active row
(N int32 — e.g. 256 KiB at N=64k, well inside VMEM) plus the link block,
gathers are row-local, and the output block is the updated label slice.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .contracts import ANY_INT, ArraySpec, INT_OR_BOOL, kernel_contract


def _label_prop_kernel(labels_row_ref, active_row_ref,
                       l_ref, r_ref, p_ref, active_blk_ref, out_ref):
    row = labels_row_ref[0]            # (N,) full row for gathers
    act_row = active_row_ref[0]
    N = row.shape[0]
    blk = out_ref.shape[1]
    base = pl.program_id(1) * blk
    cur = jax.lax.dynamic_slice(row, (base,), (blk,))
    act = active_blk_ref[0]

    def nb(link):
        ok = (link >= 0) & act
        linkc = jnp.clip(link, 0, N - 1)
        lab = row[linkc]
        a = act_row[linkc]
        return jnp.where(ok & a, lab, N)

    new = jnp.minimum(cur, jnp.minimum(nb(l_ref[0]),
                                       jnp.minimum(nb(r_ref[0]), nb(p_ref[0]))))
    jumped = jnp.where(new < N, row[jnp.clip(new, 0, N - 1)], new)
    out_ref[0, :] = jnp.minimum(new, jumped)


def _label_prop_vmem(a: dict) -> int:
    # per step: two full padded rows (label + active) + five (1, bn)
    # link/active blocks + the output block, all int32
    bn = a["bn"]
    n_pad = int(np.ceil(max(a["labels"].shape[1], 1) / bn)) * bn
    return 4 * (2 * n_pad + 6 * bn)


@kernel_contract(
    in_specs={
        "labels": ArraySpec(("B", "N"), ANY_INT),
        "link_l": ArraySpec(("B", "N"), ANY_INT),
        "link_r": ArraySpec(("B", "N"), ANY_INT),
        "link_p": ArraySpec(("B", "N"), ANY_INT),
        "active": ArraySpec(("B", "N"), INT_OR_BOOL),
    },
    out_specs=ArraySpec(("B", "N"), ("int32",)),
    vmem_bound=_label_prop_vmem,
)
def label_prop_round(labels, link_l, link_r, link_p, active, *,
                     bn: int = 2048, interpret: bool = True):
    """One (B, N) propagation + jump round. Matches ref.label_prop_round."""
    B, N = labels.shape
    npad = int(np.ceil(max(N, 1) / bn)) * bn - N
    pad2 = lambda a, fill: jnp.pad(a, ((0, 0), (0, npad)), constant_values=fill)
    labels_p = pad2(labels.astype(jnp.int32), N)
    act_p = pad2(active, False)
    l_p = pad2(link_l.astype(jnp.int32), -1)
    r_p = pad2(link_r.astype(jnp.int32), -1)
    p_p = pad2(link_p.astype(jnp.int32), -1)
    Np = N + npad
    out = pl.pallas_call(
        _label_prop_kernel,
        grid=(B, Np // bn),
        in_specs=[
            pl.BlockSpec((1, Np), lambda b, j: (b, 0)),   # full label row
            pl.BlockSpec((1, Np), lambda b, j: (b, 0)),   # full active row
            pl.BlockSpec((1, bn), lambda b, j: (b, j)),
            pl.BlockSpec((1, bn), lambda b, j: (b, j)),
            pl.BlockSpec((1, bn), lambda b, j: (b, j)),
            pl.BlockSpec((1, bn), lambda b, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda b, j: (b, j)),
        out_shape=jax.ShapeDtypeStruct((B, Np), jnp.int32),
        interpret=interpret,
    )(labels_p, act_p, l_p, r_p, p_p, act_p)
    return out[:, :N]
