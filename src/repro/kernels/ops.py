"""Public jit'd wrappers: Pallas kernel <-> pure-jnp reference dispatch.

On this CPU container every kernel runs with ``interpret=True`` (the Pallas
interpreter executes the kernel body op-for-op); on TPU the same
``pl.pallas_call`` lowers to Mosaic. ``use_pallas(False)`` routes everything
through the jnp references (the default inside big jitted training graphs,
where XLA fusion is already the right tool and kernel dispatch would only
fragment it).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import kcore_peel as _kp
from . import label_prop as _lp
from . import ref
from . import segment_matmul as _sm

_USE_PALLAS = True
_INTERPRET = True     # CPU container: interpret mode; flip on real TPUs


def use_pallas(flag: bool):
    global _USE_PALLAS
    _USE_PALLAS = flag


def degree_count(src, dst, alive, n: int):
    if _USE_PALLAS:
        return _kp.degree_count(src, dst, alive, n, interpret=_INTERPRET)
    return ref.degree_count(src, dst, alive, n)


def kcore_peel_round(src, dst, alive, n: int, k: int):
    if _USE_PALLAS:
        new_alive = _kp.peel_round(src, dst, alive, n, k, interpret=_INTERPRET)
        return new_alive, jnp.any(new_alive != alive)
    return ref.kcore_peel_round(src, dst, alive, n, k)


def kcore_fixpoint(src, dst, n: int, k: int):
    """Device-side k-core edge mask (used by serving/benches)."""
    return ref.kcore_fixpoint(src, dst, n, k)


def label_prop_round(labels, link_l, link_r, link_p, active):
    if _USE_PALLAS:
        return _lp.label_prop_round(labels, link_l, link_r, link_p, active,
                                    interpret=_INTERPRET)
    return ref.label_prop_round(labels, link_l, link_r, link_p, active)


def matmul(a, b):
    if _USE_PALLAS:
        return _sm.matmul(a, b, interpret=_INTERPRET)
    return ref.matmul(a, b)


def segment_sum(vals, ids, num_segments: int):
    if _USE_PALLAS:
        return _sm.segment_sum(vals, ids, num_segments, interpret=_INTERPRET)
    return ref.segment_sum_sorted(vals, ids, num_segments)


def embedding_bag(table, ids, weights=None):
    return _sm.embedding_bag(table, ids, weights)


def flash_attention(q, k, v, *, causal: bool = False):
    if _USE_PALLAS:
        return _fa.flash_attention(q, k, v, causal=causal, interpret=_INTERPRET)
    return ref.flash_attention(q, k, v, causal=causal)
