"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth: kernels are validated against
these with assert_allclose across shape/dtype sweeps (tests/test_kernels.py)
and hypothesis-generated inputs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def degree_count(src: jnp.ndarray, dst: jnp.ndarray, alive: jnp.ndarray,
                 n: int) -> jnp.ndarray:
    """int32[n] degree of each vertex counting alive edges (both endpoints)."""
    w = alive.astype(jnp.int32)
    deg = jax.ops.segment_sum(w, src, num_segments=n)
    deg = deg + jax.ops.segment_sum(w, dst, num_segments=n)
    return deg


def kcore_peel_round(src, dst, alive, n: int, k: int):
    """One peel round: drop edges with an endpoint of degree < k.

    Returns (new_alive, changed)."""
    deg = degree_count(src, dst, alive, n)
    ok = deg >= k
    new_alive = alive & ok[src] & ok[dst]
    return new_alive, jnp.any(new_alive != alive)


def kcore_fixpoint(src, dst, n: int, k: int, alive0=None):
    """Full peel fixpoint via lax.while_loop (device-side k-core)."""
    alive = jnp.ones(src.shape, bool) if alive0 is None else alive0

    def cond(state):
        return state[1]

    def body(state):
        alive, _ = state
        return kcore_peel_round(src, dst, alive, n, k)

    alive, _ = jax.lax.while_loop(cond, body, (alive, jnp.array(True)))
    return alive


def label_prop_round(labels, link_l, link_r, link_p, active):
    """One min-label round over batched forest links (B, N) + jump."""
    B, N = labels.shape

    def nb(link):
        ok = (link >= 0) & active
        linkc = jnp.clip(link, 0, N - 1)
        l = jnp.take_along_axis(labels, linkc, axis=1)
        a = jnp.take_along_axis(active, linkc, axis=1)
        return jnp.where(ok & a, l, N)

    new = jnp.minimum(labels, jnp.minimum(nb(link_l), jnp.minimum(nb(link_r), nb(link_p))))
    # pointer jump reads the PRE-round labels: the blockwise kernel cannot
    # see other blocks' updates within a round (same fixpoint, one fewer
    # intra-round dependency)
    jc = jnp.clip(new, 0, N - 1)
    jumped = jnp.where(new < N, jnp.take_along_axis(labels, jc, axis=1), new)
    return jnp.minimum(new, jumped)


def segment_sum_sorted(vals: jnp.ndarray, ids: jnp.ndarray, num_segments: int):
    """Segment sum of (m, d) rows by sorted-or-not int ids (oracle uses the
    generic scatter; the kernel requires nothing about ordering either)."""
    return jax.ops.segment_sum(vals, ids, num_segments=num_segments)


def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))


def embedding_bag(table, ids, weights=None):
    """(bags, k) ids -> (bags, d) weighted sum — torch EmbeddingBag('sum')."""
    emb = jnp.take(table, ids, axis=0)                   # (bags, k, d)
    if weights is not None:
        emb = emb * weights[..., None]
    return emb.sum(axis=1)


def flash_attention(q, k, v, *, causal: bool = False):
    """(B, S, H, dh) x (B, T, H, dh) -> (B, S, H, dh), f32 softmax."""
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(q.shape[-1]))
    if causal:
        S, T = q.shape[1], k.shape[1]
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs.astype(v.dtype), v)
    return out
