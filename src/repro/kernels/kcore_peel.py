"""Pallas kernel: fused degree-count + threshold for one k-core peel round.

TPU adaptation of the peeling inner loop (DESIGN.md §3). Scatter-add is the
CPU idiom; the TPU-native formulation turns the degree histogram into a
*one-hot compare + row reduction* over (edge-block x vertex-block) tiles —
dense VPU work with an MXU-shaped inner product, no atomics, deterministic.

Grid: (n_edge_blocks, n_vertex_blocks). Each step loads an edge block
(src, dst, alive int32) and accumulates the partial histogram of its
endpoints against the vertex-id range of the current vertex block:

    part[j] = sum_i alive[i] * ([src_i == base+j] + [dst_i == base+j])

The output block (per vertex-block) is revisited across edge blocks
(accumulation across the first grid dim), initialized at edge-block 0.
A second tiny kernel applies the k-threshold + edge mask update.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .contracts import ANY_INT, ArraySpec, INT_OR_BOOL, kernel_contract

DEFAULT_EDGE_BLOCK = 1024
DEFAULT_VERT_BLOCK = 512


def _degree_kernel(src_ref, dst_ref, alive_ref, out_ref):
    eb = pl.program_id(0)
    vb = pl.program_id(1)
    base = vb * out_ref.shape[0]
    src = src_ref[...]
    dst = dst_ref[...]
    alive = alive_ref[...]
    vids = base + jax.lax.broadcasted_iota(jnp.int32, (src.shape[0], out_ref.shape[0]), 1)
    hit = (src[:, None] == vids).astype(jnp.int32) + (dst[:, None] == vids).astype(jnp.int32)
    part = jnp.sum(hit * alive[:, None], axis=0)

    @pl.when(eb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += part


@kernel_contract(
    in_specs={
        "src": ArraySpec(("E",), ANY_INT),
        "dst": ArraySpec(("E",), ANY_INT),
        "alive": ArraySpec(("E",), INT_OR_BOOL),
    },
    out_specs=ArraySpec(("n",), ("int32",)),
    # per step: three edge blocks + the vertex-block output, i32
    vmem_bound=lambda a: 4 * (3 * a["edge_block"] + a["vert_block"]),
)
def degree_count(src, dst, alive, n: int, *,
                 edge_block: int = DEFAULT_EDGE_BLOCK,
                 vert_block: int = DEFAULT_VERT_BLOCK,
                 interpret: bool = True) -> jnp.ndarray:
    """int32[n] alive-edge degrees. Pads edges/vertices to block multiples."""
    m = src.shape[0]
    mp = int(np.ceil(max(m, 1) / edge_block)) * edge_block
    np_ = int(np.ceil(max(n, 1) / vert_block)) * vert_block
    pad_e = mp - m
    src_p = jnp.pad(src.astype(jnp.int32), (0, pad_e), constant_values=-1)
    dst_p = jnp.pad(dst.astype(jnp.int32), (0, pad_e), constant_values=-1)
    alive_p = jnp.pad(alive.astype(jnp.int32), (0, pad_e))
    grid = (mp // edge_block, np_ // vert_block)
    out = pl.pallas_call(
        _degree_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((edge_block,), lambda e, v: (e,)),
            pl.BlockSpec((edge_block,), lambda e, v: (e,)),
            pl.BlockSpec((edge_block,), lambda e, v: (e,)),
        ],
        out_specs=pl.BlockSpec((vert_block,), lambda e, v: (v,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.int32),
        interpret=interpret,
    )(src_p, dst_p, alive_p)
    return out[:n]


def _threshold_kernel(src_ref, dst_ref, alive_ref, deg_ref, k_ref, out_ref):
    src = src_ref[...]
    dst = dst_ref[...]
    k = k_ref[0]
    deg = deg_ref[...]        # full degree vector in VMEM
    ok_s = deg[src] >= k
    ok_d = deg[dst] >= k
    out_ref[...] = (alive_ref[...] > 0) & ok_s & ok_d


def _peel_vmem(a: dict) -> int:
    # the threshold kernel holds the WHOLE padded degree vector in VMEM
    # (deg.shape BlockSpec) — the dominant term for large n
    n_pad = (int(np.ceil(max(a["n"], 1) / DEFAULT_VERT_BLOCK))
             * DEFAULT_VERT_BLOCK)
    return 4 * (3 * a["edge_block"] + n_pad + 1) + a["edge_block"]


@kernel_contract(
    in_specs={
        "src": ArraySpec(("E",), ANY_INT),
        "dst": ArraySpec(("E",), ANY_INT),
        "alive": ArraySpec(("E",), INT_OR_BOOL),
    },
    out_specs=ArraySpec(("E",), ("bool",)),
    vmem_bound=_peel_vmem,
)
def peel_round(src, dst, alive, n: int, k: int, *,
               edge_block: int = DEFAULT_EDGE_BLOCK,
               interpret: bool = True):
    """One fused peel round; returns the new alive mask (bool[m])."""
    deg = degree_count(src, dst, alive, n, interpret=interpret)
    m = src.shape[0]
    mp = int(np.ceil(max(m, 1) / edge_block)) * edge_block
    pad_e = mp - m
    src_p = jnp.pad(src.astype(jnp.int32), (0, pad_e))
    dst_p = jnp.pad(dst.astype(jnp.int32), (0, pad_e))
    alive_p = jnp.pad(alive.astype(jnp.int32), (0, pad_e))
    out = pl.pallas_call(
        _threshold_kernel,
        grid=(mp // edge_block,),
        in_specs=[
            pl.BlockSpec((edge_block,), lambda e: (e,)),
            pl.BlockSpec((edge_block,), lambda e: (e,)),
            pl.BlockSpec((edge_block,), lambda e: (e,)),
            pl.BlockSpec(deg.shape, lambda e: (0,)),      # whole degree vector
            pl.BlockSpec((1,), lambda e: (0,)),
        ],
        out_specs=pl.BlockSpec((edge_block,), lambda e: (e,)),
        out_shape=jax.ShapeDtypeStruct((mp,), jnp.bool_),
        interpret=interpret,
    )(src_p, dst_p, alive_p, deg, jnp.array([k], jnp.int32))
    return out[:m]
