"""Kernel contracts, the device-layout table, and the runtime shape
witness (DESIGN.md §15).

The static ``kernels`` pass (``repro.analysis.passes_kernels``) proves
what it can about every ``pl.pallas_call`` site from the AST; this module
is its runtime counterpart, mirroring the lock-witness split of
``repro.obs.locks``: declarations live next to the code they constrain,
production pays (almost) nothing, and CI arms a process-wide witness
around the fast suite.

* :data:`LAYOUT_CONTRACTS` — the declared dtype+rank of every array in
  the :class:`~repro.core.batch_query.DeviceIndex` layout. The static
  layout-contract rule cross-checks construction sites against this
  table; :func:`check_layout` validates the actual host arrays on upload
  when the witness is armed.
* :func:`kernel_contract` — decorator for the Pallas wrappers in this
  package. It always registers the declaration in :data:`CONTRACTS`
  (so coverage is assertable without arming anything) and attaches it as
  ``__kernel_contract__``; per call it is a no-op unless
  ``REPRO_KERNEL_WITNESS=1`` — unlike the lock witness the flag is read
  at *call* time, because kernels are module-level functions decorated
  once at import while locks are constructed per object. One env read
  per kernel launch is noise next to the launch itself.
* :class:`KernelWitness` — records every armed call, validates array
  rank/dtype/symbolic-dim bindings against the contract, evaluates the
  declared VMEM bound against the per-platform budget, and deduplicates
  violations into a JSON-able report. ``tests/conftest.py`` fails the
  suite on any problem, exactly like the lock gate.

Imports here are numpy-only: the analysis pass imports this module for
:data:`LAYOUT_CONTRACTS` and must not drag jax into a lint run.
"""

from __future__ import annotations

import dataclasses
import functools
import inspect
import os
import threading
from typing import Callable, Mapping, Sequence

import numpy as np

_ENV_FLAG = "REPRO_KERNEL_WITNESS"
_BUDGET_ENV = "REPRO_KERNEL_VMEM_BUDGET"

#: default per-step VMEM budget: ~16 MiB/core on current TPUs (the
#: compiler reserves some; kernels should stay well under). Overridable
#: per-process via REPRO_KERNEL_VMEM_BUDGET, per-run via pyproject for
#: the static estimator.
DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024

#: dtype families for contract specs
ANY_INT = ("int32", "int64", "int16", "int8", "uint32", "uint8")
ANY_FLOAT = ("float32", "bfloat16", "float16", "float64")
INT_OR_BOOL = ANY_INT + ("bool",)


def witness_enabled() -> bool:
    """True when the process-wide kernel witness is armed (checked per
    call, so a long-lived process can arm without re-importing)."""
    return os.environ.get(_ENV_FLAG, "") not in ("", "0", "false", "no")


class KernelContractViolation(Exception):
    """Raised by the conftest session gate when an armed run recorded
    contract problems."""


# ---------------------------------------------------------------------------
# declarations
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArraySpec:
    """Declared shape+dtype of one kernel operand or output.

    ``dims`` entries are either exact ints or symbol strings bound at
    validation time — first from same-named scalar int arguments, then
    from the first array dim they appear at; every later occurrence must
    agree, which is how cross-operand constraints (label/link/active rows
    all (B, N)) are expressed. ``dtypes`` is the set of accepted dtype
    names."""

    dims: tuple
    dtypes: tuple[str, ...]

    def describe(self) -> str:
        return (f"({', '.join(str(d) for d in self.dims)})"
                f":{'|'.join(self.dtypes)}")


@dataclasses.dataclass(frozen=True)
class KernelContract:
    """The declared interface of one Pallas wrapper."""

    name: str
    in_specs: tuple[tuple[str, ArraySpec], ...]   # (param name, spec)
    out_specs: tuple[ArraySpec, ...]
    #: bound-arguments dict -> worst-case per-step VMEM bytes
    vmem_bound: Callable[[dict], int] | None = None


#: every decorated wrapper's declaration, keyed by qualified name —
#: lets tests assert that each Pallas wrapper carries a contract without
#: arming the witness.
CONTRACTS: dict[str, KernelContract] = {}


#: The device-layout table: dtype + rank of every array entering
#: ``to_device`` / ``_host_layout`` (DESIGN.md §15.4). The static
#: layout-contract rule checks construction sites against this both ways
#: (undeclared keys, missing keys, unprovable dtypes); the armed witness
#: checks the real arrays on upload.
LAYOUT_CONTRACTS: dict[str, tuple[str, int]] = {
    "node_u": ("int32", 1),
    "node_v": ("int32", 1),
    "node_ct": ("int32", 1),
    "live_from": ("int32", 1),
    "live_to": ("int32", 1),
    "row_ptr": ("int32", 1),
    "ent_ts": ("int32", 1),
    "ent_left": ("int32", 1),
    "ent_right": ("int32", 1),
    "ent_parent": ("int32", 1),
    "vrow_ptr": ("int32", 1),
    "vent_ts": ("int32", 1),
    "vent_node": ("int32", 1),
    "ver_ts_from": ("int32", 1),
    "ver_ts_to": ("int32", 1),
    "ver_ct": ("int32", 1),
    "ver_src": ("int32", 1),
    "ver_k": ("int32", 1),
}


# ---------------------------------------------------------------------------
# the witness
# ---------------------------------------------------------------------------

def _dtype_name(value) -> str:
    return str(getattr(value, "dtype", type(value).__name__))


class KernelWitness:
    """Validates armed kernel calls against their contracts and records a
    process-wide report.

    Thread-safe; violations are deduplicated by (kind, kernel, message)
    so a hot loop cannot grow the report without bound. The VMEM budget
    is compared against each call's *declared* bound — the witness
    checks the contract's model, the static pass checks the code against
    the same model, and together a kernel whose tiles outgrow VMEM fails
    in CI before it ever runs on hardware."""

    def __init__(self, vmem_budget: int | None = None):
        self.vmem_budget = (vmem_budget if vmem_budget is not None
                            else int(os.environ.get(_BUDGET_ENV,
                                                    DEFAULT_VMEM_BUDGET)))
        self._mu = threading.Lock()
        # kernel name -> {"calls": int, "max_vmem": int}
        self._kernels: dict[str, dict] = {}
        # (kind, kernel, message) -> {"count": int, ...}
        self._violations: dict[tuple[str, str, str], dict] = {}
        self.calls = 0

    # -- recording --------------------------------------------------------
    def on_call(self, kernel: str, vmem_bytes: int | None) -> None:
        with self._mu:
            self.calls += 1
            entry = self._kernels.setdefault(
                kernel, {"calls": 0, "max_vmem": 0})
            entry["calls"] += 1
            if vmem_bytes is not None:
                entry["max_vmem"] = max(entry["max_vmem"], int(vmem_bytes))

    def note(self, kind: str, kernel: str, message: str) -> None:
        with self._mu:
            v = self._violations.setdefault(
                (kind, kernel, message),
                {"kind": kind, "kernel": kernel, "message": message,
                 "count": 0})
            v["count"] += 1

    # -- validation -------------------------------------------------------
    def validate_arrays(self, kernel: str,
                        named: Sequence[tuple[str, object, ArraySpec]],
                        symbols: dict[str, int]) -> None:
        """Check (label, array, spec) triples, binding/checking symbolic
        dims through the shared ``symbols`` map."""
        for label, arr, spec in named:
            shape = getattr(arr, "shape", None)
            if shape is None:
                self.note("shape-contract", kernel,
                          f"{label}: expected an array with .shape, got "
                          f"{type(arr).__name__}")
                continue
            if len(shape) != len(spec.dims):
                self.note("shape-contract", kernel,
                          f"{label}: rank {len(shape)} != declared rank "
                          f"{len(spec.dims)} {spec.describe()}")
                continue
            for dim, actual in zip(spec.dims, shape):
                actual = int(actual)
                if isinstance(dim, int):
                    if actual != dim:
                        self.note("shape-contract", kernel,
                                  f"{label}: dim {actual} != declared "
                                  f"{dim} in {spec.describe()}")
                elif dim in symbols:
                    if actual != symbols[dim]:
                        self.note("shape-contract", kernel,
                                  f"{label}: dim {dim}={actual} "
                                  f"conflicts with {dim}="
                                  f"{symbols[dim]} bound earlier")
                else:
                    symbols[dim] = actual
            dt = _dtype_name(arr)
            if dt not in spec.dtypes:
                self.note("dtype-contract", kernel,
                          f"{label}: dtype {dt} not in declared "
                          f"{{{'|'.join(spec.dtypes)}}}")

    def validate_vmem(self, kernel: str, vmem_bytes: int) -> None:
        if vmem_bytes > self.vmem_budget:
            self.note("vmem-budget", kernel,
                      f"declared per-step VMEM bound {vmem_bytes} B "
                      f"exceeds the budget {self.vmem_budget} B")

    # -- reading ----------------------------------------------------------
    def problems(self) -> list[dict]:
        with self._mu:
            return [dict(v) for v in self._violations.values()]

    def report(self) -> dict:
        """JSON-able summary (written as a CI artifact)."""
        with self._mu:
            kernels = {k: dict(v) for k, v in sorted(self._kernels.items())}
        return {
            "vmem_budget": self.vmem_budget,
            "calls": self.calls,
            "contracts": sorted(CONTRACTS),
            "kernels": kernels,
            "problems": self.problems(),
        }

    def reset(self) -> None:
        with self._mu:
            self._kernels.clear()
            self._violations.clear()
            self.calls = 0


#: Process-wide witness the armed wrappers report into.
WITNESS = KernelWitness()


def _active_witness() -> KernelWitness | None:
    return WITNESS if witness_enabled() else None


# ---------------------------------------------------------------------------
# the decorator
# ---------------------------------------------------------------------------

def _validate_call(contract: KernelContract, witness: KernelWitness,
                   fn: Callable, args: tuple, kwargs: dict):
    try:
        bound = inspect.signature(fn).bind(*args, **kwargs)
        bound.apply_defaults()
        values = dict(bound.arguments)
    except TypeError:
        # a mis-called wrapper fails in fn itself with the real traceback
        return fn(*args, **kwargs)

    # symbols seed: scalar int args whose names appear in the specs
    symbols: dict[str, int] = {}
    spec_syms = {d for _, s in contract.in_specs for d in s.dims
                 if isinstance(d, str)}
    spec_syms |= {d for s in contract.out_specs for d in s.dims
                  if isinstance(d, str)}
    for name, val in values.items():
        if (name in spec_syms and isinstance(val, int)
                and not isinstance(val, bool)):
            symbols[name] = val

    witness.validate_arrays(
        contract.name,
        [(name, values.get(name), spec) for name, spec in contract.in_specs
         if values.get(name) is not None],
        symbols)

    vmem = None
    if contract.vmem_bound is not None:
        try:
            vmem = int(contract.vmem_bound(values))
        except Exception as e:  # a broken bound is itself a finding
            witness.note("vmem-budget", contract.name,
                         f"vmem_bound raised {type(e).__name__}: {e}")
        else:
            witness.validate_vmem(contract.name, vmem)
    witness.on_call(contract.name, vmem)

    out = fn(*args, **kwargs)
    if contract.out_specs:
        outs = out if isinstance(out, tuple) else (out,)
        witness.validate_arrays(
            contract.name,
            [(f"out[{i}]", o, spec)
             for i, (o, spec) in enumerate(zip(outs, contract.out_specs))],
            symbols)
    return out


def kernel_contract(*, in_specs: Mapping[str, ArraySpec],
                    out_specs: Sequence[ArraySpec] | ArraySpec = (),
                    vmem_bound: Callable[[dict], int] | None = None):
    """Declare a Pallas wrapper's interface and arm it for the witness.

    Always registers the contract (coverage is checkable unarmed); the
    per-call validation path only runs under ``REPRO_KERNEL_WITNESS=1``.
    """
    if isinstance(out_specs, ArraySpec):
        out_specs = (out_specs,)

    def deco(fn: Callable) -> Callable:
        contract = KernelContract(
            name=fn.__name__,
            in_specs=tuple(in_specs.items()),
            out_specs=tuple(out_specs),
            vmem_bound=vmem_bound)
        CONTRACTS[fn.__name__] = contract

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            witness = _active_witness()
            if witness is None:
                return fn(*args, **kwargs)
            return _validate_call(contract, witness, fn, args, kwargs)

        wrapper.__kernel_contract__ = contract
        wrapper.__wrapped__ = fn
        return wrapper

    return deco


# ---------------------------------------------------------------------------
# device-layout validation
# ---------------------------------------------------------------------------

def check_layout(arrays: Mapping[str, object],
                 witness: KernelWitness | None = None) -> list[str]:
    """Cross-check a host layout dict against :data:`LAYOUT_CONTRACTS`
    both ways (undeclared / missing keys, dtype, rank). Returns the
    problem strings; when a witness is given they are also recorded as
    ``layout-contract`` violations. ``to_device`` calls this on every
    upload while the witness is armed."""
    problems: list[str] = []
    for name in arrays:
        if name not in LAYOUT_CONTRACTS:
            problems.append(f"{name}: not declared in LAYOUT_CONTRACTS")
    for name, (dtype, rank) in LAYOUT_CONTRACTS.items():
        if name not in arrays:
            problems.append(f"{name}: declared but absent from the layout")
            continue
        arr = np.asarray(arrays[name])
        if str(arr.dtype) != dtype:
            problems.append(
                f"{name}: dtype {arr.dtype} != declared {dtype}")
        if arr.ndim != rank:
            problems.append(f"{name}: rank {arr.ndim} != declared {rank}")
    if witness is not None:
        for p in problems:
            witness.note("layout-contract", "to_device", p)
    return problems
