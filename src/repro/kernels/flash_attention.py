"""Pallas kernel: blockwise online-softmax attention (FlashAttention-style).

Grid (batch*heads, q_blocks, kv_blocks); the kv dimension is the innermost
(fastest-varying) grid axis, so the output tile and the running max / sum
statistics are revisited and carried across kv steps in VMEM:

    m_new = max(m, rowmax(S));  alpha = exp(m - m_new)
    l     = alpha * l + rowsum(exp(S - m_new))
    acc   = alpha * acc + exp(S - m_new) @ V

The unnormalized accumulator is divided by l at the final kv step. Causal
masking skips whole kv blocks above the diagonal (`pl.when` guard) and
applies the triangular mask inside the diagonal block; kv padding past the
true sequence length is always masked.

Running stats are *revisited outputs* (block constant along the kv axis)
rather than scratch, for interpret-mode portability. VMEM per step: q tile
(bq, dh) + k/v tiles (bk, dh) + stats — MXU-aligned for 128-multiples.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .contracts import ANY_FLOAT, ArraySpec, kernel_contract

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, causal: bool, scale: float, blocks_kv: int, t_real: int):
    kv_idx = pl.program_id(2)
    q_idx = pl.program_id(1)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bq = q_ref.shape[1]
    bk = k_ref.shape[1]

    if causal:
        run = kv_idx * bk <= (q_idx + 1) * bq - 1   # below/at the diagonal
    else:
        run = kv_idx * bk < t_real                  # any real keys in block

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        kpos = kv_idx * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < t_real
        if causal:
            qpos = q_idx * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            mask = mask & (qpos >= kpos)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[0] = alpha * l_ref[0] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[0] = alpha * acc_ref[0] + jnp.dot(
            p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32)
        m_ref[0] = m_new

    @pl.when(kv_idx == blocks_kv - 1)
    def _finalize():
        o_ref[0] = (acc_ref[0] / jnp.maximum(l_ref[0], 1e-30)).astype(o_ref.dtype)


def _flash_vmem(a: dict) -> int:
    # per step (upper bound with the declared bq/bk — the wrapper may
    # shrink them for short sequences): q tile + k/v tiles + o/acc tiles
    # + (bq, 1) running stats, f32 bound per element
    dh = a["q"].shape[3]
    return 4 * (3 * a["bq"] * dh + 2 * a["bk"] * dh + 2 * a["bq"])


@kernel_contract(
    in_specs={
        "q": ArraySpec(("B", "S", "H", "dh"), ANY_FLOAT),
        "k": ArraySpec(("B", "T", "H", "dh"), ANY_FLOAT),
        "v": ArraySpec(("B", "T", "H", "dh"), ANY_FLOAT),
    },
    out_specs=ArraySpec(("B", "S", "H", "dh"), ANY_FLOAT),
    vmem_bound=_flash_vmem,
)
def flash_attention(q, k, v, *, causal: bool = False, bq: int = 128,
                    bk: int = 128, interpret: bool = True):
    """(B, S, H, dh) attention with KV (B, T, H, dh); H == kv-head count
    (expand GQA before calling). Returns (B, S, H, dh) in q.dtype."""
    B, S, H, dh = q.shape
    T = k.shape[1]
    bq = min(bq, int(np.ceil(S / 8)) * 8 if S < bq else bq)
    bk = min(bk, int(np.ceil(T / 8)) * 8 if T < bk else bk)
    Sp = int(np.ceil(S / bq)) * bq
    Tp = int(np.ceil(T / bk)) * bk
    qf = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    kf = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    qf = qf.transpose(0, 2, 1, 3).reshape(B * H, Sp, dh)
    kf = kf.transpose(0, 2, 1, 3).reshape(B * H, Tp, dh)
    vf = vf.transpose(0, 2, 1, 3).reshape(B * H, Tp, dh)
    blocks_kv = Tp // bk
    kernel = functools.partial(_flash_kernel, causal=causal,
                               scale=1.0 / float(np.sqrt(dh)),
                               blocks_kv=blocks_kv, t_real=T)
    # bq/bk shrink via min() and dh is a model dim (<=256); the static
    # worst case (2048^2 tiles) is unreachable, and the armed witness
    # checks the real-tree bound at call time
    # repro: ignore[pallas-vmem-budget]
    outs = pl.pallas_call(
        kernel,
        grid=(B * H, Sp // bq, blocks_kv),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq, 1), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq, 1), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Sp, dh), q.dtype),
            jax.ShapeDtypeStruct((B * H, Sp, 1), jnp.float32),
            jax.ShapeDtypeStruct((B * H, Sp, 1), jnp.float32),
            jax.ShapeDtypeStruct((B * H, Sp, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    o = outs[0].reshape(B, H, Sp, dh).transpose(0, 2, 1, 3)
    return o[:, :S]
