"""Segmented k-th-smallest selection over CSR segments (DESIGN.md §3).

The construction plane's inner op: for every CSR segment (one vertex's
incident pair slots) select the k-th smallest slot value, with a floor
``lo`` so the caller gets ``max(lo, kth)`` directly (the clamped fixpoint
update of ``core_time``). Values live in a small integer domain
``[0, inf_value]``, which admits a *counting bisection* formulation: the
k-th smallest is the least ``x`` with ``|{i in seg : w_i <= x}| >= k``.
Each bisection step needs only a segmented count — no sort, no scatter.

Three interchangeable backends:

* ``count_le_csr`` / ``kth_smallest_csr`` — jnp, used inside the jitted
  construction sweep (`core_time._sweep_jax`). Segments are contiguous, so
  the count is a cumsum + two gathers; XLA lowers this without scatter
  (whose CPU lowering is serial) and without sort.
* ``segmented_count_le`` — Pallas kernel. The TPU-native formulation turns
  the segmented count into a one-hot compare + row reduction over
  (slot_block x segment_block) tiles, exactly like ``kcore_peel``'s degree
  histogram: dense VPU work, no atomics, deterministic accumulation over
  the slot-block grid dimension. ``kth_smallest_pallas`` runs the same
  bisection with the Pallas counter as the inner op.
* ``segmented_kth_smallest_np`` — numpy packed-sort reference (tests and
  the host construction engine share this formulation).

All three are asserted equal in ``tests/test_kernels.py``.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .contracts import ANY_INT, ArraySpec, kernel_contract

DEFAULT_SLOT_BLOCK = 1024
DEFAULT_SEG_BLOCK = 512


# ----------------------------------------------------------------------
# jnp (XLA) path — contiguous-CSR counting, used by the jitted sweep
# ----------------------------------------------------------------------

def count_le_csr(w: jnp.ndarray, thr: jnp.ndarray, seg: jnp.ndarray,
                 vptr: jnp.ndarray) -> jnp.ndarray:
    """int32[n] per-segment count of ``w[i] <= thr[seg[i]]``.

    ``seg`` must be non-decreasing with segments delimited by ``vptr``
    (CSR); the count is then a cumsum + boundary gathers, which XLA CPU
    handles far better than scatter-based ``segment_sum``.
    """
    x = (w <= thr[seg]).astype(jnp.int32)
    s = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(x)])
    return s[vptr[1:]] - s[vptr[:-1]]


def kth_smallest_csr(w: jnp.ndarray, lo: jnp.ndarray, k: int, inf_value: int,
                     steps: int, seg: jnp.ndarray, vptr: jnp.ndarray,
                     count_fn=count_le_csr) -> jnp.ndarray:
    """Per-segment ``max(lo, k-th smallest of w)`` clamped to ``inf_value``.

    Counting bisection over ``[lo, inf_value]``: invariantly the answer is
    in ``[lo, hi]``; ``steps`` must be >= ceil(log2(inf_value + 1)).
    Segments whose k-th smallest is below ``lo`` resolve to ``lo``; segments
    with fewer than k qualifying slots resolve to ``inf_value``.
    """
    hi = jnp.full_like(lo, inf_value)

    def bstep(_, state):
        lo, hi = state
        mid = (lo + hi) // 2
        ge = count_fn(w, mid, seg, vptr) >= k
        new_lo = jnp.where(ge | (lo >= hi), lo, mid + 1)
        new_hi = jnp.where(ge & (lo < hi), mid, hi)
        return new_lo, new_hi

    lo, _ = jax.lax.fori_loop(0, steps, bstep, (lo, hi))
    return jnp.minimum(lo, inf_value)


# ----------------------------------------------------------------------
# Pallas path — one-hot tile histogram (kcore_peel idiom)
# ----------------------------------------------------------------------

def _count_le_kernel(seg_ref, w_ref, thr_ref, out_ref):
    sb = pl.program_id(0)                      # slot-block index (accumulated)
    gb = pl.program_id(1)                      # segment-block index
    base = gb * out_ref.shape[0]
    seg = seg_ref[...]
    w = w_ref[...]
    thr = thr_ref[...]                         # this segment block's thresholds
    gids = base + jax.lax.broadcasted_iota(
        jnp.int32, (seg.shape[0], out_ref.shape[0]), 1)
    hit = (seg[:, None] == gids) & (w[:, None] <= thr[None, :])
    part = jnp.sum(hit.astype(jnp.int32), axis=0)

    @pl.when(sb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += part


@kernel_contract(
    in_specs={
        "w": ArraySpec(("E",), ANY_INT),
        "seg": ArraySpec(("E",), ANY_INT),
        "thr": ArraySpec(("n",), ANY_INT),
    },
    out_specs=ArraySpec(("n",), ("int32",)),
    # per step: two slot blocks (seg, w) + threshold block + out block, i32
    vmem_bound=lambda a: 4 * (2 * a["slot_block"] + 2 * a["seg_block"]),
)
def segmented_count_le(w, seg, thr, n: int, *,
                       slot_block: int = DEFAULT_SLOT_BLOCK,
                       seg_block: int = DEFAULT_SEG_BLOCK,
                       interpret: bool = True) -> jnp.ndarray:
    """int32[n] Pallas counterpart of :func:`count_le_csr` (``seg`` need not
    be sorted here — the histogram never assumes contiguity)."""
    e = w.shape[0]
    ep = int(np.ceil(max(e, 1) / slot_block)) * slot_block
    npad = int(np.ceil(max(n, 1) / seg_block)) * seg_block
    seg_p = jnp.pad(seg.astype(jnp.int32), (0, ep - e), constant_values=-1)
    w_p = jnp.pad(w.astype(jnp.int32), (0, ep - e))
    thr_p = jnp.pad(thr.astype(jnp.int32), (0, npad - n))
    out = pl.pallas_call(
        _count_le_kernel,
        grid=(ep // slot_block, npad // seg_block),
        in_specs=[
            pl.BlockSpec((slot_block,), lambda s, g: (s,)),
            pl.BlockSpec((slot_block,), lambda s, g: (s,)),
            pl.BlockSpec((seg_block,), lambda s, g: (g,)),
        ],
        out_specs=pl.BlockSpec((seg_block,), lambda s, g: (g,)),
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.int32),
        interpret=interpret,
    )(seg_p, w_p, thr_p)
    return out[:n]


@kernel_contract(
    in_specs={
        "w": ArraySpec(("E",), ANY_INT),
        "seg": ArraySpec(("E",), ANY_INT),
        "lo": ArraySpec(("n",), ANY_INT),
    },
    out_specs=ArraySpec(("n",), ("int32",)),
    # the inner segmented_count_le carries the per-step VMEM bound
)
def kth_smallest_pallas(w, seg, n: int, k: int, inf_value: int, *,
                        lo=None, interpret: bool = True) -> jnp.ndarray:
    """Per-segment clamped k-th smallest with the Pallas counter as the
    bisection inner op. Host-driven bisection loop (one kernel per step)."""
    lo = jnp.zeros(n, jnp.int32) if lo is None else lo.astype(jnp.int32)
    hi = jnp.full(n, inf_value, jnp.int32)
    steps = int(np.ceil(np.log2(inf_value + 1))) + 1 if inf_value > 0 else 1
    for _ in range(steps):
        mid = (lo + hi) // 2
        ge = segmented_count_le(w, seg, mid, n, interpret=interpret) >= k
        lo = jnp.where(ge | (lo >= hi), lo, mid + 1)
        hi = jnp.where(ge & (lo < hi), mid, hi)
    return jnp.minimum(lo, inf_value)


# ----------------------------------------------------------------------
# numpy reference
# ----------------------------------------------------------------------

def segmented_kth_smallest_np(w: np.ndarray, vptr: np.ndarray, k: int,
                              inf_value: int,
                              lo: np.ndarray | None = None) -> np.ndarray:
    """Reference: per-segment ``max(lo, k-th smallest)`` clamped to
    ``inf_value`` (segments are ``w[vptr[i]:vptr[i+1]]``)."""
    n = vptr.shape[0] - 1
    out = np.full(n, inf_value, np.int64)
    for v in range(n):
        segv = np.sort(w[vptr[v]:vptr[v + 1]])
        if segv.shape[0] >= k:
            out[v] = min(int(segv[k - 1]), inf_value)
    if lo is not None:
        out = np.maximum(out, lo)
    return np.minimum(out, inf_value)
