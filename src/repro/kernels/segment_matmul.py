"""Pallas kernels: blocked MXU matmul and segment-sum as one-hot GEMM.

``matmul`` — the classic tiled GEMM: grid (M/bm, N/bn, K/bk), A/B tiles in
VMEM, f32 accumulation in the revisited output tile (MXU shapes: tiles are
multiples of 128).

``segment_sum`` — the GNN/EmbeddingBag scatter-reduce, TPU-style: instead of
atomics, each edge block builds the one-hot matrix of its segment ids
against the current segment block and contracts it with the value rows on
the MXU:

    out[s, :] += sum_i [ids_i == s] * vals[i, :]    (bs x bm @ bm x d)

Grid (m/bm, S/bs); the output tile is revisited across edge blocks.
This is the fused gather->GEMM->scatter pattern of GE-SpMM/FusedMM mapped
onto the systolic array (kernel_taxonomy §GNN).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .contracts import ANY_FLOAT, ANY_INT, ArraySpec, kernel_contract


def _matmul_kernel(a_ref, b_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)


@kernel_contract(
    in_specs={
        "a": ArraySpec(("M", "K"), ANY_FLOAT),
        "b": ArraySpec(("K", "N"), ANY_FLOAT),
    },
    out_specs=ArraySpec(("M", "N"), ("float32",)),
    # per step: A tile + B tile + f32 accumulator tile
    vmem_bound=lambda v: 4 * (v["bm"] * v["bk"] + v["bk"] * v["bn"]
                              + v["bm"] * v["bn"]),
)
def matmul(a, b, *, bm: int = 128, bn: int = 128, bk: int = 128,
           interpret: bool = True):
    """f32[M, N] = a @ b with (bm, bn, bk) VMEM tiles; pads to multiples."""
    M, K = a.shape
    K2, N = b.shape
    if K != K2:
        raise ValueError(f"inner dims disagree: a is (?, {K}), b is ({K2}, ?)")
    Mp, Kp, Np = (int(np.ceil(M / bm)) * bm, int(np.ceil(K / bk)) * bk,
                  int(np.ceil(N / bn)) * bn)
    a_p = jnp.pad(a, ((0, Mp - M), (0, Kp - K)))
    b_p = jnp.pad(b, ((0, Kp - K), (0, Np - N)))
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(Mp // bm, Np // bn, Kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        interpret=interpret,
    )(a_p, b_p)
    return out[:M, :N]


def _segsum_kernel(ids_ref, vals_ref, out_ref):
    eb = pl.program_id(0)
    sb = pl.program_id(1)
    bs = out_ref.shape[0]
    base = sb * bs
    ids = ids_ref[...]
    vals = vals_ref[...]
    seg = base + jax.lax.broadcasted_iota(jnp.int32, (bs, ids.shape[0]), 0)
    onehot = (seg == ids[None, :]).astype(vals.dtype)       # (bs, bm)

    @pl.when(eb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(onehot, vals, preferred_element_type=jnp.float32)


@kernel_contract(
    in_specs={
        "vals": ArraySpec(("E", "D"), ANY_FLOAT),
        "ids": ArraySpec(("E",), ANY_INT),
    },
    out_specs=ArraySpec(("num_segments", "D"), ("float32",)),
    # per step: id block + value rows + f32 output tile (d = row width)
    vmem_bound=lambda a: 4 * (a["bm"] + (a["bm"] + a["bs"])
                              * a["vals"].shape[1]),
)
def segment_sum(vals, ids, num_segments: int, *, bm: int = 512, bs: int = 256,
                interpret: bool = True):
    """f32[num_segments, d] scatter-add of rows by id, via one-hot GEMM."""
    m, d = vals.shape
    mp = int(np.ceil(max(m, 1) / bm)) * bm
    sp = int(np.ceil(max(num_segments, 1) / bs)) * bs
    vals_p = jnp.pad(vals.astype(jnp.float32), ((0, mp - m), (0, 0)))
    ids_p = jnp.pad(ids.astype(jnp.int32), (0, mp - m), constant_values=-1)
    out = pl.pallas_call(
        _segsum_kernel,
        grid=(mp // bm, sp // bs),
        in_specs=[
            pl.BlockSpec((bm,), lambda e, s: (e,)),
            pl.BlockSpec((bm, d), lambda e, s: (e, 0)),
        ],
        out_specs=pl.BlockSpec((bs, d), lambda e, s: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((sp, d), jnp.float32),
        interpret=interpret,
    )(ids_p, vals_p)
    return out[:num_segments]


def embedding_bag(table, ids, weights=None, *, interpret: bool = True):
    """(bags, k) -> (bags, d): gather + weighted within-bag sum.

    The gather stays an XLA gather (TPUs do this well); the bag reduction is
    a tiny einsum. Provided for API parity with the torch EmbeddingBag and
    reused by the recsys path; the heavy lifting for *scatter* bags goes
    through :func:`segment_sum`.
    """
    emb = jnp.take(table, ids, axis=0)
    if weights is not None:
        emb = emb * weights[..., None]
    return emb.sum(axis=1)
