"""Atomic, async, versioned checkpoints with elastic-reshard restore.

Design for 1000+ node operation:

* **Atomicity** — a checkpoint is written to ``step_<n>.tmp-<pid>`` and
  ``os.rename``d into place; a crash mid-write can never corrupt the latest
  good checkpoint. A ``latest`` pointer file is rewritten last (also via
  rename), so restart discovery is a single read.
* **Async** — ``save_async`` snapshots the (host-transferred) pytree and
  hands serialization to a worker thread; the train loop blocks only for
  device->host. ``wait()`` joins before the next save to bound queue depth.
* **Elastic restore** — arrays are stored *unsharded* (host layout) plus a
  manifest of logical partition specs. ``restore`` re-shards onto whatever
  mesh the restarted job has (different device count included): the specs
  are re-resolved against the new mesh, so a 512-chip checkpoint restores
  onto 256 or 1024 chips unchanged.
* **Versioning / retention** — monotone step numbers; ``keep`` most recent
  checkpoints survive garbage collection.
* **Integrity** — every array blob carries a crc32; restore verifies.

The atomic tmp-rename write and the crc32 blob envelope are the shared
:mod:`repro.store.blobio` primitives — one durable-write idiom for both
checkpoints and the persistent index store (DESIGN.md §13.1).
"""

from __future__ import annotations

import os
import pickle
import threading
import time

import jax
import numpy as np

from repro.obs.locks import named_lock
from repro.store.blobio import array_blob, atomic_write, blob_array


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        # guards the worker slot + last error; the join itself happens
        # outside the lock so a slow disk write never blocks other callers
        # on the mutex (DESIGN.md §12.2: "checkpoint" is the innermost
        # hierarchy level)
        self._lock = named_lock("checkpoint")
        self._thread: threading.Thread | None = None
        self._last_error: Exception | None = None

    # -- save ------------------------------------------------------------
    def _serialize(self, step: int, host_tree, meta: dict):
        tmp = os.path.join(self.dir, f"step_{step:010d}.tmp-{os.getpid()}")
        final = os.path.join(self.dir, f"step_{step:010d}.ckpt")
        leaves, treedef = jax.tree_util.tree_flatten(host_tree)
        blobs = [array_blob(np.asarray(leaf)) for leaf in leaves]
        payload = {"step": step, "treedef": pickle.dumps(treedef),
                   "meta": meta, "blobs": blobs, "written_at": time.time()}
        atomic_write(final,
                     pickle.dumps(payload, protocol=pickle.HIGHEST_PROTOCOL),
                     tmp=tmp)
        # 'latest' pointer, atomically; a lost pointer only costs discovery,
        # so no fsync on it (matching the segment store's pointer policy)
        atomic_write(os.path.join(self.dir, "latest"),
                     os.path.basename(final).encode(),
                     tmp=os.path.join(self.dir, f".latest.tmp-{os.getpid()}"),
                     fsync=False)
        self._gc()

    def _gc(self):
        ckpts = sorted(p for p in os.listdir(self.dir) if p.endswith(".ckpt"))
        for stale in ckpts[: -self.keep] if self.keep else []:
            try:
                os.remove(os.path.join(self.dir, stale))
            except OSError:
                pass

    def save(self, step: int, tree, meta: dict | None = None):
        """Synchronous save (used at job end and in tests)."""
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._serialize(step, host, meta or {})

    def save_async(self, step: int, tree, meta: dict | None = None):
        """Device->host now; disk write on a worker thread."""
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                self._serialize(step, host, meta or {})
            except Exception as e:  # surfaced on next wait()
                with self._lock:
                    self._last_error = e

        t = threading.Thread(target=work, daemon=True, name="checkpoint-save")
        with self._lock:
            self._thread = t
        t.start()

    def wait(self):
        with self._lock:
            t, self._thread = self._thread, None
        if t is not None:
            t.join()
        with self._lock:
            err, self._last_error = self._last_error, None
        if err is not None:
            raise err

    # -- restore -----------------------------------------------------------
    def latest_step(self) -> int | None:
        ptr = os.path.join(self.dir, "latest")
        if not os.path.exists(ptr):
            return None
        with open(ptr) as f:
            name = f.read().strip()
        if not os.path.exists(os.path.join(self.dir, name)):
            return None
        return int(name.split("_")[1].split(".")[0])

    def restore(self, step: int | None = None, shardings=None):
        """Returns (step, tree, meta). ``shardings``: optional pytree of
        NamedSharding (same structure) to place arrays onto a (possibly
        different) mesh — the elastic-reshard path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:010d}.ckpt")
        with open(path, "rb") as f:
            payload = pickle.load(f)
        treedef = pickle.loads(payload["treedef"])
        leaves = [blob_array(blob, label=f"checkpoint {path}")
                  for blob in payload["blobs"]]
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return payload["step"], tree, payload["meta"]
