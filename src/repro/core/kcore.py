"""K-core computation: numpy peeling oracle + window/TCCS brute force.

These are the ground-truth routines every index in the repo is tested
against. They are deliberately simple; the fast paths live in
``core_time.py`` (host build plane) and ``batch_query.py`` / ``kernels``
(device query plane).
"""

from __future__ import annotations

import numpy as np

from .temporal_graph import TemporalGraph


def kcore_edge_mask(src: np.ndarray, dst: np.ndarray, n: int, k: int,
                    active: np.ndarray | None = None) -> np.ndarray:
    """Boolean mask over edges that survive in the k-core of the (multi)graph.

    Iterative peeling as a fixpoint: drop every edge incident to a vertex of
    degree < k; repeat. Matches Definition 2.2 (connectivity ignored).
    Parallel edges each count toward degree (consistent with projecting a
    temporal multigraph, as in the paper's Figure 1 examples).
    """
    m = src.shape[0]
    alive = np.ones(m, bool) if active is None else active.copy()
    while True:
        deg = np.bincount(src[alive], minlength=n) + np.bincount(dst[alive], minlength=n)
        vk = deg >= k
        new_alive = alive & vk[src] & vk[dst]
        if new_alive.sum() == alive.sum():
            return new_alive
        alive = new_alive


def distinct_kcore_edge_mask(src: np.ndarray, dst: np.ndarray, n: int, k: int) -> np.ndarray:
    """Like :func:`kcore_edge_mask` but with the paper's semantics: degree =
    number of *distinct* neighbours ("at least k neighbors", Def 2.1/2.2).
    Parallel temporal edges are collapsed for peeling and the surviving mask
    is broadcast back to every parallel copy."""
    if src.size == 0:
        return np.zeros(0, bool)
    key = np.minimum(src, dst).astype(np.int64) * n + np.maximum(src, dst)
    uniq, inv = np.unique(key, return_inverse=True)
    us = (uniq // n).astype(np.int64)
    ud = (uniq % n).astype(np.int64)
    return kcore_edge_mask(us, ud, n, k)[inv]


def temporal_kcore_edges(g: TemporalGraph, k: int, ts: int, te: int) -> np.ndarray:
    """Edge ids (into g) of the temporal k-core of window [ts, te]."""
    s, d, ids = g.project(ts, te)
    alive = distinct_kcore_edge_mask(s, d, g.n, k)
    return ids[alive]


def connected_component(src: np.ndarray, dst: np.ndarray, n: int, u: int) -> np.ndarray:
    """Vertices reachable from u over the given edges (u included iff it has
    an incident edge or stands alone)."""
    parent = np.arange(n, dtype=np.int64)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for a, b in zip(src.tolist(), dst.tolist()):
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[ra] = rb
    ru = find(u)
    roots = np.fromiter((find(i) for i in range(n)), dtype=np.int64, count=n)
    return np.nonzero(roots == ru)[0]


def tccs_oracle(g: TemporalGraph, k: int, u: int, ts: int, te: int) -> set[int]:
    """Brute-force TCCS: the k-core component of u in G_[ts,te].

    Returns the empty set when u is not in the temporal k-core (the paper's
    query semantics: the component containing u, which does not exist then).
    """
    ids = temporal_kcore_edges(g, k, ts, te)
    if ids.size == 0:
        return set()
    s, d = g.src[ids], g.dst[ids]
    touched = np.zeros(g.n, bool)
    touched[s] = True
    touched[d] = True
    if not touched[u]:
        return set()
    comp = connected_component(s, d, g.n, u)
    return set(int(v) for v in comp if touched[v])


def tccs_oracle_edges(g: TemporalGraph, k: int, u: int, ts: int, te: int) -> set[int]:
    """Brute-force member edges of u's k-core component in G_[ts,te]:
    edge ids (into g) of the temporal k-core edges with an endpoint in the
    component (components partition core edges, so one endpoint in implies
    both). Ground truth for the v2 EDGES/SUBGRAPH result modes."""
    ids = temporal_kcore_edges(g, k, ts, te)
    if ids.size == 0:
        return set()
    s, d = g.src[ids], g.dst[ids]
    touched = np.zeros(g.n, bool)
    touched[s] = True
    touched[d] = True
    if not touched[u]:
        return set()
    comp = connected_component(s, d, g.n, u)
    in_comp = np.zeros(g.n, bool)
    in_comp[comp] = True
    return set(int(e) for e in ids[in_comp[s]])


def k_max(g: TemporalGraph) -> int:
    """Largest k with a non-empty k-core of the full window (paper Table 3)."""
    s, d = g.src, g.dst
    lo, hi = 1, 1
    while distinct_kcore_edge_mask(s, d, g.n, hi).any():
        lo, hi = hi, hi * 2
    # binary search in (lo, hi]
    while lo + 1 < hi:
        mid = (lo + hi) // 2
        if distinct_kcore_edge_mask(s, d, g.n, mid).any():
            lo = mid
        else:
            hi = mid
    return lo
