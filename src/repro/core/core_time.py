"""Edge core times for all start times (paper §5, Def 4.3).

``CT(e)_ts`` = earliest end time ``te`` such that edge ``e`` is in the k-core
of ``[ts, te]``; ``INF`` (= ``t_max + 1``) when no such ``te`` exists (in
particular whenever ``t(e) < ts``).

Instead of the sequential decremental maintenance of Yu et al. [33], we use a
data-parallel *least-fixpoint* formulation (our TPU-facing adaptation, see
DESIGN.md §3):

    c_v = k-th smallest over distinct neighbours u of  max(t_uv, c_u)
          (t_uv = earliest timestamp >= ts among parallel (u,v) edges),
    c_v = INF when v has < k distinct neighbours in [ts, t_max].

Iterating this monotone operator from the lower bound ``c0_v`` = k-th
smallest ``t_uv`` converges to the least fixpoint, which equals the true
vertex core times: for any fixpoint c* and any te, S = {v : c*_v <= te}
induces a subgraph of G_[ts,te] with min degree >= k, so S is inside the true
k-core (hence true <= c*); Kleene iteration from below yields the least
fixpoint (hence <= true). Edge core times follow as
``CT(e)_ts = max(t_e, c_u, c_v)`` (§5: "the larger one among the core times
of its terminal vertices", plus window membership t_e >= ts).

Start times are processed ascending with warm starts: c_{ts} is a valid lower
bound for c_{ts+1} because shrinking the window only raises core times.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .temporal_graph import TemporalGraph


def _simple_projection(g: TemporalGraph, ts: int):
    """Doubled (directed) simple-graph arrays for window [ts, t_max]:
    per (v, u) distinct pair the earliest timestamp >= ts."""
    keep = g.t >= ts
    s, d, t = g.src[keep], g.dst[keep], g.t[keep]
    src_d = np.concatenate([s, d]).astype(np.int64)
    dst_d = np.concatenate([d, s]).astype(np.int64)
    t_d = np.concatenate([t, t]).astype(np.int64)
    # group by (src, dst), keep min t
    key = src_d * g.n + dst_d
    order = np.lexsort((t_d, key))
    key, t_d = key[order], t_d[order]
    first = np.ones(key.shape[0], bool)
    first[1:] = key[1:] != key[:-1]
    key, t_d = key[first], t_d[first]
    return (key // g.n).astype(np.int64), (key % g.n).astype(np.int64), t_d


def vertex_core_times(g: TemporalGraph, k: int, ts: int,
                      warm: np.ndarray | None = None) -> np.ndarray:
    """int64[n] vertex core times for start time ts (INF = t_max + 1)."""
    INF = g.t_max + 1
    src_d, dst_d, t_d = _simple_projection(g, ts)
    n = g.n
    deg = np.bincount(src_d, minlength=n)
    offsets = np.zeros(n + 1, np.int64)
    np.cumsum(deg, out=offsets[1:])
    has_k = deg >= k
    sel = offsets[:-1][has_k] + (k - 1)  # index of k-th smallest within segment

    c = np.full(n, INF, np.int64)
    if warm is not None:
        c = np.maximum(warm, np.where(has_k, 0, INF))
        c[~has_k] = INF
    else:
        # lower bound: k-th smallest edge timestamp per vertex
        order = np.lexsort((t_d, src_d))
        c[has_k] = t_d[order[sel]]
    while True:
        w = np.maximum(t_d, c[dst_d])
        order = np.lexsort((w, src_d))
        c_new = np.full(n, INF, np.int64)
        c_new[has_k] = w[order[sel]]
        c_new = np.minimum(c_new, INF)
        if np.array_equal(c_new, c):
            return c
        c = c_new


@dataclasses.dataclass(frozen=True)
class CoreTimeTable:
    """Compressed core times for all start times (paper Table 1 layout).

    Version records, sorted by (edge_id, ts_from): edge ``edge_id`` has core
    time ``ct`` for every start time in ``[ts_from, ts_to]`` (inclusive);
    ``ts_to`` is the paper's ``lst``. Only finite-CT versions are stored.
    """

    n: int
    m: int
    t_max: int
    edge_id: np.ndarray   # int64[R]
    ts_from: np.ndarray   # int64[R]
    ts_to: np.ndarray     # int64[R]  (lst)
    ct: np.ndarray        # int64[R]
    vertex_ct: np.ndarray  # int64[t_max + 1, n]; row ts = vertex core times

    @property
    def INF(self) -> int:
        return self.t_max + 1

    @property
    def num_versions(self) -> int:
        return int(self.edge_id.shape[0])

    def nbytes(self) -> int:
        """Index-size accounting for the compressed core-time table alone
        (4 int32 words per version record)."""
        return self.num_versions * 16

    def ct_at(self, edge: int, ts: int) -> int:
        """CT(edge)_ts by scanning this edge's versions (test helper)."""
        sel = (self.edge_id == edge) & (self.ts_from <= ts) & (ts <= self.ts_to)
        idx = np.nonzero(sel)[0]
        return int(self.ct[idx[0]]) if idx.size else self.INF


def edge_core_times(g: TemporalGraph, k: int) -> CoreTimeTable:
    """Compute CT(e)_ts for every edge and start time, delta-compressed."""
    t_max = g.t_max
    INF = t_max + 1
    m = g.m
    su, sv, st = g.src.astype(np.int64), g.dst.astype(np.int64), g.t.astype(np.int64)

    cur = np.full(m, -1, np.int64)          # current CT per edge (-1 = unseen)
    open_from = np.zeros(m, np.int64)       # ts at which `cur` became valid
    recs_e, recs_a, recs_b, recs_c = [], [], [], []
    vct = np.full((t_max + 2, g.n), INF, np.int64)

    warm = None
    for ts in range(1, t_max + 1):
        c = vertex_core_times(g, k, ts, warm=warm)
        warm = c
        vct[ts] = c
        ct_ts = np.maximum(st, np.maximum(c[su], c[sv]))
        ct_ts = np.where(st >= ts, ct_ts, INF)
        ct_ts = np.minimum(ct_ts, INF)
        changed = ct_ts != cur
        if changed.any():
            idx = np.nonzero(changed)[0]
            closing = idx[cur[idx] >= 0]
            # close versions whose CT was finite
            fin = closing[cur[closing] < INF]
            if fin.size:
                recs_e.append(fin)
                recs_a.append(open_from[fin])
                recs_b.append(np.full(fin.size, ts - 1, np.int64))
                recs_c.append(cur[fin])
            cur[idx] = ct_ts[idx]
            open_from[idx] = ts
    # close the tail versions
    tail = np.nonzero((cur >= 0) & (cur < INF))[0]
    if tail.size:
        recs_e.append(tail)
        recs_a.append(open_from[tail])
        recs_b.append(np.full(tail.size, t_max, np.int64))
        recs_c.append(cur[tail])

    if recs_e:
        edge_id = np.concatenate(recs_e)
        ts_from = np.concatenate(recs_a)
        ts_to = np.concatenate(recs_b)
        ct = np.concatenate(recs_c)
        order = np.lexsort((ts_from, edge_id))
        edge_id, ts_from, ts_to, ct = edge_id[order], ts_from[order], ts_to[order], ct[order]
    else:
        edge_id = ts_from = ts_to = ct = np.zeros(0, np.int64)
    return CoreTimeTable(g.n, m, t_max, edge_id, ts_from, ts_to, ct, vct[: t_max + 1])


# ----------------------------------------------------------------------
# Brute-force oracle (tests only): CT by scanning te for each (ts, e).
# ----------------------------------------------------------------------

def edge_core_time_naive(g: TemporalGraph, k: int, ts: int) -> np.ndarray:
    """int64[m] CT(e)_ts by recomputing the k-core for every te."""
    from .kcore import kcore_edge_mask

    INF = g.t_max + 1
    out = np.full(g.m, INF, np.int64)
    for te in range(ts, g.t_max + 1):
        s, d, ids = g.project(ts, te)
        if ids.size == 0:
            continue
        # distinct-neighbour degrees: collapse parallel edges for peeling
        key = np.minimum(s, d).astype(np.int64) * g.n + np.maximum(s, d)
        uniq, inv = np.unique(key, return_inverse=True)
        us, ud = (uniq // g.n).astype(np.int64), (uniq % g.n).astype(np.int64)
        alive_simple = kcore_edge_mask(us, ud, g.n, k)
        alive = alive_simple[inv]
        newly = ids[alive]
        out[newly] = np.minimum(out[newly], te)
    return out
