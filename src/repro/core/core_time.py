"""Edge core times for all start times (paper §5, Def 4.3).

``CT(e)_ts`` = earliest end time ``te`` such that edge ``e`` is in the k-core
of ``[ts, te]``; ``INF`` (= ``t_max + 1``) when no such ``te`` exists (in
particular whenever ``t(e) < ts``).

Instead of the sequential decremental maintenance of Yu et al. [33], we use a
data-parallel *least-fixpoint* formulation (our TPU-facing adaptation, see
DESIGN.md §3):

    c_v = k-th smallest over distinct neighbours u of  max(t_uv, c_u)
          (t_uv = earliest timestamp >= ts among parallel (u,v) edges),
    c_v = INF when v has < k distinct neighbours in [ts, t_max].

Iterating this monotone operator from a lower bound converges to the least
fixpoint, which equals the true vertex core times: for any fixpoint c* and
any te, S = {v : c*_v <= te} induces a subgraph of G_[ts,te] with min degree
>= k, so S is inside the true k-core (hence true <= c*); Kleene iteration
from below yields the least fixpoint (hence <= true). We iterate the
*clamped* operator ``c <- max(c, kth(w))``: iterates are then monotone, stay
below the least fixpoint, and a converged point is simultaneously a pre- and
post-fixpoint, hence the least fixpoint itself. Edge core times follow as
``CT(e)_ts = max(t_e, c_u, c_v)`` (§5: "the larger one among the core times
of its terminal vertices", plus window membership t_e >= ts).

Construction plane (PR 2): the per-start-time projection + lexsort loop of
the seed became the *batched sweep* engines below. All engines share one
precomputed structure (`_PairCSR` + blockwise `_tuv_rows` of per-pair
earliest timestamps >= ts) and one inner op (segmented k-th-smallest
selection, `kernels/segmented_select.py`), and run the sweep ts = 1..t_max
with warm-started lower bounds (c_{ts-1} <= c_ts because shrinking the
window only raises core times):

* ``engine="host"`` — vectorized numpy sweep: per iteration one in-place
  packed sort (segment-id packed into the key's high bits) gives both the
  fixpoint *verification* (a searchsorted rank probe: c is converged iff
  count(w <= c_v) >= k) and, when not converged, the k-th smallest climb.
* ``engine="jax"`` — one jitted launch sweeps a whole block of start times
  (`lax.scan` over ts, warm carry across blocks); the inner op is the
  counting-bisection segmented select, with a `lax.cond`-gated climb so
  converged start times pay a single verification pass. This is the
  device-plane path (Pallas counter selectable via ``use_pallas``).
* ``engine="legacy"`` — the seed's per-ts numpy lexsort loop, kept as the
  differential-testing oracle and the PR-1 benchmark baseline.

All engines produce bit-identical ``CoreTimeTable``s (the least fixpoint is
unique; tests assert array equality), delta-compressed by the shared
vectorized run-length `_compress`.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .temporal_graph import TemporalGraph


# ----------------------------------------------------------------------
# Shared precomputed structure: directed distinct-pair CSR + t_uv table
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _PairCSR:
    """Doubled (directed) distinct-pair CSR over *all* edges, pairs sorted
    by (src, dst), per-pair timestamps ascending. Built once per (g,)."""

    src: np.ndarray      # int32[E] pair source, non-decreasing
    dst: np.ndarray      # int32[E]
    ptr: np.ndarray      # int64[E+1] pair -> slots in tsorted
    tsorted: np.ndarray  # int32[2m] per-pair ascending timestamps
    vptr: np.ndarray     # int64[n+1] vertex -> pair rows (CSR over src)
    pidx: np.ndarray     # int64[2m] slot -> pair (inverse of ptr)


def _pair_csr(g: TemporalGraph) -> _PairCSR:
    n = g.n
    s = np.concatenate([g.src, g.dst]).astype(np.int64)
    d = np.concatenate([g.dst, g.src]).astype(np.int64)
    t = np.concatenate([g.t, g.t]).astype(np.int64)
    key = s * n + d
    order = np.lexsort((t, key))
    key, t = key[order], t[order]
    first = np.ones(key.shape[0], bool)
    first[1:] = key[1:] != key[:-1]
    starts = np.flatnonzero(first)
    ptr = np.concatenate([starts, [key.shape[0]]]).astype(np.int64)
    pkey = key[first]
    src = (pkey // n).astype(np.int32)
    vptr = np.zeros(n + 1, np.int64)
    np.cumsum(np.bincount(src, minlength=n), out=vptr[1:])
    pidx = np.repeat(np.arange(ptr.shape[0] - 1), np.diff(ptr))
    return _PairCSR(src, (pkey % n).astype(np.int32), ptr,
                    t.astype(np.int32), vptr, pidx)


#: ts rows materialized per t_uv block: bounds sweep scratch at O(BLOCK * E)
TUV_BLOCK = 256


def _tuv_rows(csr: _PairCSR, ts0: int, ts1: int, t_max: int) -> np.ndarray:
    """int32[ts1-ts0, E]: row i = earliest pair timestamp >= ts0+i (INF when
    none). Blocked so the sweep never holds the full (t_max, E) table: a
    global searchsorted seeds row ts1, block-local events + one reverse
    running-min fill the rest."""
    E = csr.ptr.shape[0] - 1
    inf = t_max + 1
    # stored descending (row i = ts1 - i) so the running min walks forward
    # over contiguous memory; the caller gets an ascending reversed view
    rev = np.full((ts1 - ts0 + 1, E), inf, np.int32)
    if E == 0:
        return rev[1:]
    # seed (row 0): earliest timestamp >= ts1 per pair. tsorted is sorted
    # by (pair, t), so pair*stride + t is globally sorted and one
    # searchsorted answers every pair at once.
    stride = np.int64(t_max + 2)
    packed = csr.pidx * stride + csr.tsorted
    pos = np.searchsorted(packed, np.arange(E, dtype=np.int64) * stride + ts1)
    valid = pos < csr.ptr[1:]
    rev[0, valid] = csr.tsorted[pos[valid]]
    # events inside [ts0, ts1), then running min toward ts0
    ev = (csr.tsorted >= ts0) & (csr.tsorted < ts1)
    rev[ts1 - csr.tsorted[ev], csr.pidx[ev]] = csr.tsorted[ev]
    np.minimum.accumulate(rev, axis=0, out=rev)
    return rev[1:][::-1]


# ----------------------------------------------------------------------
# Legacy per-ts fixpoint (seed implementation; oracle + PR-1 baseline)
# ----------------------------------------------------------------------

def _simple_projection(g: TemporalGraph, ts: int):
    """Doubled (directed) simple-graph arrays for window [ts, t_max]:
    per (v, u) distinct pair the earliest timestamp >= ts."""
    keep = g.t >= ts
    s, d, t = g.src[keep], g.dst[keep], g.t[keep]
    src_d = np.concatenate([s, d]).astype(np.int64)
    dst_d = np.concatenate([d, s]).astype(np.int64)
    t_d = np.concatenate([t, t]).astype(np.int64)
    # group by (src, dst), keep min t
    key = src_d * g.n + dst_d
    order = np.lexsort((t_d, key))
    key, t_d = key[order], t_d[order]
    first = np.ones(key.shape[0], bool)
    first[1:] = key[1:] != key[:-1]
    key, t_d = key[first], t_d[first]
    return (key // g.n).astype(np.int64), (key % g.n).astype(np.int64), t_d


def vertex_core_times(g: TemporalGraph, k: int, ts: int,
                      warm: np.ndarray | None = None) -> np.ndarray:
    """int64[n] vertex core times for start time ts (INF = t_max + 1).

    The seed's per-ts numpy lexsort fixpoint, kept verbatim: the batched
    engines are asserted bit-identical against it."""
    INF = g.t_max + 1
    src_d, dst_d, t_d = _simple_projection(g, ts)
    n = g.n
    deg = np.bincount(src_d, minlength=n)
    offsets = np.zeros(n + 1, np.int64)
    np.cumsum(deg, out=offsets[1:])
    has_k = deg >= k
    sel = offsets[:-1][has_k] + (k - 1)  # index of k-th smallest within segment

    c = np.full(n, INF, np.int64)
    if warm is not None:
        c = np.maximum(warm, np.where(has_k, 0, INF))
        c[~has_k] = INF
    else:
        # lower bound: k-th smallest edge timestamp per vertex
        order = np.lexsort((t_d, src_d))
        c[has_k] = t_d[order[sel]]
    while True:
        w = np.maximum(t_d, c[dst_d])
        order = np.lexsort((w, src_d))
        c_new = np.full(n, INF, np.int64)
        c_new[has_k] = w[order[sel]]
        c_new = np.minimum(c_new, INF)
        if np.array_equal(c_new, c):
            return c
        c = c_new


# ----------------------------------------------------------------------
# Compressed table
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CoreTimeTable:
    """Compressed core times for all start times (paper Table 1 layout).

    Version records, sorted by (edge_id, ts_from): edge ``edge_id`` has core
    time ``ct`` for every start time in ``[ts_from, ts_to]`` (inclusive);
    ``ts_to`` is the paper's ``lst``. Only finite-CT versions are stored.
    All values are bounded by ``max(t_max + 1, m)``, so records are stored
    int32; ``nbytes`` is the paper's index-size metric and sums the actual
    bytes of the stored version arrays (mirroring ``PECBIndex.nbytes``).
    """

    n: int
    m: int
    t_max: int
    edge_id: np.ndarray   # int32[R]
    ts_from: np.ndarray   # int32[R]
    ts_to: np.ndarray     # int32[R]  (lst)
    ct: np.ndarray        # int32[R]
    vertex_ct: np.ndarray  # int32[t_max + 1, n]; row ts = vertex core times

    @property
    def INF(self) -> int:
        return self.t_max + 1

    @property
    def num_versions(self) -> int:
        return int(self.edge_id.shape[0])

    def nbytes(self) -> int:
        """True byte size of the stored version arrays (the compressed
        core-time table alone, excluding the dense vertex_ct matrix)."""
        return int(self.edge_id.nbytes + self.ts_from.nbytes
                   + self.ts_to.nbytes + self.ct.nbytes)

    def ct_at(self, edge: int, ts: int) -> int:
        """CT(edge)_ts by scanning this edge's versions (test helper)."""
        sel = (self.edge_id == edge) & (self.ts_from <= ts) & (ts <= self.ts_to)
        idx = np.nonzero(sel)[0]
        return int(self.ct[idx[0]]) if idx.size else self.INF


def _as_table(g: TemporalGraph, edge_id, ts_from, ts_to, ct,
              vct) -> CoreTimeTable:
    i32 = lambda a: np.ascontiguousarray(a, np.int32)
    return CoreTimeTable(g.n, g.m, g.t_max, i32(edge_id), i32(ts_from),
                         i32(ts_to), i32(ct), i32(vct))


# ----------------------------------------------------------------------
# Vectorized delta-compression (shared by every engine)
# ----------------------------------------------------------------------

def _compress(g: TemporalGraph, vct: np.ndarray,
              edge_chunk: int = 8192) -> CoreTimeTable:
    """Version records from the dense (t_max+1, n) vertex-core-time matrix.

    Per edge, CT rows over ts form maximal constant runs; finite runs are
    the stored versions. Edge-major run detection keeps the output exactly
    in the legacy path's (edge_id, ts_from) lexsort order. Chunked over
    edges to bound the (chunk, T) scratch. (The streaming plane's
    `extend_core_times` does not recompress full rows: it keeps old records
    verbatim and run-detects only the per-vertex flip intervals.)"""
    t_max, m = g.t_max, g.m
    inf = t_max + 1
    if t_max == 0 or m == 0:
        z = np.zeros(0, np.int32)
        return _as_table(g, z, z, z, z, vct)
    ts_row = np.arange(1, t_max + 1, dtype=np.int32)[None, :]
    vct_t = np.ascontiguousarray(vct[1:].T)               # (n, T) row-major
    recs = []
    for lo in range(0, m, edge_chunk):
        hi = min(lo + edge_chunk, m)
        su = g.src[lo:hi].astype(np.int64)
        sv = g.dst[lo:hi].astype(np.int64)
        st = g.t[lo:hi].astype(np.int32)
        ctm = np.maximum(vct_t[su], vct_t[sv])            # (B, T) edge-major
        np.maximum(ctm, st[:, None], out=ctm)
        np.minimum(ctm, inf, out=ctm)
        ctm[ts_row > st[:, None]] = inf                   # edge outside window
        flat = ctm.reshape(-1)
        start = np.empty(flat.shape[0], bool)
        start[0] = True
        np.not_equal(flat[1:], flat[:-1], out=start[1:])
        start[::t_max] = True                             # runs never span edges
        sidx = np.flatnonzero(start)
        vals = flat[sidx]
        nxt = np.empty_like(sidx)
        nxt[:-1] = sidx[1:]
        nxt[-1] = flat.shape[0]
        keep = vals < inf
        sidx, nxt, vals = sidx[keep], nxt[keep], vals[keep]
        recs.append((sidx // t_max + lo, sidx % t_max + 1,
                     (nxt - 1) % t_max + 1, vals))
    edge_id = np.concatenate([r[0] for r in recs])
    ts_from = np.concatenate([r[1] for r in recs])
    ts_to = np.concatenate([r[2] for r in recs])
    ct = np.concatenate([r[3] for r in recs])
    return _as_table(g, edge_id, ts_from, ts_to, ct, vct)


# ----------------------------------------------------------------------
# Host engine: vectorized numpy sweep (default on CPU-only backends)
# ----------------------------------------------------------------------

def _sweep_host(g: TemporalGraph, k: int) -> np.ndarray:
    """(t_max+1, n) int32 vertex core times for every start time.

    Per iteration one in-place sort of segment-packed keys serves both the
    convergence probe (searchsorted rank test) and the k-th-smallest climb;
    warm starts make most start times converge in a single iteration."""
    n, t_max = g.n, g.t_max
    inf = t_max + 1
    vct = np.full((t_max + 1, n), inf, np.int32)
    if g.m == 0 or t_max == 0:
        return vct
    csr = _pair_csr(g)
    deg = np.diff(csr.vptr)
    has_k = deg >= k
    sel = csr.vptr[:-1][has_k] + (k - 1)
    # segment id packed into high bits: one flat sort orders every segment
    S = 1
    while S < inf + 2:
        S *= 2
    kdtype = np.int32 if n * S < 2 ** 31 else np.int64
    base = (csr.src.astype(np.int64) * S).astype(kdtype)
    vbase = (np.arange(n, dtype=np.int64) * S).astype(kdtype)
    pd = csr.dst.astype(np.int64)
    vstart = csr.vptr[:-1]

    c = np.zeros(n, np.int32)
    for ts0 in range(1, t_max + 1, TUV_BLOCK):
        ts1 = min(ts0 + TUV_BLOCK, t_max + 1)
        tuv_rows = _tuv_rows(csr, ts0, ts1, t_max)
        for ts in range(ts0, ts1):
            tuv = tuv_rows[ts - ts0]
            while True:
                w = np.maximum(tuv, c[pd]).astype(kdtype, copy=False)
                key = base + w
                key.sort()
                # count(w <= c_v) per segment: rank probe in the sorted keys
                cnt = np.searchsorted(key, vbase + c + 1) - vstart
                if bool(((cnt >= k) | (c >= inf)).all()):
                    break
                c_new = np.full(n, inf, np.int32)
                c_new[has_k] = (key[sel] & (S - 1)) if kdtype == np.int32 \
                    else key[sel] % S
                np.minimum(c_new, inf, out=c_new)
                np.maximum(c, c_new, out=c)
            vct[ts] = c
    return vct


# ----------------------------------------------------------------------
# JAX engine: jitted multi-start-time sweep (device plane)
# ----------------------------------------------------------------------

def _sweep_jax(g: TemporalGraph, k: int, *, block: int = 512,
               use_pallas: bool = False) -> np.ndarray:
    """Same least fixpoint as `_sweep_host`, as a jitted `lax.scan` over a
    block of start times per launch (warm carry across launches). Each ts
    runs verification + a `lax.cond`-gated counting-bisection climb, so
    already-converged start times cost one segmented count."""
    import functools

    import jax
    import jax.numpy as jnp

    from repro.kernels.segmented_select import (count_le_csr,
                                                kth_smallest_csr,
                                                segmented_count_le)

    n, t_max = g.n, g.t_max
    inf = t_max + 1
    vct = np.full((t_max + 1, n), inf, np.int32)
    if g.m == 0 or t_max == 0:
        return vct
    csr = _pair_csr(g)
    ksteps = int(np.ceil(np.log2(inf + 1))) + 1

    if use_pallas:
        # interpret only where no real Pallas backend exists (CPU testing)
        interpret = jax.default_backend() == "cpu"

        def count_fn(w, thr, seg, vptr):
            return segmented_count_le(w, seg, thr, n, interpret=interpret)
    else:
        count_fn = count_le_csr

    @functools.partial(jax.jit, static_argnums=(0, 1, 2))
    def sweep(k, inf, ksteps, tuv_rows, seg, dst, vptr, c0):
        def per_ts(c, tuv):
            def body(state):
                c, _ = state
                w = jnp.maximum(tuv, c[dst])
                cnt = count_fn(w, c, seg, vptr)
                need = ~jnp.all((cnt >= k) | (c >= inf))
                c = jax.lax.cond(
                    need,
                    lambda c: kth_smallest_csr(w, c, k, inf, ksteps, seg,
                                               vptr, count_fn=count_fn),
                    lambda c: c, c)
                return c, need

            c, _ = jax.lax.while_loop(lambda s: s[1], body,
                                      (c, jnp.array(True)))
            return c, c

        return jax.lax.scan(per_ts, c0, tuv_rows)

    seg = jnp.asarray(csr.src.astype(np.int32))
    dst = jnp.asarray(csr.dst.astype(np.int32))
    vptr = jnp.asarray(csr.vptr.astype(np.int32))
    c = jnp.zeros(n, jnp.int32)
    for ts0 in range(1, t_max + 1, block):
        hi = min(ts0 + block, t_max + 1)
        rows = jnp.asarray(_tuv_rows(csr, ts0, hi, t_max))
        c, out = sweep(k, inf, ksteps, rows, seg, dst, vptr, c)
        vct[ts0:hi] = np.asarray(out)
    return vct


# ----------------------------------------------------------------------
# Engine dispatch
# ----------------------------------------------------------------------

def _edge_core_times_legacy(g: TemporalGraph, k: int) -> CoreTimeTable:
    """The seed's construction loop (PR-1 baseline): per-ts projection +
    lexsort fixpoint, incremental version bookkeeping."""
    t_max = g.t_max
    INF = t_max + 1
    m = g.m
    su, sv, st = (g.src.astype(np.int64), g.dst.astype(np.int64),
                  g.t.astype(np.int64))

    cur = np.full(m, -1, np.int64)          # current CT per edge (-1 = unseen)
    open_from = np.zeros(m, np.int64)       # ts at which `cur` became valid
    recs_e, recs_a, recs_b, recs_c = [], [], [], []
    vct = np.full((t_max + 2, g.n), INF, np.int64)

    warm = None
    for ts in range(1, t_max + 1):
        c = vertex_core_times(g, k, ts, warm=warm)
        warm = c
        vct[ts] = c
        ct_ts = np.maximum(st, np.maximum(c[su], c[sv]))
        ct_ts = np.where(st >= ts, ct_ts, INF)
        ct_ts = np.minimum(ct_ts, INF)
        changed = ct_ts != cur
        if changed.any():
            idx = np.nonzero(changed)[0]
            closing = idx[cur[idx] >= 0]
            # close versions whose CT was finite
            fin = closing[cur[closing] < INF]
            if fin.size:
                recs_e.append(fin)
                recs_a.append(open_from[fin])
                recs_b.append(np.full(fin.size, ts - 1, np.int64))
                recs_c.append(cur[fin])
            cur[idx] = ct_ts[idx]
            open_from[idx] = ts
    # close the tail versions
    tail = np.nonzero((cur >= 0) & (cur < INF))[0]
    if tail.size:
        recs_e.append(tail)
        recs_a.append(open_from[tail])
        recs_b.append(np.full(tail.size, t_max, np.int64))
        recs_c.append(cur[tail])

    if recs_e:
        edge_id = np.concatenate(recs_e)
        ts_from = np.concatenate(recs_a)
        ts_to = np.concatenate(recs_b)
        ct = np.concatenate(recs_c)
        order = np.lexsort((ts_from, edge_id))
        edge_id, ts_from, ts_to, ct = (edge_id[order], ts_from[order],
                                       ts_to[order], ct[order])
    else:
        edge_id = ts_from = ts_to = ct = np.zeros(0, np.int64)
    return _as_table(g, edge_id, ts_from, ts_to, ct, vct[: t_max + 1])


ENGINES = ("auto", "host", "jax", "jax_pallas", "legacy")


def edge_core_times(g: TemporalGraph, k: int, *,
                    engine: str = "auto") -> CoreTimeTable:
    """Compute CT(e)_ts for every edge and start time, delta-compressed.

    ``engine="auto"`` picks the jitted sweep when a non-CPU JAX backend is
    present and the vectorized host sweep otherwise (XLA CPU lowers the
    sweep's sorts/scans poorly; the host engine is the same formulation in
    numpy). ``"jax_pallas"`` is the jitted sweep with the Pallas tile
    counter as the selection inner op (compiled on device backends,
    interpreted on CPU). All engines return bit-identical tables.
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}, expected one of {ENGINES}")
    if engine == "auto":
        try:
            import jax
            backend = jax.default_backend()
        except Exception:  # pragma: no cover - jax is a hard dep in practice
            backend = "cpu"
        engine = "jax" if backend != "cpu" else "host"
    if engine == "legacy":
        return _edge_core_times_legacy(g, k)
    if engine == "host":
        vct = _sweep_host(g, k)
    else:
        vct = _sweep_jax(g, k, use_pallas=(engine == "jax_pallas"))
    return _compress(g, vct)


# ----------------------------------------------------------------------
# Streaming plane: incremental sweep for suffix-extended graphs
# ----------------------------------------------------------------------

def extend_core_times(g: TemporalGraph, k: int,
                      prev: CoreTimeTable) -> CoreTimeTable:
    """Extend a core-time table after a suffix append (streaming epochs).

    ``g`` must be a suffix extension of the graph ``prev`` was built for
    (``TemporalGraph.extend``): the old edges are a prefix of ``g``'s
    arrays and every appended timestamp exceeds ``prev.t_max``. The result
    is **bit-identical** to ``edge_core_times(g, k)`` (test-asserted), but
    recomputes only what a suffix append can change:

    * **Finite old entries are final.** For ``te <= t_old`` the window
      ``[ts, te]`` contains no appended edge, so its k-core — and hence
      any vertex core time that was ``<= t_old`` — is unchanged. Only
      entries that were INF in the old epoch can move (into
      ``(t_old, t_new]``, or to the new INF).
    * **New start times see only the suffix.** For ``ts > t_old`` the
      window contains appended edges exclusively, so those rows come from
      one ordinary sweep over the (timestamp-shifted) suffix subgraph.
    * **Old start times run a frontier fixpoint.** Per ts, only vertices
      whose old entry was INF *and* whose entry at ts-1 is still finite
      in the new epoch (column monotonicity: ``c[ts] >= c[ts-1]``, so a
      column that reaches the new INF stays there) are re-solved; all
      other vertices enter the operator as constants. Iterating the
      clamped operator from the lower bound ``max(c[ts-1], t_old + 1)``
      converges to the same least fixpoint as the full-width sweep
      (same sandwich argument as the module docstring, with the known
      coordinates pinned at their — already least-fixpoint — values).
    * **Interval recompress.** Every previous record is kept verbatim, and
      *new* records are detected only over the cells that can hold one: a
      cell ``(e, ts)`` grows a record iff an endpoint's vertex core time
      flipped from old-INF to new-finite there, and by column monotonicity
      those cells form one ts-interval per vertex (``[first old-INF,
      last new-finite]``). Runs never straddle the interval boundary
      (values change from ``<= t_old`` to ``> t_old`` across it), so run
      detection over the flattened per-edge interval union is exact.
    """
    t_old, t_new = prev.t_max, g.t_max
    m_old, m_new = prev.m, g.m
    if prev.n != g.n:
        raise ValueError(f"vertex count changed ({prev.n} -> {g.n}); "
                         "extend_core_times needs the same vertex set")
    if m_old > m_new or t_old > t_new:
        raise ValueError("prev table does not describe a prefix of g")
    if m_old and g.t[m_old - 1] > t_old:
        raise ValueError("prev table does not match g's edge prefix")
    if m_new > m_old and g.t[m_old] <= t_old:
        raise ValueError(
            f"appended edges must be a timestamp suffix (> {t_old}); "
            "historical edges need a cold edge_core_times rebuild")
    if m_new == m_old:
        return prev                       # no appended edges: same epoch
    if m_old == 0 or t_old == 0:
        return _compress(g, _sweep_host(g, k))   # nothing to extend from
    inf_old, inf_new = t_old + 1, t_new + 1
    n = g.n
    vct = np.full((t_new + 1, n), inf_new, np.int32)
    vo = prev.vertex_ct

    # -- new start times: ordinary sweep over the shifted suffix ---------
    g_suf = TemporalGraph(n, g.src[m_old:], g.dst[m_old:],
                          (g.t[m_old:] - t_old).astype(np.int32))
    vs = _sweep_host(g_suf, k)            # (t_new - t_old + 1, n)
    t_suf = t_new - t_old
    fin = vs[1:] <= t_suf
    block = np.full((t_suf, n), inf_new, np.int32)
    block[fin] = (vs[1:][fin] + t_old).astype(np.int32)
    vct[t_old + 1:] = block

    # -- old start times: frontier fixpoint ------------------------------
    csr = _pair_csr(g)
    stride = np.int64(t_new + 2)
    packed = csr.pidx * stride + csr.tsorted      # globally sorted
    rowend = csr.ptr[1:]
    deg_all = np.diff(csr.vptr)
    S = np.int64(1)
    while S < inf_new + 2:
        S <<= 1
    carry = np.zeros(n, np.int32)     # previous new row (lower bound)
    for ts in range(1, t_old + 1):
        old = vo[ts]
        known = old <= t_old
        vct[ts] = np.where(known, old, inf_new)
        front = np.flatnonzero(~known & (carry <= t_new) & (deg_all >= k))
        if front.size == 0:
            carry = vct[ts]
            continue
        starts = csr.vptr[front]
        counts = csr.vptr[front + 1] - starts
        total = int(counts.sum())
        segptr = np.zeros(front.size + 1, np.int64)
        np.cumsum(counts, out=segptr[1:])
        rows = (np.arange(total, dtype=np.int64)
                - np.repeat(segptr[:-1], counts) + np.repeat(starts, counts))
        # t_uv at this ts for the frontier's pair rows only
        pos = np.searchsorted(packed, rows * stride + ts)
        tuv = np.full(total, inf_new, np.int64)
        valid = pos < rowend[rows]
        tuv[valid] = csr.tsorted[pos[valid]]
        dstv = csr.dst[rows].astype(np.int64)
        base = np.repeat(np.arange(front.size, dtype=np.int64), counts) * S
        segbase = np.arange(front.size, dtype=np.int64) * S
        sel = segptr[:-1] + (k - 1)
        val = vct[ts].astype(np.int64)    # known + settled-INF constants
        c = np.maximum(carry[front].astype(np.int64), t_old + 1)
        while True:
            val[front] = c
            key = base + np.maximum(tuv, val[dstv])
            key.sort()
            cnt = np.searchsorted(key, segbase + c + 1) - segptr[:-1]
            if bool(((cnt >= k) | (c >= inf_new)).all()):
                break
            c_new = key[sel] % S          # k-th smallest per segment
            np.minimum(c_new, inf_new, out=c_new)
            np.maximum(c, c_new, out=c)
        vct[ts, front] = c.astype(np.int32)
        carry = vct[ts]

    # -- interval recompress ----------------------------------------------
    # Per vertex, the cells whose CT flipped old-INF -> new-finite form one
    # ts-interval [s_v, L_v] (both signals are monotone in ts): s_v = first
    # old-INF row, L_v = last new-finite row. A new record of an old edge
    # lives only where an endpoint flipped; appended edges are all-new over
    # [1, t(e)]. Flatten those per-edge intervals and run-detect over them.
    s_v = (vo[1:] <= t_old).sum(axis=0).astype(np.int64) + 1
    L_v = (vct[1:] <= t_new).sum(axis=0).astype(np.int64)
    eu = g.src.astype(np.int64)
    ev = g.dst.astype(np.int64)
    te_e = g.t.astype(np.int64)
    # old edges: union of the two endpoint intervals, clipped to [1, t(e)]
    a1 = np.maximum(s_v[eu[:m_old]], 1)
    b1 = np.minimum(L_v[eu[:m_old]], te_e[:m_old])
    a2 = np.maximum(s_v[ev[:m_old]], 1)
    b2 = np.minimum(L_v[ev[:m_old]], te_e[:m_old])
    swap = a2 < a1
    a1s, a2s = np.where(swap, a2, a1), np.where(swap, a1, a2)
    b1s, b2s = np.where(swap, b2, b1), np.where(swap, b1, b2)
    merged = a2s <= b1s + 1                     # touching/overlapping
    lo_a = a1s
    hi_a = np.where(merged, np.maximum(b1s, b2s), b1s)
    lo_b = np.where(merged, 1, a2s)             # second piece (if distinct)
    hi_b = np.where(merged, 0, b2s)
    # appended edges: one full piece [1, t(e)]
    app = np.arange(m_old, m_new, dtype=np.int64)
    piece_e = np.concatenate([np.arange(m_old, dtype=np.int64)] * 2 + [app])
    piece_lo = np.concatenate([lo_a, lo_b, np.ones(app.size, np.int64)])
    piece_hi = np.concatenate([hi_a, hi_b, te_e[app]])
    keep_p = piece_lo <= piece_hi
    piece_e, piece_lo, piece_hi = piece_e[keep_p], piece_lo[keep_p], piece_hi[keep_p]
    lens = piece_hi - piece_lo + 1
    total_cells = int(lens.sum())
    if total_cells == 0:
        new_e = new_f = new_t = new_c = np.zeros(0, np.int64)
    else:
        # order pieces by (edge, ts) so runs are contiguous per edge
        po = np.lexsort((piece_lo, piece_e))
        piece_e, piece_lo, lens = piece_e[po], piece_lo[po], lens[po]
        pp = np.zeros(piece_e.size + 1, np.int64)
        np.cumsum(lens, out=pp[1:])
        flat_ts = (np.arange(total_cells, dtype=np.int64)
                   - np.repeat(pp[:-1], lens) + np.repeat(piece_lo, lens))
        flat_e = np.repeat(piece_e, lens)
        cu = vct[flat_ts, eu[flat_e]].astype(np.int64)
        cv = vct[flat_ts, ev[flat_e]].astype(np.int64)
        cval = np.maximum(np.maximum(cu, cv), te_e[flat_e])
        np.minimum(cval, inf_new, out=cval)
        # run boundaries: edge change, ts gap, or value change
        brk = np.ones(total_cells, bool)
        brk[1:] = ((flat_e[1:] != flat_e[:-1])
                   | (flat_ts[1:] != flat_ts[:-1] + 1)
                   | (cval[1:] != cval[:-1]))
        sidx = np.flatnonzero(brk)
        eidx = np.empty_like(sidx)
        eidx[:-1] = sidx[1:] - 1
        eidx[-1] = total_cells - 1
        fin = cval[sidx] < inf_new
        sidx, eidx = sidx[fin], eidx[fin]
        new_e, new_f = flat_e[sidx], flat_ts[sidx]
        new_t, new_c = flat_ts[eidx], cval[sidx]
    edge_id = np.concatenate([prev.edge_id.astype(np.int64), new_e])
    ts_from = np.concatenate([prev.ts_from.astype(np.int64), new_f])
    ts_to = np.concatenate([prev.ts_to.astype(np.int64), new_t])
    ct = np.concatenate([prev.ct.astype(np.int64), new_c])
    order = np.lexsort((ts_from, edge_id))
    return _as_table(g, edge_id[order], ts_from[order], ts_to[order],
                     ct[order], vct)


# ----------------------------------------------------------------------
# Retention plane: prefix expiry for sliding-window epochs
# ----------------------------------------------------------------------

def shrink_core_times(g: TemporalGraph, k: int,
                      prev: CoreTimeTable) -> CoreTimeTable:
    """Shrink a core-time table after prefix expiry (sliding-window epochs).

    ``g`` must be the shifted epoch ``old_graph.expire_before(t_cut)`` of
    the graph ``prev`` was built for: edges with timestamp ``< t_cut``
    dropped, survivors shifted by ``shift = t_cut - 1`` and renumbered by
    ``-cut`` (the expired edge count). The result is **bit-identical** to
    ``edge_core_times(g, k)`` (test-asserted) at pure-slicing cost,
    because of the *cut invariant*:

        every surviving start time ``ts >= t_cut`` projects a window
        ``[ts, te] ⊆ [ts, t_max]`` whose edges all have ``t >= ts >=
        t_cut`` — no expired edge can appear in it.

    So no vertex needs re-solving: the k-core of every surviving window
    is untouched, and the whole table reduces by relabeling —

    * **vertex rows**: new row ``ts`` = old row ``ts + shift``, finite
      values shifted down, old-INF (``t_old + 1``) mapped to new-INF.
    * **version records die or clip, never change.** A record survives
      iff its start-time interval reaches the cut (``ts_to >= t_cut``);
      a surviving record keeps its core time (shifted) with ``ts_from``
      clipped to the cut. Clipping cannot merge runs (run values are
      constant and maximal already) and preserves the ``(edge_id,
      ts_from)`` sort, so the record stream needs no re-sort and no
      re-run-detection. Records of expired edges always die: their
      intervals end at ``ts_to <= t(e) < t_cut``.

    Raises ``ValueError`` when ``(g, prev)`` is not a consistent
    prefix-expiry pair, so a wrong table is never produced silently.
    """
    shift = prev.t_max - g.t_max
    cut_m = prev.m - g.m
    t_cut = shift + 1
    if prev.n != g.n:
        raise ValueError(f"vertex count changed ({prev.n} -> {g.n}); "
                         "shrink_core_times needs the same vertex set")
    if shift < 0 or cut_m < 0:
        raise ValueError("prev table does not describe a supergraph of g "
                         "(shrink goes forward in time; use "
                         "extend_core_times to grow)")
    if shift == 0 and cut_m == 0:
        return prev                       # no cut: same epoch
    if g.m == 0 or g.t_max == 0:
        return _compress(g, _sweep_host(g, k))   # everything expired
    inf_old, inf_new = prev.t_max + 1, g.t_max + 1

    # -- vertex rows: slice + shift, INF remapped -------------------------
    vo = prev.vertex_ct[t_cut:].astype(np.int64)
    vct = np.full((g.t_max + 1, g.n), inf_new, np.int32)
    fin = vo < inf_old
    block = np.full(vo.shape, inf_new, np.int64)
    block[fin] = vo[fin] - shift
    # values are core times bounded by inf_new = g.t_max + 1, int32 by
    # the CoreTimeTable dtype contract
    vct[1:] = block.astype(np.int32)  # repro: ignore[int32-narrowing]

    # -- records: drop dead, clip the cut straddlers, shift, renumber -----
    keep = prev.ts_to.astype(np.int64) >= t_cut
    edge_id = prev.edge_id[keep].astype(np.int64) - cut_m
    if edge_id.size and edge_id.min() < 0:
        raise ValueError(
            "a surviving version references an expired edge; prev is not "
            "the table of g's pre-expiry epoch")
    ts_from = np.maximum(prev.ts_from[keep].astype(np.int64), t_cut) - shift
    ts_to = prev.ts_to[keep].astype(np.int64) - shift
    ct = prev.ct[keep].astype(np.int64) - shift
    return _as_table(g, edge_id, ts_from, ts_to, ct, vct)


# ----------------------------------------------------------------------
# K-stratified plane: one build serves every k (DESIGN.md §14)
# ----------------------------------------------------------------------

def _rle_columns(vct: np.ndarray, t_max: int):
    """Run-length encode the finite cells of a dense (t_max+1, n) vertex
    core-time matrix, per vertex over ts = 1..t_max.

    Returns ``(counts, ts_from, ts_to, val)`` with runs sorted by
    (vertex, ts_from) — the same edge-major run detection as `_compress`,
    applied to vertex columns. INF cells are simply absent (decode fills
    INF), so encode/decode round-trips bit-exactly.
    """
    n = vct.shape[1]
    inf = t_max + 1
    z = np.zeros(0, np.int32)
    if t_max == 0 or n == 0:
        return np.zeros(n, np.int64), z, z, z
    cols = np.ascontiguousarray(vct[1:].T).reshape(-1)    # (n*T,) row-major
    start = np.empty(cols.shape[0], bool)
    start[0] = True
    np.not_equal(cols[1:], cols[:-1], out=start[1:])
    start[::t_max] = True                                 # runs stay in-column
    sidx = np.flatnonzero(start)
    vals = cols[sidx]
    nxt = np.empty_like(sidx)
    nxt[:-1] = sidx[1:]
    nxt[-1] = cols.shape[0]
    keep = vals < inf
    sidx, nxt, vals = sidx[keep], nxt[keep], vals[keep]
    counts = np.bincount(sidx // t_max, minlength=n).astype(np.int64)
    return (counts, (sidx % t_max + 1).astype(np.int32),
            ((nxt - 1) % t_max + 1).astype(np.int32),
            vals.astype(np.int32))


def _expand_runs(n: int, t_max: int, vptr: np.ndarray, ts_from: np.ndarray,
                 ts_to: np.ndarray, val: np.ndarray) -> np.ndarray:
    """Inverse of `_rle_columns`: dense (t_max+1, n) int32 matrix, INF
    everywhere no run covers. ``vptr`` is the per-vertex run CSR."""
    vct = np.full((t_max + 1, n), t_max + 1, np.int32)
    if ts_from.size == 0:
        return vct
    lens = (ts_to - ts_from + 1).astype(np.int64)
    total = int(lens.sum())
    off = np.zeros(ts_from.shape[0] + 1, np.int64)
    np.cumsum(lens, out=off[1:])
    flat_ts = (np.arange(total, dtype=np.int64)
               - np.repeat(off[:-1], lens) + np.repeat(ts_from, lens))
    run_vert = np.repeat(np.arange(n, dtype=np.int64), np.diff(vptr))
    vct[flat_ts, np.repeat(run_vert, lens)] = np.repeat(val, lens)
    return vct


@dataclasses.dataclass(frozen=True)
class StratifiedCoreTable:
    """Core-time tables for every supported k, packed as one structure.

    Record arrays are the per-k ``CoreTimeTable`` version records
    concatenated in ascending-k blocks (``kptr`` bounds block i); each
    block keeps its (edge_id, ts_from) lexsort order verbatim, so
    ``table_for(k)`` is a zero-copy slice that is bit-identical to
    ``edge_core_times(g, k)``'s records.

    Vertex core times are stored run-length encoded per (k, vertex) slot
    (``vptr`` is a CSR over slot = k_index * n + vertex) instead of |K|
    dense (t_max+1, n) matrices — columns are piecewise constant in ts,
    so this is the memory lever that lets one stratified handle undercut
    |K| per-k handles. ``table_for`` re-expands the dense matrix on
    demand (streaming extend needs it).
    """

    n: int
    m: int
    t_max: int
    ks: tuple[int, ...]       # ascending, strictly increasing
    kptr: np.ndarray          # int64[|K|+1] record-block bounds
    edge_id: np.ndarray       # int32[R] concat per-k blocks
    ts_from: np.ndarray       # int32[R]
    ts_to: np.ndarray         # int32[R]
    ct: np.ndarray            # int32[R]
    vptr: np.ndarray          # int64[|K|*n + 1] vertex-run CSR over slots
    v_ts_from: np.ndarray     # int32[VR]
    v_ts_to: np.ndarray       # int32[VR]
    v_ct: np.ndarray          # int32[VR]

    @property
    def INF(self) -> int:
        return self.t_max + 1

    @property
    def num_versions(self) -> int:
        return int(self.edge_id.shape[0])

    def nbytes(self) -> int:
        """Bytes of everything stored — records, vertex runs and both
        pointer tables (unlike `CoreTimeTable.nbytes` there is no dense
        matrix to exclude; the RLE strata *are* the vertex storage)."""
        return int(self.kptr.nbytes + self.edge_id.nbytes
                   + self.ts_from.nbytes + self.ts_to.nbytes + self.ct.nbytes
                   + self.vptr.nbytes + self.v_ts_from.nbytes
                   + self.v_ts_to.nbytes + self.v_ct.nbytes)

    def k_index(self, k: int) -> int:
        i = int(np.searchsorted(np.asarray(self.ks), k))
        if i >= len(self.ks) or self.ks[i] != k:
            raise KeyError(f"k={k} not in supported strata {self.ks}")
        return i

    def table_for(self, k: int) -> CoreTimeTable:
        """The per-k ``CoreTimeTable`` of stratum k: record arrays are
        views, the dense vertex matrix is re-expanded from the runs."""
        i = self.k_index(k)
        lo, hi = int(self.kptr[i]), int(self.kptr[i + 1])
        vlo, vhi = i * self.n, (i + 1) * self.n
        rlo, rhi = int(self.vptr[vlo]), int(self.vptr[vhi])
        vct = _expand_runs(self.n, self.t_max,
                           self.vptr[vlo:vhi + 1] - self.vptr[vlo],
                           self.v_ts_from[rlo:rhi], self.v_ts_to[rlo:rhi],
                           self.v_ct[rlo:rhi])
        return CoreTimeTable(self.n, self.m, self.t_max,
                             self.edge_id[lo:hi], self.ts_from[lo:hi],
                             self.ts_to[lo:hi], self.ct[lo:hi], vct)

    @classmethod
    def from_tables(cls, g: TemporalGraph, ks, tables) -> "StratifiedCoreTable":
        """Stratify per-k ``CoreTimeTable``s (ascending k order). Each
        table's records are taken verbatim; dense matrices are RLE'd."""
        ks = _validate_ks(ks)
        if len(tables) != len(ks):
            raise ValueError("one table per k required")
        n, t_max = g.n, g.t_max
        kptr = np.zeros(len(ks) + 1, np.int64)
        counts_all = []
        for i, tab in enumerate(tables):
            if (tab.n, tab.m, tab.t_max) != (n, g.m, t_max):
                raise ValueError("table shape mismatch with graph")
            kptr[i + 1] = kptr[i] + tab.num_versions
        i32 = lambda parts: (np.concatenate(parts).astype(np.int32, copy=False)
                             if parts else np.zeros(0, np.int32))
        rle = [_rle_columns(tab.vertex_ct, t_max) for tab in tables]
        for counts, _, _, _ in rle:
            counts_all.append(counts)
        vptr = np.zeros(len(ks) * n + 1, np.int64)
        if counts_all:
            np.cumsum(np.concatenate(counts_all), out=vptr[1:])
        return cls(
            n, g.m, t_max, ks, kptr,
            i32([t.edge_id for t in tables]), i32([t.ts_from for t in tables]),
            i32([t.ts_to for t in tables]), i32([t.ct for t in tables]),
            vptr, i32([r[1] for r in rle]), i32([r[2] for r in rle]),
            i32([r[3] for r in rle]))


def _validate_ks(ks) -> tuple[int, ...]:
    ks = tuple(int(k) for k in ks)
    if any(k < 1 for k in ks):
        raise ValueError(f"strata must be k >= 1, got {ks}")
    if any(b <= a for a, b in zip(ks, ks[1:])):
        raise ValueError(f"strata must be strictly ascending, got {ks}")
    return ks


def default_ks(g: TemporalGraph) -> tuple[int, ...]:
    """The full useful range 2..k_max(g): below 2 a TCCS query is invalid,
    above the degeneracy every answer is exactly empty (no stratum needed)."""
    from .kcore import k_max

    if g.m == 0:
        return ()
    return tuple(range(2, k_max(g) + 1))


def _sweep_host_stratified(g: TemporalGraph, ks) -> list[np.ndarray]:
    """Dense (t_max+1, n) vertex core times for every k in ``ks``, fused.

    One pair-CSR and one blocked t_uv table serve every stratum; inside a
    ts block the k loop ascends and seeds each stratum's fixpoint with
    ``max(carry_k(ts-1), c_{kprev}(ts))`` — both are lower bounds of the
    least fixpoint (window shrink / k-core nesting), and iterating the
    clamped operator from *any* lower bound converges to the same lfp, so
    every stratum row is bit-identical to the per-k `_sweep_host` row.
    The inner loop is `_sweep_host`'s verbatim (one packed sort per
    iteration serves both the rank probe and the climb).
    """
    n, t_max = g.n, g.t_max
    inf = t_max + 1
    vcts = [np.full((t_max + 1, n), inf, np.int32) for _ in ks]
    if g.m == 0 or t_max == 0 or not ks:
        return vcts
    csr = _pair_csr(g)
    deg = np.diff(csr.vptr)
    S = 1
    while S < inf + 2:
        S *= 2
    kdtype = np.int32 if n * S < 2 ** 31 else np.int64
    base = (csr.src.astype(np.int64) * S).astype(kdtype)
    vbase = (np.arange(n, dtype=np.int64) * S).astype(kdtype)
    pd = csr.dst.astype(np.int64)
    vstart = csr.vptr[:-1]
    has_k = [deg >= k for k in ks]
    sel = [csr.vptr[:-1][h] + (k - 1) for k, h in zip(ks, has_k)]
    carry = [np.zeros(n, np.int32) for _ in ks]
    for ts0 in range(1, t_max + 1, TUV_BLOCK):
        ts1 = min(ts0 + TUV_BLOCK, t_max + 1)
        tuv_rows = _tuv_rows(csr, ts0, ts1, t_max)
        for ki, k in enumerate(ks):
            c = carry[ki]
            vct = vcts[ki]
            seed_rows = vcts[ki - 1] if ki else None
            for ts in range(ts0, ts1):
                tuv = tuv_rows[ts - ts0]
                if seed_rows is not None:
                    np.maximum(c, seed_rows[ts], out=c)
                while True:
                    w = np.maximum(tuv, c[pd]).astype(kdtype, copy=False)
                    key = base + w
                    key.sort()
                    cnt = np.searchsorted(key, vbase + c + 1) - vstart
                    if bool(((cnt >= k) | (c >= inf)).all()):
                        break
                    c_new = np.full(n, inf, np.int32)
                    c_new[has_k[ki]] = (key[sel[ki]] & (S - 1)) \
                        if kdtype == np.int32 else key[sel[ki]] % S
                    np.minimum(c_new, inf, out=c_new)
                    np.maximum(c, c_new, out=c)
                vct[ts] = c
    return vcts


def stratified_core_times(g: TemporalGraph, ks=None, *,
                          engine: str = "auto") -> StratifiedCoreTable:
    """One k-stratified core-time build covering every k in ``ks``
    (default: the full useful range ``default_ks(g)``).

    Every stratum is bit-identical to ``edge_core_times(g, k)`` — the
    host path runs the fused warm-seeded sweep `_sweep_host_stratified`;
    other engines fall back to per-k sweeps (still sharing nothing worse
    than the status quo) and exist for differential testing.
    """
    ks = _validate_ks(default_ks(g) if ks is None else ks)
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}, expected one of {ENGINES}")
    if engine == "auto":
        try:
            import jax
            backend = jax.default_backend()
        except Exception:  # pragma: no cover - jax is a hard dep in practice
            backend = "cpu"
        engine = "jax" if backend != "cpu" else "host"
    if engine == "host":
        tables = [_compress(g, vct)
                  for vct in _sweep_host_stratified(g, ks)]
    else:
        tables = [edge_core_times(g, k, engine=engine) for k in ks]
    return StratifiedCoreTable.from_tables(g, ks, tables)


def extend_stratified_core_times(g: TemporalGraph, prev: StratifiedCoreTable,
                                 ks=None) -> StratifiedCoreTable:
    """Suffix-append epoch for every stratum at once: existing strata go
    through `extend_core_times` (bit-identical incremental), strata newly
    requested via ``ks`` (e.g. the appended edges raised k_max) are built
    cold. ``ks`` defaults to ``prev.ks``."""
    ks = _validate_ks(prev.ks if ks is None else ks)
    tables = []
    for k in ks:
        if k in prev.ks:
            tables.append(extend_core_times(g, k, prev.table_for(k)))
        else:
            tables.append(edge_core_times(g, k, engine="host"))
    return StratifiedCoreTable.from_tables(g, ks, tables)


def shrink_stratified_core_times(g: TemporalGraph, prev: StratifiedCoreTable,
                                 ks=None) -> StratifiedCoreTable:
    """Prefix-expiry epoch for every stratum at once (see
    `shrink_core_times`); ``ks`` defaults to ``prev.ks`` and may drop
    strata (expiry can lower k_max) but must not add any."""
    ks = _validate_ks(prev.ks if ks is None else ks)
    missing = [k for k in ks if k not in prev.ks]
    if missing:
        raise ValueError(f"shrink cannot add strata {missing}; "
                         "build them cold instead")
    return StratifiedCoreTable.from_tables(
        g, ks, [shrink_core_times(g, k, prev.table_for(k)) for k in ks])


# ----------------------------------------------------------------------
# Brute-force oracle (tests only): CT by scanning te for each (ts, e).
# ----------------------------------------------------------------------

def edge_core_time_naive(g: TemporalGraph, k: int, ts: int) -> np.ndarray:
    """int64[m] CT(e)_ts by recomputing the k-core for every te."""
    from .kcore import kcore_edge_mask

    INF = g.t_max + 1
    out = np.full(g.m, INF, np.int64)
    for te in range(ts, g.t_max + 1):
        s, d, ids = g.project(ts, te)
        if ids.size == 0:
            continue
        # distinct-neighbour degrees: collapse parallel edges for peeling
        key = np.minimum(s, d).astype(np.int64) * g.n + np.maximum(s, d)
        uniq, inv = np.unique(key, return_inverse=True)
        us, ud = (uniq // g.n).astype(np.int64), (uniq % g.n).astype(np.int64)
        alive_simple = kcore_edge_mask(us, ud, g.n, k)
        alive = alive_simple[inv]
        newly = ids[alive]
        out[newly] = np.minimum(out[newly], te)
    return out
