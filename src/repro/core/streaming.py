"""Streaming epoch plane: grow a packed PECB index across suffix epochs,
shrink it across prefix-expiry (retention) epochs.

``TemporalGraph.extend`` appends *suffix* edges (every timestamp strictly
newer than ``t_max``) and yields the next graph epoch;
``core_time.extend_core_times`` grows the core-time table; this module
grows the **packed PECB index** — bit-identical to a cold
``build_pecb_index`` on the merged edge list (test-asserted), at a small
fraction of the cost.

Why a suffix append is cheap (the two structural facts everything below
rests on):

1.  **Old records are final, new records rank above them.** A finite
    core-time cell ``CT(e)_ts <= t_old`` describes a window that contains
    no appended edge, so it cannot change; cells that were ``INF`` in the
    old epoch can only become finite with ``ct in (t_old, t_new]``. Hence
    the new epoch's version set is exactly *old records (verbatim) + new
    records, all with ct > t_old* — and since the ECB rank is ``(ct,
    edge_id)`` ascending, **every new record outranks every old record**.

2.  **The old forest layer is epoch-invariant.** The ECB forest at start
    time ``ts`` is the unique rank-MSF of the active versions with
    children = per-endpoint component maxima (Def 4.9). Kruskal consumes
    edges in ascending rank, so the sub-forest over old records is decided
    before any new record is examined: old nodes keep their children, their
    acceptance, and their forest lifetimes from the old epoch, and old
    expiries replay identically (the expired LCA of an old insert lies on
    an old path). New records only ever (a) form an **overlay** on top —
    attaching to the *roots* of old components — and (b) expire *other
    overlay nodes*. The only old-node state that can change is the parent
    pointer of an old root that gets **adopted** by an overlay node, and
    the per-vertex entry point of a vertex whose old layer offers none.

The grow algorithm is therefore *snapshot differencing*, not cascade
replay: sweep ``ts`` from ``t_new`` down to 1, maintain the old layer by
replaying the previous epoch's **recorded delta entries** (cheap array
scatters — no Python forest work), and per ts build the overlay from
scratch as a Kruskal over the new records on the **contracted graph**
whose supernodes are old-component roots (found by pointer-jumping over
the replayed parent array). Because the incremental builder's state at
every ts equals the canonical Def-4.9 construction (link-exact, slot-exact
— asserted against ``build_forest_at``), consecutive-ts snapshot diffs
reproduce the cold builder's delta-compressed entries exactly. Finally,
node ids are renumbered to the cold build's insertion order — which is
fully determined by ``(live_to descending, rank ascending)`` — and every
id reference is remapped, yielding bit-identical packed arrays.

Cost: ``O(t_new)`` vectorized old-layer replay steps plus per-ts overlay
work proportional to the *active new records* (with per-contracted-pair
dedup before the Python Kruskal), plus one final lexsort pack — versus the
cold build's Python insert cascade over *all* versions. On ``em_like``
suffix appends the refresh is >5x faster than a cold rebuild
(``benchmarks/bench_streaming.py`` asserts equality before reporting).
"""

from __future__ import annotations

import numpy as np

from .core_time import (CoreTimeTable, default_ks,
                        extend_stratified_core_times,
                        shrink_stratified_core_times)
from .ecb_forest import NONE, ForestInvariantError
from .pecb_index import (PECBIndex, StratifiedPECB, _assemble_stratified,
                         _csr_sorted, _forest_builder, pack_index)
from .query_api import VersionStore
from .temporal_graph import TemporalGraph


# ----------------------------------------------------------------------
# helpers
# ----------------------------------------------------------------------

def array_delta(prev, new) -> str:
    """Classify how ``new`` relates to ``prev`` across one epoch step:
    ``"reuse"`` (identical), ``"suffix"`` (1-D, ``prev`` is a strict
    prefix — graph edge arrays under a suffix append), ``"prefix"`` (1-D,
    ``prev`` is a strict *suffix* — the packed node-table arrays: the cold
    insertion order is ``(live_to desc, rank asc)``, so an epoch's new
    overlay nodes renumber *in front of* the old nodes, whose relative
    order is preserved verbatim), else ``"full"``. The persistent store
    keys its delta commits on this (DESIGN.md §13.2): reuse re-references
    the on-disk parts, suffix/prefix write only the changed bytes."""
    if prev is None:
        return "full"
    prev, new = np.asarray(prev), np.asarray(new)
    if prev.dtype != new.dtype:
        return "full"
    if prev.shape == new.shape and np.array_equal(prev, new):
        return "reuse"
    if prev.ndim == 1 and new.ndim == 1 and new.size > prev.size:
        if np.array_equal(new[:prev.size], prev):
            return "suffix"
        if np.array_equal(new[new.size - prev.size:], prev):
            return "prefix"
    return "full"


def _flatten_entries(idx: PECBIndex):
    """(node, ts, l, r, p) flat views of the per-node entry CSR."""
    node = np.repeat(np.arange(idx.num_nodes, dtype=np.int64),
                     np.diff(idx.row_ptr).astype(np.int64))
    return (node, idx.ent_ts.astype(np.int64), idx.ent_left.astype(np.int64),
            idx.ent_right.astype(np.int64), idx.ent_parent.astype(np.int64))


def _flatten_vent(idx: PECBIndex):
    """(vert, ts, node) flat views of the per-vertex entry CSR."""
    vert = np.repeat(np.arange(idx.n, dtype=np.int64),
                     np.diff(idx.vrow_ptr).astype(np.int64))
    return vert, idx.vent_ts.astype(np.int64), idx.vent_node.astype(np.int64)


class _TsGroups:
    """Slices of a record array grouped by a ts key, consumed descending.
    Slice bounds for every ts are precomputed with one vectorized
    searchsorted so the sweep's per-ts lookups are O(1)."""

    def __init__(self, ts: np.ndarray, t_hi: int):
        ts = ts.astype(np.int64)
        self.order = np.argsort(-ts, kind="stable")
        neg = -ts[self.order]                       # ascending
        qs = -np.arange(t_hi + 1, dtype=np.int64)
        self._lo = np.searchsorted(neg, qs, side="left")
        self._hi = np.searchsorted(neg, qs, side="right")

    def at(self, ts: int) -> np.ndarray:
        return self.order[self._lo[ts]:self._hi[ts]]


def _step_lookup(keys_desc: np.ndarray, vals: np.ndarray,
                 queries: np.ndarray, default: int) -> np.ndarray:
    """Step-function lookup for a descending-recorded event stream: the
    value at query q is the payload of the *last* event with key >= q
    (events hold downward); ``default`` where no event covers q."""
    if keys_desc.size == 0:
        return np.full(queries.shape[0], default, np.int64)
    j = np.searchsorted(-keys_desc, -queries, side="right") - 1
    out = vals[np.clip(j, 0, None)]
    return np.where(j >= 0, out, default)


class _UnionFind:
    """Tiny union-find over dict keys with per-component max-node tracking
    (the Def 4.9 attachment point). Node refs use the sweep's encoding."""

    __slots__ = ("parent", "cmax")

    def __init__(self):
        self.parent: dict = {}
        self.cmax: dict = {}

    def find(self, x):
        p = self.parent
        root = x
        while p.get(root, root) != root:
            root = p[root]
        while p.get(x, x) != x:
            p[x], x = root, p[x]
        return root


# ----------------------------------------------------------------------
# the grow path
# ----------------------------------------------------------------------

def extend_pecb_index(g: TemporalGraph, k: int, tab: CoreTimeTable,
                      prev: PECBIndex) -> PECBIndex:
    """Grow ``prev`` (the previous epoch's packed index) into the index for
    suffix-extended graph ``g`` with extended core-time table ``tab``.

    Bit-identical to ``build_pecb_index(g, k, tab)`` — every packed array,
    including node-id assignment (test-asserted). Raises ``ValueError``
    when ``(g, tab, prev)`` are not a consistent suffix-epoch triple, so a
    wrong index is never produced silently.
    """
    from .pecb_index import build_pecb_index   # cold fallback (cycle-safe)

    t_old, t_new = prev.t_max, g.t_max
    if prev.k != k:
        raise ValueError(f"index k={prev.k} does not match k={k}")
    if prev.n != g.n:
        raise ValueError(f"vertex count changed ({prev.n} -> {g.n}); "
                         "extend needs the same vertex set")
    if prev.m > g.m or t_old > t_new:
        raise ValueError("prev index does not describe a prefix of g")
    if tab.t_max != t_new or tab.m != g.m:
        raise ValueError("tab is not the core-time table of g")
    if prev.m and g.t[prev.m - 1] > t_old:
        raise ValueError("prev index does not match g's edge prefix")
    if g.m > prev.m and g.t[prev.m] <= t_old:
        raise ValueError(
            f"appended edges must be a timestamp suffix (> {t_old})")
    if prev.versions is None or prev.m == 0 or t_old == 0:
        return build_pecb_index(g, k, tab)    # nothing trustworthy to grow

    # -- split the table: old records verbatim, new records ct > t_old ----
    new_mask = tab.ct.astype(np.int64) > t_old
    vs = prev.versions
    old_sel = ~new_mask
    if int(old_sel.sum()) != vs.num_versions or not (
            np.array_equal(tab.edge_id[old_sel], vs.edge_id)
            and np.array_equal(tab.ts_from[old_sel], vs.ts_from)
            and np.array_equal(tab.ts_to[old_sel], vs.ts_to)
            and np.array_equal(tab.ct[old_sel], vs.ct)):
        raise ValueError(
            "old version records changed across the epoch; this is not a "
            "suffix extension of the index's graph (cold rebuild required)")

    n, n_old = g.n, prev.num_nodes
    stride = np.int64(g.m + 1)
    rec_ids = np.flatnonzero(new_mask)
    r_new = rec_ids.shape[0]
    if r_new == 0:
        # no new versions: the forest is unchanged; only metadata grows
        return PECBIndex(
            g.n, g.m, t_new, k,
            prev.node_u, prev.node_v, prev.node_ct, prev.node_edge,
            prev.node_live_from, prev.node_live_to,
            prev.row_ptr, prev.ent_ts, prev.ent_left, prev.ent_right,
            prev.ent_parent, prev.vrow_ptr, prev.vent_ts, prev.vent_node,
            versions=VersionStore.from_table(g, k, tab),
        )

    # new records, sorted by rank (ct, edge) ascending — the Kruskal order
    ne_edge = tab.edge_id[rec_ids].astype(np.int64)
    ne_ct = tab.ct[rec_ids].astype(np.int64)
    ne_from = tab.ts_from[rec_ids].astype(np.int64)
    ne_to = tab.ts_to[rec_ids].astype(np.int64)
    rorder = np.lexsort((ne_edge, ne_ct))
    ne_edge, ne_ct = ne_edge[rorder], ne_ct[rorder]
    ne_from, ne_to = ne_from[rorder], ne_to[rorder]
    ne_rank = ne_ct * stride + ne_edge
    ne_u = g.src[ne_edge].astype(np.int64)
    ne_v = g.dst[ne_edge].astype(np.int64)

    # node-ref encoding for the sweep: old node o -> o; overlay record j ->
    # n_old + j; NONE -> -1. Contraction keys additionally tag node-less
    # vertices as n_old + r_new + vertex.
    OV = n_old                    # overlay ref base
    VTAG = n_old + r_new          # vertex-tag base (UF keys only)

    # -- old-layer replay feeds -------------------------------------------
    oe_node, oe_ts, oe_l, oe_r, oe_p = _flatten_entries(prev)
    oe_groups = _TsGroups(oe_ts, t_new)
    ov_vert, ov_ts, ov_node = _flatten_vent(prev)
    ov_groups = _TsGroups(ov_ts, t_new)
    old_live_to = prev.node_live_to.astype(np.int64)
    old_live_from = prev.node_live_from.astype(np.int64)
    act_groups = _TsGroups(old_live_to, t_new)          # activate at live_to
    deact_groups = _TsGroups(old_live_from - 1, t_new)  # dead below live_from
    rec_add = _TsGroups(ne_to, t_new)                   # active at ts_to
    rec_del = _TsGroups(ne_from - 1, t_new)             # inactive below

    # -- old-layer replay state -------------------------------------------
    par = np.full(n_old, NONE, np.int64)     # current old parent per node
    alive = np.zeros(n_old, bool)
    old_vent = np.full(n, NONE, np.int64)    # current old entry node / vert
    roots = np.arange(max(n_old, 1), dtype=np.int64)  # lazily recomputed
    roots_fresh = False

    # -- overlay sweep state ----------------------------------------------
    act = np.zeros(r_new, bool)
    inf_prev = np.zeros(r_new, bool)
    l_prev = np.full(r_new, NONE, np.int64)
    r_prev = np.full(r_new, NONE, np.int64)
    p_prev = np.full(r_new, NONE, np.int64)
    ever_in = np.zeros(r_new, bool)
    live_to_rec = np.zeros(r_new, np.int64)
    live_from_rec = np.ones(r_new, np.int64)
    adopt_prev: dict = {}        # old root -> overlay j currently adopting
    ovr_arr = np.full(n, NONE, np.int64)   # vertex -> current overlay vent
    prev_ov_verts = np.zeros(0, np.int64)  # vertices with ovr_arr != NONE

    # emissions (chunked arrays, concatenated at assembly)
    em_node: list[np.ndarray] = []     # overlay entries (enc refs)
    em_ts: list[np.ndarray] = []
    em_l: list[np.ndarray] = []
    em_r: list[np.ndarray] = []
    em_p: list[np.ndarray] = []
    adopt_events: dict[int, list] = {}   # old node -> [(ts, j | NONE)] desc
    vent_events: dict[int, list] = {}    # vertex -> [(ts, ref | NONE)] desc

    scratch_cid = np.full(n, NONE, np.int64)   # vertex -> contracted key

    for ts in range(t_new, 0, -1):
        # 1. old layer at ts (activations first: a node inserted and expired
        # at the same ts nets to dead, matching the cold builder's flush)
        a_ids = act_groups.at(ts)
        d_ids = deact_groups.at(ts)
        e_ids = oe_groups.at(ts)
        v_ids = ov_groups.at(ts)
        old_changed = a_ids.size or d_ids.size or e_ids.size or v_ids.size
        if a_ids.size:
            alive[a_ids] = True
            par[a_ids] = NONE
        if e_ids.size:
            par[oe_node[e_ids]] = oe_p[e_ids]
        if d_ids.size:
            alive[d_ids] = False
        if v_ids.size:
            old_vent[ov_vert[v_ids]] = ov_node[v_ids]
        if old_changed:
            roots_fresh = False

        # 2. active new records at ts
        adds = rec_add.at(ts)
        dels = rec_del.at(ts)
        rec_changed = adds.size or dels.size
        if adds.size:
            act[adds] = True
        if dels.size:
            act[dels] = False
            gone = dels[inf_prev[dels]]
            if gone.size:
                # leaving the active window while still in the forest: the
                # cold builder's parallel lower-ct version expires it here
                live_from_rec[gone] = ts + 1
                inf_prev[gone] = False
                l_prev[gone] = r_prev[gone] = p_prev[gone] = NONE

        if not old_changed and not rec_changed:
            continue    # both layers static: snapshot provably unchanged

        ids = np.flatnonzero(act)            # rank-ascending by construction

        # 3. contraction: endpoint vertex -> old component root (or tag)
        infn = np.zeros(r_new, bool)
        ln = np.full(r_new, NONE, np.int64)
        rn = np.full(r_new, NONE, np.int64)
        pn = np.full(r_new, NONE, np.int64)
        adopt_now: dict = {}
        if ids.size:
            verts = np.unique(np.concatenate([ne_u[ids], ne_v[ids]]))
            if n_old and not roots_fresh:
                live_ids = np.flatnonzero(alive)
                p_live = par[live_ids]
                roots[live_ids] = np.where(p_live >= 0, p_live, live_ids)
                while True:
                    nxt = roots[roots[live_ids]]
                    if np.array_equal(nxt, roots[live_ids]):
                        break
                    roots[live_ids] = nxt
                roots_fresh = True
            ent = old_vent[verts]
            if n_old:
                cid = np.where(ent >= 0, roots[np.clip(ent, 0, None)],
                               VTAG + verts)
            else:
                cid = VTAG + verts
            scratch_cid[verts] = cid
            cu = scratch_cid[ne_u[ids]]
            cv = scratch_cid[ne_v[ids]]

            # 4. per-pair dedup (Kruskal rejects the higher-ranked parallel
            # record anyway; dropping it keeps the Python loop short)
            key = (np.minimum(cu, cv) * np.int64(VTAG + n + 1)
                   + np.maximum(cu, cv))
            _, first = np.unique(key, return_index=True)
            first.sort()
            kr = ids[first]
            kcu, kcv = cu[first], cv[first]

            uf = _UnionFind()
            parent = uf.parent
            cmax = uf.cmax
            for j, a0, b0 in zip(kr.tolist(), kcu.tolist(), kcv.tolist()):
                ra, rb = uf.find(a0), uf.find(b0)
                if ra == rb:
                    continue
                # component max: the old root itself for untouched old
                # comps, NONE for bare vertices, else the tracked overlay ref
                la = cmax.get(ra, ra if ra < n_old else NONE)
                lb = cmax.get(rb, rb if rb < n_old else NONE)
                infn[j] = True
                ln[j], rn[j] = la, lb
                for child in (la, lb):
                    if child == NONE:
                        continue
                    if child >= OV:
                        pn[child - OV] = OV + j
                    else:
                        adopt_now[child] = j
                parent[ra] = rb
                cmax[rb] = OV + j

        # 5. diff vs the previous ts snapshot -> emissions (vectorized)
        entered = infn & ~inf_prev
        if entered.any():
            ej = np.flatnonzero(entered)
            if ever_in[ej].any():
                raise ForestInvariantError(
                    "overlay version re-entered the forest: non-interval "
                    f"lifetime at ts={ts}")
            ever_in[ej] = True
            live_to_rec[ej] = ts
        left = inf_prev & ~infn
        if left.any():
            live_from_rec[np.flatnonzero(left)] = ts + 1
        changed = infn & (entered | (ln != l_prev) | (rn != r_prev)
                          | (pn != p_prev))
        cj = np.flatnonzero(changed)
        if cj.size:
            em_node.append(OV + cj)
            em_ts.append(np.full(cj.size, ts, np.int64))
            em_l.append(ln[cj].copy())
            em_r.append(rn[cj].copy())
            em_p.append(pn[cj].copy())
        inf_prev, l_prev, r_prev, p_prev = infn, ln, rn, pn

        # 6. adoption diff (old roots whose merged parent is an overlay ref)
        if adopt_now != adopt_prev:
            for o, j in adopt_now.items():
                if adopt_prev.get(o) != j:
                    adopt_events.setdefault(o, []).append((ts, j))
            for o in adopt_prev:
                if o not in adopt_now:
                    adopt_events.setdefault(o, []).append((ts, NONE))
            adopt_prev = adopt_now

        # 7. vertex entry-point overrides: lowest-rank in-forest overlay
        # node per endpoint vertex (relevant only where the old layer has
        # no entry; the merge is resolved at assembly time)
        fj = np.flatnonzero(infn)
        if fj.size:
            v_all = np.concatenate([ne_u[fj], ne_v[fj]])
            j_all = np.concatenate([fj, fj])
            vord = np.lexsort((j_all, v_all))
            v_s, j_s = v_all[vord], j_all[vord]
            vfirst = np.ones(v_s.size, bool)
            vfirst[1:] = v_s[1:] != v_s[:-1]
            cur_verts = v_s[vfirst]
            cur_vals = OV + j_s[vfirst]
        else:
            cur_verts = np.zeros(0, np.int64)
            cur_vals = np.zeros(0, np.int64)
        union_verts = np.union1d(cur_verts, prev_ov_verts)
        if union_verts.size:
            new_vals = np.full(union_verts.size, NONE, np.int64)
            if cur_verts.size:
                pos = np.searchsorted(union_verts, cur_verts)
                new_vals[pos] = cur_vals
            delta = new_vals != ovr_arr[union_verts]
            if delta.any():
                for vtx, val in zip(union_verts[delta].tolist(),
                                    new_vals[delta].tolist()):
                    vent_events.setdefault(vtx, []).append((ts, val))
                ovr_arr[union_verts] = new_vals
            prev_ov_verts = cur_verts

    # ------------------------------------------------------------------
    # assembly
    # ------------------------------------------------------------------
    js = np.flatnonzero(ever_in)
    n_ov = js.shape[0]
    total = n_old + n_ov

    # cold insertion order: (live_to descending, rank ascending)
    old_rank = (prev.node_ct.astype(np.int64) * stride
                + prev.node_edge.astype(np.int64))
    all_live_to = np.concatenate([old_live_to, live_to_rec[js]])
    all_rank = np.concatenate([old_rank, ne_rank[js]])
    order = np.lexsort((all_rank, -all_live_to))
    newid = np.empty(total, np.int64)
    newid[order] = np.arange(total, dtype=np.int64)
    map_old = newid[:n_old]
    map_rec = np.full(r_new, NONE, np.int64)
    map_rec[js] = newid[n_old:]

    def remap_refs(refs: np.ndarray) -> np.ndarray:
        """Sweep-encoded refs -> final node ids (NONE passthrough)."""
        refs = np.asarray(refs, np.int64)
        out = np.full(refs.shape, NONE, np.int64)
        m_o = (0 <= refs) & (refs < OV)
        out[m_o] = map_old[refs[m_o]]
        m_v = refs >= OV
        out[m_v] = map_rec[refs[m_v] - OV]
        if (out[m_v] == NONE).any():
            raise ForestInvariantError("entry references a rejected version")
        return out

    # node table
    node_u = np.empty(total, np.int64)
    node_v = np.empty(total, np.int64)
    node_ct = np.empty(total, np.int64)
    node_edge = np.empty(total, np.int64)
    node_lf = np.empty(total, np.int64)
    node_lt = np.empty(total, np.int64)
    node_u[map_old] = prev.node_u
    node_v[map_old] = prev.node_v
    node_ct[map_old] = prev.node_ct
    node_edge[map_old] = prev.node_edge
    node_lf[map_old] = old_live_from
    node_lt[map_old] = old_live_to
    mj = map_rec[js]
    node_u[mj] = ne_u[js]
    node_v[mj] = ne_v[js]
    node_ct[mj] = ne_ct[js]
    node_edge[mj] = ne_edge[js]
    node_lf[mj] = live_from_rec[js]
    node_lt[mj] = live_to_rec[js]

    # entries: verbatim old (never-adopted) + rebuilt adopted + overlay
    adopted = np.fromiter(adopt_events.keys(), np.int64,
                          count=len(adopt_events))
    keep = (~np.isin(oe_node, adopted)) if adopted.size else np.ones(
        oe_node.shape[0], bool)
    fe_node = [map_old[oe_node[keep]]]
    fe_ts = [oe_ts[keep]]
    fe_l = [remap_refs(oe_l[keep])]
    fe_r = [remap_refs(oe_r[keep])]
    fe_p = [remap_refs(oe_p[keep])]

    for o, events in adopt_events.items():
        # merge the node's old entry stream with its adoption override
        # intervals; re-delta-compress exactly as the cold builder would
        lo_, hi_ = int(prev.row_ptr[o]), int(prev.row_ptr[o + 1])
        e_ts = prev.ent_ts[lo_:hi_].astype(np.int64)      # ascending
        ev_ts = np.asarray([t for (t, _) in events], np.int64)   # descending
        ev_ref = np.asarray([r for (_, r) in events], np.int64)
        lt_o, lf_o = int(old_live_to[o]), int(old_live_from[o])
        cands = np.unique(np.concatenate([e_ts, ev_ts]))[::-1]
        cands = cands[(cands >= lf_o) & (cands <= lt_o)]
        pos = np.searchsorted(e_ts, cands, side="left")
        if (pos >= e_ts.shape[0]).any():
            raise ForestInvariantError(
                f"adopted node {o} lacks an old entry covering a change")
        l0 = prev.ent_left[lo_:hi_].astype(np.int64)[pos]
        r0 = prev.ent_right[lo_:hi_].astype(np.int64)[pos]
        p0 = prev.ent_parent[lo_:hi_].astype(np.int64)[pos]
        ov = _step_lookup(ev_ts, ev_ref, cands, NONE)
        p1 = np.where(ov != NONE, OV + ov, p0)
        chg = np.ones(cands.size, bool)
        chg[1:] = ((l0[1:] != l0[:-1]) | (r0[1:] != r0[:-1])
                   | (p1[1:] != p1[:-1]))
        if chg.any():
            ci = np.flatnonzero(chg)
            fe_node.append(np.full(ci.size, map_old[o], np.int64))
            fe_ts.append(cands[ci])
            fe_l.append(remap_refs(l0[ci]))
            fe_r.append(remap_refs(r0[ci]))
            fe_p.append(remap_refs(p1[ci]))

    if em_node:
        fe_node.append(remap_refs(np.concatenate(em_node)))
        fe_ts.append(np.concatenate(em_ts))
        fe_l.append(remap_refs(np.concatenate(em_l)))
        fe_r.append(remap_refs(np.concatenate(em_r)))
        fe_p.append(remap_refs(np.concatenate(em_p)))

    ent_node = np.concatenate(fe_node)
    ent_ts_f = np.concatenate(fe_ts)
    ent_l_f = np.concatenate(fe_l)
    ent_r_f = np.concatenate(fe_r)
    ent_p_f = np.concatenate(fe_p)

    # vertex entries: verbatim for unaffected vertices + rebuilt merges
    affected = np.fromiter(vent_events.keys(), np.int64,
                           count=len(vent_events))
    vkeep = (~np.isin(ov_vert, affected)) if affected.size else np.ones(
        ov_vert.shape[0], bool)
    fv_vert = [ov_vert[vkeep]]
    fv_ts = [ov_ts[vkeep]]
    fv_node = [remap_refs(ov_node[vkeep])]

    for vtx, events in vent_events.items():
        lo_, hi_ = int(prev.vrow_ptr[vtx]), int(prev.vrow_ptr[vtx + 1])
        o_ts = prev.vent_ts[lo_:hi_].astype(np.int64)     # ascending
        o_nd = prev.vent_node[lo_:hi_].astype(np.int64)
        ev_ts = np.asarray([t for (t, _) in events], np.int64)   # descending
        ev_ref = np.asarray([r for (_, r) in events], np.int64)
        cands = np.unique(np.concatenate([o_ts, ev_ts]))[::-1]
        pos = np.searchsorted(o_ts, cands, side="left")
        base = np.where(pos < o_ts.shape[0],
                        o_nd[np.clip(pos, 0, max(o_ts.shape[0] - 1, 0))]
                        if o_ts.size else NONE, NONE)
        ov = _step_lookup(ev_ts, ev_ref, cands, NONE)
        val = np.where(base != NONE, base, ov)
        chg = np.ones(cands.size, bool)
        chg[1:] = val[1:] != val[:-1]
        ci = np.flatnonzero(chg)
        if ci.size:
            fv_vert.append(np.full(ci.size, vtx, np.int64))
            fv_ts.append(cands[ci])
            fv_node.append(remap_refs(val[ci]))

    vent_vert = np.concatenate(fv_vert)
    vent_ts_f = np.concatenate(fv_ts)
    vent_node_f = np.concatenate(fv_node)

    # pack: identical CSR layout to pack_index
    row_ptr, ent_ts_c, (ent_l_c, ent_r_c, ent_p_c) = _csr_sorted(
        ent_node, ent_ts_f, (ent_l_f, ent_r_f, ent_p_f), total)
    vrow_ptr, vent_ts_c, (vent_node_c,) = _csr_sorted(
        vent_vert, vent_ts_f, (vent_node_f,), n)
    i32 = lambda a: np.ascontiguousarray(a, np.int32)
    return PECBIndex(
        g.n, g.m, t_new, k,
        i32(node_u), i32(node_v), i32(node_ct), i32(node_edge),
        i32(node_lf), i32(node_lt),
        row_ptr, ent_ts_c, ent_l_c, ent_r_c, ent_p_c,
        vrow_ptr, vent_ts_c, vent_node_c,
        versions=VersionStore.from_table(g, k, tab),
    )


# ----------------------------------------------------------------------
# the shrink path (retention plane)
# ----------------------------------------------------------------------

def shrink_pecb_index(g: TemporalGraph, k: int, tab: CoreTimeTable,
                      prev: PECBIndex) -> PECBIndex:
    """Shrink ``prev`` (the pre-expiry epoch's packed index) into the index
    for the prefix-expired, shifted graph ``g`` with shrunk core-time table
    ``tab`` (``core_time.shrink_core_times``).

    Bit-identical to ``build_pecb_index(g, k, tab)`` — every packed array,
    including node-id assignment (test-asserted) — at pure-slicing cost.
    Where the grow path must *replay* the old layer and overlay new
    Kruskal work, the shrink path needs neither: by the cut invariant
    (no surviving window contains an expired edge) the ECB forest at every
    surviving start time ``ts >= t_cut`` is **literally the old forest**
    at that ts, so the new index is the old one restricted to the
    surviving time range and relabeled:

    * **Nodes** survive iff their forest lifetime reaches the cut
      (``live_to >= t_cut``); ``live_from`` clips to the cut. Node ids
      compact in order: the cold insertion order is ``(live_to desc,
      rank asc)`` (the PR-4 invariant) and both keys shift uniformly
      (``live_to - shift``; rank ``(ct - shift, edge - cut)``), so stable
      compaction of the surviving old ids *is* the cold id assignment.
    * **Entries** survive iff recorded at ``ts >= t_cut``. Recording
      points above the cut are unchanged (same state changes at the same
      sweep steps), and the entry covering the new ``ts = 1`` is exactly
      the old entry covering ``t_cut`` (the step function holds
      downward), so a ts-filter reproduces the cold build's delta
      compression verbatim. Every reference inside a kept entry points at
      a node in the forest at the recording ts ``>= t_cut`` — a survivor
      — so remapping is total (a miss raises ``ForestInvariantError``).
    * **Per-vertex entry points** filter and remap the same way.

    Raises ``ValueError`` when ``(g, tab, prev)`` is not a consistent
    prefix-expiry triple, so a wrong index is never produced silently.
    """
    from .pecb_index import build_pecb_index   # cold fallback (cycle-safe)

    shift = prev.t_max - g.t_max
    cut_m = prev.m - g.m
    t_cut = shift + 1
    if prev.k != k:
        raise ValueError(f"index k={prev.k} does not match k={k}")
    if prev.n != g.n:
        raise ValueError(f"vertex count changed ({prev.n} -> {g.n}); "
                         "shrink needs the same vertex set")
    if shift < 0 or cut_m < 0:
        raise ValueError("prev index does not describe a supergraph of g "
                         "(shrink goes forward in time; use "
                         "extend_pecb_index to grow)")
    if tab.t_max != g.t_max or tab.m != g.m or tab.n != g.n:
        raise ValueError("tab is not the core-time table of g; pass "
                         "tab=shrink_core_times(g, k, prev_tab)")
    if shift == 0 and cut_m == 0:
        return prev                       # no cut: same epoch
    if prev.versions is None or g.m == 0 or g.t_max == 0:
        return build_pecb_index(g, k, tab)   # nothing trustworthy to slice

    # -- integrity: prev's surviving records, clipped+shifted, must be tab
    vs = prev.versions
    vkeep = vs.ts_to.astype(np.int64) >= t_cut
    if not (int(vkeep.sum()) == tab.num_versions
            and np.array_equal(vs.edge_id[vkeep].astype(np.int64) - cut_m,
                               tab.edge_id)
            and np.array_equal(
                np.maximum(vs.ts_from[vkeep].astype(np.int64), t_cut) - shift,
                tab.ts_from)
            and np.array_equal(vs.ts_to[vkeep].astype(np.int64) - shift,
                               tab.ts_to)
            and np.array_equal(vs.ct[vkeep].astype(np.int64) - shift,
                               tab.ct)):
        raise ValueError(
            "surviving version records of prev do not clip to tab; this is "
            "not a prefix expiry of the index's graph (cold rebuild "
            "required)")

    # -- node survival + id compaction (order-preserving) -----------------
    old_lt = prev.node_live_to.astype(np.int64)
    nkeep = old_lt >= t_cut
    newid = np.cumsum(nkeep, dtype=np.int64) - 1      # valid where nkeep
    total = int(nkeep.sum())

    def remap_refs(refs: np.ndarray) -> np.ndarray:
        """Old node refs -> compacted ids (NONE passthrough); referencing a
        dead node means the index was not a consistent epoch snapshot."""
        refs = np.asarray(refs, np.int64)
        live = refs >= 0
        if live.any() and not nkeep[refs[live]].all():
            raise ForestInvariantError(
                "a surviving entry references an expired forest node")
        out = np.full(refs.shape, NONE, np.int64)
        out[live] = newid[refs[live]]
        return out

    node_edge = prev.node_edge[nkeep].astype(np.int64) - cut_m
    if node_edge.size and node_edge.min() < 0:
        raise ValueError(
            "a surviving forest node references an expired edge; prev is "
            "not the index of g's pre-expiry epoch")

    # -- entries: ts-filter on surviving nodes, shift, remap --------------
    oe_node, oe_ts, oe_l, oe_r, oe_p = _flatten_entries(prev)
    ekeep = nkeep[oe_node] & (oe_ts >= t_cut)
    ov_vert, ov_ts, ov_node = _flatten_vent(prev)
    vent_keep = ov_ts >= t_cut

    row_ptr, ent_ts_c, (ent_l_c, ent_r_c, ent_p_c) = _csr_sorted(
        newid[oe_node[ekeep]], oe_ts[ekeep] - shift,
        (remap_refs(oe_l[ekeep]), remap_refs(oe_r[ekeep]),
         remap_refs(oe_p[ekeep])), total)
    vrow_ptr, vent_ts_c, (vent_node_c,) = _csr_sorted(
        ov_vert[vent_keep], ov_ts[vent_keep] - shift,
        (remap_refs(ov_node[vent_keep]),), g.n)

    i32 = lambda a: np.ascontiguousarray(a, np.int32)
    return PECBIndex(
        g.n, g.m, g.t_max, k,
        i32(prev.node_u[nkeep]), i32(prev.node_v[nkeep]),
        i32(prev.node_ct[nkeep].astype(np.int64) - shift), i32(node_edge),
        i32(np.maximum(prev.node_live_from[nkeep].astype(np.int64), t_cut)
            - shift),
        i32(old_lt[nkeep] - shift),
        row_ptr, ent_ts_c, ent_l_c, ent_r_c, ent_p_c,
        vrow_ptr, vent_ts_c, vent_node_c,
        versions=VersionStore.from_table(g, k, tab),
    )


# ----------------------------------------------------------------------
# stratified epoch lifecycle: one call covers every k (DESIGN.md §14)
# ----------------------------------------------------------------------

def extend_stratified_index(g: TemporalGraph, prev: StratifiedPECB,
                            ks=None, *, strata=None) -> StratifiedPECB:
    """Grow a whole k-stratified index across one suffix-append epoch.

    Each existing stratum grows through :func:`extend_pecb_index`
    (bit-identical incremental); strata the new epoch adds (``ks``
    defaults to ``default_ks(g)``, and appended edges can raise the
    graph's degeneracy) are built cold through the fastest forest
    engine. One call replaces |K| per-k lifecycle operations. Pass
    ``strata`` to reuse an already-extended table (the registry times
    the core and forest stages separately).
    """
    from .kcore import k_max as _graph_k_max
    from .pecb_index import build_stratified_index

    if prev.strata is None:
        return build_stratified_index(g, ks, strata=strata)
    if ks is None:
        ks = default_ks(g)
    stab = (strata if strata is not None
            else extend_stratified_core_times(g, prev.strata, ks))
    indices = []
    for k in stab.ks:
        tab = stab.table_for(int(k))
        if k in prev.supported_ks:
            indices.append(extend_pecb_index(g, int(k), tab,
                                             prev.slice_k(k)))
        else:
            indices.append(pack_index(g, int(k), _forest_builder(g, tab)))
    return _assemble_stratified(g, stab, indices, _graph_k_max(g))


def shrink_stratified_index(g: TemporalGraph, prev: StratifiedPECB,
                            ks=None, *, strata=None) -> StratifiedPECB:
    """Shrink a whole k-stratified index across one prefix-expiry epoch
    (pure slicing per stratum, :func:`shrink_pecb_index`). ``ks``
    defaults to ``default_ks(g)`` — expiry can lower the degeneracy, in
    which case the dropped strata simply disappear (queries above the
    new ``k_max_graph`` stay exactly empty)."""
    from .kcore import k_max as _graph_k_max
    from .pecb_index import build_stratified_index

    if prev.strata is None:
        return build_stratified_index(g, ks, strata=strata)
    if ks is None:
        ks = default_ks(g)
    stab = (strata if strata is not None
            else shrink_stratified_core_times(g, prev.strata, ks))
    indices = [shrink_pecb_index(g, int(k), stab.table_for(int(k)),
                                 prev.slice_k(k))
               for k in stab.ks]
    return _assemble_stratified(g, stab, indices, _graph_k_max(g))
